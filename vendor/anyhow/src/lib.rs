//! Offline stand-in for the `anyhow` crate.
//!
//! This workspace must build with no network and no crates.io registry
//! (DESIGN.md §6.3), so the subset of the `anyhow` API the codebase uses
//! is reimplemented here as a path dependency: [`Error`], [`Result`], and
//! the [`anyhow!`], [`bail!`], [`ensure!`] macros. The real crate can be
//! swapped back in by pointing the `anyhow` dependency at the registry —
//! no source changes required.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` conversion (the `?` operator) coherent.

use std::fmt;

/// A type-erased error: a message plus an optional source chain, already
/// rendered to strings.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on real anyhow prints the whole cause chain; our messages
        // are pre-rendered, so plain and alternate forms coincide.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible result.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(s)
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
        assert_eq!(format!("{e:#}"), "bad thing at 7");
        assert_eq!(format!("{e:?}"), "bad thing at 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(check(5).unwrap(), 5);
        assert!(check(-1).unwrap_err().to_string().contains("positive"));
        assert!(check(200).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn check() -> Result<()> {
            let flag = false;
            ensure!(flag);
            Ok(())
        }
        assert!(check().unwrap_err().to_string().contains("flag"));
    }
}
