//! API stub of the `xla` crate (PJRT C-API bindings).
//!
//! The PJRT execution backend (`ringmaster::runtime::pjrt`, behind the
//! `pjrt` cargo feature) is written against the published `xla` crate,
//! whose native libraries are not present in the offline build image.
//! This stub declares the exact API surface that backend uses so the
//! feature keeps compiling; every runtime entry point returns a clear
//! error instead of executing. To run real PJRT, point the `xla`
//! dependency in `rust/Cargo.toml` at the registry crate — the signatures
//! here match it, so no source changes are needed (DESIGN.md §6.3).

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (Display-able, carried by results).
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build links the offline `xla` API stub — native PJRT \
         is unavailable; depend on the real `xla` crate (and its libs) to \
         execute AOT artifacts, or use the default reference backend"
    )))
}

/// Element types `Literal` buffers can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// Host-side tensor handle.
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub_err("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub_err("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err("Literal::to_tuple")
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        stub_err("HloModuleProto::from_text_file")
    }
}

/// A computation ready to hand to a client for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (`Rc`-backed in the real crate, hence `!Send`).
pub struct PjRtClient {
    // mirror the real crate's !Send so threading bugs surface in CI even
    // against the stub
    _not_send: std::rc::Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }
}
