# ringmaster build entry points.
#
# `make artifacts` needs python3 + jax (build-time only; see DESIGN.md §1).
# Everything else is pure cargo and runs on a bare toolchain.

.PHONY: all artifacts test bench bench-scale bench-ckpt lint clean

all:
	cargo build --release

# Lower the L2/L1 model to artifacts/*.hlo.txt + manifest.json.
# The manifest is checked in (and embedded in the binary); this re-emits
# it alongside the HLO files the PJRT backend executes.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts --presets tiny,small,base

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hotpath

# 100 -> 100k job scale sweep; writes BENCH_SCALE.json at the repo root
# (the perf trajectory later PRs race — see EXPERIMENTS.md A5).
# THREADS caps the sweep-runner workers (default: all cores); PRUNE=0
# re-times the unpruned completion scan. Results are bit-identical
# either way — the knobs only move wall time.
#   make bench-scale THREADS=4 PRUNE=0
THREADS ?=
PRUNE ?=
bench-scale:
	RINGMASTER_THREADS=$(THREADS) RINGMASTER_PRUNE=$(PRUNE) cargo bench --bench scale_sweep

# 1024 jobs' snapshots through the content-addressed checkpoint store vs
# whole-file Checkpoint::save: bytes written + restart latency per phase
# (cold / resave / delta / load / drain); writes BENCH_CKPT.json.
bench-ckpt:
	cargo bench --bench bench_ckpt

lint:
	cargo fmt --all --check
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -f artifacts/*.hlo.txt
