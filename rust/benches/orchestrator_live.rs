//! Sim-vs-real: the same trace, the same strategy, DES-predicted vs
//! live-measured average JCT.
//!
//! The DES (`sim::des`) reallocates *instantly* at every event; the live
//! orchestrator can only stop a job at a segment boundary and pays real
//! checkpoint I/O + engine startup on every restart. This bench runs one
//! bursty trace both ways for doubling and fixed-8 and reports the gap —
//! the boundary-granularity cost of going from simulation to execution —
//! plus the real wall time, measured restart overhead, and checkpoint
//! bytes of the live runs. A third row reruns doubling through the
//! content-addressed store (`--ckpt-store`): the schedule must not move,
//! while restart checkpoint bytes collapse to manifest size.
//!
//! `cargo bench --bench orchestrator_live`

use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::orchestrator::{
    orchestrate, scheduler_by_name, OrchestratorConfig, TraceGen,
};
use ringmaster::sim::{simulate, SimConfig, StrategyKind};
use ringmaster::trainer::TrainConfig;

fn main() -> ringmaster::Result<()> {
    let capacity = 8;
    let restart_cost = 10.0;
    let seed = 42;

    // bursty arrivals (5s mean), miniature epochs so live training is
    // seconds; the *virtual* profiles stay paper-scale
    let gen = TraceGen { n_jobs: 8, mean_interarrival: 5.0, total_epochs: 1.0, max_w: 8 };
    let specs = ringmaster::orchestrator::generate_trace(&gen, seed);
    let profiles: Vec<_> = specs.iter().map(|s| s.profile.clone()).collect();

    let des_cfg = |strategy: StrategyKind| SimConfig {
        capacity,
        mean_interarrival: gen.mean_interarrival,
        n_jobs: gen.n_jobs,
        strategy,
        restart_cost,
        explore_secs_per_size: 150.0,
        explore_sizes: vec![1, 2, 4, 8],
        seed,
        topology: ringmaster::cluster::Topology::flat(capacity),
        placement: ringmaster::perfmodel::PlacementModel::paper(),
        place_policy: ringmaster::cluster::PlacePolicy::Pack,
        link_contention: ringmaster::perfmodel::LinkContention::OFF,
        completion_prune: true,
    };

    let mut train = TrainConfig::new(
        std::env::var("RINGMASTER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "tiny",
        1,
    );
    train.dataset_examples = 256;
    train.log_every = u64::MAX;
    train.seed = seed;
    let mut ocfg = OrchestratorConfig::new(train, capacity);
    ocfg.restart_cost = restart_cost;
    ocfg.segment_steps = 16;

    let store_root =
        std::env::temp_dir().join(format!("rm-live-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_root);
    let mut store_cfg = ocfg.clone();
    store_cfg.ckpt_store = Some(store_root.clone());

    let mut table = CsvTable::new(&[
        "strategy", "des_avg_jct_s", "live_avg_jct_s", "live/des", "live_util_%", "restarts",
        "measured_restart_s", "ckpt_io_s", "restart_ckpt_kb", "live_wall_s",
    ]);
    let mut bench = BenchJson::new("orchestrator_live");
    bench
        .meta("capacity", Json::num(capacity as f64))
        .meta("n_jobs", Json::num(gen.n_jobs as f64))
        .meta("seed", Json::num(seed as f64));
    let mut doubling_file = None; // (avg_jct bits, restart bytes) of whole-file doubling
    for (name, kind, cfg) in [
        ("doubling", StrategyKind::Precompute, &ocfg),
        ("fixed-8", StrategyKind::Fixed(8), &ocfg),
        ("doubling+store", StrategyKind::Precompute, &store_cfg),
    ] {
        let des = simulate(&des_cfg(kind), &profiles);
        let des_avg = des.avg_completion_hours * 3600.0;

        let sched = scheduler_by_name(name.trim_end_matches("+store"))?;
        let live = orchestrate(cfg, sched.as_ref(), &specs)?;
        let measured_restart: f64 = live.jobs.iter().map(|j| j.measured_restart_secs).sum();
        table.row(&[
            name.to_string(),
            format!("{des_avg:.1}"),
            format!("{:.1}", live.avg_jct_secs()),
            format!("{:.2}", live.avg_jct_secs() / des_avg),
            format!("{:.1}", 100.0 * live.utilization),
            live.total_restarts.to_string(),
            format!("{measured_restart:.2}"),
            format!("{:.2}", live.ckpt_io_secs()),
            format!("{:.1}", live.restart_ckpt_bytes() as f64 / 1024.0),
            format!("{:.2}", live.wall_secs),
        ]);
        bench.row(vec![
            ("strategy", Json::str(name)),
            ("des_avg_jct_s", Json::num(des_avg)),
            ("live_avg_jct_s", Json::num(live.avg_jct_secs())),
            ("live_over_des", Json::num(live.avg_jct_secs() / des_avg)),
            ("live_utilization", Json::num(live.utilization)),
            ("restarts", Json::num(live.total_restarts as f64)),
            ("measured_restart_s", Json::num(measured_restart)),
            ("ckpt_io_s", Json::num(live.ckpt_io_secs())),
            ("ckpt_bytes_written", Json::num(live.ckpt_bytes_written() as f64)),
            ("restart_ckpt_bytes", Json::num(live.restart_ckpt_bytes() as f64)),
            ("live_wall_s", Json::num(live.wall_secs)),
        ]);

        // the live run can lag the idealized DES (boundary granularity)
        // but must reproduce its *shape*: both measure the same physics
        assert!(
            live.avg_jct_secs() > 0.0 && des_avg > 0.0,
            "degenerate run for {name}"
        );
        match name {
            "doubling" => {
                doubling_file =
                    Some((live.avg_jct_secs().to_bits(), live.restart_ckpt_bytes()));
            }
            "doubling+store" => {
                let (jct_bits, file_restart_bytes) =
                    doubling_file.expect("doubling ran first");
                // the store lives on the measured side of the two-clock
                // split: the virtual schedule may not move a bit...
                assert_eq!(
                    live.avg_jct_secs().to_bits(),
                    jct_bits,
                    "--ckpt-store moved the virtual schedule"
                );
                // ...while restart traffic shrinks from full payload
                // images to manifest commits
                assert!(
                    live.restart_ckpt_bytes() < file_restart_bytes,
                    "store restarts wrote {} bytes vs whole-file {}",
                    live.restart_ckpt_bytes(),
                    file_restart_bytes
                );
                assert!(!store_root.exists(), "store not drained after the run");
            }
            _ => {}
        }
    }
    print!("{}", table.render());
    table.write_csv("orchestrator_live.csv")?;
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "LIVE")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());
    println!(
        "\nlive/des > 1 is the boundary-granularity + real-restart cost the DES idealizes away;\n\
         the strategy ordering (doubling < fixed-8 on a burst) must agree between the two,\n\
         and doubling+store must match doubling's schedule while shrinking restart_ckpt_kb."
    );
    Ok(())
}
