//! Sim-vs-real: the same trace, the same strategy, DES-predicted vs
//! live-measured average JCT.
//!
//! The DES (`sim::des`) reallocates *instantly* at every event; the live
//! orchestrator can only stop a job at a segment boundary and pays real
//! checkpoint I/O + engine startup on every restart. This bench runs one
//! bursty trace both ways for doubling and fixed-8 and reports the gap —
//! the boundary-granularity cost of going from simulation to execution —
//! plus the real wall time and measured restart overhead of the live
//! runs.
//!
//! `cargo bench --bench orchestrator_live`

use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::orchestrator::{
    orchestrate, scheduler_by_name, OrchestratorConfig, TraceGen,
};
use ringmaster::sim::{simulate, SimConfig, StrategyKind};
use ringmaster::trainer::TrainConfig;

fn main() -> ringmaster::Result<()> {
    let capacity = 8;
    let restart_cost = 10.0;
    let seed = 42;

    // bursty arrivals (5s mean), miniature epochs so live training is
    // seconds; the *virtual* profiles stay paper-scale
    let gen = TraceGen { n_jobs: 8, mean_interarrival: 5.0, total_epochs: 1.0, max_w: 8 };
    let specs = ringmaster::orchestrator::generate_trace(&gen, seed);
    let profiles: Vec<_> = specs.iter().map(|s| s.profile.clone()).collect();

    let des_cfg = |strategy: StrategyKind| SimConfig {
        capacity,
        mean_interarrival: gen.mean_interarrival,
        n_jobs: gen.n_jobs,
        strategy,
        restart_cost,
        explore_secs_per_size: 150.0,
        explore_sizes: vec![1, 2, 4, 8],
        seed,
        topology: ringmaster::cluster::Topology::flat(capacity),
        placement: ringmaster::perfmodel::PlacementModel::paper(),
        place_policy: ringmaster::cluster::PlacePolicy::Pack,
        link_contention: ringmaster::perfmodel::LinkContention::OFF,
        completion_prune: true,
    };

    let mut train = TrainConfig::new(
        std::env::var("RINGMASTER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "tiny",
        1,
    );
    train.dataset_examples = 256;
    train.log_every = u64::MAX;
    train.seed = seed;
    let mut ocfg = OrchestratorConfig::new(train, capacity);
    ocfg.restart_cost = restart_cost;
    ocfg.segment_steps = 16;

    let mut table = CsvTable::new(&[
        "strategy", "des_avg_jct_s", "live_avg_jct_s", "live/des", "live_util_%", "restarts",
        "measured_restart_s", "live_wall_s",
    ]);
    let mut bench = BenchJson::new("orchestrator_live");
    bench
        .meta("capacity", Json::num(capacity as f64))
        .meta("n_jobs", Json::num(gen.n_jobs as f64))
        .meta("seed", Json::num(seed as f64));
    for (name, kind) in [("doubling", StrategyKind::Precompute), ("fixed-8", StrategyKind::Fixed(8))]
    {
        let des = simulate(&des_cfg(kind), &profiles);
        let des_avg = des.avg_completion_hours * 3600.0;

        let sched = scheduler_by_name(name)?;
        let live = orchestrate(&ocfg, sched.as_ref(), &specs)?;
        let measured_restart: f64 = live.jobs.iter().map(|j| j.measured_restart_secs).sum();
        table.row(&[
            name.to_string(),
            format!("{des_avg:.1}"),
            format!("{:.1}", live.avg_jct_secs()),
            format!("{:.2}", live.avg_jct_secs() / des_avg),
            format!("{:.1}", 100.0 * live.utilization),
            live.total_restarts.to_string(),
            format!("{measured_restart:.2}"),
            format!("{:.2}", live.wall_secs),
        ]);
        bench.row(vec![
            ("strategy", Json::str(name)),
            ("des_avg_jct_s", Json::num(des_avg)),
            ("live_avg_jct_s", Json::num(live.avg_jct_secs())),
            ("live_over_des", Json::num(live.avg_jct_secs() / des_avg)),
            ("live_utilization", Json::num(live.utilization)),
            ("restarts", Json::num(live.total_restarts as f64)),
            ("measured_restart_s", Json::num(measured_restart)),
            ("live_wall_s", Json::num(live.wall_secs)),
        ]);

        // the live run can lag the idealized DES (boundary granularity)
        // but must reproduce its *shape*: both measure the same physics
        assert!(
            live.avg_jct_secs() > 0.0 && des_avg > 0.0,
            "degenerate run for {name}"
        );
    }
    print!("{}", table.render());
    table.write_csv("orchestrator_live.csv")?;
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "LIVE")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());
    println!(
        "\nlive/des > 1 is the boundary-granularity + real-restart cost the DES idealizes away;\n\
         the strategy ordering (doubling < fixed-8 on a burst) must agree between the two."
    );
    Ok(())
}
