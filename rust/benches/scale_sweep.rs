//! A5: scheduling-core scale sweep — the repo's first recorded perf
//! trajectory.
//!
//! Replays heavy-tailed traces of J ∈ {100, 1k, 10k, 100k} jobs under
//! {doubling, optimus, fixed-8} on a flat 128-GPU pool and a 16×8 grid,
//! measuring wall seconds, events/sec, and µs/event. The workload
//! targets ~65% offered load at every size ([`WorkloadGen::trace_scale`]),
//! so the *active* set is bounded while total work grows linearly —
//! exactly the regime where the event-heap engine must hold per-event
//! cost flat. The pre-PR-5 scan engine was O(events × jobs) here: every
//! event walked all J jobs four times, so 100k jobs cost ~1000× more
//! *per event* than 100 jobs.
//!
//! Emits `BENCH_SCALE.json` at the repo root (cargo runs bench binaries
//! with the *package* root as cwd, so the path is anchored on
//! `CARGO_MANIFEST_DIR/..`) so later PRs have a trajectory to beat, and
//! asserts the loose sublinearity bound from the issue: 10× jobs must
//! cost < 100× wall time.
//!
//! `cargo bench --bench scale_sweep`

use ringmaster::cluster::Topology;
use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::sim::{simulate, Contention, SimConfig, StrategyKind, WorkloadGen};

const CAPACITY: usize = 128;
const SEED: u64 = 42;

struct Row {
    jobs: usize,
    strategy: String,
    topology: String,
    wall_secs: f64,
    events: u64,
}

fn main() -> ringmaster::Result<()> {
    let sizes = [100usize, 1_000, 10_000, 100_000];
    let strategies =
        [StrategyKind::Precompute, StrategyKind::Optimus, StrategyKind::Fixed(8)];

    let mut rows: Vec<Row> = Vec::new();
    let mut table =
        CsvTable::new(&["jobs", "strategy", "topology", "wall_s", "events", "events/s", "us/event"]);

    for grid in [false, true] {
        for &strategy in &strategies {
            for &n in &sizes {
                // same seed at every (strategy, topology): each size is
                // one fixed trace raced by every configuration
                let jobs = WorkloadGen::trace_scale(n, CAPACITY, SEED);
                // contention preset is irrelevant: trace_scale sets the
                // arrival process, and capacity/topology are overridden
                let mut cfg = SimConfig::paper(strategy, Contention::Moderate, SEED);
                cfg.n_jobs = n;
                if grid {
                    cfg = cfg.with_topology(16, 8);
                } else {
                    cfg.capacity = CAPACITY;
                    cfg.topology = Topology::flat(CAPACITY);
                }
                let t = std::time::Instant::now();
                let r = simulate(&cfg, &jobs);
                let wall = t.elapsed().as_secs_f64();

                assert_eq!(
                    r.completed, n,
                    "{} on {} left jobs unfinished at J={n}",
                    r.strategy,
                    if grid { "16x8" } else { "flat" }
                );
                let topology = if grid { "16x8".to_string() } else { format!("flat({CAPACITY})") };
                table.row(&[
                    n.to_string(),
                    r.strategy.clone(),
                    topology.clone(),
                    format!("{wall:.3}"),
                    r.events.to_string(),
                    format!("{:.0}", r.events as f64 / wall.max(1e-9)),
                    format!("{:.2}", wall * 1e6 / r.events.max(1) as f64),
                ]);
                rows.push(Row { jobs: n, strategy: r.strategy, topology, wall_secs: wall, events: r.events });
            }
        }
    }
    print!("{}", table.render());

    // ---- sublinearity: 10x jobs < 100x wall -----------------------------
    // (tiny sizes are timer noise, so floor the denominator at 1 ms; the
    // scan engine fails this at the 10k->100k step by construction)
    for w in rows.chunks(sizes.len()) {
        for pair in w.windows(2) {
            let (small, big) = (&pair[0], &pair[1]);
            let ratio = big.wall_secs / small.wall_secs.max(1e-3);
            assert!(
                ratio < 100.0,
                "{} {}: {}->{} jobs cost {ratio:.1}x wall (superlinear blowup)",
                small.strategy,
                small.topology,
                small.jobs,
                big.jobs
            );
        }
    }

    // ---- BENCH_SCALE.json: the trajectory later PRs race ----------------
    let mut bench = BenchJson::new("scale_sweep");
    bench
        .meta("capacity", Json::num(CAPACITY as f64))
        .meta("seed", Json::num(SEED as f64))
        .meta("offered_load", Json::num(0.65));
    for r in &rows {
        bench.row(vec![
            ("jobs", Json::num(r.jobs as f64)),
            ("strategy", Json::str(r.strategy.as_str())),
            ("topology", Json::str(r.topology.as_str())),
            ("wall_secs", Json::num(r.wall_secs)),
            ("events", Json::num(r.events as f64)),
            ("events_per_sec", Json::num(r.events as f64 / r.wall_secs.max(1e-9))),
            ("us_per_event", Json::num(r.wall_secs * 1e6 / r.events.max(1) as f64)),
        ]);
    }
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "SCALE")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());
    Ok(())
}
