//! A5: scheduling-core scale sweep — the repo's recorded perf
//! trajectory, round 2.
//!
//! Replays heavy-tailed traces of J ∈ {100, 1k, 10k, 100k} jobs under
//! {doubling, optimus, fixed-8} on a flat 128-GPU pool and a 16×8 grid,
//! in three passes:
//!
//! - **Pass A (per-cell)**: each cell timed serially — wall seconds,
//!   events/sec, µs/event, plus the completion-scan pruner's skip rate
//!   (`scan_skipped / scan_candidates`; `RINGMASTER_PRUNE=0` re-runs
//!   the sweep down the unpruned path).
//! - **Pass B (threads-vs-wall)**: the same cells fanned across the
//!   `sim::sweep` runner at 1, 2, and `RINGMASTER_THREADS`-or-all-cores
//!   workers; every result is asserted bit-identical to Pass A (the
//!   sweep determinism contract), and total wall per thread count is
//!   recorded.
//! - **Pass C (per-phase)**: the 100k cells re-run through a
//!   [`PhaseProfiler`] sink — phase timings only, no event stream — so
//!   the fire/reallocate/scan/advance split lands in the trajectory.
//!
//! The workload targets ~65% offered load at every size
//! ([`WorkloadGen::trace_scale`]), so the *active* set is bounded while
//! total work grows linearly — exactly the regime where the event-heap
//! engine must hold per-event cost flat. Emits `BENCH_SCALE.json` at
//! the repo root (anchored on `CARGO_MANIFEST_DIR/..`) and asserts the
//! loose sublinearity bound: 10× jobs must cost < 100× wall time.
//!
//! `cargo bench --bench scale_sweep` (env: `RINGMASTER_THREADS`,
//! `RINGMASTER_PRUNE`)

use std::sync::Arc;

use ringmaster::cluster::Topology;
use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::sim::{
    prune_from_env, simulate_traced, sweep, Contention, SimConfig, SimResult, StrategyKind,
    SweepCell, WorkloadGen,
};
use ringmaster::telemetry::PhaseProfiler;

const CAPACITY: usize = 128;
const SEED: u64 = 42;

struct Row {
    jobs: usize,
    strategy: String,
    topology: String,
    wall_secs: f64,
    events: u64,
    scan_candidates: u64,
    scan_skipped: u64,
}

fn assert_cells_bit_identical(a: &SimResult, b: &SimResult, label: &str) {
    assert_eq!(
        a.avg_completion_hours.to_bits(),
        b.avg_completion_hours.to_bits(),
        "{label}: avg_completion_hours diverged across thread counts"
    );
    assert_eq!(a.total_rescales, b.total_rescales, "{label}: total_rescales");
    assert_eq!(a.events, b.events, "{label}: events");
    for (i, (x, y)) in a.completion_secs.iter().zip(&b.completion_secs).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: job {i} completion");
    }
}

fn main() -> ringmaster::Result<()> {
    let sizes = [100usize, 1_000, 10_000, 100_000];
    let strategies =
        [StrategyKind::Precompute, StrategyKind::Optimus, StrategyKind::Fixed(8)];
    let prune = prune_from_env().unwrap_or(true);

    // One fixed trace per size, Arc-shared by every configuration (and
    // every sweep worker) that races it.
    let traces: Vec<Arc<Vec<ringmaster::sim::JobProfile>>> = sizes
        .iter()
        .map(|&n| Arc::new(WorkloadGen::trace_scale(n, CAPACITY, SEED)))
        .collect();

    let mut cells: Vec<SweepCell> = Vec::new();
    for grid in [false, true] {
        for &strategy in &strategies {
            for (si, &n) in sizes.iter().enumerate() {
                // contention preset is irrelevant: trace_scale sets the
                // arrival process, and capacity/topology are overridden
                let mut cfg = SimConfig::paper(strategy, Contention::Moderate, SEED);
                cfg.n_jobs = n;
                cfg.completion_prune = prune;
                if grid {
                    cfg = cfg.with_topology(16, 8);
                } else {
                    cfg.capacity = CAPACITY;
                    cfg.topology = Topology::flat(CAPACITY);
                }
                cells.push(SweepCell::new(cfg, traces[si].clone()));
            }
        }
    }
    let cell_topology = |cell: &SweepCell| -> String {
        if cell.cfg.topology.is_flat() { format!("flat({CAPACITY})") } else { "16x8".into() }
    };

    // ---- Pass A: per-cell serial timings + pruner skip rates ------------
    let mut rows: Vec<Row> = Vec::new();
    let mut serial: Vec<SimResult> = Vec::new();
    let mut serial_wall = 0.0f64;
    let mut table = CsvTable::new(&[
        "jobs", "strategy", "topology", "wall_s", "events", "events/s", "us/event", "skip_%",
    ]);
    for cell in &cells {
        let t = std::time::Instant::now();
        let r = ringmaster::sim::simulate(&cell.cfg, &cell.jobs);
        let wall = t.elapsed().as_secs_f64();
        serial_wall += wall;
        let topology = cell_topology(cell);
        assert_eq!(
            r.completed,
            cell.cfg.n_jobs,
            "{} on {topology} left jobs unfinished at J={}",
            r.strategy,
            cell.cfg.n_jobs
        );
        let skip_pct = 100.0 * r.scan_skipped as f64 / r.scan_candidates.max(1) as f64;
        table.row(&[
            cell.cfg.n_jobs.to_string(),
            r.strategy.clone(),
            topology.clone(),
            format!("{wall:.3}"),
            r.events.to_string(),
            format!("{:.0}", r.events as f64 / wall.max(1e-9)),
            format!("{:.2}", wall * 1e6 / r.events.max(1) as f64),
            format!("{skip_pct:.1}"),
        ]);
        rows.push(Row {
            jobs: cell.cfg.n_jobs,
            strategy: r.strategy.clone(),
            topology,
            wall_secs: wall,
            events: r.events,
            scan_candidates: r.scan_candidates,
            scan_skipped: r.scan_skipped,
        });
        serial.push(r);
    }
    print!("{}", table.render());

    // ---- sublinearity: 10x jobs < 100x wall -----------------------------
    // (tiny sizes are timer noise, so floor the denominator at 1 ms; the
    // scan engine fails this at the 10k->100k step by construction)
    for w in rows.chunks(sizes.len()) {
        for pair in w.windows(2) {
            let (small, big) = (&pair[0], &pair[1]);
            let ratio = big.wall_secs / small.wall_secs.max(1e-3);
            assert!(
                ratio < 100.0,
                "{} {}: {}->{} jobs cost {ratio:.1}x wall (superlinear blowup)",
                small.strategy,
                small.topology,
                small.jobs,
                big.jobs
            );
        }
    }

    // ---- Pass B: threads vs total wall, bit parity per cell -------------
    let max_threads = sweep::resolve_threads(None).max(2);
    let mut thread_counts = vec![1usize, 2, max_threads];
    thread_counts.dedup();
    let mut thread_rows: Vec<(usize, f64)> = Vec::new();
    let mut threads_table = CsvTable::new(&["threads", "total_wall_s", "speedup"]);
    let mut base_wall = None;
    for &t in &thread_counts {
        let t0 = std::time::Instant::now();
        let results = sweep::run_cells(&cells, t);
        let wall = t0.elapsed().as_secs_f64();
        for (i, (r, s)) in results.iter().zip(&serial).enumerate() {
            assert_cells_bit_identical(r, s, &format!("cell {i} @ {t} threads"));
        }
        let base = *base_wall.get_or_insert(wall);
        threads_table.row(&[
            t.to_string(),
            format!("{wall:.3}"),
            format!("{:.2}x", base / wall.max(1e-9)),
        ]);
        thread_rows.push((t, wall));
    }
    print!("{}", threads_table.render());

    // ---- Pass C: per-phase wall split on the 100k cells -----------------
    // PhaseProfiler collects `phase_secs` without building the event
    // stream, so profiling the biggest cells stays honest.
    let mut phase_rows: Vec<(String, String, &'static str, u64, f64)> = Vec::new();
    let mut phase_table = CsvTable::new(&["strategy", "topology", "phase", "calls", "total_s"]);
    for (idx, cell) in cells.iter().enumerate() {
        if cell.cfg.n_jobs != *sizes.last().unwrap() {
            continue;
        }
        let mut prof = PhaseProfiler::new();
        let r = simulate_traced(&cell.cfg, &cell.jobs, &mut prof);
        assert_cells_bit_identical(&r, &serial[idx], "phase-profiled run");
        for (phase, calls, total) in prof.totals() {
            phase_table.row(&[
                r.strategy.clone(),
                cell_topology(cell),
                phase.to_string(),
                calls.to_string(),
                format!("{total:.3}"),
            ]);
            phase_rows.push((r.strategy.clone(), cell_topology(cell), phase, calls, total));
        }
    }
    print!("{}", phase_table.render());

    // ---- BENCH_SCALE.json: the trajectory later PRs race ----------------
    let mut bench = BenchJson::new("scale_sweep");
    bench
        .meta("capacity", Json::num(CAPACITY as f64))
        .meta("seed", Json::num(SEED as f64))
        .meta("offered_load", Json::num(0.65))
        .meta("prune", Json::Bool(prune));
    for r in &rows {
        bench.row(vec![
            ("kind", Json::str("cell")),
            ("jobs", Json::num(r.jobs as f64)),
            ("strategy", Json::str(r.strategy.as_str())),
            ("topology", Json::str(r.topology.as_str())),
            ("wall_secs", Json::num(r.wall_secs)),
            ("events", Json::num(r.events as f64)),
            ("events_per_sec", Json::num(r.events as f64 / r.wall_secs.max(1e-9))),
            ("us_per_event", Json::num(r.wall_secs * 1e6 / r.events.max(1) as f64)),
            ("scan_candidates", Json::num(r.scan_candidates as f64)),
            ("scan_skipped", Json::num(r.scan_skipped as f64)),
            (
                "scan_skip_rate",
                Json::num(r.scan_skipped as f64 / r.scan_candidates.max(1) as f64),
            ),
        ]);
    }
    for &(t, wall) in &thread_rows {
        bench.row(vec![
            ("kind", Json::str("threads")),
            ("threads", Json::num(t as f64)),
            ("total_wall_secs", Json::num(wall)),
            ("serial_cell_wall_secs", Json::num(serial_wall)),
        ]);
    }
    for (strategy, topology, phase, calls, total) in &phase_rows {
        bench.row(vec![
            ("kind", Json::str("phase")),
            ("jobs", Json::num(*sizes.last().unwrap() as f64)),
            ("strategy", Json::str(strategy.as_str())),
            ("topology", Json::str(topology.as_str())),
            ("phase", Json::str(*phase)),
            ("calls", Json::num(*calls as f64)),
            ("total_secs", Json::num(*total)),
        ]);
    }
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "SCALE")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());
    Ok(())
}
