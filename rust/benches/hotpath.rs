//! Hot-path micro-benchmarks (§Perf of EXPERIMENTS.md).
//!
//! Each row is one L3 hot path with its practical roofline comparison:
//!  - all-reduce throughput vs a single-thread memcpy roofline,
//!  - scheduler allocate() latency at Table-3 scale (206 jobs),
//!  - DES throughput (events/sec) on the extreme-contention workload,
//!  - NNLS / eq-1 / eq-5 fit latency (the per-interval modelling cost),
//!  - jsonx parse throughput on a manifest-shaped document,
//!  - checkpoint save+load bandwidth.
//!
//! `cargo bench --bench hotpath`

use ringmaster::collectives::{self, comm::run_world, Algorithm};
use ringmaster::linalg::Matrix;
use ringmaster::metrics::CsvTable;
use ringmaster::nnls::nnls;
use ringmaster::perfmodel::{ConvergenceModel, SpeedModel};
use ringmaster::rngx::Rng;
use ringmaster::scheduler::{doubling::Doubling, JobInfo, Scheduler, Speed};
use ringmaster::sim::{simulate, Contention, SimConfig, StrategyKind, WorkloadGen};
use ringmaster::trainer::Checkpoint;

fn median_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    let mut v: Vec<f64> = (0..reps).map(|_| f()).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() -> ringmaster::Result<()> {
    let mut table = CsvTable::new(&["hot path", "metric", "value", "roofline/context"]);

    // ---- all-reduce throughput ------------------------------------------
    let n = 1_000_000usize;
    let w = 8;
    let ar_secs = median_of(5, || {
        let payloads: Vec<Vec<f32>> = (0..w).map(|r| vec![r as f32; n]).collect();
        let t = std::time::Instant::now();
        run_world(w, payloads, |rank, data| {
            collectives::all_reduce(Algorithm::DoublingHalving, rank, data).unwrap();
        });
        t.elapsed().as_secs_f64()
    });
    // roofline: per rank moves 2n(1-1/w) elems; memcpy of the same volume
    let volume = (2.0 * n as f64 * (1.0 - 1.0 / w as f64)) * 4.0;
    let src = vec![1.0f32; n];
    let mut dst = vec![0.0f32; n];
    let memcpy_secs = median_of(5, || {
        let t = std::time::Instant::now();
        for _ in 0..2 {
            dst.copy_from_slice(&src);
        }
        std::hint::black_box(&dst);
        t.elapsed().as_secs_f64()
    });
    table.row(&[
        format!("dh all-reduce w={w} n=1M"),
        "GiB/s per rank".into(),
        format!("{:.2}", volume / ar_secs / (1 << 30) as f64),
        format!("memcpy roofline {:.1} GiB/s", volume / memcpy_secs / (1 << 30) as f64),
    ]);

    // §Perf optimization: shared-memory transport vs message passing
    let shm_secs = median_of(5, || {
        let world = ringmaster::collectives::shmem::ShmemWorld::new(w);
        let t = std::time::Instant::now();
        let handles: Vec<_> = (0..w)
            .map(|r| {
                let rank = world.rank(r);
                std::thread::spawn(move || {
                    let mut data = vec![r as f32; n];
                    rank.all_reduce(&mut data);
                    data[0]
                })
            })
            .collect();
        for h in handles {
            std::hint::black_box(h.join().unwrap());
        }
        t.elapsed().as_secs_f64()
    });
    table.row(&[
        format!("shmem all-reduce w={w} n=1M"),
        "GiB/s per rank".into(),
        format!("{:.2}", volume / shm_secs / (1 << 30) as f64),
        format!("{:.2}x over dh channels (§Perf)", ar_secs / shm_secs),
    ]);

    // ---- scheduler latency at Table-3 scale -------------------------------
    let profiles = WorkloadGen::default().generate(206, 250.0, 42);
    let jobs: Vec<JobInfo> = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| JobInfo {
            id: i as u64,
            q: p.total_epochs,
            speed: Speed::Table(p.speed_table()),
            max_w: 64,
        })
        .collect();
    let sched_us = median_of(9, || {
        let t = std::time::Instant::now();
        std::hint::black_box(Doubling.allocate(&jobs, 64));
        t.elapsed().as_secs_f64() * 1e6
    });
    table.row(&[
        "doubling.allocate 206 jobs".into(),
        "latency µs".into(),
        format!("{sched_us:.0}"),
        "scheduling interval is seconds — must be ≪1s".into(),
    ]);

    // ---- DES throughput ----------------------------------------------------
    let des_secs = median_of(3, || {
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Extreme, 42);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 42);
        let t = std::time::Instant::now();
        std::hint::black_box(simulate(&cfg, &jobs));
        t.elapsed().as_secs_f64()
    });
    table.row(&[
        "DES extreme workload (206 jobs)".into(),
        "wall ms".into(),
        format!("{:.1}", des_secs * 1e3),
        "full Table 3 = 18 sims".into(),
    ]);

    // ---- DES inner loop: completion scan, pruner on vs off ----------------
    // fixed-1 on a 128-GPU pool keeps the most jobs running at once —
    // the scan-heaviest regime the engine sees — so this row is where a
    // completion-scan regression shows up without a full scale sweep.
    let scan_trace = WorkloadGen::trace_scale(4_000, 128, 42);
    let mut scan_cfg = SimConfig::paper(StrategyKind::Fixed(1), Contention::Moderate, 42);
    scan_cfg.n_jobs = 4_000;
    scan_cfg.capacity = 128;
    scan_cfg.topology = ringmaster::cluster::Topology::flat(128);
    let scan_result = simulate(&scan_cfg, &scan_trace);
    let scan_on_secs = median_of(3, || {
        let t = std::time::Instant::now();
        std::hint::black_box(simulate(&scan_cfg, &scan_trace));
        t.elapsed().as_secs_f64()
    });
    scan_cfg.completion_prune = false;
    let scan_off_secs = median_of(3, || {
        let t = std::time::Instant::now();
        std::hint::black_box(simulate(&scan_cfg, &scan_trace));
        t.elapsed().as_secs_f64()
    });
    table.row(&[
        "DES completion scan (fixed-1, 4k jobs)".into(),
        "wall ms pruned".into(),
        format!("{:.1}", scan_on_secs * 1e3),
        format!(
            "unpruned {:.1} ms; skip rate {:.0}%",
            scan_off_secs * 1e3,
            100.0 * scan_result.scan_skipped as f64 / scan_result.scan_candidates.max(1) as f64
        ),
    ]);

    // ---- DES inner loop: ledger resync ------------------------------------
    // The dirty-job reconcile path: release + largest-first re-place of
    // a 16-gang batch on a 16x8 grid, the unit of work `touched` pays
    // per event on grids.
    let grid = ringmaster::cluster::Topology::cluster(16, 8);
    let resync_us = median_of(9, || {
        let mut cluster = ringmaster::cluster::ClusterState::with_policy(
            grid.spec(),
            ringmaster::cluster::PlacePolicy::Pack,
        );
        let t = std::time::Instant::now();
        for round in 0..100usize {
            let movers: Vec<(u64, usize)> =
                (0..16u64).map(|j| (j, 4 + (round + j as usize) % 5)).collect();
            cluster.place_batch(&movers).unwrap();
            for j in 0..16u64 {
                cluster.release(j).unwrap();
            }
        }
        t.elapsed().as_secs_f64() * 1e6 / (100.0 * 16.0)
    });
    table.row(&[
        "ledger resync (16-gang batch, 16x8)".into(),
        "µs per place+release".into(),
        format!("{resync_us:.2}"),
        "touched-set unit cost per event".into(),
    ]);

    // ---- model fits ---------------------------------------------------------
    let mut rng = Rng::new(7);
    let a = Matrix::from_fn(200, 4, |_, _| rng.uniform_range(0.0, 1.0));
    let b: Vec<f64> = (0..200).map(|_| rng.uniform_range(0.0, 2.0)).collect();
    let nnls_us = median_of(9, || {
        let t = std::time::Instant::now();
        std::hint::black_box(nnls(&a, &b).unwrap());
        t.elapsed().as_secs_f64() * 1e6
    });
    table.row(&[
        "NNLS 200x4".into(),
        "latency µs".into(),
        format!("{nnls_us:.0}"),
        "per-job per-interval".into(),
    ]);

    let losses: Vec<(f64, f64)> =
        (0..200).map(|e| (e as f64, 1.0 / (0.3 * e as f64 + 1.0) + 0.2)).collect();
    let conv_us = median_of(5, || {
        let t = std::time::Instant::now();
        std::hint::black_box(ConvergenceModel::fit(&losses).unwrap());
        t.elapsed().as_secs_f64() * 1e6
    });
    table.row(&[
        "eq-1 fit, 200 samples".into(),
        "latency µs".into(),
        format!("{conv_us:.0}"),
        "2-level grid x NNLS".into(),
    ]);

    let speed_samples: Vec<(usize, f64)> =
        [1usize, 2, 4, 8].iter().map(|&w| (w, 0.01 * w as f64)).collect();
    let eq5_us = median_of(9, || {
        let t = std::time::Instant::now();
        std::hint::black_box(SpeedModel::fit(&speed_samples, 128.0, 4e6).unwrap());
        t.elapsed().as_secs_f64() * 1e6
    });
    table.row(&["eq-5 fit, 4 samples".into(), "latency µs".into(), format!("{eq5_us:.0}"), "".into()]);

    // ---- jsonx ---------------------------------------------------------------
    let manifest = std::fs::read_to_string("artifacts/manifest.json")
        .unwrap_or_else(|_| include_str!("../../artifacts/manifest.json").to_string());
    let json_mb_s = {
        let secs = median_of(9, || {
            let t = std::time::Instant::now();
            std::hint::black_box(ringmaster::jsonx::parse(&manifest).unwrap());
            t.elapsed().as_secs_f64()
        });
        manifest.len() as f64 / secs / 1e6
    };
    table.row(&[
        "jsonx parse manifest".into(),
        "MB/s".into(),
        format!("{json_mb_s:.0}"),
        "startup-path only".into(),
    ]);

    // ---- checkpoint I/O ---------------------------------------------------
    let ck = Checkpoint {
        preset: "bench".into(),
        step: 1,
        epochs: 1.0,
        workers: 8,
        lr: 0.1,
        theta: vec![0.5f32; 1_000_000],
        mu: vec![0.25f32; 1_000_000],
    };
    let path = std::env::temp_dir().join(format!("rmck-hotpath-{}.ckpt", std::process::id()));
    let ck_secs = median_of(5, || {
        let t = std::time::Instant::now();
        ck.save(&path).unwrap();
        std::hint::black_box(Checkpoint::load(&path).unwrap());
        t.elapsed().as_secs_f64()
    });
    let _ = std::fs::remove_file(&path);
    table.row(&[
        "checkpoint 1M params save+load".into(),
        "MiB/s".into(),
        format!("{:.0}", 16.0 / ck_secs),
        "restart path; paper budget ~10s".into(),
    ]);

    print!("{}", table.render());
    table.write_csv("hotpath.csv")?;
    Ok(())
}
