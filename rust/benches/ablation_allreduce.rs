//! Ablation A1 (DESIGN.md §5): the all-reduce algorithm landscape.
//!
//! (a) analytic crossover matrix from eqs 2–4 — which algorithm wins at
//!     each (w, n); reproduces §2.1's "doubling-halving wins for n up to
//!     1e7 at powers of two" and the binary-blocks penalty;
//! (b) measured wall times of the real rust implementations;
//! (c) the 8→9 per-GPU cost cliff that motivates the doubling heuristic.
//!
//! `cargo bench --bench ablation_allreduce`

use ringmaster::collectives::cost::{comm_time, Algorithm, CostParams};
use ringmaster::collectives::{self, bb, comm::run_world, dh, ring};
use ringmaster::metrics::CsvTable;

fn main() -> ringmaster::Result<()> {
    let p = CostParams::default();

    // ---- (a) analytic crossover matrix ---------------------------------
    println!("analytic winner per (workers, params) — eqs 2-4, {p:?}:\n");
    let sizes: [(usize, &str); 5] = [
        (10_000, "1e4"),
        (100_000, "1e5"),
        (1_000_000, "1e6"),
        (10_000_000, "1e7"),
        (100_000_000, "1e8"),
    ];
    let mut matrix = CsvTable::new(&["workers", "1e4", "1e5", "1e6", "1e7", "1e8"]);
    for w in [2usize, 4, 8, 16, 32, 64] {
        let mut cells = vec![w.to_string()];
        for &(n, _) in &sizes {
            let nb = (n * 4) as f64;
            let ring_t = comm_time(Algorithm::Ring, w, nb, &p);
            let dh_t = comm_time(Algorithm::DoublingHalving, w, nb, &p);
            let best = if dh_t <= ring_t { "dh" } else { "ring" };
            cells.push(best.to_string());
        }
        matrix.row(&cells);
    }
    print!("{}", matrix.render());
    println!("(paper §2.1: dh significantly better up to ~1e7 params at powers of 2)\n");

    // ---- (b) measured wall times ----------------------------------------
    println!("measured all-reduce wall time, w=8 threads (median of 5):\n");
    let mut meas = CsvTable::new(&["elems", "ring_ms", "dh_ms", "bb(w=9)_ms"]);
    for n in [10_000usize, 100_000, 1_000_000] {
        let time_alg = |w: usize, alg: Algorithm| -> f64 {
            let mut samples = Vec::new();
            for _ in 0..5 {
                let payloads: Vec<Vec<f32>> = (0..w).map(|r| vec![r as f32; n]).collect();
                let t = std::time::Instant::now();
                let (_, _) = run_world(w, payloads, move |rank, data| {
                    collectives::all_reduce(alg, rank, data).unwrap();
                });
                samples.push(t.elapsed().as_secs_f64() * 1e3);
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            samples[2]
        };
        meas.row(&[
            n.to_string(),
            format!("{:.2}", time_alg(8, Algorithm::Ring)),
            format!("{:.2}", time_alg(8, Algorithm::DoublingHalving)),
            format!("{:.2}", time_alg(9, Algorithm::BinaryBlocks)),
        ]);
    }
    print!("{}", meas.render());

    // ---- (c) the 8->9 cliff ---------------------------------------------
    // The cliff lives on the critical path: eq 4's 7nβ + 3nγ vs eq 3's
    // 4nβ + 2.5nγ. Crossing 8->9 switches equations and *increases* the
    // per-step all-reduce time even though GPUs were added; 16 (back on
    // eq 3) is barely above 8. Also visible in measured world messages.
    println!("\ncritical-path all-reduce time (1M params) — the §4.2 cliff:");
    let n = 1_000_000;
    let nb = (n * 4) as f64;
    for w in [8usize, 9, 12, 15, 16] {
        let (alg, name) = if w.is_power_of_two() {
            (Algorithm::DoublingHalving, "doubling-halving")
        } else {
            (Algorithm::BinaryBlocks, "binary-blocks")
        };
        let msgs = if w.is_power_of_two() {
            dh::predicted_messages(w)
        } else {
            bb::predicted_messages(w)
        };
        println!(
            "  w={w:>2}  {name:>16}  {:>8.3} ms/step  {msgs:>4} msgs  (ring: {:>7.3} ms, {} msgs)",
            comm_time(alg, w, nb, &p) * 1e3,
            comm_time(Algorithm::Ring, w, nb, &p) * 1e3,
            ring::predicted_messages(w),
        );
    }
    let t8 = comm_time(Algorithm::DoublingHalving, 8, nb, &p);
    let t9 = comm_time(Algorithm::BinaryBlocks, 9, nb, &p);
    let t16 = comm_time(Algorithm::DoublingHalving, 16, nb, &p);
    println!(
        "\n-> 8->9 adds {:+.1}% all-reduce time; 8->16 only {:+.1}%: the local",
        100.0 * (t9 - t8) / t8,
        100.0 * (t16 - t8) / t8
    );
    println!("   optimum that traps +1 greedy and motivates the doubling heuristic.");
    assert!(t9 > t8 && (t16 - t8) < (t9 - t8));
    Ok(())
}
