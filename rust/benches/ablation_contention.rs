//! A6 — link-contention ablation: contention-blind vs contention-aware
//! gang placement on a comm-bound heavy-tailed trace.
//!
//! Three worlds, same traces, same fixed-6 strategy, same 12×4 grid
//! (48 GPUs):
//!
//! - **off / pack** — the PR-3 idealization: rings crossing the same
//!   uplink don't see each other (printed as the reference floor);
//! - **blind / pack** — fair-share link contention is *physical* but the
//!   placer still packs by locality alone, so best-fit remainder
//!   stacking piles crossing 4+2 gangs onto the same uplinks;
//! - **aware / spread** — the same physics, but crossing gangs prefer
//!   the least-loaded uplinks ([`PlacePolicy::Spread`]).
//!
//! Fixed-6 on 4-wide nodes forces every gang to split 4+2 regardless of
//! the speed model (fixed-k consults none), so the grid *must* make
//! contention-relevant choices on every placement. The payload is
//! comm-bound (1e8 bytes on the 10 GbE inter tier: crossing costs
//! ~17 s/epoch and every extra tenant another ~17), the regime where
//! uplink sharing is first-order. Results are averaged over three
//! seeds of [`WorkloadGen::trace_scale`]'s ~65%-load heavy-tailed
//! trace.
//!
//! The nine (arm, seed) cells fan across the [`sweep`] runner
//! (`RINGMASTER_THREADS` or all cores); results come back in
//! submission order, so tables and means are byte-stable regardless of
//! worker count.
//!
//! Asserted: aware ≤ blind on mean avg JCT (the issue's acceptance
//! bar), contention never speeds the blind world up vs off, every run
//! completes its whole trace, and the aware arm is bit-deterministic
//! across a repeat run.
//!
//! `cargo bench --bench ablation_contention`

use std::sync::Arc;

use ringmaster::cluster::PlacePolicy;
use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::perfmodel::{LinkContention, PlacementModel};
use ringmaster::sim::{
    simulate, sweep, Contention, SimConfig, SimResult, StrategyKind, SweepCell, WorkloadGen,
};

const NODES: usize = 12;
const GPUS_PER_NODE: usize = 4;
const N_JOBS: usize = 240;
const MODEL_BYTES: f64 = 1.0e8;
const SEEDS: [u64; 3] = [7, 11, 13];

fn cell(seed: u64, policy: PlacePolicy, law: LinkContention) -> SweepCell {
    let jobs = WorkloadGen::trace_scale(N_JOBS, NODES * GPUS_PER_NODE, seed);
    // preset arrivals are irrelevant: trace_scale bakes the arrival
    // process into the profiles, and topology overrides the capacity
    let mut cfg = SimConfig::paper(StrategyKind::Fixed(6), Contention::Moderate, seed)
        .with_topology(NODES, GPUS_PER_NODE);
    cfg.n_jobs = N_JOBS;
    cfg.placement = PlacementModel::paper().with_model_bytes(MODEL_BYTES);
    cfg.place_policy = policy;
    cfg.link_contention = law;
    SweepCell::new(cfg, Arc::new(jobs))
}

fn run(seed: u64, policy: PlacePolicy, law: LinkContention) -> SimResult {
    let c = cell(seed, policy, law);
    simulate(&c.cfg, &c.jobs)
}

fn main() -> ringmaster::Result<()> {
    let arms = [
        ("off/pack", PlacePolicy::Pack, LinkContention::OFF),
        ("blind/pack", PlacePolicy::Pack, LinkContention::fair_share()),
        ("aware/spread", PlacePolicy::Spread, LinkContention::fair_share()),
    ];

    let mut table = CsvTable::new(&["world", "seed", "avg_jct_h", "events", "completed"]);
    let mut bench = BenchJson::new("ablation_contention");
    bench
        .meta("nodes", Json::num(NODES as f64))
        .meta("gpus_per_node", Json::num(GPUS_PER_NODE as f64))
        .meta("n_jobs", Json::num(N_JOBS as f64))
        .meta("model_bytes", Json::num(MODEL_BYTES));
    // all nine (arm, seed) cells fan across the sweep runner at once;
    // results come back in submission order, so the arm-major walk
    // below (and the means accumulation order) is unchanged
    let cells: Vec<SweepCell> = arms
        .iter()
        .flat_map(|(_, policy, law)| SEEDS.iter().map(move |&seed| cell(seed, *policy, *law)))
        .collect();
    let results = sweep::run_cells(&cells, sweep::resolve_threads(None));

    let mut means = [0.0f64; 3];
    for (i, (name, _, _)) in arms.iter().enumerate() {
        for (k, &seed) in SEEDS.iter().enumerate() {
            let r = &results[i * SEEDS.len() + k];
            assert_eq!(
                r.completed, N_JOBS,
                "{name} seed {seed} left {} jobs unfinished",
                N_JOBS - r.completed
            );
            table.row(&[
                name.to_string(),
                seed.to_string(),
                format!("{:.4}", r.avg_completion_hours),
                r.events.to_string(),
                r.completed.to_string(),
            ]);
            bench.row(vec![
                ("world", Json::str(*name)),
                ("seed", Json::num(seed as f64)),
                ("avg_jct_h", Json::num(r.avg_completion_hours)),
                ("events", Json::num(r.events as f64)),
                ("completed", Json::num(r.completed as f64)),
            ]);
            means[i] += r.avg_completion_hours / SEEDS.len() as f64;
        }
    }
    print!("{}", table.render());
    table.write_csv("ablation_contention.csv")?;
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "CONTENTION")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());

    let [off, blind, aware] = means;
    println!(
        "\nmean avg JCT: off/pack {off:.3}h  blind/pack {blind:.3}h  aware/spread {aware:.3}h\n\
         blind-off is what shared uplinks cost a contention-blind packer;\n\
         blind-aware is what spreading crossing rings over idle uplinks buys back."
    );

    // the physics only ever slows rings down: modelling it cannot make
    // the blind world faster than the PR-3 idealization
    assert!(
        blind >= off - 1e-9,
        "contention sped the blind world up ({blind:.4}h < {off:.4}h)"
    );
    // the issue's acceptance bar: contention-aware placement is never
    // worse than contention-blind on the same contended physics
    assert!(
        aware <= blind + 1e-9,
        "aware {aware:.4}h must not lose to blind {blind:.4}h"
    );

    // bit-determinism of the contended engine: a repeat of the aware
    // arm at the first seed must reproduce the run exactly
    let a = run(SEEDS[0], PlacePolicy::Spread, LinkContention::fair_share());
    let b = run(SEEDS[0], PlacePolicy::Spread, LinkContention::fair_share());
    assert_eq!(a.completed, b.completed, "repeat run diverged on completions");
    assert_eq!(a.events, b.events, "repeat run diverged on event count");
    assert_eq!(
        a.avg_completion_hours.to_bits(),
        b.avg_completion_hours.to_bits(),
        "repeat run diverged on avg JCT bits"
    );
    Ok(())
}
