//! Table 1 reproduction: per-step time decomposition vs worker count.
//!
//! The paper profiles ResNet-110 at m=128/GPU on 1–8 K40m GPUs, reporting
//! T_forward, T_back, T_total and images/sec, and the headline 94.5%
//! scaling efficiency from 4→8. We reproduce the same decomposition for
//! the LM workload: forward-only time from the `fwd_loss` artifact,
//! backward = train_step − forward, plus the all-reduce and update phases
//! the rust side adds, with tokens/sec as the images/sec analogue.
//!
//! `cargo bench --bench table1_profiling` (honors RINGMASTER_BENCH_WORKERS)

use ringmaster::data::Corpus;
use ringmaster::metrics::CsvTable;
use ringmaster::runtime::{Artifacts, Engine};
use ringmaster::trainer::{train, TrainConfig};

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() -> ringmaster::Result<()> {
    let artifacts_dir = std::env::var("RINGMASTER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let preset = std::env::var("RINGMASTER_BENCH_PRESET").unwrap_or_else(|_| "tiny".into());
    let workers: Vec<usize> = std::env::var("RINGMASTER_BENCH_WORKERS")
        .unwrap_or_else(|_| "1,2,4".into())
        .split(',')
        .map(|s| s.trim().parse().unwrap())
        .collect();
    let steps = 12u64;

    // ---- single-engine phase decomposition (T_forward / T_back) --------
    let artifacts = Artifacts::resolve(&artifacts_dir)?;
    let engine = Engine::load(&artifacts, &preset)?;
    let p = engine.preset().clone();
    let corpus = Corpus::new(p.vocab, 0.08, 7);
    let theta = engine.init(42)?;
    let mu = vec![0.0f32; theta.len()];
    let (inputs, targets) = corpus.batch(0, 0, p.batch, p.seq_len);

    let time_n = |f: &mut dyn FnMut()| -> f64 {
        let reps = 8;
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t = std::time::Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        median(samples)
    };

    // warm up (compile)
    let _ = engine.fwd_loss(&theta, &inputs, &targets)?;
    let _ = engine.train_step(&theta, &inputs, &targets)?;
    let _ = engine.sgd_update(&theta, &vec![0.0; theta.len()], &mu, 0.1, 0.9)?;

    let t_fwd = time_n(&mut || {
        engine.fwd_loss(&theta, &inputs, &targets).unwrap();
    });
    let t_step = time_n(&mut || {
        engine.train_step(&theta, &inputs, &targets).unwrap();
    });
    let t_update = time_n(&mut || {
        engine.sgd_update(&theta, &theta, &mu, 0.1, 0.9).unwrap();
    });
    let t_back = (t_step - t_fwd).max(0.0);

    println!("phase decomposition, preset={preset} (batch {} x seq {}):", p.batch, p.seq_len);
    println!("  T_forward          {:>8.2} ms", t_fwd * 1e3);
    println!("  T_back (fwd+bwd-f) {:>8.2} ms", t_back * 1e3);
    println!("  T_update (fused)   {:>8.2} ms", t_update * 1e3);
    println!();

    // ---- distributed scaling table (the Table 1 shape) -----------------
    let mut table = CsvTable::new(&[
        "workers", "alg", "T_step_ms", "T_allreduce_ms", "tokens_per_s", "scaling_eff_%",
    ]);
    let mut per_worker_base: Option<f64> = None;
    for &w in &workers {
        let mut cfg = TrainConfig::new(artifacts_dir.clone(), &preset, w);
        cfg.log_every = u64::MAX;
        let (_, r) = train(&cfg, None, steps)?;
        let tps = r.tokens_per_sec;
        let base = *per_worker_base.get_or_insert(tps / w as f64);
        table.row(&[
            w.to_string(),
            r.algorithm.to_string(),
            format!("{:.1}", r.mean_step_secs * 1e3),
            format!("{:.2}", r.mean_allreduce_secs * 1e3),
            format!("{:.0}", tps),
            format!("{:.1}", 100.0 * tps / (base * w as f64)),
        ]);
    }
    print!("{}", table.render());
    table.write_csv("table1.csv")?;

    println!("\npaper Table 1 (ResNet-110, m=128/GPU, K40m) for comparison:");
    println!("  GPUs  T_fwd(ms)  T_back(ms)  T_total(ms)  images/s");
    println!("   1      108.0      236.5        402.5        318.0");
    println!("   2      110.2      274.6        427.2        576.2");
    println!("   4      107.1      290.1        444.3       1152.4");
    println!("   8      106.0      307.4        470.2       2177.8");
    println!("  (4->8 scaling efficiency: 94.5%)");
    println!("\nShape claims: T_forward flat in w; per-step time grows mildly with w");
    println!("(all-reduce overhead); throughput scales near-linearly. table1.csv written.");
    Ok(())
}
