//! Table 2 reproduction: stop/restart statistics.
//!
//! Two parts:
//!  1. **Real measurement** — run the miniature protocol on the live
//!     trainer (baselines at w=1, w=2; rescale 1→2 at midpoint) and
//!     measure wall times plus the restart cost (checkpoint I/O + PJRT
//!     client/compile), our analogue of the paper's ~10 s.
//!  2. **Calibrated projection** — feed the *paper's own* per-epoch
//!     times (Table 2) through our eq-5 fit + simulator arithmetic and
//!     regenerate the paper's rows, checking the ~32%/~23% savings of
//!     the 4→8 rescales emerge from our code path.
//!
//! `cargo bench --bench table2_rescale`

use ringmaster::coordinator::run_with_rescales;
use ringmaster::metrics::CsvTable;
use ringmaster::perfmodel::SpeedModel;
use ringmaster::sim::workload::PAPER_EPOCH_SECS;
use ringmaster::trainer::TrainConfig;

fn main() -> ringmaster::Result<()> {
    let artifacts = std::env::var("RINGMASTER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // ---- part 1: real runs ---------------------------------------------
    let steps = 60u64;
    let cfg = TrainConfig::new(artifacts, "tiny", 1);
    let mut table = CsvTable::new(&["config", "epochs", "train_s", "restart_s", "final_loss"]);
    for w in [1usize, 2] {
        let out = run_with_rescales(&cfg, &[(w, steps)])?;
        table.row(&[
            format!("fixed w={w}"),
            format!("{:.2}", out.checkpoint.epochs),
            format!("{:.1}", out.segments[0].report.wall_secs),
            "0.0".into(),
            format!("{:.4}", out.final_loss().unwrap()),
        ]);
    }
    let out = run_with_rescales(&cfg, &[(1, steps / 2), (2, steps / 2)])?;
    let restart: f64 = out.segments.iter().map(|s| s.restart_secs).sum();
    table.row(&[
        "rescale 1->2".into(),
        format!("{:.2}", out.checkpoint.epochs),
        format!("{:.1}", out.segments.iter().map(|s| s.report.wall_secs).sum::<f64>()),
        format!("{:.1}", restart),
        format!("{:.4}", out.final_loss().unwrap()),
    ]);
    println!("real runs (tiny preset):");
    print!("{}", table.render());
    println!("measured stop/restart cost: {restart:.1}s (paper: ~10 s, §6)\n");

    // ---- part 2: calibrated projection of the paper's table -------------
    // eq-5 fit of the paper's measured epoch times
    let samples: Vec<(usize, f64)> =
        PAPER_EPOCH_SECS.iter().map(|&(w, s)| (w, 1.0 / s)).collect();
    let model = SpeedModel::fit(&samples, 50_000.0, 6.9e6)?;

    let total_epochs = 165.0; // paper: 160-170
    let restart_cost = 10.0;
    let project = |plan: &[(usize, f64)]| -> (f64, f64) {
        // (total minutes, total epochs) for a plan of (w, epochs) legs
        let mut mins = 0.0;
        for (i, &(w, epochs)) in plan.iter().enumerate() {
            mins += epochs * model.secs_per_epoch(w) / 60.0;
            if i > 0 {
                mins += restart_cost / 60.0;
            }
        }
        (mins, plan.iter().map(|p| p.1).sum())
    };

    let mut proj = CsvTable::new(&["config", "epochs", "T_tot_min(ours)", "T_tot_min(paper)"]);
    let rows: Vec<(&str, Vec<(usize, f64)>, f64)> = vec![
        ("1 GPU", vec![(1, total_epochs)], 368.0),
        ("2 GPUs", vec![(2, total_epochs)], 232.0),
        ("4 GPUs", vec![(4, total_epochs)], 126.0),
        ("8 GPUs", vec![(8, total_epochs)], 84.0),
        // stop at 5k steps = 51 epochs (paper), rest at 8 GPUs
        ("4->8 @51ep", vec![(4, 51.0), (8, total_epochs - 51.0)], 104.0),
        ("4->8 @102ep", vec![(4, 102.0), (8, total_epochs - 102.0)], 113.0),
    ];
    for (name, plan, paper_min) in &rows {
        let (mins, epochs) = project(plan);
        proj.row(&[
            name.to_string(),
            format!("{epochs:.0}"),
            format!("{mins:.0}"),
            format!("{paper_min:.0}"),
        ]);
    }
    println!("calibrated projection of paper Table 2 through eq 5 + restart model:");
    print!("{}", proj.render());

    // the paper's headline savings
    let (t4, _) = project(&[(4, total_epochs)]);
    let (t48a, _) = project(&[(4, 51.0), (8, total_epochs - 51.0)]);
    let (t48b, _) = project(&[(4, 102.0), (8, total_epochs - 102.0)]);
    println!(
        "\nsavings vs fixed-4: rescale@51ep {:.0}% (paper ~32%), rescale@102ep {:.0}% (paper ~23%)",
        100.0 * (t4 - t48a) / t4,
        100.0 * (t4 - t48b) / t4
    );
    Ok(())
}
