//! A4 — online modelling ablation: oracle (trace-table) vs learned
//! (`--online-model`) scheduling on the same bursty trace.
//!
//! Two worlds, same 10-job burst, same doubling strategy, 8 workers:
//!
//! - **oracle** — the §4 precompute assumption: every job's speed table
//!   is scheduler knowledge at submission;
//! - **learned** — the tables are hidden ground truth; each job's
//!   finished segments feed its `OnlineModel`, and the scheduler runs
//!   on the trace-table prior until the confidence gate opens, then on
//!   the measured eq-5 fit.
//!
//! Jobs are eq-5-realizable (`a/w + b(w-1) + c`), so a learner reaching
//! three distinct widths reproduces the whole curve — the interesting
//! output is the *trajectory*: how many segments each job needed before
//! its gate opened, and the learned-vs-oracle JCT gap, which is the
//! price of learning (the paper's precompute-vs-explore tradeoff, §7,
//! measured live instead of simulated).
//!
//! Asserted: the learned world completes everything, at least one gate
//! opens, per-job RMSE never rises between first and last gated refit,
//! and avg JCT stays within 2x of oracle in both directions.
//!
//! The two arms run concurrently through [`sweep::parallel_map`];
//! results land in submission order so the report is byte-stable.
//!
//! `cargo bench --bench ablation_online` (env: `RINGMASTER_THREADS`)

use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::orchestrator::{
    orchestrate, scheduler_by_name, JobSpec, OrchestratorConfig, OrchestratorReport,
};
use ringmaster::sim::sweep;
use ringmaster::sim::workload::JobProfile;
use ringmaster::trainer::TrainConfig;

/// Eq-5-realizable job: `secs/epoch(w) = a/w + b(w-1) + c` scaled by
/// `size`, measured at the paper's widths.
fn learnable_job(id: u64, arrival: f64, total_epochs: f64, size: f64) -> JobSpec {
    let (a, b, c) = (120.0 * size, 1.2 * size, 16.0 * size);
    let secs = |w: usize| a / w as f64 + b * (w as f64 - 1.0) + c;
    let epoch_secs = vec![(1, secs(1)), (2, secs(2)), (4, secs(4)), (8, secs(8))];
    JobSpec::from_profile(id, JobProfile { arrival, epoch_secs, total_epochs }, 8)
}

fn bursty_trace() -> Vec<JobSpec> {
    let sizes = [1.0, 1.1, 0.9, 1.2, 0.8, 1.05, 0.95, 1.15, 0.85, 0.7];
    sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| learnable_job(i as u64, i as f64, 3.0, s))
        .collect()
}

fn run(cfg: OrchestratorConfig, specs: &[JobSpec]) -> ringmaster::Result<OrchestratorReport> {
    let sched = scheduler_by_name("doubling")?;
    orchestrate(&cfg, sched.as_ref(), specs)
}

fn main() -> ringmaster::Result<()> {
    let mut train = TrainConfig::new(
        std::env::var("RINGMASTER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "tiny",
        1,
    );
    train.dataset_examples = 256;
    train.log_every = u64::MAX;
    train.seed = 42;

    let specs = bursty_trace();
    let base = OrchestratorConfig::new(train, 8);

    // the two worlds are independent (checkpoints live in memory, the
    // artifacts dir is read-only), so they fan across the sweep runner;
    // each worker builds its own scheduler inside the closure
    let mut online_cfg = base.clone();
    online_cfg.online_model = true;
    let cfgs = [base, online_cfg];
    let mut reports =
        sweep::parallel_map(&cfgs, sweep::resolve_threads(None).min(cfgs.len()), |cfg| {
            run(cfg.clone(), &specs)
        });
    let online = reports.pop().expect("learned arm missing")?;
    let oracle = reports.pop().expect("oracle arm missing")?;

    let mut table = CsvTable::new(&[
        "world", "avg_jct_s", "p50_jct_s", "makespan_s", "restarts", "learned_jobs",
        "mean_final_rmse",
    ]);
    let mut bench = BenchJson::new("ablation_online");
    bench.meta("capacity", Json::num(8.0)).meta("n_jobs", Json::num(specs.len() as f64));
    for (name, r) in [("oracle", &oracle), ("learned", &online)] {
        let rmses: Vec<f64> = r.jobs.iter().filter_map(|j| j.model_rmse).collect();
        let mean_rmse = if rmses.is_empty() {
            "-".to_string()
        } else {
            format!("{:.2}", rmses.iter().sum::<f64>() / rmses.len() as f64)
        };
        table.row(&[
            name.to_string(),
            format!("{:.1}", r.avg_jct_secs()),
            format!("{:.1}", r.p50_jct_secs()),
            format!("{:.1}", r.makespan_secs),
            r.total_restarts.to_string(),
            r.learned_jobs().to_string(),
            mean_rmse,
        ]);
        bench.row(vec![
            ("world", Json::str(name)),
            ("avg_jct_s", Json::num(r.avg_jct_secs())),
            ("p50_jct_s", Json::num(r.p50_jct_secs())),
            ("makespan_s", Json::num(r.makespan_secs)),
            ("restarts", Json::num(r.total_restarts as f64)),
            ("learned_jobs", Json::num(r.learned_jobs() as f64)),
            (
                "mean_final_rmse",
                if rmses.is_empty() {
                    Json::Null
                } else {
                    Json::num(rmses.iter().sum::<f64>() / rmses.len() as f64)
                },
            ),
        ]);
    }
    print!("{}", table.render());
    table.write_csv("ablation_online.csv")?;
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "ONLINE")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());

    println!("\nper-job learning trajectory (learned world):");
    let mut detail =
        CsvTable::new(&["job", "segs", "gate_at_seg", "rmse_first", "rmse_last", "jct_s"]);
    for j in &online.jobs {
        detail.row(&[
            j.id.to_string(),
            j.segments.to_string(),
            j.learned_after_segments.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            j.model_rmse_first.map(|r| format!("{r:.2}")).unwrap_or_else(|| "-".into()),
            j.model_rmse.map(|r| format!("{r:.2}")).unwrap_or_else(|| "-".into()),
            format!("{:.1}", j.jct_secs),
        ]);
    }
    print!("{}", detail.render());

    assert_eq!(online.jobs.len(), specs.len(), "learned world lost jobs");
    assert!(online.learned_jobs() >= 1, "no confidence gate ever opened");
    for j in &online.jobs {
        if let (Some(first), Some(last)) = (j.model_rmse_first, j.model_rmse) {
            assert!(last <= first + 1e-3, "job {}: rmse rose {first} -> {last}", j.id);
        }
    }
    let (o, l) = (oracle.avg_jct_secs(), online.avg_jct_secs());
    assert!(l <= 2.0 * o && o <= 2.0 * l, "learned {l:.1}s vs oracle {o:.1}s out of bounds");

    println!(
        "\nlearned-vs-oracle gap: {:+.1}s avg JCT ({:+.1}%) — the live price of \
         discovering f(w)\ninstead of being handed it (§7's precompute-vs-explore \
         tradeoff as a service).",
        l - o,
        100.0 * (l - o) / o,
    );
    Ok(())
}
