//! A3 — gang placement ablation: flat vs topology-aware JCT on the
//! bursty trace.
//!
//! Three worlds, same 10-job burst, same doubling strategy, same total
//! GPU count (16):
//!
//! - **flat(16)** — the pre-placement idealization: no ring ever pays an
//!   inter-node cost;
//! - **2x8 pack** — locality-aware best-fit-decreasing placement on a
//!   two-node grid: gangs of w ≤ 8 stay on one node whenever the grid
//!   allows, so only genuine overflow pays the eq-2 inter-node delta;
//! - **2x8 scatter** — the locality-blind strawman: one GPU at a time
//!   across the emptiest nodes, so even small gangs span both nodes.
//!
//! Jobs carry a communication-bound payload (VGG-class, 1e8 bytes) on a
//! 10 GbE-class inter-node network — the regime GADGET (arXiv
//! 2202.01158) shows makes placement first-order for ring all-reduce.
//! Asserted: `pack < scatter` on average JCT (the value of
//! locality-aware placement) and that only grid worlds cross nodes.
//! The flat world is printed as the idealized reference; it is *not*
//! asserted as a lower bound, because eq-6 doubling ignores the §6
//! restart charge and the flat world can over-double 8→16 at a net
//! loss the placement-penalized world refuses.
//!
//! `cargo bench --bench ablation_placement`

use ringmaster::cluster::PlacePolicy;
use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::orchestrator::{
    orchestrate, scheduler_by_name, JobSpec, OrchestratorConfig, OrchestratorReport,
};
use ringmaster::sim::workload::JobProfile;
use ringmaster::trainer::TrainConfig;

/// Communication-bound payload: locality matters at this size.
const MODEL_BYTES: f64 = 1.0e8;

/// Paper-profile job (Table 1/2 epoch times scaled by `size`), with the
/// profile extended to w=16 by near-flat extrapolation so the scheduler
/// may be tempted to span nodes.
fn paper_job(id: u64, arrival: f64, total_epochs: f64, size: f64) -> JobSpec {
    let epoch_secs = vec![
        (1, 138.0 * size),
        (2, 81.9 * size),
        (4, 47.3 * size),
        (8, 29.6 * size),
        (16, 26.0 * size),
    ];
    let mut spec = JobSpec::from_profile(
        id,
        JobProfile { arrival, epoch_secs, total_epochs },
        16,
    );
    spec.model_bytes = MODEL_BYTES;
    spec
}

/// The 10-job burst of the orchestrator integration suite (arrivals 1 s
/// apart), heavy enough that the grid has to make placement choices.
fn bursty_trace() -> Vec<JobSpec> {
    let sizes = [1.0, 1.1, 0.9, 1.2, 0.8, 1.05, 0.95, 1.15, 0.85, 0.7];
    sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| paper_job(i as u64, i as f64, 1.0, s))
        .collect()
}

fn run(cfg: OrchestratorConfig, specs: &[JobSpec]) -> ringmaster::Result<OrchestratorReport> {
    let sched = scheduler_by_name("doubling")?;
    orchestrate(&cfg, sched.as_ref(), specs)
}

fn main() -> ringmaster::Result<()> {
    let mut train = TrainConfig::new(
        std::env::var("RINGMASTER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "tiny",
        1,
    );
    train.dataset_examples = 256;
    train.log_every = u64::MAX;
    train.seed = 42;

    let specs = bursty_trace();
    let base = OrchestratorConfig::new(train, 16);

    let flat = run(base.clone(), &specs)?;
    let pack = run(base.clone().with_topology(2, 8), &specs)?;
    let mut scatter_cfg = base.with_topology(2, 8);
    scatter_cfg.place_policy = PlacePolicy::Scatter;
    let scatter = run(scatter_cfg, &specs)?;

    let mut table = CsvTable::new(&[
        "world", "avg_jct_s", "p50_jct_s", "makespan_s", "xnode_segs", "restarts", "util_%",
    ]);
    let mut bench = BenchJson::new("ablation_placement");
    bench
        .meta("capacity", Json::num(16.0))
        .meta("model_bytes", Json::num(MODEL_BYTES))
        .meta("n_jobs", Json::num(specs.len() as f64));
    for (name, r) in [("flat(16)", &flat), ("2x8 pack", &pack), ("2x8 scatter", &scatter)] {
        table.row(&[
            name.to_string(),
            format!("{:.1}", r.avg_jct_secs()),
            format!("{:.1}", r.p50_jct_secs()),
            format!("{:.1}", r.makespan_secs),
            r.cross_node_segments.to_string(),
            r.total_restarts.to_string(),
            format!("{:.1}", 100.0 * r.utilization),
        ]);
        bench.row(vec![
            ("world", Json::str(name)),
            ("avg_jct_s", Json::num(r.avg_jct_secs())),
            ("p50_jct_s", Json::num(r.p50_jct_secs())),
            ("makespan_s", Json::num(r.makespan_secs)),
            ("cross_node_segments", Json::num(r.cross_node_segments as f64)),
            ("restarts", Json::num(r.total_restarts as f64)),
            ("utilization", Json::num(r.utilization)),
        ]);
    }
    print!("{}", table.render());
    table.write_csv("ablation_placement.csv")?;
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "PLACEMENT")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());

    // The ablation's claim, asserted: locality-aware placement beats
    // locality-blind on the same grid. (flat is printed as the
    // idealized reference but NOT asserted as a lower bound — doubling
    // ignores the §6 restart cost, so the flat world can over-double
    // 8→16 at a net loss that the placement-penalized world refuses,
    // occasionally letting pack edge out flat.)
    assert!(
        pack.avg_jct_secs() < scatter.avg_jct_secs(),
        "locality-aware {:.1}s must beat locality-blind {:.1}s",
        pack.avg_jct_secs(),
        scatter.avg_jct_secs()
    );
    assert!(
        pack.cross_node_segments < scatter.cross_node_segments,
        "pack crossed nodes {} times vs scatter {} — packing isn't packing",
        pack.cross_node_segments,
        scatter.cross_node_segments
    );
    assert_eq!(flat.cross_node_segments, 0, "flat pools have no node boundaries");

    println!(
        "\npack<scatter on avg JCT: the gap ({:.0}s) is what locality-aware gang \
         placement buys;\nflat is the no-topology idealization ({:+.0}s vs pack) — \
         the cost the flat capacity model was hiding.",
        scatter.avg_jct_secs() - pack.avg_jct_secs(),
        pack.avg_jct_secs() - flat.avg_jct_secs(),
    );
    Ok(())
}
