//! Ablation A2 (DESIGN.md §5): doubling heuristic vs Optimus-greedy vs
//! the exact DP on cliffy (eq 3/eq 4-shaped) workloads.
//!
//! Reports the optimality gap of each heuristic, how often greedy gets
//! stuck below doubling's allocation, and decision latency (the
//! scheduler runs every interval, so allocate() must be fast).
//!
//! `cargo bench --bench ablation_heuristic`

use ringmaster::collectives::cost::{comm_time, Algorithm, CostParams};
use ringmaster::metrics::{CsvTable, Stat};
use ringmaster::rngx::Rng;
use ringmaster::scheduler::{
    doubling::Doubling, exact::ExactDp, objective, optimus::OptimusGreedy, JobInfo, Scheduler,
    Speed,
};

/// A job whose truth table follows the piecewise eq 3/eq 4 cost models
/// with randomized compute weight (the §4.2 cliff landscape).
fn cliffy_job(rng: &mut Rng, id: u64) -> JobInfo {
    let p = CostParams { alpha: rng.uniform_range(1e-3, 3e-2), beta: 8e-11, gamma: 1e-10 };
    let compute = rng.uniform_range(0.1, 0.8);
    let dataset = rng.uniform_range(200.0, 800.0);
    let n_bytes = rng.uniform_range(1e6, 2e7);
    let table: Vec<(usize, f64)> = (1usize..=64)
        .map(|w| {
            let alg = if w.is_power_of_two() {
                Algorithm::DoublingHalving
            } else {
                Algorithm::BinaryBlocks
            };
            let epoch = (dataset / w as f64) * (compute + comm_time(alg, w, n_bytes, &p));
            (w, 1.0 / epoch)
        })
        .collect();
    JobInfo { id, q: rng.uniform_range(50.0, 300.0), speed: Speed::Table(table), max_w: 64 }
}

fn main() {
    let mut rng = Rng::new(4242);
    let trials = 60;
    let capacity = 64;

    let mut gap_doubling = Stat::new();
    let mut gap_greedy = Stat::new();
    let mut greedy_stuck = 0usize;
    let mut lat_doubling = Stat::new();
    let mut lat_greedy = Stat::new();
    let mut lat_exact = Stat::new();

    for _ in 0..trials {
        let n_jobs = 2 + rng.below(7);
        let jobs: Vec<JobInfo> = (0..n_jobs).map(|i| cliffy_job(&mut rng, i as u64)).collect();

        let t = std::time::Instant::now();
        let d = Doubling.allocate(&jobs, capacity);
        lat_doubling.push(t.elapsed().as_secs_f64() * 1e6);
        let t = std::time::Instant::now();
        let g = OptimusGreedy.allocate(&jobs, capacity);
        lat_greedy.push(t.elapsed().as_secs_f64() * 1e6);
        let t = std::time::Instant::now();
        let e = ExactDp.allocate(&jobs, capacity);
        lat_exact.push(t.elapsed().as_secs_f64() * 1e6);

        let oe = objective(&jobs, &e);
        gap_doubling.push(objective(&jobs, &d) / oe);
        gap_greedy.push(objective(&jobs, &g) / oe);
        if d.values().sum::<usize>() > g.values().sum::<usize>() {
            greedy_stuck += 1;
        }
    }

    let mut table = CsvTable::new(&["heuristic", "mean_gap", "worst_gap", "mean_latency_us"]);
    table.row(&[
        "doubling (paper)".into(),
        format!("{:.3}", gap_doubling.mean()),
        format!("{:.3}", gap_doubling.max()),
        format!("{:.0}", lat_doubling.mean()),
    ]);
    table.row(&[
        "optimus +1 greedy".into(),
        format!("{:.3}", gap_greedy.mean()),
        format!("{:.3}", gap_greedy.max()),
        format!("{:.0}", lat_greedy.mean()),
    ]);
    table.row(&[
        "exact DP".into(),
        "1.000".into(),
        "1.000".into(),
        format!("{:.0}", lat_exact.mean()),
    ]);
    println!("optimality gap vs exact DP over {trials} cliffy workloads (cap {capacity}):\n");
    print!("{}", table.render());
    println!(
        "\ngreedy allocated fewer total GPUs than doubling in {greedy_stuck}/{trials} trials \
         (stuck below a cliff)"
    );
    println!(
        "\nprecompute-table advantage (§4.2): doubling evaluates log2(C)={} \
         configurations per job vs greedy's C={capacity}",
        (capacity as f64).log2() as usize
    );
    assert!(gap_doubling.mean() <= gap_greedy.mean() + 0.02, "doubling should win on cliffy workloads");
}
