//! A7 — fault ablation: what failures cost each scheduling strategy,
//! and what checkpoint-store recovery buys back.
//!
//! **Part 1 (DES).** The paper's 8×8 grid under seeded node faults at
//! three steady rates (per-node MTBF 40000/20000/10000 s, 600 s
//! repairs) plus the fault-off floor, for precompute (doubling),
//! optimus, and fixed-8. Evicted gangs lose progress back to their last
//! segment boundary and downed nodes leave the pool until repair, so
//! mean JCT inflates with the failure rate. Results are averaged over
//! three seeds; all `4 rates × 3 strategies × 3 seeds` cells fan across
//! the [`sweep`] runner and come back in submission order, so the table
//! is byte-stable regardless of worker count.
//!
//! **Part 2 (live orchestrator).** The same two-job rescale trace run
//! under a survivable fault storm (60 s MTBF vs ~40-80 s segments, so
//! roughly every other segment dies) twice: whole-file checkpoint
//! recovery vs the content-addressed store (`--ckpt-store`). The
//! schedule — and the trained model bits — may not move (recovery
//! lives on the measured side of the two-clock split), while restart
//! round-trip bytes must strictly shrink: a store retry re-commits the
//! unchanged parked snapshot as a manifest instead of a full theta‖mu
//! image. This is the issue's acceptance bar.
//!
//! Asserted: every DES run completes its whole trace, fault-on arms
//! actually evicted gangs, faults never speed fixed-8 up vs its
//! fault-off floor, a faulted arm is bit-deterministic across a repeat
//! run; live: zero given-up jobs, same schedule both modes, store
//! rework bytes strictly below whole-file.
//!
//! `cargo bench --bench ablation_faults`

use std::sync::Arc;

use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::orchestrator::{
    orchestrate, scheduler_by_name, JobSpec, OrchestratorConfig, OrchestratorReport,
};
use ringmaster::sim::workload::JobProfile;
use ringmaster::sim::{
    simulate, sweep, Contention, FaultPlan, SimConfig, SimResult, StrategyKind, SweepCell,
    WorkloadGen,
};
use ringmaster::trainer::TrainConfig;

const NODES: usize = 8;
const GPUS_PER_NODE: usize = 8;
const SEEDS: [u64; 3] = [7, 11, 13];
const HORIZON_SECS: f64 = 4.0e6;
const MTTR_SECS: f64 = 600.0;

fn rate_plan(mtbf_secs: f64, seed: u64) -> FaultPlan {
    if mtbf_secs <= 0.0 {
        FaultPlan::OFF
    } else {
        FaultPlan::steady(mtbf_secs, MTTR_SECS, HORIZON_SECS, seed)
    }
}

fn cell(strategy: StrategyKind, mtbf_secs: f64, seed: u64) -> SweepCell {
    let mut cfg = SimConfig::paper(strategy, Contention::Moderate, seed)
        .with_topology(NODES, GPUS_PER_NODE);
    cfg.faults = rate_plan(mtbf_secs, seed);
    let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
    SweepCell::new(cfg, Arc::new(jobs))
}

fn run(strategy: StrategyKind, mtbf_secs: f64, seed: u64) -> SimResult {
    let c = cell(strategy, mtbf_secs, seed);
    simulate(&c.cfg, &c.jobs)
}

// ---- part 2: live recovery rework (same fixture as tests/ckpt_store.rs) ----

fn paper_job(id: u64, arrival: f64, total_epochs: f64) -> JobSpec {
    let epoch_secs = vec![(1, 138.0), (2, 81.9), (4, 47.3), (8, 29.6)];
    JobSpec::from_profile(id, JobProfile { arrival, epoch_secs, total_epochs }, 8)
}

fn live_cfg(store: Option<std::path::PathBuf>, seed: u64) -> OrchestratorConfig {
    let mut train = TrainConfig::new(
        std::env::var("RINGMASTER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        "tiny",
        1,
    );
    train.dataset_examples = 256;
    train.log_every = u64::MAX;
    train.seed = seed;
    let mut cfg = OrchestratorConfig::new(train, 8);
    cfg.segment_steps = 16;
    cfg.restart_cost = 10.0;
    cfg.ckpt_store = store;
    // ~50% per-segment hazard with a deep retry budget and quick
    // backoff: lots of rework traffic, zero given-up jobs
    cfg.faults = FaultPlan::steady(60.0, 60.0, 1.0e9, seed);
    cfg.faults.max_retries = 30;
    cfg.faults.backoff_base_secs = 2.0;
    cfg
}

fn assert_same_schedule(a: &OrchestratorReport, b: &OrchestratorReport) {
    assert_eq!(a.events, b.events, "event counts diverged");
    assert_eq!(a.makespan_secs.to_bits(), b.makespan_secs.to_bits(), "virtual clock diverged");
    for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(ja.jct_secs.to_bits(), jb.jct_secs.to_bits(), "job {} JCT diverged", ja.id);
        assert_eq!(ja.failures, jb.failures, "job {} fault pattern diverged", ja.id);
        assert_eq!(
            ja.final_loss.map(f32::to_bits),
            jb.final_loss.map(f32::to_bits),
            "job {} trained different models",
            ja.id
        );
    }
}

fn main() -> ringmaster::Result<()> {
    let strategies = [
        ("doubling", StrategyKind::Precompute),
        ("optimus", StrategyKind::Optimus),
        ("fixed-8", StrategyKind::Fixed(8)),
    ];
    // mtbf 0 encodes the fault-off floor
    let rates = [("off", 0.0f64), ("rare", 40_000.0), ("moderate", 20_000.0), ("harsh", 10_000.0)];

    let mut table =
        CsvTable::new(&["strategy", "mtbf_s", "mean_avg_jct_h", "inflation", "evictions"]);
    let mut bench = BenchJson::new("ablation_faults");
    bench
        .meta("nodes", Json::num(NODES as f64))
        .meta("gpus_per_node", Json::num(GPUS_PER_NODE as f64))
        .meta("mttr_secs", Json::num(MTTR_SECS));

    // strategy-major, rate-minor, seed-innermost: the index arithmetic
    // below relies on this submission order
    let cells: Vec<SweepCell> = strategies
        .iter()
        .flat_map(|&(_, s)| {
            rates
                .iter()
                .flat_map(move |&(_, mtbf)| SEEDS.iter().map(move |&seed| cell(s, mtbf, seed)))
        })
        .collect();
    let results = sweep::run_cells(&cells, sweep::resolve_threads(None));

    for (si, (sname, _)) in strategies.iter().enumerate() {
        let mut floor = 0.0f64;
        for (ri, (rname, mtbf)) in rates.iter().enumerate() {
            let mut mean = 0.0f64;
            let mut evictions = 0u64;
            for (k, &seed) in SEEDS.iter().enumerate() {
                let r = &results[(si * rates.len() + ri) * SEEDS.len() + k];
                assert_eq!(
                    r.completed,
                    r.completion_secs.len(),
                    "{sname}/{rname} seed {seed}: jobs left unfinished"
                );
                if *mtbf > 0.0 {
                    assert!(r.evictions > 0, "{sname}/{rname} seed {seed}: no faults fired");
                } else {
                    assert_eq!(r.evictions, 0, "{sname} fault-off floor evicted a gang");
                }
                mean += r.avg_completion_hours / SEEDS.len() as f64;
                evictions += r.evictions;
            }
            if ri == 0 {
                floor = mean;
            }
            let inflation = mean / floor;
            if *sname == "fixed-8" {
                // the fixed strategy never re-widens, so losing progress
                // and capacity to faults can only cost it
                assert!(
                    inflation >= 1.0 - 1e-9,
                    "faults sped fixed-8 up: {mean:.4}h vs floor {floor:.4}h"
                );
            }
            table.row(&[
                sname.to_string(),
                format!("{mtbf:.0}"),
                format!("{mean:.4}"),
                format!("{inflation:.3}"),
                evictions.to_string(),
            ]);
            bench.row(vec![
                ("strategy", Json::str(*sname)),
                ("mtbf_s", Json::num(*mtbf)),
                ("mean_avg_jct_h", Json::num(mean)),
                ("inflation", Json::num(inflation)),
                ("evictions", Json::num(evictions as f64)),
            ]);
        }
    }

    // bit-determinism of the faulted engine: repeat one harsh arm
    let a = run(StrategyKind::Precompute, 10_000.0, SEEDS[0]);
    let b = run(StrategyKind::Precompute, 10_000.0, SEEDS[0]);
    assert_eq!(a.events, b.events, "faulted repeat run diverged on event count");
    assert_eq!(a.evictions, b.evictions, "faulted repeat run diverged on evictions");
    assert_eq!(
        a.avg_completion_hours.to_bits(),
        b.avg_completion_hours.to_bits(),
        "faulted repeat run diverged on avg JCT bits"
    );

    // ---- part 2: recovery rework, whole-file vs store ----
    let specs = vec![paper_job(0, 0.0, 2.0), paper_job(1, 30.0, 2.0)];
    let seed = 42;
    let root = std::env::temp_dir().join(format!("rm-faultbench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let sched = scheduler_by_name("doubling")?;
    let whole_file = orchestrate(&live_cfg(None, seed), sched.as_ref(), &specs)?;
    let through_store = orchestrate(&live_cfg(Some(root.clone()), seed), sched.as_ref(), &specs)?;

    assert!(whole_file.total_failures() > 0, "fault storm injected no failures — part 2 vacuous");
    assert_eq!(whole_file.failed_jobs(), 0, "a job exhausted a 30-deep retry budget");
    assert_same_schedule(&whole_file, &through_store);
    let (file_bytes, store_bytes) =
        (whole_file.restart_ckpt_bytes(), through_store.restart_ckpt_bytes());
    assert!(file_bytes > 0 && store_bytes > 0, "no measured recovery traffic");
    // the acceptance bar: store recovery strictly reduces rework bytes
    // at an identical schedule
    assert!(
        store_bytes < file_bytes,
        "store recovery wrote {store_bytes} bytes vs whole-file {file_bytes}"
    );
    assert!(!root.exists(), "store not drained after the faulted run");

    bench.row(vec![
        ("strategy", Json::str("live/whole-file")),
        ("failures", Json::num(whole_file.total_failures() as f64)),
        ("restart_ckpt_bytes", Json::num(file_bytes as f64)),
    ]);
    bench.row(vec![
        ("strategy", Json::str("live/store")),
        ("failures", Json::num(through_store.total_failures() as f64)),
        ("restart_ckpt_bytes", Json::num(store_bytes as f64)),
    ]);

    print!("{}", table.render());
    table.write_csv("ablation_faults.csv")?;
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "FAULTS")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());
    println!(
        "\ninflation is mean avg JCT over the strategy's own fault-off floor; recovery\n\
         rework: whole-file {:.1} KiB vs store {:.1} KiB over {} failed segments\n\
         (a store retry re-commits its parked snapshot as a manifest, not a full image).",
        file_bytes as f64 / 1024.0,
        store_bytes as f64 / 1024.0,
        whole_file.total_failures(),
    );
    Ok(())
}
