//! Checkpoint-store microbenchmark at fleet scale: 1024 concurrent
//! jobs' snapshots through the content-addressed store vs the
//! whole-file `Checkpoint::save` path.
//!
//! Phases:
//!   cold      first save of every job (everything is new content)
//!   resave    unchanged content again (the width-only-rescale restart:
//!             the store commits only a manifest per job)
//!   delta     a localized 1/8th of each payload mutated, then saved
//!             (only dirtied chunks rewritten)
//!   load      restore every job (restart latency)
//!   drain     free every job; the store must GC to empty
//!
//! Each phase reports wall seconds and bytes written, alongside the
//! whole-file baseline doing the same work. The dedup claims are
//! asserted, not just printed.
//!
//! `cargo bench --bench bench_ckpt`

use std::time::Instant;

use ringmaster::jsonx::Json;
use ringmaster::metrics::{BenchJson, CsvTable};
use ringmaster::store::CkptStore;
use ringmaster::trainer::Checkpoint;

const JOBS: usize = 1024;
const N_PARAMS: usize = 4096; // 32 KiB payload per snapshot
const CHUNK_BYTES: usize = 4096; // 8 chunks per snapshot

/// Deterministic per-job checkpoint; `round > 0` perturbs the first
/// 1/8th of theta and of mu, so a delta save dirties 2 of the 8 chunks
/// (the head chunk of each half) and leaves the rest content-identical.
fn ck(job: usize, round: u32) -> Checkpoint {
    let base = |i: usize| ((job * 31 + i) % 997) as f32 * 0.125;
    let mut theta: Vec<f32> = (0..N_PARAMS).map(base).collect();
    let mut mu: Vec<f32> = theta.iter().map(|t| t * -0.5).collect();
    if round > 0 {
        for (i, t) in theta.iter_mut().take(N_PARAMS / 8).enumerate() {
            *t = round as f32 + i as f32 * 0.25;
        }
        for (i, m) in mu.iter_mut().take(N_PARAMS / 8).enumerate() {
            *m = (round as f32 + i as f32 * 0.25) * -0.5;
        }
    }
    Checkpoint {
        preset: "tiny".into(),
        step: round as u64,
        epochs: 0.5,
        workers: 2,
        lr: 0.25,
        theta,
        mu,
    }
}

fn key(job: usize) -> String {
    format!("job-{job}")
}

fn main() -> ringmaster::Result<()> {
    let root = std::env::temp_dir().join(format!("rm-bench-ckpt-{}", std::process::id()));
    let files = std::env::temp_dir().join(format!("rm-bench-ckpt-files-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let _ = std::fs::remove_dir_all(&files);
    std::fs::create_dir_all(&files)?;
    let store = CkptStore::open_with_chunk_bytes(&root, CHUNK_BYTES)?;

    let mut table = CsvTable::new(&[
        "phase", "store_s", "store_mb", "file_s", "file_mb", "store/file_bytes",
    ]);
    let mut bench = BenchJson::new("bench_ckpt");
    bench
        .meta("jobs", Json::num(JOBS as f64))
        .meta("n_params", Json::num(N_PARAMS as f64))
        .meta("chunk_bytes", Json::num(CHUNK_BYTES as f64));

    let mut emit = |table: &mut CsvTable,
                    bench: &mut BenchJson,
                    phase: &str,
                    store_s: f64,
                    store_b: u64,
                    file_s: f64,
                    file_b: u64| {
        let ratio = if file_b > 0 { store_b as f64 / file_b as f64 } else { f64::NAN };
        table.row(&[
            phase.to_string(),
            format!("{store_s:.3}"),
            format!("{:.2}", store_b as f64 / (1024.0 * 1024.0)),
            format!("{file_s:.3}"),
            format!("{:.2}", file_b as f64 / (1024.0 * 1024.0)),
            format!("{ratio:.3}"),
        ]);
        bench.row(vec![
            ("phase", Json::str(phase)),
            ("store_secs", Json::num(store_s)),
            ("store_bytes", Json::num(store_b as f64)),
            ("file_secs", Json::num(file_s)),
            ("file_bytes", Json::num(file_b as f64)),
        ]);
    };

    // --- cold: first save of 1024 jobs --------------------------------
    let snaps: Vec<Checkpoint> = (0..JOBS).map(|j| ck(j, 0)).collect();
    let t = Instant::now();
    let mut store_cold = 0u64;
    for (j, c) in snaps.iter().enumerate() {
        store_cold += store.save(&key(j), c)?.bytes_written;
    }
    let store_cold_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let mut file_cold = 0u64;
    for (j, c) in snaps.iter().enumerate() {
        file_cold += c.save(files.join(format!("{}.ckpt", key(j))))?;
    }
    let file_cold_s = t.elapsed().as_secs_f64();
    emit(&mut table, &mut bench, "cold", store_cold_s, store_cold, file_cold_s, file_cold);

    // --- resave: unchanged content (manifest-only commits) ------------
    let t = Instant::now();
    let mut store_resave = 0u64;
    let mut new_chunks = 0usize;
    for (j, c) in snaps.iter().enumerate() {
        let s = store.save(&key(j), c)?;
        store_resave += s.bytes_written;
        new_chunks += s.chunks_new;
    }
    let store_resave_s = t.elapsed().as_secs_f64();
    assert_eq!(new_chunks, 0, "resave of unchanged content rewrote chunks");
    assert!(
        store_resave * 10 < store_cold,
        "manifest-only resave wrote {store_resave} bytes vs cold {store_cold}"
    );

    let t = Instant::now();
    let mut file_resave = 0u64;
    for (j, c) in snaps.iter().enumerate() {
        file_resave += c.save(files.join(format!("{}.ckpt", key(j))))?;
    }
    let file_resave_s = t.elapsed().as_secs_f64();
    emit(&mut table, &mut bench, "resave", store_resave_s, store_resave, file_resave_s, file_resave);

    // --- delta: 1/8th of theta (and mirrored mu head) dirtied ---------
    let deltas: Vec<Checkpoint> = (0..JOBS).map(|j| ck(j, 1)).collect();
    let t = Instant::now();
    let mut store_delta = 0u64;
    for (j, c) in deltas.iter().enumerate() {
        store_delta += store.save(&key(j), c)?.bytes_written;
    }
    let store_delta_s = t.elapsed().as_secs_f64();
    assert!(
        store_delta < store_cold / 2,
        "localized delta rewrote {store_delta} of {store_cold} cold bytes"
    );

    let t = Instant::now();
    let mut file_delta = 0u64;
    for (j, c) in deltas.iter().enumerate() {
        file_delta += c.save(files.join(format!("{}.ckpt", key(j))))?;
    }
    let file_delta_s = t.elapsed().as_secs_f64();
    emit(&mut table, &mut bench, "delta", store_delta_s, store_delta, file_delta_s, file_delta);

    // --- load: restart latency for every job --------------------------
    let t = Instant::now();
    for (j, c) in deltas.iter().enumerate() {
        assert_eq!(&store.load(&key(j))?, c, "store load diverged for job {j}");
    }
    let store_load_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    for (j, c) in deltas.iter().enumerate() {
        assert_eq!(&Checkpoint::load(files.join(format!("{}.ckpt", key(j))))?, c);
    }
    let file_load_s = t.elapsed().as_secs_f64();
    emit(&mut table, &mut bench, "load", store_load_s, 0, file_load_s, 0);

    // --- drain: completed fleet must GC the store to nothing ----------
    let t = Instant::now();
    for j in 0..JOBS {
        store.free(&key(j))?;
    }
    let drain_s = t.elapsed().as_secs_f64();
    assert_eq!(store.snapshot_count(), 0);
    assert_eq!(store.chunk_count(), 0);
    assert!(store.remove_if_empty()?, "drained store should remove its root");
    emit(&mut table, &mut bench, "drain", drain_s, 0, 0.0, 0);

    let _ = std::fs::remove_dir_all(&files);

    print!("{}", table.render());
    table.write_csv("bench_ckpt.csv")?;
    let path = bench.save(env!("CARGO_MANIFEST_DIR"), "CKPT")?;
    println!("wrote {} ({} rows)", path.display(), bench.len());
    println!(
        "\nresave is the width-only-rescale restart: the store commits ~a manifest per job\n\
         where the whole-file path rewrites the full theta‖mu image; delta shows the cost\n\
         scaling with *changed* chunks, not payload size."
    );
    Ok(())
}
