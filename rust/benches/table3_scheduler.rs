//! Table 3 reproduction bench: 6 strategies x 3 contention regimes on
//! the 64-GPU simulated cluster, averaged over seeds, with paper values
//! side by side and wall-clock cost of the simulation itself.
//!
//! `cargo bench --bench table3_scheduler`

use ringmaster::metrics::CsvTable;
use ringmaster::sim::{simulate, Contention, SimConfig, StrategyKind, WorkloadGen};

const PAPER: [(&str, [f64; 3]); 6] = [
    ("precompute", [7.63, 2.63, 1.40]),
    ("exploratory", [20.42, 2.92, 1.47]),
    ("fixed-8", [22.76, 6.20, 1.40]),
    ("fixed-4", [12.90, 3.50, 2.21]),
    ("fixed-2", [11.49, 4.58, 3.78]),
    ("fixed-1", [10.10, 6.32, 6.37]),
];

fn main() -> ringmaster::Result<()> {
    let seeds = [42u64, 1337, 7, 99, 2024];
    let t0 = std::time::Instant::now();
    let mut sims = 0u64;

    let mut table = CsvTable::new(&[
        "strategy", "ext(ours)", "ext(paper)", "mod(ours)", "mod(paper)", "none(ours)", "none(paper)",
    ]);
    let mut ours = vec![vec![0.0f64; 3]; 6];
    for (row, s) in StrategyKind::table3_rows().into_iter().enumerate() {
        let mut cells = vec![s.name()];
        for (col, c) in Contention::all().into_iter().enumerate() {
            let mut sum = 0.0;
            for &seed in &seeds {
                let cfg = SimConfig::paper(s, c, seed);
                let jobs =
                    WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
                sum += simulate(&cfg, &jobs).avg_completion_hours;
                sims += 1;
            }
            let mean = sum / seeds.len() as f64;
            ours[row][col] = mean;
            cells.push(format!("{mean:.2}"));
            cells.push(format!("{:.2}", PAPER[row].1[col]));
        }
        table.row(&cells);
    }
    print!("{}", table.render());
    table.write_csv("table3_bench.csv")?;

    // shape assertions (who wins / direction of every §7 claim)
    let pre = 0usize;
    let eight = 2usize;
    let one = 5usize;
    assert!(ours[pre][1] * 1.25 < ours[eight][1], "precompute should halve-ish fixed-8 at moderate");
    assert!(ours[eight][0] > ours[one][0], "fixed-8 should be worse than fixed-1 at extreme");
    assert!(ours[one][2] > 3.0 * ours[eight][2], "fixed-1 should be worst with no contention");
    for col in 0..3 {
        for row in 0..6 {
            assert!(
                ours[pre][col] <= ours[row][col] * 1.05,
                "precompute must win/tie: col {col} row {row}"
            );
        }
    }
    println!("\nall §7 shape claims hold across {} simulations", sims);

    // ---- restart-cost sensitivity (the §6 feasibility argument) ---------
    // Dynamic scheduling is only viable because stop/restart is ~10 s. If
    // it cost minutes, rescaling would burn the gains: sweep it.
    println!("\nrestart-cost sensitivity (precompute, moderate contention, seed 42):");
    println!("  restart_s  avg_hours  rescales");
    for restart in [0.0f64, 10.0, 60.0, 300.0, 1800.0] {
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 42);
        cfg.restart_cost = restart;
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 42);
        let r = simulate(&cfg, &jobs);
        println!("  {restart:>9.0}  {:>9.2}  {:>8}", r.avg_completion_hours, r.total_rescales);
    }
    let cheap = {
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 42);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 42);
        simulate(&cfg, &jobs).avg_completion_hours
    };
    let dear = {
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 42);
        cfg.restart_cost = 1800.0;
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 42);
        simulate(&cfg, &jobs).avg_completion_hours
    };
    println!(
        "  -> 30-min restarts cost {:+.0}% avg completion: cheap stop/restart (§6) is what makes \
         dynamic scheduling pay.",
        100.0 * (dear - cheap) / cheap
    );
    println!(
        "simulation throughput: {} sims in {:.2}s ({:.0} jobs/s scheduled)",
        sims,
        t0.elapsed().as_secs_f64(),
        sims as f64 * 120.0 / t0.elapsed().as_secs_f64()
    );
    Ok(())
}
