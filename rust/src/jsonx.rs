//! Minimal JSON parser + writer (substrate).
//!
//! The offline vendor snapshot carries no serde, so the crate parses the
//! AOT `manifest.json`, run configs, and checkpoint metadata with its own
//! ~recursive-descent parser. Supports the full JSON grammar except
//! `\uXXXX` surrogate pairs beyond the BMP (not needed — all our
//! documents are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        anyhow::ensure!(f >= 0.0 && f.fract() == 0.0, "expected usize, got {f}");
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => anyhow::bail!("expected object, got {other:?}"),
        }
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ---- serialization ---------------------------------------------------
    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        anyhow::ensure!(
            self.peek() == Some(c),
            "expected {:?} at byte {}, found {:?}",
            c as char,
            self.pos,
            self.peek().map(|b| b as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(val)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            anyhow::ensure!(self.pos + 4 <= self.bytes.len(), "short \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| anyhow::anyhow!("bad \\u escape: {e}"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint {code:#x}"))?,
                            );
                        }
                        other => anyhow::bail!("unknown escape \\{}", other as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    anyhow::ensure!(start + len <= self.bytes.len(), "truncated UTF-8");
                    out.push_str(std::str::from_utf8(&self.bytes[start..start + len])?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected , or ] at byte {}, got {:?}", self.pos, other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected , or }} at byte {}, got {:?}", self.pos, other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, {"b": "c"}, null], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v, Json::Str("a\nb\t\"q\"A".into()));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse(r#""héllo — ✓""#).unwrap();
        assert_eq!(v, Json::Str("héllo — ✓".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("tru").is_err());
    }

    #[test]
    fn round_trips_compact() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":-7}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn round_trips_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("tiny")),
            ("n", Json::num(117376.0)),
            ("arr", Json::arr(vec![Json::num(1.0), Json::num(2.0)])),
        ]);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_decimal() {
        assert_eq!(Json::num(5.0).dump(), "5");
        assert_eq!(Json::num(5.25).dump(), "5.25");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "presets": {
            "tiny": {
              "n_params": 117376,
              "entries": {"train_step": {"file": "train_step_tiny.hlo.txt", "outputs": ["loss", "grad"]}},
              "param_layout": [{"name": "tok_embed", "shape": [256, 64], "offset": 0}]
            }
          }
        }"#;
        let v = parse(doc).unwrap();
        let tiny = v.get("presets").unwrap().get("tiny").unwrap();
        assert_eq!(tiny.get("n_params").unwrap().as_usize().unwrap(), 117376);
        let layout = tiny.get("param_layout").unwrap().as_arr().unwrap();
        assert_eq!(layout[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
    }
}
