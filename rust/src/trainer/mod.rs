//! Data-parallel training runtime: the Horovod analogue of this repo.
//!
//! `w` worker threads each own a full [`Engine`] (execution backend +
//! preset — the PJRT client is `!Send`, so engines never cross threads),
//! train on disjoint shards of the synthetic corpus, and exchange
//! gradients through the rust [`collectives`](crate::collectives)
//! ring/dh/bb all-reduce — python is nowhere on this path. Every worker
//! applies the identical averaged update, so parameters stay
//! bit-identical across ranks (asserted in tests) and rank 0's state is
//! the checkpoint.
//!
//! Rescaling (§6): the coordinator trains in segments — each [`train`]
//! call runs `run_steps` steps from a [`Checkpoint`] and returns a new
//! one. Restarting with a different `w` applies eq 7 LR scaling through
//! the [`lr::LrSchedule`] (base·w) and pays the client+compile startup
//! cost, which [`TrainReport::startup_secs`] measures — the stop/restart
//! overhead of Table 2.

pub mod checkpoint;
pub mod lr;

pub use checkpoint::Checkpoint;
pub use lr::LrSchedule;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use crate::collectives::{self, Algorithm, World};
use crate::data::Corpus;
use crate::runtime::{Artifacts, Engine};
use crate::Result;

/// Configuration of one training job.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifacts_dir: PathBuf,
    pub preset: String,
    /// Data-parallel worker count (the `w` the scheduler assigns).
    pub workers: usize,
    pub momentum: f32,
    pub schedule: LrSchedule,
    /// Windows per epoch — defines the epoch length (CIFAR-10: 50k).
    pub dataset_examples: usize,
    /// Bigram-noise of the synthetic corpus (controls the loss floor).
    pub corpus_noise: f64,
    pub seed: u64,
    /// Record a loss sample every this many steps.
    pub log_every: u64,
    /// Force an all-reduce algorithm (None = §2.1 auto policy).
    pub algorithm: Option<Algorithm>,
    /// Use the shared-memory transport instead of the message-passing
    /// algorithms on the gradient hot path (§Perf; traffic counters then
    /// read zero since nothing crosses the "wire").
    pub shared_mem: bool,
    /// Mid-segment preemption: when set, every rank polls this flag at
    /// the top of each step and the world agrees on stopping via a
    /// one-word all-reduce (all ranks must break at the same step or the
    /// gradient all-reduce deadlocks). `None` (the default) keeps the
    /// loop bit-identical to the pre-flag trainer.
    pub stop_flag: Option<Arc<AtomicBool>>,
}

impl TrainConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, preset: &str, workers: usize) -> Self {
        TrainConfig {
            artifacts_dir: artifacts_dir.into(),
            preset: preset.to_string(),
            workers,
            momentum: 0.9,
            schedule: LrSchedule { base: 0.05, decay_epochs: vec![100.0, 150.0], decay_factor: 10.0 },
            dataset_examples: 2048,
            corpus_noise: 0.08,
            seed: 42,
            log_every: 5,
            algorithm: None,
            shared_mem: false,
            stop_flag: None,
        }
    }
}

/// One logged loss sample.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: u64,
    pub epoch: f64,
    /// Cross-worker mean loss.
    pub loss: f32,
    /// Wall seconds of this step (rank 0).
    pub secs: f64,
}

/// Measurements of one training segment.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub logs: Vec<StepLog>,
    /// Steps actually executed — less than requested when a
    /// [`TrainConfig::stop_flag`] preempted the segment early.
    pub steps: u64,
    pub epochs_done: f64,
    /// Wall time of the training loop (excluding startup).
    pub wall_secs: f64,
    /// Client + compile time, max across workers — the restart cost.
    pub startup_secs: f64,
    pub steps_per_sec: f64,
    pub tokens_per_sec: f64,
    /// All-reduce traffic across the segment (world totals).
    pub allreduce_msgs: u64,
    pub allreduce_bytes: u64,
    pub algorithm: &'static str,
    /// Execution-backend label (`Engine::platform`), so reports always
    /// say which engine produced the numbers (reference vs pjrt).
    pub backend: String,
    /// Mean per-step phase times on rank 0 (Table 1 decomposition).
    pub mean_step_secs: f64,
    pub mean_allreduce_secs: f64,
}

/// Train up to `run_steps` steps at `cfg.workers` workers, resuming
/// from `resume` if given (the checkpoint may come from a different
/// worker count — that's the rescale path). A set
/// [`TrainConfig::stop_flag`] ends the segment at the next step
/// boundary, all ranks agreeing via consensus. Returns rank 0's final
/// state.
pub fn train(cfg: &TrainConfig, resume: Option<Checkpoint>, run_steps: u64) -> Result<(Checkpoint, TrainReport)> {
    anyhow::ensure!(cfg.workers >= 1, "need >= 1 worker");
    let w = cfg.workers;

    // Resolve the initial state once (rank 0 semantics), clone per worker.
    let (start_step, start_epochs, theta0, mu0) = match resume {
        Some(ck) => {
            anyhow::ensure!(
                ck.preset == cfg.preset,
                "checkpoint preset {:?} != config preset {:?}",
                ck.preset,
                cfg.preset
            );
            (ck.step, ck.epochs, Some(ck.theta), Some(ck.mu))
        }
        None => (0, 0.0, None, None),
    };

    let mut world = World::new(w);
    let traffic = world.traffic();
    let corpus = Corpus::new(
        preset_vocab(cfg)?,
        cfg.corpus_noise,
        cfg.seed,
    );

    let shmem_world = crate::collectives::shmem::ShmemWorld::new(w);
    let (log_tx, log_rx) = channel::<StepLog>();
    let handles: Vec<_> = world
        .take_ranks()
        .into_iter()
        .map(|mut rank| {
            let cfg = cfg.clone();
            let corpus = corpus.clone();
            let theta0 = theta0.clone();
            let mu0 = mu0.clone();
            let log_tx = log_tx.clone();
            let shmem = shmem_world.rank(rank.rank());
            std::thread::spawn(move || -> Result<WorkerOut> {
                let startup_t = Instant::now();
                let artifacts = Artifacts::resolve(&cfg.artifacts_dir)?;
                let engine = Engine::load(&artifacts, &cfg.preset)?;
                // compile only what the training path needs — this is the
                // dominant share of the stop/restart cost (§6)
                engine.warmup(theta0.is_none())?;
                let preset = engine.preset().clone();
                let backend = engine.platform();
                let alg = cfg
                    .algorithm
                    .unwrap_or_else(|| collectives::select_algorithm(w, preset.n_params));
                let startup_secs = startup_t.elapsed().as_secs_f64();

                let mut theta = match &theta0 {
                    Some(t) => t.clone(),
                    None => engine.init(cfg.seed)?,
                };
                let mut mu = match &mu0 {
                    Some(m) => m.clone(),
                    None => vec![0.0; theta.len()],
                };

                let epochs_per_step = (preset.batch * w) as f64 / cfg.dataset_examples as f64;
                let mut epoch = start_epochs;
                let mut step_time_sum = 0.0;
                let mut ar_time_sum = 0.0;
                let mut steps_run = 0u64;
                let loop_t = Instant::now();

                for s in start_step..start_step + run_steps {
                    // Mid-segment preemption (ROADMAP): the orchestrator
                    // flips the shared flag and every rank sees a stop
                    // request — but ranks may read it at different
                    // moments, so the *decision* is a one-word all-reduce
                    // (identical mean on every rank = identical verdict).
                    if let Some(flag) = &cfg.stop_flag {
                        let mut vote = [if flag.load(Ordering::Relaxed) { 1.0f32 } else { 0.0 }];
                        if cfg.shared_mem {
                            shmem.all_reduce_mean(&mut vote);
                        } else {
                            collectives::all_reduce_mean(alg, &mut rank, &mut vote)?;
                        }
                        if vote[0] > 0.0 {
                            break;
                        }
                    }
                    let step_t = Instant::now();
                    let (inputs, targets) =
                        corpus.batch(rank.rank(), s, preset.batch, preset.seq_len);
                    let (loss, mut grad) = engine.train_step(&theta, &inputs, &targets)?;

                    let ar_t = Instant::now();
                    let mut loss_buf = [loss];
                    if cfg.shared_mem {
                        shmem.all_reduce_mean(&mut grad);
                        shmem.all_reduce_mean(&mut loss_buf);
                    } else {
                        collectives::all_reduce_mean(alg, &mut rank, &mut grad)?;
                        collectives::all_reduce_mean(alg, &mut rank, &mut loss_buf)?;
                    }
                    let ar_secs = ar_t.elapsed().as_secs_f64();

                    let lr = cfg.schedule.lr(w, epoch);
                    let (t2, m2) = engine.sgd_update(&theta, &grad, &mu, lr, cfg.momentum)?;
                    theta = t2;
                    mu = m2;
                    epoch += epochs_per_step;
                    steps_run += 1;

                    if rank.rank() == 0 {
                        let secs = step_t.elapsed().as_secs_f64();
                        step_time_sum += secs;
                        ar_time_sum += ar_secs;
                        if s % cfg.log_every == 0 || s + 1 == start_step + run_steps {
                            let _ = log_tx.send(StepLog { step: s, epoch, loss: loss_buf[0], secs });
                        }
                    }
                }

                Ok(WorkerOut {
                    rank: rank.rank(),
                    theta,
                    mu,
                    epoch,
                    steps_run,
                    startup_secs,
                    loop_secs: loop_t.elapsed().as_secs_f64(),
                    step_time_sum,
                    ar_time_sum,
                    algorithm: alg.name(),
                    backend,
                })
            })
        })
        .collect();
    drop(log_tx);

    let mut logs: Vec<StepLog> = log_rx.iter().collect();
    logs.sort_by_key(|l| l.step);

    let mut outs = Vec::with_capacity(w);
    for h in handles {
        outs.push(h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??);
    }
    outs.sort_by_key(|o| o.rank);
    let rank0 = &outs[0];

    // data-parallel invariant: all ranks hold identical parameters and
    // agreed on the same stop step (the consensus vote guarantees it)
    for o in &outs[1..] {
        anyhow::ensure!(
            o.theta == rank0.theta,
            "rank {} diverged from rank 0 — all-reduce broke determinism",
            o.rank
        );
        anyhow::ensure!(
            o.steps_run == rank0.steps_run,
            "rank {} stopped at step {} but rank 0 at {} — stop consensus broke",
            o.rank,
            o.steps_run,
            rank0.steps_run
        );
    }

    let steps_run = rank0.steps_run;
    let end_step = start_step + steps_run;
    let preset_tokens = {
        let artifacts = Artifacts::resolve(&cfg.artifacts_dir)?;
        artifacts.preset(&cfg.preset)?.tokens_per_step
    };
    let wall = rank0.loop_secs;
    let report = TrainReport {
        logs,
        steps: steps_run,
        epochs_done: rank0.epoch,
        wall_secs: wall,
        startup_secs: outs.iter().map(|o| o.startup_secs).fold(0.0, f64::max),
        steps_per_sec: steps_run as f64 / wall.max(1e-9),
        tokens_per_sec: (steps_run as usize * preset_tokens * w) as f64 / wall.max(1e-9),
        allreduce_msgs: traffic.messages(),
        allreduce_bytes: traffic.bytes(),
        algorithm: rank0.algorithm,
        backend: rank0.backend.clone(),
        mean_step_secs: rank0.step_time_sum / steps_run.max(1) as f64,
        mean_allreduce_secs: rank0.ar_time_sum / steps_run.max(1) as f64,
    };

    let lr_now = cfg.schedule.lr(w, rank0.epoch);
    let ck = Checkpoint {
        preset: cfg.preset.clone(),
        step: end_step,
        epochs: rank0.epoch,
        workers: w,
        lr: lr_now,
        theta: rank0.theta.clone(),
        mu: rank0.mu.clone(),
    };
    Ok((ck, report))
}

struct WorkerOut {
    rank: usize,
    theta: Vec<f32>,
    mu: Vec<f32>,
    epoch: f64,
    steps_run: u64,
    startup_secs: f64,
    loop_secs: f64,
    step_time_sum: f64,
    ar_time_sum: f64,
    algorithm: &'static str,
    backend: String,
}

fn preset_vocab(cfg: &TrainConfig) -> Result<usize> {
    let artifacts = Artifacts::resolve(&cfg.artifacts_dir)?;
    Ok(artifacts.preset(&cfg.preset)?.vocab)
}
