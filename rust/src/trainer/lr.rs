//! Learning-rate policy — eq 7 plus the paper's step decay (§5).
//!
//! The paper keeps per-GPU minibatch constant (128) so global batch grows
//! with the worker count, and scales LR linearly with it (Goyal et al.'s
//! rule, eq 7): `lr_new = (#GPUs_new / #GPUs_last) * lr_last`. With a
//! per-1-worker base LR this is simply `lr(w) = base * w`. Decay divides
//! by `factor` at fixed epoch marks (paper: /10 at epochs 100 and 150).

/// LR schedule parameters.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    /// LR at one worker (paper: 0.1 for batch 128).
    pub base: f32,
    /// Epochs at which LR is divided by `factor` (paper: [100, 150]).
    pub decay_epochs: Vec<f64>,
    /// Division factor at each mark (paper: 10).
    pub decay_factor: f32,
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule { base: 0.1, decay_epochs: vec![100.0, 150.0], decay_factor: 10.0 }
    }
}

impl LrSchedule {
    /// Effective LR at `w` workers and training progress `epoch`.
    pub fn lr(&self, workers: usize, epoch: f64) -> f32 {
        let passed = self.decay_epochs.iter().filter(|&&e| epoch >= e).count() as i32;
        self.base * workers as f32 / self.decay_factor.powi(passed)
    }
}

/// Eq 7 verbatim: rescale an LR across a worker-count change.
pub fn rescale_lr(lr_last: f32, w_last: usize, w_new: usize) -> f32 {
    lr_last * w_new as f32 / w_last as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values() {
        // §5: "initial learning rates for 4 GPUs as 0.4 and 8 GPUs as 0.8"
        let s = LrSchedule::default();
        assert!((s.lr(1, 0.0) - 0.1).abs() < 1e-7);
        assert!((s.lr(4, 0.0) - 0.4).abs() < 1e-7);
        assert!((s.lr(8, 0.0) - 0.8).abs() < 1e-7);
    }

    #[test]
    fn decays_at_marks() {
        let s = LrSchedule::default();
        assert!((s.lr(1, 99.9) - 0.1).abs() < 1e-7);
        assert!((s.lr(1, 100.0) - 0.01).abs() < 1e-8);
        assert!((s.lr(1, 150.0) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn eq7_consistency_with_schedule() {
        // schedule lr at w=8 == eq 7 rescale of schedule lr at w=4
        let s = LrSchedule::default();
        let via_eq7 = rescale_lr(s.lr(4, 51.0), 4, 8);
        assert!((s.lr(8, 51.0) - via_eq7).abs() < 1e-7);
    }

    #[test]
    fn eq7_doubles_on_4_to_8() {
        assert!((rescale_lr(0.4, 4, 8) - 0.8).abs() < 1e-7);
        assert!((rescale_lr(0.8, 8, 4) - 0.4).abs() < 1e-7);
    }
}
