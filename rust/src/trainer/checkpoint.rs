//! Checkpoint format: the stop/restart substrate (§6 of the paper).
//!
//! One file: `RMCK` magic + version, a JSON metadata header, then raw
//! little-endian f32 payloads for theta and the momentum buffer. Save +
//! load must be fast — the paper's whole argument rests on stop/restart
//! being ~10 s; ours is dominated by PJRT recompilation, not this I/O.
//!
//! Durability goes through [`crate::fsx::atomic_write`]: tmp + fsync +
//! rename + parent-dir fsync, with tmp cleanup on failure. The
//! content-addressed store (`crate::store`) persists the same logical
//! checkpoint as chunked payload + manifest instead of this single file;
//! [`Checkpoint::payload_bytes`] is the shared payload encoding.

use std::path::Path;

use crate::jsonx::Json;
use crate::Result;

const MAGIC: &[u8; 4] = b"RMCK";
const VERSION: u32 = 1;

/// Everything needed to resume a job, possibly at a different scale.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub preset: String,
    /// Global steps completed so far.
    pub step: u64,
    /// Epochs completed so far (batch·w aware).
    pub epochs: f64,
    /// Worker count the checkpoint was written at (eq 7 input).
    pub workers: usize,
    /// Effective LR at save time (eq 7 input).
    pub lr: f32,
    pub theta: Vec<f32>,
    pub mu: Vec<f32>,
}

impl Checkpoint {
    /// The raw parameter payload: theta then mu, little-endian f32.
    /// This is both the tail of the single-file format and the byte
    /// stream the content-addressed store chunks and hashes.
    pub fn payload_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity((self.theta.len() + self.mu.len()) * 4);
        for v in self.theta.iter().chain(self.mu.iter()) {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        payload
    }

    /// Rebuild theta/mu from a payload produced by [`payload_bytes`],
    /// checking the length against `n_params` exactly.
    pub fn split_payload(payload: &[u8], n_params: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let want = n_params
            .checked_mul(8)
            .ok_or_else(|| anyhow::anyhow!("n_params {n_params} overflows payload size"))?;
        anyhow::ensure!(
            payload.len() == want,
            "checkpoint payload is {} bytes but n_params={} implies {} (truncated or mismatched metadata)",
            payload.len(),
            n_params,
            want
        );
        let mut floats = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        let theta: Vec<f32> = floats.by_ref().take(n_params).collect();
        let mu: Vec<f32> = floats.collect();
        Ok((theta, mu))
    }

    /// JSON metadata header shared by the file format and the store's
    /// snapshot manifests.
    pub fn meta_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("step", Json::num(self.step as f64)),
            ("epochs", Json::num(self.epochs)),
            ("workers", Json::num(self.workers as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("n_params", Json::num(self.theta.len() as f64)),
        ])
    }

    /// Rebuild the metadata fields (everything but theta/mu) from a
    /// header produced by [`meta_json`].
    pub fn from_meta_json(meta: &Json, theta: Vec<f32>, mu: Vec<f32>) -> Result<Checkpoint> {
        Ok(Checkpoint {
            preset: meta.get("preset")?.as_str()?.to_string(),
            step: meta.get("step")?.as_f64()? as u64,
            epochs: meta.get("epochs")?.as_f64()?,
            workers: meta.get("workers")?.as_usize()?,
            lr: meta.get("lr")?.as_f64()? as f32,
            theta,
            mu,
        })
    }

    /// The complete single-file image (magic + version + meta + payload).
    pub fn encode(&self) -> Vec<u8> {
        let meta = self.meta_json().dump();
        let payload = self.payload_bytes();
        let mut out = Vec::with_capacity(12 + meta.len() + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        out.extend_from_slice(meta.as_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Atomic, durable save via [`crate::fsx::atomic_write`]: the image
    /// is written to a sibling `.tmp`, flushed + fsynced, renamed over
    /// `path`, and the parent directory is fsynced so the rename itself
    /// survives a crash. A preemption mid-save can never leave a torn
    /// checkpoint at `path` — either the previous complete checkpoint
    /// survives or the new one does — and a failed rename removes the
    /// tmp sibling instead of leaking it. (The orchestrator preempts
    /// jobs exactly around this call.) Returns bytes written.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<u64> {
        crate::fsx::atomic_write(path, &self.encode())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {}: {e}", path.display()))?;
        Self::decode(&bytes)
    }

    /// Parse a full file image, rejecting truncation, trailing garbage,
    /// and metadata that disagrees with the payload length.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        anyhow::ensure!(bytes.len() >= 12, "truncated checkpoint: {} byte header", bytes.len());
        anyhow::ensure!(&bytes[0..4] == MAGIC, "not a ringmaster checkpoint");
        let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let meta_len = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
        anyhow::ensure!(
            bytes.len() - 12 >= meta_len,
            "truncated checkpoint: metadata header claims {meta_len} bytes, {} available",
            bytes.len() - 12
        );
        let meta_bytes = &bytes[12..12 + meta_len];
        let meta = crate::jsonx::parse(std::str::from_utf8(meta_bytes)?)?;
        let n = meta.get("n_params")?.as_usize()?;
        // exact-length check: errors on a truncated payload AND on
        // trailing garbage / an n_params that disagrees with the file
        let (theta, mu) = Self::split_payload(&bytes[12 + meta_len..], n)?;
        Self::from_meta_json(&meta, theta, mu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            preset: "tiny".into(),
            step: 5000,
            epochs: 51.2,
            workers: 4,
            lr: 0.4,
            theta: (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect(),
            mu: (0..1000).map(|i| -(i as f32) * 0.25).collect(),
        }
    }

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rmck-test-{tag}-{}.ckpt", std::process::id()))
    }

    #[test]
    fn round_trips_exactly() {
        let p = tmpfile("rt");
        let ck = sample();
        let bytes = ck.save(&p).unwrap();
        assert_eq!(bytes, std::fs::metadata(&p).unwrap().len());
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmpfile("bad");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_future_version() {
        let mut img = sample().encode();
        img[4..8].copy_from_slice(&2u32.to_le_bytes());
        let err = Checkpoint::decode(&img).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version 2"), "{err}");
    }

    #[test]
    fn rejects_truncated_payload() {
        let img = sample().encode();
        // chop mid-payload and mid-header
        for cut in [img.len() - 1, img.len() - 123, img.len() / 2, 13, 11, 3] {
            let err = Checkpoint::decode(&img[..cut]);
            assert!(err.is_err(), "accepted a {cut}-byte prefix of a {}-byte file", img.len());
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut img = sample().encode();
        img.extend_from_slice(&[0u8; 16]);
        let err = Checkpoint::decode(&img).unwrap_err().to_string();
        assert!(err.contains("payload"), "{err}");
    }

    #[test]
    fn rejects_n_params_mismatch() {
        // metadata says more params than the payload holds: rebuild the
        // image with a lying n_params over the real 1000-float payload
        let ck = sample();
        let meta = Json::obj(vec![
            ("preset", Json::str("tiny")),
            ("step", Json::num(5000.0)),
            ("epochs", Json::num(51.2)),
            ("workers", Json::num(4.0)),
            ("lr", Json::num(0.4)),
            ("n_params", Json::num(2000.0)),
        ])
        .dump();
        let payload = ck.payload_bytes();
        let mut img = Vec::new();
        img.extend_from_slice(MAGIC);
        img.extend_from_slice(&VERSION.to_le_bytes());
        img.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        img.extend_from_slice(meta.as_bytes());
        img.extend_from_slice(&payload);
        let err = Checkpoint::decode(&img).unwrap_err().to_string();
        assert!(err.contains("n_params=2000"), "{err}");
    }

    #[test]
    fn rejects_meta_len_past_eof() {
        let mut img = sample().encode();
        let huge = (img.len() as u32) * 4;
        img[8..12].copy_from_slice(&huge.to_le_bytes());
        let err = Checkpoint::decode(&img).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn save_load_is_fast() {
        // the §6 argument: checkpoint I/O is negligible. 1M params round
        // trip must be well under a second on any disk.
        let p = tmpfile("fast");
        let mut ck = sample();
        ck.theta = vec![0.5; 1_000_000];
        ck.mu = vec![0.25; 1_000_000];
        let t0 = std::time::Instant::now();
        ck.save(&p).unwrap();
        let _ = Checkpoint::load(&p).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 1.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn save_is_atomic_leaves_no_tmp_and_survives_overwrite() {
        let p = tmpfile("atomic");
        let first = sample();
        first.save(&p).unwrap();
        // no temp residue after a successful save
        let tmp = p.with_file_name(format!(
            "{}.tmp",
            p.file_name().unwrap().to_string_lossy()
        ));
        assert!(!tmp.exists(), "tmp file left behind");
        // overwriting an existing checkpoint goes through the same
        // rename, so the destination is never a partial file
        let mut second = sample();
        second.step = 9999;
        second.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().step, 9999);
        // a stale/garbage .tmp from a torn earlier save must not break
        // either saving or loading the real path
        std::fs::write(&tmp, b"torn partial write").unwrap();
        first.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), first);
        assert!(!tmp.exists(), "save must clobber the stale tmp");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn failed_rename_cleans_tmp_and_preserves_target() {
        // a directory at the checkpoint path makes the rename fail after
        // the tmp write succeeded — the tmp must not leak
        let p = tmpfile("rename-fail");
        std::fs::create_dir_all(&p).unwrap();
        assert!(sample().save(&p).is_err());
        let tmp = p.with_file_name(format!(
            "{}.tmp",
            p.file_name().unwrap().to_string_lossy()
        ));
        assert!(!tmp.exists(), "failed rename leaked the tmp sibling");
        assert!(p.is_dir(), "failed save must not disturb the target");
        let _ = std::fs::remove_dir(&p);
    }

    #[test]
    fn save_rejects_pathless_target() {
        // a bare root (no file name) cannot be renamed into
        assert!(sample().save("/").is_err());
    }

    #[test]
    fn preserves_rescale_inputs() {
        let p = tmpfile("meta");
        sample().save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        // the two fields eq 7 needs at restart:
        assert_eq!(back.workers, 4);
        assert!((back.lr - 0.4).abs() < 1e-7);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn payload_bytes_round_trips_through_split() {
        let ck = sample();
        let payload = ck.payload_bytes();
        let (theta, mu) = Checkpoint::split_payload(&payload, ck.theta.len()).unwrap();
        assert_eq!(theta, ck.theta);
        assert_eq!(mu, ck.mu);
        assert!(Checkpoint::split_payload(&payload[..payload.len() - 4], ck.theta.len()).is_err());
    }
}
