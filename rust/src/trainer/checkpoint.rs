//! Checkpoint format: the stop/restart substrate (§6 of the paper).
//!
//! One file: `RMCK` magic + version, a JSON metadata header, then raw
//! little-endian f32 payloads for theta and the momentum buffer. Save +
//! load must be fast — the paper's whole argument rests on stop/restart
//! being ~10 s; ours is dominated by PJRT recompilation, not this I/O.

use std::io::{Read, Write};
use std::path::Path;

use crate::jsonx::Json;
use crate::Result;

const MAGIC: &[u8; 4] = b"RMCK";
const VERSION: u32 = 1;

/// Everything needed to resume a job, possibly at a different scale.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub preset: String,
    /// Global steps completed so far.
    pub step: u64,
    /// Epochs completed so far (batch·w aware).
    pub epochs: f64,
    /// Worker count the checkpoint was written at (eq 7 input).
    pub workers: usize,
    /// Effective LR at save time (eq 7 input).
    pub lr: f32,
    pub theta: Vec<f32>,
    pub mu: Vec<f32>,
}

impl Checkpoint {
    /// Atomic save: the payload is written to a sibling `.tmp` file and
    /// renamed over `path` only after a successful flush+fsync, so a
    /// preemption mid-save can never leave a torn checkpoint at `path` —
    /// either the previous complete checkpoint survives or the new one
    /// does. (The orchestrator preempts jobs exactly around this call.)
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let file_name = path
            .file_name()
            .ok_or_else(|| anyhow::anyhow!("checkpoint path {} has no file name", path.display()))?;
        let mut tmp_name = file_name.to_os_string();
        tmp_name.push(".tmp");
        let tmp = path.with_file_name(tmp_name);

        let write = || -> Result<()> {
            let meta = Json::obj(vec![
                ("preset", Json::str(self.preset.clone())),
                ("step", Json::num(self.step as f64)),
                ("epochs", Json::num(self.epochs)),
                ("workers", Json::num(self.workers as f64)),
                ("lr", Json::num(self.lr as f64)),
                ("n_params", Json::num(self.theta.len() as f64)),
            ])
            .dump();
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&(meta.len() as u32).to_le_bytes())?;
            f.write_all(meta.as_bytes())?;
            for v in self.theta.iter().chain(self.mu.iter()) {
                f.write_all(&v.to_le_bytes())?;
            }
            f.flush()?;
            f.get_ref().sync_all()?;
            Ok(())
        };
        if let Err(e) = write() {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a ringmaster checkpoint");
        let mut word = [0u8; 4];
        f.read_exact(&mut word)?;
        let version = u32::from_le_bytes(word);
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        f.read_exact(&mut word)?;
        let meta_len = u32::from_le_bytes(word) as usize;
        let mut meta_bytes = vec![0u8; meta_len];
        f.read_exact(&mut meta_bytes)?;
        let meta = crate::jsonx::parse(std::str::from_utf8(&meta_bytes)?)?;

        let n = meta.get("n_params")?.as_usize()?;
        let mut payload = vec![0u8; n * 4 * 2];
        f.read_exact(&mut payload)?;
        let mut floats = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        let theta: Vec<f32> = floats.by_ref().take(n).collect();
        let mu: Vec<f32> = floats.collect();

        Ok(Checkpoint {
            preset: meta.get("preset")?.as_str()?.to_string(),
            step: meta.get("step")?.as_f64()? as u64,
            epochs: meta.get("epochs")?.as_f64()?,
            workers: meta.get("workers")?.as_usize()?,
            lr: meta.get("lr")?.as_f64()? as f32,
            theta,
            mu,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            preset: "tiny".into(),
            step: 5000,
            epochs: 51.2,
            workers: 4,
            lr: 0.4,
            theta: (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect(),
            mu: (0..1000).map(|i| -(i as f32) * 0.25).collect(),
        }
    }

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rmck-test-{tag}-{}.ckpt", std::process::id()))
    }

    #[test]
    fn round_trips_exactly() {
        let p = tmpfile("rt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmpfile("bad");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn save_load_is_fast() {
        // the §6 argument: checkpoint I/O is negligible. 1M params round
        // trip must be well under a second on any disk.
        let p = tmpfile("fast");
        let mut ck = sample();
        ck.theta = vec![0.5; 1_000_000];
        ck.mu = vec![0.25; 1_000_000];
        let t0 = std::time::Instant::now();
        ck.save(&p).unwrap();
        let _ = Checkpoint::load(&p).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 1.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn save_is_atomic_leaves_no_tmp_and_survives_overwrite() {
        let p = tmpfile("atomic");
        let first = sample();
        first.save(&p).unwrap();
        // no temp residue after a successful save
        let tmp = p.with_file_name(format!(
            "{}.tmp",
            p.file_name().unwrap().to_string_lossy()
        ));
        assert!(!tmp.exists(), "tmp file left behind");
        // overwriting an existing checkpoint goes through the same
        // rename, so the destination is never a partial file
        let mut second = sample();
        second.step = 9999;
        second.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().step, 9999);
        // a stale/garbage .tmp from a torn earlier save must not break
        // either saving or loading the real path
        std::fs::write(&tmp, b"torn partial write").unwrap();
        first.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), first);
        assert!(!tmp.exists(), "save must clobber the stale tmp");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn save_rejects_pathless_target() {
        // a bare root (no file name) cannot be renamed into
        assert!(sample().save("/").is_err());
    }

    #[test]
    fn preserves_rescale_inputs() {
        let p = tmpfile("meta");
        sample().save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        // the two fields eq 7 needs at restart:
        assert_eq!(back.workers, 4);
        assert!((back.lr - 0.4).abs() < 1e-7);
        let _ = std::fs::remove_file(&p);
    }
}
