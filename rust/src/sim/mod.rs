//! Scheduler simulation — §7 / Table 3 of the paper.
//!
//! A discrete-event simulator of a shared GPU cluster: jobs arrive as a
//! Poisson process (exponential inter-arrival, mean 250/500/1000 s for
//! extreme/moderate/no contention), each with a hidden *true* speed
//! profile ([`workload`]) calibrated from the paper's Table 1/2 numbers.
//! Six strategies are simulated:
//!
//! - **precompute** — eq-5/eq-1 models known at arrival; doubling
//!   heuristic reallocation at every event.
//! - **exploratory** — each new job first holds 8 GPUs for 10 minutes,
//!   running 2.5 min at each of 1/2/4/8 workers to collect `(w, f(w))`
//!   samples, then joins the adaptive pool.
//! - **fixed-1/2/4/8** — every job requests that many GPUs, FIFO.
//!
//! Every worker-count change charges the measured stop/restart cost
//! (~10 s, §6). The headline output is the Table 3 statistic: average
//! job completion time in hours.

pub mod des;
pub mod reference;
pub mod sweep;
pub mod workload;

pub use des::{simulate, simulate_traced, SimResult};
pub use reference::simulate_reference;
pub use sweep::{parallel_map, run_cells, SweepCell};
pub use workload::{FaultEvent, FaultKind, FaultPlan, JobProfile, WorkloadGen};

use crate::cluster::{PlacePolicy, Topology};
use crate::perfmodel::{LinkContention, PlacementModel};

/// Which Table 3 strategy a simulation runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    Precompute,
    Exploratory,
    /// The +1-greedy baseline (not a Table 3 row; used by the scale
    /// sweep and ablations to race the doubling heuristic at scale).
    Optimus,
    Fixed(usize),
}

impl StrategyKind {
    pub fn name(self) -> String {
        match self {
            StrategyKind::Precompute => "precompute".into(),
            StrategyKind::Exploratory => "exploratory".into(),
            StrategyKind::Optimus => "optimus".into(),
            StrategyKind::Fixed(k) => format!("fixed-{k}"),
        }
    }

    /// The six rows of Table 3.
    pub fn table3_rows() -> Vec<StrategyKind> {
        vec![
            StrategyKind::Precompute,
            StrategyKind::Exploratory,
            StrategyKind::Fixed(8),
            StrategyKind::Fixed(4),
            StrategyKind::Fixed(2),
            StrategyKind::Fixed(1),
        ]
    }
}

/// Simulation parameters (defaults = the paper's §7 setup).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Cluster GPU capacity (paper: 64).
    pub capacity: usize,
    /// Mean exponential inter-arrival seconds (250 / 500 / 1000).
    pub mean_interarrival: f64,
    /// Total jobs in the workload (206 / 114 / 44).
    pub n_jobs: usize,
    pub strategy: StrategyKind,
    /// Stop/checkpoint/restart cost charged on every rescale (§6: ~10 s).
    pub restart_cost: f64,
    /// Exploration: seconds at each probe size (§7: 2.5 min each).
    pub explore_secs_per_size: f64,
    /// Exploration probe sizes (§7: 1, 2, 4, 8 — reserving max while probing).
    pub explore_sizes: Vec<usize>,
    pub seed: u64,
    /// Pool shape. [`Topology::Flat`] (the default) reproduces the
    /// pre-placement simulator bit-for-bit; a cluster topology makes
    /// every job's speed depend on the nodes its ring spans.
    pub topology: Topology,
    /// Eq 2–4 intra/inter-node split applied when `topology` is a grid.
    pub placement: PlacementModel,
    /// How gangs are laid out on the grid (pack = locality-aware BFD).
    pub place_policy: PlacePolicy,
    /// Shared-bandwidth law for inter-node links: when enabled (and the
    /// pool is a grid), concurrent rings crossing the same uplink
    /// degrade each other's eq-2 constants per the per-link ring ledger.
    /// [`LinkContention::OFF`] (the default) is provably the
    /// contention-free engine — every pricing call structurally
    /// delegates to the PR-3 path, bit for bit.
    pub link_contention: LinkContention,
    /// Completion-scan pruner (DESIGN.md §15): skip running jobs whose
    /// monotone finish-time lower bound already exceeds the best
    /// candidate. On or off, the next-event instant is bit-identical by
    /// construction; the switch exists so CI can prove that claim on
    /// both code paths. Default: on.
    pub completion_prune: bool,
    /// Seeded node-failure model (DESIGN.md §17). [`FaultPlan::OFF`]
    /// (the default) is provably the fault-free engine: no timeline is
    /// generated, no fault state is allocated, and the event loop never
    /// consults the fault cursor.
    pub faults: workload::FaultPlan,
}

impl SimConfig {
    /// The paper's three contention regimes.
    pub fn paper(strategy: StrategyKind, contention: Contention, seed: u64) -> SimConfig {
        let (mean, n_jobs) = match contention {
            Contention::Extreme => (250.0, 206),
            Contention::Moderate => (500.0, 114),
            Contention::None => (1000.0, 44),
        };
        SimConfig {
            capacity: 64,
            mean_interarrival: mean,
            n_jobs,
            strategy,
            restart_cost: 10.0,
            explore_secs_per_size: 150.0,
            explore_sizes: vec![1, 2, 4, 8],
            seed,
            topology: Topology::flat(64),
            placement: PlacementModel::paper(),
            place_policy: PlacePolicy::Pack,
            link_contention: LinkContention::OFF,
            completion_prune: true,
            faults: workload::FaultPlan::OFF,
        }
    }

    /// Switch the pool to a `nodes × gpus_per_node` grid (capacity
    /// follows the grid).
    pub fn with_topology(mut self, nodes: usize, gpus_per_node: usize) -> SimConfig {
        self.topology = Topology::cluster(nodes, gpus_per_node);
        self.capacity = self.topology.capacity();
        self
    }
}

/// `RINGMASTER_PRUNE` env override for [`SimConfig::completion_prune`]:
/// `0`/`off`/`false` disables the completion-scan pruner, `1`/`on`/`true`
/// forces it, unset or unrecognised leaves the config default. The CLI,
/// the scale benches, and `tests/scale_smoke.rs` all honor it so CI can
/// run the whole suite down either code path.
pub fn prune_from_env() -> Option<bool> {
    match std::env::var("RINGMASTER_PRUNE") {
        Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
            "0" | "off" | "false" | "no" => Some(false),
            "1" | "on" | "true" | "yes" => Some(true),
            _ => None,
        },
        Err(_) => None,
    }
}

/// Table 3's three columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Contention {
    Extreme,
    Moderate,
    None,
}

impl Contention {
    pub fn name(self) -> &'static str {
        match self {
            Contention::Extreme => "extreme",
            Contention::Moderate => "moderate",
            Contention::None => "none",
        }
    }

    pub fn all() -> [Contention; 3] {
        [Contention::Extreme, Contention::Moderate, Contention::None]
    }
}
