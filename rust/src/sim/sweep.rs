//! Parallel sweep runner: fan independent `(config, workload)` cells
//! across OS threads and collect their [`SimResult`]s **in submission
//! order**.
//!
//! The determinism contract (DESIGN.md §15.3): every cell is a pure
//! function of its own `(SimConfig, Vec<JobProfile>)` — `simulate`
//! takes no global state, allocates its own cluster ledger, and never
//! reads the clock — so the output vector is a pure function of the
//! input slice regardless of worker count or OS scheduling. Threads
//! only change *when* a cell runs, never *what* it computes, and the
//! per-slot collection below erases completion order. `--threads 1`
//! and `--threads 64` are therefore byte-identical by construction,
//! and `tests/sweep_invariance.rs` + the golden-parity matrix pin it.
//!
//! No new dependencies: plain `std::thread::scope` (vendor/ carries
//! only `anyhow` and the `xla` shim). Worker panics propagate to the
//! caller when the scope joins, so a failing cell fails the sweep
//! loudly instead of yielding a hole.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::des::{simulate, SimResult};
use super::workload::JobProfile;
use super::SimConfig;

/// One unit of sweep work: a simulator config plus the trace it runs.
/// The trace is behind an `Arc` so seed×strategy grids can race many
/// strategies over one shared workload without cloning 100k-job
/// vectors per cell (the tables inside `JobProfile` are themselves
/// `Arc`-shared across threads — see the Send/Sync contract tests in
/// `scheduler`).
#[derive(Clone)]
pub struct SweepCell {
    pub cfg: SimConfig,
    pub jobs: Arc<Vec<JobProfile>>,
}

impl SweepCell {
    pub fn new(cfg: SimConfig, jobs: Arc<Vec<JobProfile>>) -> SweepCell {
        SweepCell { cfg, jobs }
    }
}

/// Map `f` over `items` on `threads` workers, returning results in
/// input order. A strict generalization of `items.iter().map(f)`:
/// with `threads <= 1` (or one item) it *is* the serial loop on the
/// caller's thread; otherwise workers claim indices from a shared
/// atomic cursor and deposit into per-slot boxes, so no ordering
/// information survives the join. `f` must be pure w.r.t. shared
/// state for the determinism contract to hold — all ringmaster sim
/// entry points are.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(|it| f(it)).collect();
    }
    let slots: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let workers = threads.min(items.len());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("sweep: worker left an empty slot"))
        .collect()
}

/// Run a batch of sweep cells on `threads` workers; `results[i]` is
/// always cell `i`'s result.
pub fn run_cells(cells: &[SweepCell], threads: usize) -> Vec<SimResult> {
    parallel_map(cells, threads, |c| simulate(&c.cfg, &c.jobs))
}

/// Resolve a worker count: explicit request > `RINGMASTER_THREADS`
/// env > all available cores. Zero (from either source) means "auto".
pub fn resolve_threads(explicit: Option<usize>) -> usize {
    match explicit {
        Some(n) if n > 0 => n,
        _ => match threads_from_env() {
            Some(n) => n,
            None => default_threads(),
        },
    }
}

/// `RINGMASTER_THREADS` if set to a positive integer, else `None`.
pub fn threads_from_env() -> Option<usize> {
    std::env::var("RINGMASTER_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
}

/// The machine's available parallelism (1 if unknown).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Contention, StrategyKind, WorkloadGen};

    // The whole module is sound only because cells and results cross
    // thread boundaries; pin that at compile time so a future field
    // (an Rc cache, a RefCell memo) breaks the build here, with a
    // message, instead of deep inside a thread::scope bound.
    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn sweep_types_are_send_sync() {
        assert_send_sync::<SweepCell>();
        assert_send_sync::<SimConfig>();
        assert_send_sync::<JobProfile>();
        assert_send_sync::<SimResult>();
    }

    #[test]
    fn parallel_map_preserves_input_order() {
        let items: Vec<usize> = (0..97).collect();
        for threads in [1usize, 3, 8] {
            let out = parallel_map(&items, threads, |&i| i * i);
            let want: Vec<usize> = items.iter().map(|&i| i * i).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item_batches_work() {
        let none: Vec<u32> = vec![];
        assert!(parallel_map(&none, 8, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 8, |&x| x + 1), vec![8]);
    }

    #[test]
    fn run_cells_matches_serial_simulate_bit_for_bit() {
        let mut cells = Vec::new();
        for seed in [11u64, 23] {
            for s in [StrategyKind::Precompute, StrategyKind::Fixed(4)] {
                let cfg = SimConfig::paper(s, Contention::None, seed).with_topology(8, 8);
                let jobs = Arc::new(
                    WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed),
                );
                cells.push(SweepCell::new(cfg, jobs));
            }
        }
        let serial: Vec<SimResult> = cells.iter().map(|c| simulate(&c.cfg, &c.jobs)).collect();
        for threads in [2usize, 4] {
            let par = run_cells(&cells, threads);
            assert_eq!(par.len(), serial.len());
            for (i, (a, b)) in par.iter().zip(&serial).enumerate() {
                assert_eq!(
                    a.avg_completion_hours.to_bits(),
                    b.avg_completion_hours.to_bits(),
                    "cell {i} threads {threads}: avg diverged"
                );
                assert_eq!(a.total_rescales, b.total_rescales, "cell {i}");
                assert_eq!(a.events, b.events, "cell {i}");
                for (j, (x, y)) in a.completion_secs.iter().zip(&b.completion_secs).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "cell {i} job {j}");
                }
            }
        }
    }

    #[test]
    fn resolve_threads_prefers_explicit_over_auto() {
        assert_eq!(resolve_threads(Some(3)), 3);
        // Zero means auto — must resolve to something positive.
        assert!(resolve_threads(Some(0)) >= 1);
        assert!(resolve_threads(None) >= 1);
    }
}
