//! The frozen pre-heap simulation engine — the golden-parity oracle.
//!
//! This is the scan engine exactly as it shipped before the event-heap
//! rewrite of [`super::des`]: four full-array scans per event (arrival
//! firing, exploration firing, next-event search, progress), a fresh
//! `speed_table()` clone per ready job per event, and a full
//! `placed_jobs()` ledger diff at every reallocation point — O(events ×
//! jobs), which is why it was replaced. It is kept *verbatim* (modulo
//! NaN-safe `total_cmp` sorts and the shared probe helpers it now
//! imports from `des`) so `tests/golden_parity.rs` can assert the
//! rewritten engine reproduces it bit for bit on the paper workloads.
//!
//! Do not optimize this file; its only job is to stay identical to the
//! engine the Table 3 numbers were first validated on. New features go
//! in `des.rs` — and must preserve parity with this oracle or
//! consciously retire it.

use super::des::{probe_span, reservation_blocks, SimResult};
use super::workload::JobProfile;
use super::{SimConfig, StrategyKind};
use crate::cluster::{ClusterState, Topology};
use crate::scheduler::{
    doubling::Doubling, fixed::Fixed, optimus::OptimusGreedy, Allocation, JobInfo, Scheduler,
    Speed,
};

const EPS: f64 = 1e-6;

#[derive(Clone, Debug, PartialEq)]
enum State {
    NotArrived,
    WaitingExplore,
    Exploring { end: f64 },
    Ready,
    Done { finish: f64 },
}

struct SimJob {
    profile: JobProfile,
    state: State,
    w: usize,
    nodes: usize,
    remaining_epochs: f64,
    busy_until: f64,
}

impl SimJob {
    fn secs_per_epoch_placed(&self, cfg: &SimConfig) -> f64 {
        cfg.placement.placed_epoch_secs(self.profile.secs_per_epoch(self.w), self.w, self.nodes)
    }
}

/// Run one strategy over one generated workload with the frozen scan
/// engine. Identical semantics to [`super::des::simulate`]; quadratic
/// cost. Test/bench oracle only.
pub fn simulate_reference(cfg: &SimConfig, profiles: &[JobProfile]) -> SimResult {
    let topology = cfg
        .topology
        .reconciled(cfg.capacity)
        .expect("grid topology must agree with cfg.capacity (use with_topology)");
    let explore_reserve = cfg.explore_sizes.iter().copied().max().unwrap_or(8);
    let explore_duration = cfg.explore_secs_per_size * cfg.explore_sizes.len() as f64;
    let mut cluster = ClusterState::with_policy(topology.spec(), cfg.place_policy);

    let mut jobs: Vec<SimJob> = profiles
        .iter()
        .map(|p| SimJob {
            profile: p.clone(),
            state: State::NotArrived,
            w: 0,
            nodes: 0,
            remaining_epochs: p.total_epochs,
            busy_until: 0.0,
        })
        .collect();

    let mut now = 0.0f64;
    let mut peak_concurrent = 0usize;
    let mut total_rescales = 0u64;
    let mut events = 0u64;
    let mut guard = 0usize;

    loop {
        guard += 1;
        assert!(guard < 10_000_000, "simulation failed to converge");
        events += 1;

        // ---- 1. fire due events -----------------------------------------
        for j in jobs.iter_mut() {
            if j.state == State::NotArrived && j.profile.arrival <= now + EPS {
                j.state = match cfg.strategy {
                    StrategyKind::Exploratory => State::WaitingExplore,
                    _ => State::Ready,
                };
            }
        }
        for (i, j) in jobs.iter_mut().enumerate() {
            if let State::Exploring { end } = j.state {
                if end <= now + EPS {
                    // Lump-sum progress of the probe runs (2.5 min each
                    // size), paying the eq-2 penalty of the nodes each
                    // probe spans inside its reservation on a grid.
                    let blocks = if topology.is_flat() {
                        Vec::new()
                    } else {
                        reservation_blocks(&cluster, i as u64)
                    };
                    let gained: f64 = cfg
                        .explore_sizes
                        .iter()
                        .map(|&s| {
                            let base = j.profile.secs_per_epoch(s);
                            let secs = if topology.is_flat() {
                                base
                            } else {
                                let nodes = probe_span(&blocks, s, &topology);
                                cfg.placement.placed_epoch_secs(base, s, nodes)
                            };
                            cfg.explore_secs_per_size / secs
                        })
                        .sum();
                    j.remaining_epochs = (j.remaining_epochs - gained).max(0.0);
                    j.state = State::Ready;
                    j.w = 0;
                }
            }
        }
        for j in jobs.iter_mut() {
            if j.state == State::Ready && j.remaining_epochs <= EPS {
                j.state = State::Done { finish: now };
                j.w = 0;
            }
        }

        // ---- 2. reallocate ----------------------------------------------
        let mut capacity = cfg.capacity;
        for j in jobs.iter() {
            if matches!(j.state, State::Exploring { .. }) {
                capacity = capacity.saturating_sub(explore_reserve);
            }
        }
        let mut waiting: Vec<usize> = (0..jobs.len())
            .filter(|&i| jobs[i].state == State::WaitingExplore)
            .collect();
        waiting.sort_by(|&a, &b| {
            jobs[a].profile.arrival.total_cmp(&jobs[b].profile.arrival)
        });
        for i in waiting {
            if capacity >= explore_reserve {
                capacity -= explore_reserve;
                jobs[i].state = State::Exploring { end: now + explore_duration };
                jobs[i].busy_until = now; // probes include their own startup
            }
        }

        let mut ready: Vec<usize> = (0..jobs.len())
            .filter(|&i| jobs[i].state == State::Ready)
            .collect();
        ready.sort_by(|&a, &b| {
            jobs[a].profile.arrival.total_cmp(&jobs[b].profile.arrival)
        });

        let speed_of = |j: &SimJob| -> Speed {
            let table = Speed::Table(j.profile.speed_table());
            match topology {
                Topology::Flat { .. } => table,
                Topology::Cluster(spec) => Speed::placed(table, cfg.placement, spec.gpus_per_node),
            }
        };
        let infos: Vec<JobInfo> = ready
            .iter()
            .map(|&i| JobInfo {
                id: i as u64,
                q: jobs[i].remaining_epochs,
                speed: speed_of(&jobs[i]),
                max_w: cfg.capacity,
            })
            .collect();
        let alloc: Allocation = match cfg.strategy {
            StrategyKind::Fixed(k) => Fixed(k).allocate(&infos, capacity),
            StrategyKind::Optimus => OptimusGreedy.allocate(&infos, capacity),
            StrategyKind::Precompute | StrategyKind::Exploratory => {
                Doubling.allocate(&infos, capacity)
            }
        };
        for (&id, &w_new) in &alloc {
            let j = &mut jobs[id as usize];
            if j.w != w_new {
                if w_new > 0 {
                    j.busy_until = now + cfg.restart_cost;
                    total_rescales += 1;
                }
                j.w = w_new;
            }
        }

        // ---- 2b. sync the placement ledger ------------------------------
        if !topology.is_flat() {
            let mut desired: Vec<(u64, usize)> = Vec::new();
            for (i, j) in jobs.iter().enumerate() {
                match j.state {
                    State::Exploring { .. } => desired.push((i as u64, explore_reserve)),
                    State::Ready if j.w > 0 => desired.push((i as u64, j.w)),
                    _ => {}
                }
            }
            for (id, held) in cluster.placed_jobs() {
                let keep = desired.iter().any(|&(d, w)| d == id && w == held);
                if !keep {
                    cluster.release(id).expect("ledger holds what it reported");
                }
            }
            let movers: Vec<(u64, usize)> = desired
                .iter()
                .copied()
                .filter(|&(id, _)| cluster.allocation_of(id).is_none())
                .collect();
            cluster.place_batch(&movers).expect("granted widths never exceed capacity");
            for (i, j) in jobs.iter_mut().enumerate() {
                j.nodes = cluster.nodes_spanned(i as u64);
            }
        }

        let concurrent = jobs
            .iter()
            .filter(|j| {
                matches!(j.state, State::Ready | State::Exploring { .. } | State::WaitingExplore)
            })
            .count();
        peak_concurrent = peak_concurrent.max(concurrent);

        // ---- 3. find the next event --------------------------------------
        let mut next = f64::INFINITY;
        for j in jobs.iter() {
            match j.state {
                State::NotArrived => next = next.min(j.profile.arrival),
                State::Exploring { end } => next = next.min(end),
                State::Ready if j.w > 0 => {
                    let start = now.max(j.busy_until);
                    let finish = start + j.remaining_epochs * j.secs_per_epoch_placed(cfg);
                    next = next.min(finish);
                }
                _ => {}
            }
        }
        if !next.is_finite() {
            break; // nothing left to happen
        }
        let next = next.max(now + EPS);

        // ---- 4. progress running jobs to `next` ---------------------------
        for j in jobs.iter_mut() {
            if j.state == State::Ready && j.w > 0 {
                let start = now.max(j.busy_until);
                let dt = (next - start).max(0.0);
                j.remaining_epochs =
                    (j.remaining_epochs - dt / j.secs_per_epoch_placed(cfg)).max(0.0);
            }
        }
        now = next;
    }

    let completion_secs: Vec<f64> = jobs
        .iter()
        .map(|j| match j.state {
            State::Done { finish } => finish - j.profile.arrival,
            _ => f64::NAN,
        })
        .collect();
    let completed = completion_secs.iter().filter(|v| v.is_finite()).count();
    let avg = completion_secs.iter().filter(|v| v.is_finite()).sum::<f64>()
        / completed.max(1) as f64;

    SimResult {
        strategy: cfg.strategy.name(),
        avg_completion_hours: avg / 3600.0,
        completed,
        makespan_hours: now / 3600.0,
        peak_concurrent,
        total_rescales,
        completion_secs,
        events,
        // Scan diagnostics belong to the event-heap engine; the frozen
        // oracle reports zeros (and parity never compares them). The
        // oracle predates faults, so evictions is identically 0 — which
        // is exactly what fault-off parity asserts.
        scan_candidates: 0,
        scan_skipped: 0,
        evictions: 0,
    }
}
