//! Workload generation: job profiles calibrated from the paper's runs.
//!
//! The paper seeds its simulator with "data from the experimental runs";
//! we do the same, anchored on Tables 1–2 (ResNet-110 / CIFAR-10 on
//! K40m):
//!
//! | w | total min | epochs | secs/epoch |
//! |---|-----------|--------|------------|
//! | 1 | 368       | 160    | 138.0      |
//! | 2 | 232       | 170    | 81.9       |
//! | 4 | 126       | 160    | 47.3       |
//! | 8 | 84        | 170    | 29.6       |
//!
//! Jobs are heterogeneous: a log-normal size multiplier scales the whole
//! profile, a scaling-efficiency jitter perturbs how well large w pays
//! off, and total epochs vary around the paper's 160–170. Speeds beyond
//! w=8 flat-extrapolate (profiles were only measured to 8), which
//! naturally caps useful allocations at 8 GPUs per job, as in the paper.

use crate::rngx::Rng;

/// Hidden truth about one job. (`PartialEq` so orchestrator job specs —
/// which embed a profile — support trace round-trip equality checks.)
#[derive(Clone, Debug, PartialEq)]
pub struct JobProfile {
    /// Arrival time (seconds since sim start).
    pub arrival: f64,
    /// True seconds/epoch at w = 1, 2, 4, 8 (power-of-two index).
    pub epoch_secs: Vec<(usize, f64)>,
    /// Epochs to converge.
    pub total_epochs: f64,
}

/// Paper-anchored seconds/epoch at the measured worker counts.
pub const PAPER_EPOCH_SECS: [(usize, f64); 4] =
    [(1, 138.0), (2, 81.9), (4, 47.3), (8, 29.6)];

impl JobProfile {
    /// True seconds/epoch at any w (linear interpolation on the table,
    /// flat beyond both ends — matching `scheduler::Speed::Table`).
    pub fn secs_per_epoch(&self, w: usize) -> f64 {
        let t = &self.epoch_secs;
        if w <= t[0].0 {
            return t[0].1;
        }
        for pair in t.windows(2) {
            let (w0, s0) = pair[0];
            let (w1, s1) = pair[1];
            if w == w0 {
                return s0;
            }
            if w < w1 {
                let frac = (w - w0) as f64 / (w1 - w0) as f64;
                return s0 + frac * (s1 - s0);
            }
        }
        t.last().unwrap().1
    }

    /// Epochs/sec table for the scheduler (`Speed::Table`).
    pub fn speed_table(&self) -> Vec<(usize, f64)> {
        self.epoch_secs.iter().map(|&(w, s)| (w, 1.0 / s)).collect()
    }

    /// Serial completion time at fixed w (no queueing), seconds.
    pub fn serial_secs(&self, w: usize) -> f64 {
        self.total_epochs * self.secs_per_epoch(w)
    }
}

/// Deterministic workload generator.
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    /// Log-normal σ of the per-job size multiplier.
    pub size_sigma: f64,
    /// Jitter σ on scaling efficiency at each doubling.
    pub efficiency_sigma: f64,
    /// Probability a job is an "elephant" (heavy-tailed trace mode).
    /// The default generator sets 0.0, which also skips the extra rng
    /// draw — its stream, and therefore every paper workload, is
    /// bit-identical to the pre-elephant generator.
    pub elephant_prob: f64,
    /// Size multiplier applied to elephants.
    pub elephant_mult: f64,
}

impl Default for WorkloadGen {
    fn default() -> Self {
        WorkloadGen { size_sigma: 0.45, efficiency_sigma: 0.08, elephant_prob: 0.0, elephant_mult: 1.0 }
    }
}

impl WorkloadGen {
    /// Heavy-tailed generator for Philly/Helios-style synthetic traces:
    /// a wider log-normal body plus a small population of elephants
    /// (~3% of jobs, ~12× the work), so large replays exercise the
    /// queueing dynamics public traces show instead of 100k clones of
    /// ResNet-110.
    pub fn heavy_tailed() -> WorkloadGen {
        WorkloadGen { size_sigma: 0.8, efficiency_sigma: 0.08, elephant_prob: 0.03, elephant_mult: 12.0 }
    }

    /// An `n`-job heavy-tailed trace whose arrival rate keeps a
    /// `capacity`-GPU pool at ~65% offered load *regardless of `n`* —
    /// the scale-sweep workload: the active set stays bounded by load
    /// while total work grows linearly, which is exactly the regime
    /// where per-event cost must not depend on trace length.
    pub fn trace_scale(n: usize, capacity: usize, seed: u64) -> Vec<JobProfile> {
        let g = WorkloadGen::heavy_tailed();
        let mean = g.mean_interarrival_for(capacity, 0.65);
        g.generate(n, mean, seed)
    }

    /// Mean inter-arrival seconds that put a `capacity`-GPU pool at
    /// `offered_load` utilization under this generator's size
    /// distribution, costing each job at the w = 8 operating point
    /// (Table 2's knee — the widest point of the profile, so the true
    /// load is never *above* the target).
    pub fn mean_interarrival_for(&self, capacity: usize, offered_load: f64) -> f64 {
        // E[log-normal(σ)] = exp(σ²/2), times the elephant mixture mean
        let mean_mult = (self.size_sigma * self.size_sigma / 2.0).exp()
            * (1.0 - self.elephant_prob + self.elephant_prob * self.elephant_mult);
        // 165 epochs × secs/epoch(8) × 8 GPUs of work per mean-size job
        let gpu_secs = mean_mult * 165.0 * PAPER_EPOCH_SECS[3].1 * 8.0;
        gpu_secs / (capacity as f64 * offered_load)
    }

    /// Generate `n_jobs` arrivals with exponential inter-arrival times.
    pub fn generate(&self, n_jobs: usize, mean_interarrival: f64, seed: u64) -> Vec<JobProfile> {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        (0..n_jobs)
            .map(|_| {
                t += rng.exponential(mean_interarrival);
                self.one_job(&mut rng, t)
            })
            .collect()
    }

    fn one_job(&self, rng: &mut Rng, arrival: f64) -> JobProfile {
        let mut size = rng.jitter(self.size_sigma); // log-normal multiplier
        // `&&` short-circuits: with elephants off no draw happens, so
        // the default stream is untouched
        if self.elephant_prob > 0.0 && rng.uniform_range(0.0, 1.0) < self.elephant_prob {
            size *= self.elephant_mult;
        }
        let mut epoch_secs = Vec::with_capacity(4);
        let mut prev = PAPER_EPOCH_SECS[0].1 * size;
        epoch_secs.push((1, prev));
        for i in 1..PAPER_EPOCH_SECS.len() {
            let (w, base) = PAPER_EPOCH_SECS[i];
            let (_, base_prev) = PAPER_EPOCH_SECS[i - 1];
            // paper-anchored speedup ratio for this doubling, jittered
            let ratio = (base / base_prev) * rng.jitter(self.efficiency_sigma);
            // never faster than perfect halving, never slower than flat
            let ratio = ratio.clamp(0.5, 1.0);
            prev *= ratio;
            epoch_secs.push((w, prev));
        }
        let total_epochs = rng.normal_scaled(165.0, 5.0).max(120.0);
        JobProfile { arrival, epoch_secs, total_epochs }
    }
}

/// What one fault event does to its node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Node leaves the pool: gangs touching it are evicted and the node
    /// is unplaceable until its paired `Up`.
    Down,
    /// Node repair finished; it may be placed on again.
    Up,
    /// Transient process failure: gangs touching the node are evicted
    /// (losing progress back to their last segment boundary) but the
    /// node itself stays placeable.
    Transient,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Down => "down",
            FaultKind::Up => "up",
            FaultKind::Transient => "transient",
        }
    }
}

/// One scheduled fault on the virtual clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub node: usize,
    pub kind: FaultKind,
}

/// Seeded fault model: per-node exponential failure/repair clocks plus
/// transient (process-level) gang killers. `FaultPlan::OFF` is the
/// default everywhere and is off *by construction*: no clocks are
/// drawn, no timeline exists, and every engine hook short-circuits on
/// [`FaultPlan::is_off`], so the fault-off engine is the pre-fault
/// engine bit for bit (asserted in `tests/golden_parity.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Mean seconds between failures of one node (exponential clock).
    /// `0` disables node-down events.
    pub mtbf_secs: f64,
    /// Mean seconds a downed node stays out of the pool before repair.
    pub mttr_secs: f64,
    /// Mean seconds between transient gang-killing failures per node.
    /// `0` disables transient events.
    pub transient_mtbf_secs: f64,
    /// Fault clocks stop here: no events are generated past this
    /// virtual time, so a drained cluster can always finish its queue.
    pub horizon_secs: f64,
    /// Orchestrator: consecutive failed attempts of one segment before
    /// the job is abandoned and marked failed in its report.
    pub max_retries: u32,
    /// Orchestrator: retry k waits `backoff_base_secs * 2^(k-1)`
    /// virtual seconds before relaunching.
    pub backoff_base_secs: f64,
    /// Seed of the fault clocks — independent of the workload stream,
    /// so fault-on never perturbs job generation.
    pub seed: u64,
}

impl FaultPlan {
    /// The no-faults plan (the default everywhere).
    pub const OFF: FaultPlan = FaultPlan {
        mtbf_secs: 0.0,
        mttr_secs: 0.0,
        transient_mtbf_secs: 0.0,
        horizon_secs: 0.0,
        max_retries: 0,
        backoff_base_secs: 0.0,
        seed: 0,
    };

    /// True when no fault source is active; every engine hook gates on
    /// this before touching any fault state.
    pub fn is_off(&self) -> bool {
        self.mtbf_secs <= 0.0 && self.transient_mtbf_secs <= 0.0
    }

    /// Steady-state plan: node MTBF/MTTR clocks, no transients, and the
    /// orchestrator's default retry policy.
    pub fn steady(mtbf_secs: f64, mttr_secs: f64, horizon_secs: f64, seed: u64) -> FaultPlan {
        FaultPlan {
            mtbf_secs,
            mttr_secs,
            transient_mtbf_secs: 0.0,
            horizon_secs,
            max_retries: 3,
            backoff_base_secs: 30.0,
            seed,
        }
    }

    /// Failure-burst preset (the ROADMAP's real-trace scenario): short
    /// MTBF with quick repairs plus transient process deaths — a storm,
    /// not an outage.
    pub fn burst(horizon_secs: f64, seed: u64) -> FaultPlan {
        FaultPlan {
            mtbf_secs: 3_600.0,
            mttr_secs: 300.0,
            transient_mtbf_secs: 7_200.0,
            horizon_secs,
            max_retries: 3,
            backoff_base_secs: 30.0,
            seed,
        }
    }

    /// Probability that a segment of `duration_secs` virtual seconds is
    /// killed by a fault — the orchestrator's per-segment hazard, the
    /// node and transient rates combined into one exponential law.
    /// Exactly 0 when the plan is off (no rng is ever consulted).
    pub fn segment_fail_probability(&self, duration_secs: f64) -> f64 {
        if self.is_off() || duration_secs <= 0.0 {
            return 0.0;
        }
        let mut rate = 0.0;
        if self.mtbf_secs > 0.0 {
            rate += 1.0 / self.mtbf_secs;
        }
        if self.transient_mtbf_secs > 0.0 {
            rate += 1.0 / self.transient_mtbf_secs;
        }
        1.0 - (-duration_secs * rate).exp()
    }

    /// Materialize the plan's full fault timeline for an `n_nodes`-node
    /// pool, sorted by `(t, node, kind)`. Each node gets forked clocks
    /// (fail/repair and transient streams independent of each other and
    /// of every other node), so the timeline for node `i` is invariant
    /// to the pool size. Returns an empty timeline when the plan is
    /// off — callers never draw a single random number in that case.
    pub fn timeline(&self, n_nodes: usize) -> Vec<FaultEvent> {
        let mut events = Vec::new();
        if self.is_off() {
            return events;
        }
        let mut root = Rng::new(self.seed ^ 0xFA117);
        for node in 0..n_nodes {
            let mut clock = root.fork();
            if self.mtbf_secs > 0.0 {
                let mut t = 0.0;
                loop {
                    t += clock.exponential(self.mtbf_secs);
                    if t >= self.horizon_secs {
                        break;
                    }
                    events.push(FaultEvent { t, node, kind: FaultKind::Down });
                    // repair completes even past the horizon: a node
                    // must never stay down forever
                    t += clock.exponential(self.mttr_secs.max(1.0));
                    events.push(FaultEvent { t, node, kind: FaultKind::Up });
                }
            }
            let mut transient = root.fork();
            if self.transient_mtbf_secs > 0.0 {
                let mut t = 0.0;
                loop {
                    t += transient.exponential(self.transient_mtbf_secs);
                    if t >= self.horizon_secs {
                        break;
                    }
                    events.push(FaultEvent { t, node, kind: FaultKind::Transient });
                }
            }
        }
        events.sort_by(|a, b| {
            a.t.total_cmp(&b.t)
                .then_with(|| a.node.cmp(&b.node))
                .then_with(|| (a.kind as u8).cmp(&(b.kind as u8)))
        });
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(n: usize, seed: u64) -> Vec<JobProfile> {
        WorkloadGen::default().generate(n, 500.0, seed)
    }

    #[test]
    fn deterministic() {
        let a = gen(20, 1);
        let b = gen(20, 1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.epoch_secs, y.epoch_secs);
        }
    }

    #[test]
    fn arrivals_increasing_with_right_mean() {
        let jobs = gen(2000, 3);
        let mut prev = 0.0;
        for j in &jobs {
            assert!(j.arrival > prev);
            prev = j.arrival;
        }
        let mean = jobs.last().unwrap().arrival / 2000.0;
        assert!((mean - 500.0).abs() < 30.0, "mean={mean}");
    }

    #[test]
    fn more_workers_never_slower_per_epoch() {
        for j in gen(100, 7) {
            for pair in j.epoch_secs.windows(2) {
                assert!(pair[1].1 <= pair[0].1 + 1e-9);
            }
        }
    }

    #[test]
    fn speedup_bounded_by_perfect_scaling() {
        for j in gen(100, 11) {
            for pair in j.epoch_secs.windows(2) {
                let ratio = pair[1].1 / pair[0].1;
                assert!(ratio >= 0.5 - 1e-9, "superlinear: {ratio}");
            }
        }
    }

    #[test]
    fn interpolation_and_extrapolation() {
        let j = &gen(1, 5)[0];
        let s3 = j.secs_per_epoch(3);
        assert!(s3 < j.secs_per_epoch(2) && s3 > j.secs_per_epoch(4));
        assert_eq!(j.secs_per_epoch(16), j.secs_per_epoch(8));
        assert_eq!(j.secs_per_epoch(64), j.secs_per_epoch(8));
    }

    #[test]
    fn profiles_anchor_near_paper_scale() {
        // population median secs/epoch at w=1 should sit near 138 s
        let jobs = gen(500, 13);
        let mut v: Vec<f64> = jobs.iter().map(|j| j.secs_per_epoch(1)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = v[v.len() / 2];
        assert!((median - 138.0).abs() < 25.0, "median={median}");
    }

    #[test]
    fn default_generator_never_draws_the_elephant_coin() {
        // elephant_prob = 0 must leave the rng stream untouched: the
        // default workload (every paper test and golden) is bit-stable
        // against the heavy-tail extension.
        let base = WorkloadGen { elephant_prob: 0.0, elephant_mult: 99.0, ..WorkloadGen::default() };
        let a = WorkloadGen::default().generate(50, 500.0, 3);
        let b = base.generate(50, 500.0, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.epoch_secs, y.epoch_secs);
            assert_eq!(x.total_epochs.to_bits(), y.total_epochs.to_bits());
        }
    }

    #[test]
    fn trace_scale_is_deterministic_and_heavy_tailed() {
        let a = WorkloadGen::trace_scale(2000, 128, 7);
        let b = WorkloadGen::trace_scale(2000, 128, 7);
        assert_eq!(a.len(), 2000);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.epoch_secs, y.epoch_secs);
        }
        // heavy tail: the max w=1 serial time should dwarf the median
        let mut v: Vec<f64> = a.iter().map(|j| j.serial_secs(1)).collect();
        v.sort_by(|x, y| x.total_cmp(y));
        let median = v[v.len() / 2];
        let max = v[v.len() - 1];
        assert!(max > 8.0 * median, "tail too light: max={max:.0} median={median:.0}");
    }

    #[test]
    fn trace_scale_offered_load_stays_below_capacity() {
        // arrival rate × mean GPU-seconds (at the costliest w=8 point)
        // must stay below capacity — the stability condition that keeps
        // the active set bounded at any trace length.
        let jobs = WorkloadGen::trace_scale(4000, 128, 11);
        let horizon = jobs.last().unwrap().arrival;
        let gpu_secs: f64 = jobs.iter().map(|j| j.serial_secs(8) * 8.0).sum();
        let load = gpu_secs / (horizon * 128.0);
        assert!(load < 0.95, "offered load {load:.2} would diverge");
        assert!(load > 0.3, "offered load {load:.2} — sweep would be idle");
    }

    #[test]
    fn fault_plan_off_draws_nothing() {
        assert!(FaultPlan::OFF.is_off());
        assert!(FaultPlan::OFF.timeline(16).is_empty());
        // zero-rate plans with other fields set are still off
        let p = FaultPlan { mttr_secs: 100.0, horizon_secs: 1e6, seed: 9, ..FaultPlan::OFF };
        assert!(p.is_off());
        assert!(p.timeline(16).is_empty());
    }

    #[test]
    fn fault_timeline_is_deterministic_and_sorted() {
        let p = FaultPlan::burst(100_000.0, 7);
        let a = p.timeline(8);
        let b = p.timeline(8);
        assert!(!a.is_empty());
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[0].t <= w[1].t, "unsorted timeline");
        }
        for e in &a {
            assert!(e.node < 8);
            assert!(e.t > 0.0);
        }
        // a different seed moves the clocks
        assert_ne!(a, FaultPlan::burst(100_000.0, 8).timeline(8));
    }

    #[test]
    fn fault_down_up_strictly_alternate_per_node() {
        let p = FaultPlan::steady(5_000.0, 600.0, 200_000.0, 3);
        let tl = p.timeline(4);
        for node in 0..4 {
            let mut down = false;
            for e in tl.iter().filter(|e| e.node == node) {
                match e.kind {
                    FaultKind::Down => {
                        assert!(!down, "double down on node {node}");
                        down = true;
                    }
                    FaultKind::Up => {
                        assert!(down, "up without down on node {node}");
                        down = false;
                    }
                    FaultKind::Transient => {}
                }
            }
            assert!(!down, "node {node} left down forever");
        }
    }

    #[test]
    fn fault_timeline_per_node_invariant_to_pool_size() {
        // node i's clocks come from forks drawn in node order, so the
        // same node sees the same faults in a bigger pool
        let p = FaultPlan::burst(50_000.0, 11);
        let small: Vec<FaultEvent> =
            p.timeline(2).into_iter().filter(|e| e.node < 2).collect();
        let large: Vec<FaultEvent> =
            p.timeline(6).into_iter().filter(|e| e.node < 2).collect();
        assert_eq!(small, large);
    }

    #[test]
    fn serial_secs_matches_paper_table2_shape() {
        // paper: 1-GPU run 368 min, 8-GPU run 84 min -> ratio ~4.4
        let jobs = gen(500, 17);
        let mean_ratio: f64 = jobs
            .iter()
            .map(|j| j.serial_secs(1) / j.serial_secs(8))
            .sum::<f64>()
            / jobs.len() as f64;
        assert!((3.0..6.0).contains(&mean_ratio), "ratio={mean_ratio}");
    }
}
