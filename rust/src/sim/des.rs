//! The discrete-event simulation engine behind Table 3.
//!
//! Time advances event-to-event (arrival, exploration end, completion);
//! between events every running job progresses linearly at its true
//! `secs_per_epoch(w)` — adjusted for the nodes its ring spans when the
//! pool is a real grid ([`SimConfig::topology`]). Every event triggers a
//! full reallocation under the configured strategy; a placement ledger
//! ([`ClusterState`]) maps granted widths to concrete GPUs with a
//! defragmenting re-pack over the jobs that moved, and any job whose
//! worker count changes pays the stop/restart cost (§6) as a busy period
//! with no progress.

use super::workload::JobProfile;
use super::{SimConfig, StrategyKind};
use crate::cluster::{ClusterState, Topology};
use crate::scheduler::{doubling::Doubling, fixed::Fixed, Allocation, JobInfo, Scheduler, Speed};

const EPS: f64 = 1e-6;

#[derive(Clone, Debug, PartialEq)]
enum State {
    NotArrived,
    /// Exploratory strategy only: queued until 8 GPUs free up.
    WaitingExplore,
    /// Holding the probe reservation until `end`.
    Exploring { end: f64 },
    /// Schedulable (fixed pool or adaptive pool).
    Ready,
    Done { finish: f64 },
}

struct SimJob {
    profile: JobProfile,
    state: State,
    w: usize,
    /// Nodes the current gang spans (0 = unplaced; always 1 on a flat
    /// topology) — the placement half of the `(w, placement)` speed key.
    nodes: usize,
    remaining_epochs: f64,
    /// No progress before this time (restart penalty).
    busy_until: f64,
}

impl SimJob {
    /// True seconds/epoch at the job's current width *and placement*.
    fn secs_per_epoch_placed(&self, cfg: &SimConfig) -> f64 {
        cfg.placement.placed_epoch_secs(self.profile.secs_per_epoch(self.w), self.w, self.nodes)
    }
}

/// Outcome of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub strategy: String,
    /// Table 3's statistic.
    pub avg_completion_hours: f64,
    pub completed: usize,
    pub makespan_hours: f64,
    pub peak_concurrent: usize,
    pub total_rescales: u64,
    /// Per-job completion seconds (arrival -> finish).
    pub completion_secs: Vec<f64>,
}

/// Per-node GPU counts of an exploration reservation, largest block
/// first — computed once per exploring job, then consulted for every
/// probe size in the ladder. Empty when the reservation is not in the
/// ledger (callers fall back to the grid's contiguous best case).
fn reservation_blocks(cluster: &ClusterState, job: u64) -> Vec<usize> {
    let mut per_node: std::collections::BTreeMap<usize, usize> =
        std::collections::BTreeMap::new();
    for &(node, _) in cluster.allocation_of(job).unwrap_or(&[]) {
        *per_node.entry(node).or_insert(0) += 1;
    }
    let mut counts: Vec<usize> = per_node.into_values().collect();
    counts.sort_unstable_by(|a, b| b.cmp(a));
    counts
}

/// Nodes a probe ring of `s` GPUs spans inside a reservation with the
/// given per-node blocks: probes use the most-packed subset of the
/// reserved GPUs (whole blocks, largest first), so a probe that fits
/// one reserved node pays nothing even when the full reservation spans
/// several.
fn probe_span(blocks: &[usize], s: usize, topology: &Topology) -> usize {
    if blocks.is_empty() {
        return topology.min_span(s);
    }
    let mut need = s;
    let mut nodes = 0;
    for &c in blocks {
        if need == 0 {
            break;
        }
        need = need.saturating_sub(c);
        nodes += 1;
    }
    nodes.max(1)
}

/// Run one strategy over one generated workload.
pub fn simulate(cfg: &SimConfig, profiles: &[JobProfile]) -> SimResult {
    let topology = cfg
        .topology
        .reconciled(cfg.capacity)
        .expect("grid topology must agree with cfg.capacity (use with_topology)");
    let explore_reserve = cfg.explore_sizes.iter().copied().max().unwrap_or(8);
    let explore_duration = cfg.explore_secs_per_size * cfg.explore_sizes.len() as f64;
    let mut cluster = ClusterState::with_policy(topology.spec(), cfg.place_policy);

    let mut jobs: Vec<SimJob> = profiles
        .iter()
        .map(|p| SimJob {
            profile: p.clone(),
            state: State::NotArrived,
            w: 0,
            nodes: 0,
            remaining_epochs: p.total_epochs,
            busy_until: 0.0,
        })
        .collect();

    let mut now = 0.0f64;
    let mut peak_concurrent = 0usize;
    let mut total_rescales = 0u64;
    let mut guard = 0usize;

    loop {
        guard += 1;
        assert!(guard < 10_000_000, "simulation failed to converge");

        // ---- 1. fire due events -----------------------------------------
        for j in jobs.iter_mut() {
            if j.state == State::NotArrived && j.profile.arrival <= now + EPS {
                j.state = match cfg.strategy {
                    StrategyKind::Exploratory => State::WaitingExplore,
                    _ => State::Ready,
                };
            }
        }
        for (i, j) in jobs.iter_mut().enumerate() {
            if let State::Exploring { end } = j.state {
                if end <= now + EPS {
                    // Lump-sum progress of the probe runs (2.5 min each
                    // size). Probes run *inside* the reservation the
                    // ledger granted, so on a grid each probe size pays
                    // the eq-2 penalty of the nodes it must span there —
                    // a fragmented reservation makes exploration itself
                    // slower, exactly as on a real cluster. Flat pools
                    // skip the ledger and keep the original arithmetic
                    // bit-for-bit.
                    let blocks = if topology.is_flat() {
                        Vec::new()
                    } else {
                        reservation_blocks(&cluster, i as u64)
                    };
                    let gained: f64 = cfg
                        .explore_sizes
                        .iter()
                        .map(|&s| {
                            let base = j.profile.secs_per_epoch(s);
                            let secs = if topology.is_flat() {
                                base
                            } else {
                                let nodes = probe_span(&blocks, s, &topology);
                                cfg.placement.placed_epoch_secs(base, s, nodes)
                            };
                            cfg.explore_secs_per_size / secs
                        })
                        .sum();
                    j.remaining_epochs = (j.remaining_epochs - gained).max(0.0);
                    j.state = State::Ready;
                    j.w = 0;
                }
            }
        }
        for j in jobs.iter_mut() {
            if j.state == State::Ready && j.remaining_epochs <= EPS {
                j.state = State::Done { finish: now };
                j.w = 0;
            }
        }

        // ---- 2. reallocate ----------------------------------------------
        let mut capacity = cfg.capacity;
        // exploration reservations are sticky
        for j in jobs.iter() {
            if matches!(j.state, State::Exploring { .. }) {
                capacity = capacity.saturating_sub(explore_reserve);
            }
        }
        // admit waiting explorers FIFO
        let mut waiting: Vec<usize> = (0..jobs.len())
            .filter(|&i| jobs[i].state == State::WaitingExplore)
            .collect();
        waiting.sort_by(|&a, &b| jobs[a].profile.arrival.partial_cmp(&jobs[b].profile.arrival).unwrap());
        for i in waiting {
            if capacity >= explore_reserve {
                capacity -= explore_reserve;
                jobs[i].state = State::Exploring { end: now + explore_duration };
                jobs[i].busy_until = now; // probes include their own startup
            }
        }

        // schedulable pool, FIFO order
        let mut ready: Vec<usize> = (0..jobs.len())
            .filter(|&i| jobs[i].state == State::Ready)
            .collect();
        ready.sort_by(|&a, &b| jobs[a].profile.arrival.partial_cmp(&jobs[b].profile.arrival).unwrap());

        // Strategies score widths against the placement the grid would
        // actually grant: on a non-flat topology the speed is wrapped
        // with the eq-2 inter-node penalty at the contiguous best case.
        let speed_of = |j: &SimJob| -> Speed {
            let table = Speed::Table(j.profile.speed_table());
            match topology {
                Topology::Flat { .. } => table,
                Topology::Cluster(spec) => Speed::placed(table, cfg.placement, spec.gpus_per_node),
            }
        };
        let infos: Vec<JobInfo> = ready
            .iter()
            .map(|&i| JobInfo {
                id: i as u64,
                q: jobs[i].remaining_epochs,
                speed: speed_of(&jobs[i]),
                max_w: cfg.capacity,
            })
            .collect();
        let alloc: Allocation = match cfg.strategy {
            StrategyKind::Fixed(k) => Fixed(k).allocate(&infos, capacity),
            StrategyKind::Precompute | StrategyKind::Exploratory => {
                Doubling.allocate(&infos, capacity)
            }
        };
        for (&id, &w_new) in &alloc {
            let j = &mut jobs[id as usize];
            if j.w != w_new {
                if w_new > 0 {
                    // stop/checkpoint/restart (or cold start) penalty
                    j.busy_until = now + cfg.restart_cost;
                    total_rescales += 1;
                }
                j.w = w_new;
            }
        }

        // ---- 2b. sync the placement ledger ------------------------------
        // Desired holdings at this instant: explore reservations plus
        // granted ready widths. Jobs whose holding changed are released
        // and batch re-placed largest-first (the defragmenting re-pack);
        // jobs keeping their width keep their slots — no phantom
        // migrations, so spans only change when the scheduler moved you.
        // Flat pools skip the ledger entirely: `nodes` stays 0 and
        // `placed_epoch_secs` is an identity, so results are bit-equal
        // to the pre-placement simulator at zero extra cost.
        if !topology.is_flat() {
            let mut desired: Vec<(u64, usize)> = Vec::new();
            for (i, j) in jobs.iter().enumerate() {
                match j.state {
                    State::Exploring { .. } => desired.push((i as u64, explore_reserve)),
                    State::Ready if j.w > 0 => desired.push((i as u64, j.w)),
                    _ => {}
                }
            }
            for (id, held) in cluster.placed_jobs() {
                let keep = desired.iter().any(|&(d, w)| d == id && w == held);
                if !keep {
                    cluster.release(id).expect("ledger holds what it reported");
                }
            }
            let movers: Vec<(u64, usize)> = desired
                .iter()
                .copied()
                .filter(|&(id, _)| cluster.allocation_of(id).is_none())
                .collect();
            cluster.place_batch(&movers).expect("granted widths never exceed capacity");
            for (i, j) in jobs.iter_mut().enumerate() {
                j.nodes = cluster.nodes_spanned(i as u64);
            }
        }

        let concurrent = jobs
            .iter()
            .filter(|j| {
                matches!(j.state, State::Ready | State::Exploring { .. } | State::WaitingExplore)
            })
            .count();
        peak_concurrent = peak_concurrent.max(concurrent);

        // ---- 3. find the next event --------------------------------------
        let mut next = f64::INFINITY;
        for j in jobs.iter() {
            match j.state {
                State::NotArrived => next = next.min(j.profile.arrival),
                State::Exploring { end } => next = next.min(end),
                State::Ready if j.w > 0 => {
                    let start = now.max(j.busy_until);
                    let finish = start + j.remaining_epochs * j.secs_per_epoch_placed(cfg);
                    next = next.min(finish);
                }
                _ => {}
            }
        }
        if !next.is_finite() {
            break; // nothing left to happen
        }
        let next = next.max(now + EPS);

        // ---- 4. progress running jobs to `next` ---------------------------
        for j in jobs.iter_mut() {
            if j.state == State::Ready && j.w > 0 {
                let start = now.max(j.busy_until);
                let dt = (next - start).max(0.0);
                j.remaining_epochs =
                    (j.remaining_epochs - dt / j.secs_per_epoch_placed(cfg)).max(0.0);
            }
        }
        now = next;
    }

    let completion_secs: Vec<f64> = jobs
        .iter()
        .map(|j| match j.state {
            State::Done { finish } => finish - j.profile.arrival,
            _ => f64::NAN,
        })
        .collect();
    let completed = completion_secs.iter().filter(|v| v.is_finite()).count();
    let avg = completion_secs.iter().filter(|v| v.is_finite()).sum::<f64>()
        / completed.max(1) as f64;

    SimResult {
        strategy: cfg.strategy.name(),
        avg_completion_hours: avg / 3600.0,
        completed,
        makespan_hours: now / 3600.0,
        peak_concurrent,
        total_rescales,
        completion_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::super::workload::WorkloadGen;
    use super::super::{Contention, SimConfig, StrategyKind};
    use super::*;

    fn run(strategy: StrategyKind, contention: Contention, seed: u64) -> SimResult {
        let cfg = SimConfig::paper(strategy, contention, seed);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
        simulate(&cfg, &jobs)
    }

    #[test]
    fn all_jobs_complete() {
        for s in StrategyKind::table3_rows() {
            let r = run(s, Contention::None, 42);
            assert_eq!(r.completed, 44, "{}", r.strategy);
        }
    }

    #[test]
    fn single_job_no_contention_matches_serial_time() {
        let cfg = SimConfig::paper(StrategyKind::Fixed(4), Contention::None, 1);
        let mut cfg = cfg;
        cfg.n_jobs = 1;
        let jobs = WorkloadGen::default().generate(1, 1000.0, 1);
        let r = simulate(&cfg, &jobs);
        let want = jobs[0].serial_secs(4) + cfg.restart_cost;
        assert!(
            (r.completion_secs[0] - want).abs() < 1.0,
            "{} vs {}",
            r.completion_secs[0],
            want
        );
    }

    #[test]
    fn fixed8_fast_without_contention() {
        let r8 = run(StrategyKind::Fixed(8), Contention::None, 7);
        let r1 = run(StrategyKind::Fixed(1), Contention::None, 7);
        assert!(r8.avg_completion_hours < r1.avg_completion_hours / 2.0);
    }

    #[test]
    fn fixed8_poor_under_extreme_contention() {
        // Table 3: fixed-8 is the *worst* strategy at extreme contention
        let r8 = run(StrategyKind::Fixed(8), Contention::Extreme, 11);
        let r1 = run(StrategyKind::Fixed(1), Contention::Extreme, 11);
        assert!(r8.avg_completion_hours > r1.avg_completion_hours);
    }

    #[test]
    fn precompute_beats_or_ties_everything_moderate() {
        // §7: "the precompute algorithm always outperforms or ties"
        let pre = run(StrategyKind::Precompute, Contention::Moderate, 13);
        for s in [
            StrategyKind::Exploratory,
            StrategyKind::Fixed(8),
            StrategyKind::Fixed(4),
            StrategyKind::Fixed(2),
            StrategyKind::Fixed(1),
        ] {
            let r = run(s, Contention::Moderate, 13);
            assert!(
                pre.avg_completion_hours <= r.avg_completion_hours * 1.02,
                "precompute {:.2}h vs {} {:.2}h",
                pre.avg_completion_hours,
                r.strategy,
                r.avg_completion_hours
            );
        }
    }

    #[test]
    fn exploratory_pays_under_extreme_contention() {
        // §7: explore-optimize tradeoff works poorly under extreme load
        let exp = run(StrategyKind::Exploratory, Contention::Extreme, 17);
        let pre = run(StrategyKind::Precompute, Contention::Extreme, 17);
        assert!(exp.avg_completion_hours > pre.avg_completion_hours);
    }

    #[test]
    fn rescales_happen_for_adaptive_strategies() {
        let r = run(StrategyKind::Precompute, Contention::Moderate, 19);
        assert!(r.total_rescales > r.completed as u64);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(StrategyKind::Precompute, Contention::Moderate, 23);
        let b = run(StrategyKind::Precompute, Contention::Moderate, 23);
        assert_eq!(a.avg_completion_hours, b.avg_completion_hours);
        assert_eq!(a.total_rescales, b.total_rescales);
    }

    #[test]
    fn single_node_grid_reproduces_flat_bit_for_bit() {
        // Topology::Cluster(1 x 64) is the degenerate case: every ring
        // spans one node, so results must equal the flat pool exactly.
        let flat = run(StrategyKind::Precompute, Contention::Moderate, 29);
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 29)
            .with_topology(1, 64);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 29);
        let grid = simulate(&cfg, &jobs);
        assert_eq!(flat.avg_completion_hours.to_bits(), grid.avg_completion_hours.to_bits());
        assert_eq!(flat.total_rescales, grid.total_rescales);
        assert_eq!(flat.makespan_hours.to_bits(), grid.makespan_hours.to_bits());
        for (a, b) in flat.completion_secs.iter().zip(&grid.completion_secs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn topology_awareness_never_speeds_jobs_up() {
        use crate::perfmodel::PlacementModel;
        // Fixed-8 consults no speed model, so flat and grid worlds make
        // identical allocation decisions and differ only by the span
        // penalty — JCT degradation is guaranteed, not just likely.
        // (Adaptive strategies can legitimately reorder around the
        // penalty, so monotonicity is only provable for fixed-k.) On
        // 4-wide nodes every 8-gang must span 2, so with a comm-bound
        // payload the degradation is strict.
        let flat = run(StrategyKind::Fixed(8), Contention::Moderate, 31);
        let mut cfg = SimConfig::paper(StrategyKind::Fixed(8), Contention::Moderate, 31)
            .with_topology(16, 4);
        cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 31);
        let topo = simulate(&cfg, &jobs);
        assert_eq!(topo.completed, flat.completed);
        assert!(
            topo.avg_completion_hours > flat.avg_completion_hours,
            "topo {:.3}h did not degrade vs flat {:.3}h",
            topo.avg_completion_hours,
            flat.avg_completion_hours
        );
    }

    #[test]
    fn exploratory_probes_pay_the_internode_penalty_on_a_grid() {
        use crate::perfmodel::PlacementModel;
        // One comm-bound job; the probe ladder reaches 16, so the
        // exploration reservation is the whole 2x8 grid and the
        // 16-probe *must* span both nodes (smaller probes pack into one
        // reserved node and pay nothing). The job's profile is flat
        // beyond w=8, so after exploring, doubling settles at w=8 in
        // both worlds and the 8-gang packs into a single node on the
        // grid — post-explore speeds are identical, and the completion
        // gap is exactly the probes' lost progress.
        let mk = |flat: bool| -> SimResult {
            let mut cfg = SimConfig::paper(StrategyKind::Exploratory, Contention::None, 1);
            cfg.n_jobs = 1;
            cfg.explore_sizes = vec![1, 2, 4, 8, 16];
            if flat {
                cfg.capacity = 16;
                cfg.topology = Topology::flat(16);
            } else {
                cfg = cfg.with_topology(2, 8);
                cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
            }
            let jobs = WorkloadGen::default().generate(1, 1000.0, 1);
            simulate(&cfg, &jobs)
        };
        let flat = mk(true);
        let grid = mk(false);
        assert_eq!(flat.completed, 1);
        assert_eq!(grid.completed, 1);
        assert!(
            grid.completion_secs[0] > flat.completion_secs[0] + 1.0,
            "probes on the grid must make strictly less progress: \
             grid {:.1}s vs flat {:.1}s",
            grid.completion_secs[0],
            flat.completion_secs[0]
        );
    }

    #[test]
    fn exploratory_single_node_grid_is_bit_identical_to_flat() {
        // Cluster(1 x 64) is the degenerate grid: the reservation and
        // every probe span one node, so the exploratory strategy must
        // reproduce the flat pool exactly — the probe-placement change
        // costs flat worlds nothing.
        let flat = run(StrategyKind::Exploratory, Contention::Moderate, 41);
        let cfg = SimConfig::paper(StrategyKind::Exploratory, Contention::Moderate, 41)
            .with_topology(1, 64);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 41);
        let grid = simulate(&cfg, &jobs);
        assert_eq!(flat.avg_completion_hours.to_bits(), grid.avg_completion_hours.to_bits());
        assert_eq!(flat.total_rescales, grid.total_rescales);
        for (a, b) in flat.completion_secs.iter().zip(&grid.completion_secs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn deterministic_given_seed_on_a_grid() {
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 37)
            .with_topology(8, 8);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 37);
        let a = simulate(&cfg, &jobs);
        let b = simulate(&cfg, &jobs);
        assert_eq!(a.avg_completion_hours.to_bits(), b.avg_completion_hours.to_bits());
        assert_eq!(a.total_rescales, b.total_rescales);
    }
}
