//! The discrete-event simulation engine behind Table 3 — event-heap
//! edition.
//!
//! Time advances event-to-event (arrival, exploration end, completion);
//! between events every running job progresses linearly at its true
//! `secs_per_epoch(w)` — adjusted for the nodes its ring spans when the
//! pool is a real grid ([`SimConfig::topology`]). Every event triggers a
//! full reallocation under the configured strategy; a placement ledger
//! ([`ClusterState`]) maps granted widths to concrete GPUs with a
//! defragmenting re-pack over the jobs that moved, and any job whose
//! worker count changes pays the stop/restart cost (§6) as a busy period
//! with no progress.
//!
//! # Scaling design (PR 5)
//!
//! The original engine ([`super::reference`]) scanned the whole job
//! array four times per event, so a 100k-job trace cost O(events ×
//! jobs) — quadratic, since events grow with jobs. This engine keeps
//! the *decisions* bit-identical (asserted by `tests/golden_parity.rs`)
//! while making per-event cost proportional to the **active** set:
//!
//! - **arrivals** fire from a cursor over indices pre-sorted by
//!   `(arrival, idx)` with `f64::total_cmp` (NaN arrivals are excluded
//!   up front — they can never satisfy `arrival <= now`, so a malformed
//!   trace degrades to "job never arrives" instead of panicking or
//!   wedging the cursor);
//! - **exploration ends** live in a [`BinaryHeap`] keyed by end time
//!   (entries are never stale: a probe's end is fixed at admission);
//! - **ready** jobs are an indexed vector kept sorted in the FIFO
//!   `(arrival, idx)` order every strategy sees — maintained
//!   incrementally instead of re-filtered + re-sorted per event;
//! - **completions** are *not* cached in the heap: the next finish is
//!   recomputed from each running job's live `remaining_epochs` every
//!   event, exactly like the scan engine, because `remaining` is
//!   integrated with per-event floating-point subtraction and a cached
//!   forecast would drift from the scan engine in the last bits. The
//!   search is O(active), not O(jobs) — active is bounded by offered
//!   load, not trace length. PR 8 *prunes* that scan without caching
//!   the winner: each running job carries a slack-discounted **lower
//!   bound** on the finish the scan would compute, and a job whose
//!   bound already exceeds the best candidate so far is skipped — the
//!   surviving candidates go through the exact historical arithmetic,
//!   so the argmin and its bits are unchanged by construction
//!   (DESIGN.md §15.2, `SimConfig::completion_prune` switches it off);
//! - each job carries an `Arc`-shared `1/secs` table (built once) and a
//!   cached `secs/epoch` at its current `(w, nodes)`, so per-event
//!   `JobInfo` construction is an `Arc` bump per job (plus, on grids,
//!   one small `PlacedSpeed` wrapper Box — not a table copy) and
//!   progress integration does no table walks; on a grid, one shared
//!   [`PlacementModel::contiguous_extra_table`] memo prices eq 2–4 once
//!   per run instead of per probe;
//! - the **ledger** reconciles only jobs whose `(state, w)` changed this
//!   event (`touched`), instead of diffing `placed_jobs()` against a
//!   desired list rebuilt from every job. Jobs keeping their width keep
//!   their slots, so an untouched job can never need a ledger move.
//!
//! Reallocate-at-every-event semantics are fully preserved: the indexed
//! sets only change how we *find* the next event and who is
//! schedulable, never when the scheduler runs or what it sees.
//!
//! # Hot/cold state split (PR 8)
//!
//! The per-event inner loops (completion scan, progress integration)
//! stride a dense [`Hot`] array — `(remaining_epochs, secs_placed,
//! busy_until, w, finish_bound)`, one cache line per two jobs — while
//! everything an event touches at most once (profile, `Arc` speed
//! table, ledger bookkeeping, telemetry inputs) stays in the cold
//! [`SimJob`] array. The split also kills the per-event allocations:
//! the `JobInfo` batch, the mover list, and the traced-only decision
//! buffers are hoisted out of the event loop and recycled.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;
use std::sync::Arc;

use super::workload::{FaultEvent, FaultKind, JobProfile};
use super::{SimConfig, StrategyKind};
use crate::cluster::{ClusterState, Topology};
use crate::jsonx::Json;
use crate::scheduler::{
    doubling::Doubling, fixed::Fixed, optimus::OptimusGreedy, Allocation, GrantStep, JobInfo,
    Scheduler, Speed,
};
use crate::telemetry::{event, NullSink, Sink};

const EPS: f64 = 1e-6;

#[derive(Clone, Debug, PartialEq)]
enum State {
    NotArrived,
    /// Exploratory strategy only: queued until 8 GPUs free up.
    WaitingExplore,
    /// Holding the probe reservation; the end instant lives in the
    /// explore heap (the single source of truth for probe timers).
    Exploring,
    /// Schedulable (fixed pool or adaptive pool).
    Ready,
    Done { finish: f64 },
}

/// Cold per-job state: read at most a handful of times per event
/// (arrival fire, scheduler input construction, ledger reconciliation,
/// telemetry). Everything the per-event inner loops stride lives in
/// the dense [`Hot`] array instead.
struct SimJob {
    profile: JobProfile,
    state: State,
    /// Nodes the current gang spans (0 = unplaced; always 0 on a flat
    /// topology) — the placement half of the `(w, placement)` speed key.
    nodes: usize,
    /// `(w, 1/epoch_secs)` scheduler table, `Arc`-shared into every
    /// per-event `JobInfo` instead of cloned.
    speed: Arc<Vec<(usize, f64)>>,
    /// Width the placement ledger currently holds for this job
    /// (0 = unplaced; stays 0 on flat pools, which skip the ledger).
    held: usize,
    /// Rings sharing the busiest uplink this job's ring traverses,
    /// including its own (1 = sole tenant; always 1 while contention is
    /// off or the ring fits one node) — the contention third of the
    /// `(w, placement, contention)` speed key. Re-read from the link
    /// ledger after every reconciliation while contention is on.
    tenants: usize,
    /// Remaining epochs at the last stop/restart boundary — the durable
    /// checkpoint a fault eviction rolls back to (DESIGN.md §17).
    /// Snapshotted at every width change (each rescale stops the job
    /// through a checkpoint) and at probe completion. Only read when a
    /// fault fires, so fault-off runs merely store it.
    ckpt_remaining: f64,
    /// End instant of the probe currently in the explore heap; heap
    /// entries whose time no longer matches are stale (the probe was
    /// killed by a fault and the job re-queued). Fault-off probes are
    /// never killed, so every entry matches.
    probe_end: f64,
}

/// Hot per-job state: the fields the completion scan and the progress
/// integrator touch **every event** the job runs, packed into 48 bytes
/// so the scan strides a dense array instead of chasing `Arc`s through
/// ~150-byte cold structs (DESIGN.md §15.1).
#[derive(Clone, Copy)]
struct Hot {
    remaining_epochs: f64,
    /// Cached true secs/epoch at the current `(w, nodes, tenants)` —
    /// recomputed only when that key changes, read every event the job
    /// runs. Meaningless while `w == 0`.
    secs_placed: f64,
    /// No progress before this time (restart penalty).
    busy_until: f64,
    /// Completion-scan pruning bound (DESIGN.md §15.2): a strict lower
    /// bound on the finish instant the scan would compute for this job
    /// — the last *live-computed* finish discounted by
    /// [`BOUND_DISCOUNT`].
    /// The true finish is analytically constant while the job runs
    /// undisturbed; per-event FP integration of `remaining_epochs`
    /// drifts the recomputed value by ≲4 ulps/event, and the slack
    /// covers ≥10× that drift over [`BOUND_MAX_AGE`] events. Skipping
    /// a job whose bound is already `>=` the best candidate therefore
    /// cannot change the `f64::min` — the scan's winner and its bit
    /// pattern are preserved by construction. Reset to `NEG_INFINITY`
    /// (never prune) by [`refresh_secs`], which runs on every width /
    /// placement / tenancy change.
    finish_bound: f64,
    w: usize,
    /// Consecutive events this bound has pruned without a live
    /// recompute; at [`BOUND_MAX_AGE`] the job is rescanned so FP
    /// drift can never outrun the slack.
    bound_age: u32,
}

impl Hot {
    fn new(p: &JobProfile) -> Hot {
        Hot {
            remaining_epochs: p.total_epochs,
            secs_placed: f64::INFINITY,
            busy_until: 0.0,
            finish_bound: f64::NEG_INFINITY,
            w: 0,
            bound_age: 0,
        }
    }
}

/// Relative slack discounting a live-computed finish into a prune
/// bound: ~4.5e6 ulps at f64, versus ≲4 ulps/event of integration
/// drift × [`BOUND_MAX_AGE`] events ≈ 4e5 ulps worst case — an order
/// of magnitude of proof margin.
const BOUND_DISCOUNT: f64 = 1.0 - 1e-9;
/// Events a bound may keep pruning before a forced live recompute.
const BOUND_MAX_AGE: u32 = 100_000;

/// Refresh the cached secs/epoch after `w`, `nodes`, or `tenants`
/// moved. With contention off (or sole tenancy) this is exactly the
/// PR-3 `placed_epoch_secs` call — same floats, same order. Any such
/// change also voids the completion-scan prune bound: the job's finish
/// projection is about to jump, so it must be rescanned live.
fn refresh_secs(cold: &SimJob, cfg: &SimConfig, h: &mut Hot) {
    h.secs_placed = cfg.placement.contended_epoch_secs(
        cold.profile.secs_per_epoch(h.w),
        h.w,
        cold.nodes,
        cfg.link_contention,
        cold.tenants,
    );
    h.finish_bound = f64::NEG_INFINITY;
    h.bound_age = 0;
}

/// Outcome of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub strategy: String,
    /// Table 3's statistic.
    pub avg_completion_hours: f64,
    pub completed: usize,
    pub makespan_hours: f64,
    pub peak_concurrent: usize,
    pub total_rescales: u64,
    /// Per-job completion seconds (arrival -> finish).
    pub completion_secs: Vec<f64>,
    /// Distinct event instants the engine fired (loop iterations) — the
    /// denominator of the scale sweep's events/sec and µs/event rows.
    pub events: u64,
    /// Running jobs the completion scan considered over the whole run —
    /// the denominator of the pruner skip rate. Identical whether the
    /// pruner is on or off (it counts candidates, not recomputes);
    /// always 0 from the frozen reference engine. Diagnostics only:
    /// never part of the golden-parity contract.
    pub scan_candidates: u64,
    /// Candidates the finish-bound pruner skipped without a live
    /// recompute (0 when `completion_prune` is off, and from the
    /// reference engine). Diagnostics only, like `scan_candidates`.
    pub scan_skipped: u64,
    /// Gangs evicted by fault events (node-down + transient), probe
    /// reservations included. Always 0 with [`super::FaultPlan::OFF`]
    /// and from the reference engine.
    pub evictions: u64,
}

/// Heap key: ascending time via `total_cmp`, ties by job index so heap
/// order — and therefore everything downstream — is deterministic.
#[derive(Clone, Copy)]
struct TimeKey {
    t: f64,
    idx: usize,
}

impl PartialEq for TimeKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for TimeKey {}
impl PartialOrd for TimeKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimeKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t.total_cmp(&other.t).then_with(|| self.idx.cmp(&other.idx))
    }
}

/// Insert `i` into the ready pool, keeping it sorted by the FIFO key
/// `(arrival, idx)` — the exact order the scan engine's per-event
/// stable sort produced.
fn insert_ready(ready: &mut Vec<usize>, jobs: &[SimJob], i: usize) {
    let pos = ready.partition_point(|&r| {
        jobs[r]
            .profile
            .arrival
            .total_cmp(&jobs[i].profile.arrival)
            .then_with(|| r.cmp(&i))
            == Ordering::Less
    });
    ready.insert(pos, i);
}

/// Per-node GPU counts of an exploration reservation, largest block
/// first — computed once per exploring job, then consulted for every
/// probe size in the ladder. Empty when the reservation is not in the
/// ledger (callers fall back to the grid's contiguous best case).
pub(crate) fn reservation_blocks(cluster: &ClusterState, job: u64) -> Vec<usize> {
    let mut per_node: std::collections::BTreeMap<usize, usize> =
        std::collections::BTreeMap::new();
    for &(node, _) in cluster.allocation_of(job).unwrap_or(&[]) {
        *per_node.entry(node).or_insert(0) += 1;
    }
    let mut counts: Vec<usize> = per_node.into_values().collect();
    counts.sort_unstable_by(|a, b| b.cmp(a));
    counts
}

/// Nodes a probe ring of `s` GPUs spans inside a reservation with the
/// given per-node blocks: probes use the most-packed subset of the
/// reserved GPUs (whole blocks, largest first), so a probe that fits
/// one reserved node pays nothing even when the full reservation spans
/// several.
pub(crate) fn probe_span(blocks: &[usize], s: usize, topology: &Topology) -> usize {
    if blocks.is_empty() {
        return topology.min_span(s);
    }
    let mut need = s;
    let mut nodes = 0;
    for &c in blocks {
        if need == 0 {
            break;
        }
        need = need.saturating_sub(c);
        nodes += 1;
    }
    nodes.max(1)
}

/// Run one strategy over one generated workload.
pub fn simulate(cfg: &SimConfig, profiles: &[JobProfile]) -> SimResult {
    simulate_traced(cfg, profiles, &mut NullSink)
}

/// [`simulate`] narrating itself through a telemetry [`Sink`]. Every
/// hook is guarded by [`Sink::enabled`] and only *reads* engine state,
/// so with a [`NullSink`] this IS the pre-telemetry engine bit for bit
/// (golden-parity tested), and with a recorder the simulated results are
/// still bit-identical — the stream is a pure observation.
pub fn simulate_traced(
    cfg: &SimConfig,
    profiles: &[JobProfile],
    sink: &mut dyn Sink,
) -> SimResult {
    let topology = cfg
        .topology
        .reconciled(cfg.capacity)
        .expect("grid topology must agree with cfg.capacity (use with_topology)");
    let flat = topology.is_flat();
    // Link contention only exists where links do: flat pools (and the
    // off switch, the default) keep every pricing call on the exact
    // PR-3 path, so the contention-off engine is bit-identical to the
    // frozen reference (asserted by tests/golden_parity.rs).
    let contended = !flat && cfg.link_contention.enabled();
    let explore_reserve = cfg.explore_sizes.iter().copied().max().unwrap_or(8);
    let explore_duration = cfg.explore_secs_per_size * cfg.explore_sizes.len() as f64;
    let mut cluster = ClusterState::with_policy(topology.spec(), cfg.place_policy);

    // Fault injection (DESIGN.md §17): the whole timeline is drawn up
    // front from the plan's own seed, so fault-on runs are as
    // deterministic as fault-off ones — and with `FaultPlan::OFF` the
    // timeline is empty, no rng exists, and every fault branch below is
    // a false integer compare: the fault-off engine is the pre-fault
    // engine (golden-parity tested).
    let faults_on = !cfg.faults.is_off();
    assert!(
        !faults_on || !flat,
        "fault injection needs a grid topology (node failures are \
         meaningless on a flat pool) — use with_topology / --nodes"
    );
    let fault_timeline: Vec<FaultEvent> =
        if faults_on { cfg.faults.timeline(topology.spec().nodes) } else { Vec::new() };
    let mut next_fault = 0usize;
    let gpus_per_node = topology.spec().gpus_per_node;
    let mut down_count = 0usize;
    let mut total_evictions = 0u64;

    // One eq-2–4 span-penalty memo per run: in the sim the placement
    // model is global, so every job shares it.
    let memo: Option<Arc<Vec<f64>>> = match topology {
        Topology::Flat { .. } => None,
        Topology::Cluster(spec) => Some(Arc::new(
            cfg.placement.contiguous_extra_table(spec.gpus_per_node, cfg.capacity),
        )),
    };

    let mut jobs: Vec<SimJob> = profiles
        .iter()
        .map(|p| SimJob {
            profile: p.clone(),
            state: State::NotArrived,
            nodes: 0,
            speed: Arc::new(p.speed_table()),
            held: 0,
            tenants: 1,
            ckpt_remaining: p.total_epochs,
            probe_end: 0.0,
        })
        .collect();
    // Dense hot array, index-parallel to `jobs` (see module docs).
    let mut hot: Vec<Hot> = profiles.iter().map(Hot::new).collect();
    let prune = cfg.completion_prune;
    let mut scan_candidates = 0u64;
    let mut scan_skipped = 0u64;

    // Arrival cursor: indices sorted by (arrival, idx). NaN arrivals can
    // never fire (`NaN <= t` is false in the scan engine too), so they
    // are left out rather than wedging the cursor.
    let mut arrival_order: Vec<usize> =
        (0..jobs.len()).filter(|&i| !jobs[i].profile.arrival.is_nan()).collect();
    arrival_order.sort_by(|&a, &b| {
        jobs[a].profile.arrival.total_cmp(&jobs[b].profile.arrival).then_with(|| a.cmp(&b))
    });
    let mut next_arrival = 0usize;

    let mut ready: Vec<usize> = Vec::new(); // sorted by (arrival, idx)
    let mut waiting: Vec<usize> = Vec::new(); // FIFO explore-admission queue
    let mut exploring: BinaryHeap<Reverse<TimeKey>> = BinaryHeap::new();
    // Live probes. Equals `exploring.len()` except while the heap holds
    // stale entries for fault-killed probes — always equal when faults
    // are off, so using it for capacity/util keeps bit parity.
    let mut exploring_count = 0usize;

    let mut now = 0.0f64;
    let mut peak_concurrent = 0usize;
    let mut total_rescales = 0u64;
    let mut events = 0u64;
    // Convergence guard scaled with trace size: a healthy replay fires
    // ~3 events per job (arrival, optional explore end, completion); the
    // legacy 10M floor keeps the old headroom for EPS-step pathologies.
    let guard_limit = 10_000_000usize.saturating_add(jobs.len().saturating_mul(200));
    let mut guard = 0usize;

    // Jobs whose (state, w) changed this event — the only candidates
    // for a ledger move or a cached-speed refresh.
    let mut touched: Vec<usize> = Vec::new();
    // Per-event work buffers, hoisted out of the loop and recycled so
    // the steady-state event fires with zero heap allocations (the
    // scheduler's own internals aside).
    let mut infos: Vec<JobInfo> = Vec::new();
    let mut movers: Vec<(u64, usize)> = Vec::new();
    let mut grant_steps: Vec<GrantStep> = Vec::new();
    let mut decisions: Vec<(usize, usize, usize, bool)> = Vec::new();

    // Telemetry is opt-in: one branch per hook site, engine state only
    // ever *read*. Wall-clock phase timings go through the sink's
    // non-serialized side channel, never into the event stream, so the
    // stream stays a pure function of (cfg, profiles). Phase timings
    // have their own gate (`profiling`) so a PhaseProfiler can time the
    // run without paying for — or distorting itself with — the stream.
    let traced = sink.enabled();
    let profiling = sink.profiling();
    if traced {
        let (t_nodes, t_gpn) = match topology {
            Topology::Flat { .. } => (0usize, 0usize),
            Topology::Cluster(spec) => (spec.nodes, spec.gpus_per_node),
        };
        sink.emit(event(
            "run_start",
            now,
            vec![
                ("engine", Json::str("des")),
                ("strategy", Json::str(cfg.strategy.name())),
                ("capacity", Json::num(cfg.capacity as f64)),
                ("nodes", Json::num(t_nodes as f64)),
                ("gpus_per_node", Json::num(t_gpn as f64)),
                ("contended", Json::Bool(contended)),
                ("restart_cost", Json::num(cfg.restart_cost)),
                ("explore_reserve", Json::num(explore_reserve as f64)),
                ("seed", Json::num(cfg.seed as f64)),
                ("n_jobs", Json::num(jobs.len() as f64)),
            ],
        ));
    }

    loop {
        guard += 1;
        assert!(
            guard < guard_limit,
            "simulation failed to converge: {guard} events over {} jobs",
            jobs.len()
        );
        events += 1;
        touched.clear();
        let mut mark = if profiling { Some(std::time::Instant::now()) } else { None };

        // ---- 1. fire due events -----------------------------------------
        // Faults first: a completion scheduled at exactly the fault
        // instant loses the race — the failure hits before the epoch
        // boundary is checkpointed. With `FaultPlan::OFF` the timeline
        // is empty and this whole block is one false integer compare.
        while next_fault < fault_timeline.len() && fault_timeline[next_fault].t <= now + EPS {
            let f = fault_timeline[next_fault];
            next_fault += 1;
            match f.kind {
                FaultKind::Up => {
                    if cluster.is_node_down(f.node) {
                        cluster.set_node_up(f.node);
                        down_count -= 1;
                        if traced {
                            sink.count("node_ups", 1);
                            sink.emit(event(
                                "node_up",
                                now,
                                vec![("node", Json::num(f.node as f64))],
                            ));
                        }
                    }
                    continue;
                }
                FaultKind::Down => {
                    if cluster.is_node_down(f.node) {
                        continue; // overlapping bursts: already down
                    }
                    cluster.set_node_down(f.node);
                    down_count += 1;
                    if traced {
                        sink.count("node_downs", 1);
                        sink.emit(event(
                            "node_down",
                            now,
                            vec![("node", Json::num(f.node as f64))],
                        ));
                    }
                }
                FaultKind::Transient => {}
            }
            // Down and Transient both kill every gang with a GPU on the
            // node. Victims roll back to their last stop/restart
            // checkpoint; probes are killed outright and re-queued.
            // Slots are released *now*, not in the 2b sync: the
            // touched-only reconciliation compares widths, so a victim
            // re-granted its old width would otherwise keep its slots
            // on the failed node.
            for id in cluster.jobs_on_node(f.node) {
                let i = id as usize;
                let (probe, rework) = match jobs[i].state {
                    State::Ready => {
                        let rework =
                            (jobs[i].ckpt_remaining - hot[i].remaining_epochs).max(0.0);
                        hot[i].remaining_epochs = jobs[i].ckpt_remaining;
                        hot[i].w = 0;
                        (false, rework)
                    }
                    State::Exploring => {
                        jobs[i].state = State::WaitingExplore;
                        exploring_count -= 1;
                        waiting.push(i); // re-queue at the back, FIFO
                        (true, 0.0)
                    }
                    _ => continue,
                };
                cluster.release(id).expect("victim held the slots the ledger reported");
                jobs[i].held = 0;
                jobs[i].nodes = 0;
                touched.push(i);
                total_evictions += 1;
                if traced {
                    sink.count("evictions", 1);
                    sink.emit(event(
                        "seg_failed",
                        now,
                        vec![
                            ("job", Json::num(i as f64)),
                            ("node", Json::num(f.node as f64)),
                            ("kind", Json::str(f.kind.name())),
                            ("probe", Json::Bool(probe)),
                            ("rework_epochs", Json::num(rework)),
                        ],
                    ));
                }
            }
        }
        while next_arrival < arrival_order.len() {
            let i = arrival_order[next_arrival];
            if jobs[i].profile.arrival > now + EPS {
                break;
            }
            next_arrival += 1;
            match cfg.strategy {
                StrategyKind::Exploratory => {
                    jobs[i].state = State::WaitingExplore;
                    waiting.push(i); // arrivals fire in FIFO key order
                }
                _ => {
                    jobs[i].state = State::Ready;
                    insert_ready(&mut ready, &jobs, i);
                }
            }
            if traced {
                sink.count("arrivals", 1);
                sink.emit(event(
                    "arrival",
                    now,
                    vec![
                        ("job", Json::num(i as f64)),
                        ("at", Json::num(jobs[i].profile.arrival)),
                    ],
                ));
            }
        }
        while let Some(&Reverse(k)) = exploring.peek() {
            if k.t > now + EPS {
                break;
            }
            exploring.pop();
            let i = k.idx;
            // Entries for fault-killed probes are stale: the job was
            // re-queued (and possibly re-admitted with a new end). The
            // live probe's end is `probe_end` — bits-equal to its own
            // heap entry by construction, never to a stale one (ends
            // are `now + explore_duration` at distinct admission
            // instants). Fault-off probes are never killed, so this
            // guard never skips on the off path.
            if jobs[i].state != State::Exploring
                || jobs[i].probe_end.to_bits() != k.t.to_bits()
            {
                continue;
            }
            exploring_count -= 1;
            // Lump-sum progress of the probe runs (2.5 min each size).
            // Probes run *inside* the reservation the ledger granted, so
            // on a grid each probe size pays the eq-2 penalty of the
            // nodes it must span there — a fragmented reservation makes
            // exploration itself slower, exactly as on a real cluster.
            // Flat pools skip the ledger and keep the original
            // arithmetic bit-for-bit.
            let blocks =
                if flat { Vec::new() } else { reservation_blocks(&cluster, i as u64) };
            let gained: f64 = cfg
                .explore_sizes
                .iter()
                .map(|&s| {
                    let base = jobs[i].profile.secs_per_epoch(s);
                    let secs = if flat {
                        base
                    } else {
                        let nodes = probe_span(&blocks, s, &topology);
                        cfg.placement.placed_epoch_secs(base, s, nodes)
                    };
                    cfg.explore_secs_per_size / secs
                })
                .sum();
            hot[i].remaining_epochs = (hot[i].remaining_epochs - gained).max(0.0);
            jobs[i].state = State::Ready;
            // probe progress is committed at the probe's end boundary
            jobs[i].ckpt_remaining = hot[i].remaining_epochs;
            hot[i].w = 0;
            insert_ready(&mut ready, &jobs, i);
            touched.push(i); // reservation must be released (or re-won)
            if traced {
                sink.count("explore_ends", 1);
                sink.emit(event(
                    "explore_end",
                    now,
                    vec![
                        ("job", Json::num(i as f64)),
                        ("epochs_gained", Json::num(gained)),
                    ],
                ));
            }
        }
        ready.retain(|&i| {
            if hot[i].remaining_epochs <= EPS {
                jobs[i].state = State::Done { finish: now };
                hot[i].w = 0;
                touched.push(i);
                if traced {
                    sink.count("completions", 1);
                    sink.emit(event(
                        "complete",
                        now,
                        vec![
                            ("job", Json::num(i as f64)),
                            ("jct", Json::num(now - jobs[i].profile.arrival)),
                        ],
                    ));
                }
                false
            } else {
                true
            }
        });

        if let Some(m) = mark.as_mut() {
            let t = std::time::Instant::now();
            sink.phase_secs("fire", t.duration_since(*m).as_secs_f64());
            *m = t;
        }

        // ---- 2. reallocate ----------------------------------------------
        // exploration reservations are sticky; down nodes' GPUs leave
        // the schedulable pool until repair (their gangs were evicted
        // above, so the subtraction is exact)
        let pool = cfg
            .capacity
            .saturating_sub(gpus_per_node.saturating_mul(down_count));
        let mut capacity =
            pool.saturating_sub(explore_reserve.saturating_mul(exploring_count));
        // admit waiting explorers FIFO (they all need the same reserve,
        // so the first refusal ends the scan engine's full walk too)
        let mut admitted = 0usize;
        for &i in waiting.iter() {
            if capacity < explore_reserve {
                break;
            }
            capacity -= explore_reserve;
            let end = now + explore_duration;
            jobs[i].state = State::Exploring;
            jobs[i].probe_end = end;
            hot[i].busy_until = now; // probes include their own startup
            exploring.push(Reverse(TimeKey { t: end, idx: i }));
            exploring_count += 1;
            touched.push(i);
            admitted += 1;
            if traced {
                sink.count("explore_starts", 1);
                sink.emit(event(
                    "explore_start",
                    now,
                    vec![
                        ("job", Json::num(i as f64)),
                        ("hold", Json::num(explore_reserve as f64)),
                        ("until", Json::num(end)),
                    ],
                ));
            }
        }
        waiting.drain(..admitted);

        // Strategies score widths against the placement the grid would
        // actually grant: on a non-flat topology the speed is wrapped
        // with the eq-2 inter-node penalty at the contiguous best case
        // (memoized once per run).
        infos.clear();
        for &i in ready.iter() {
            let table = Speed::Shared(jobs[i].speed.clone());
            let speed = match (&memo, topology) {
                (Some(m), Topology::Cluster(spec)) => {
                    if contended {
                        // f(w, placement, contention): a candidate
                        // cross-node ring is scored as sharing its
                        // busiest link with the worst uplink on the
                        // grid (minus this job's own ring) — the
                        // pessimistic bound a scheduler can promise
                        // without knowing where the policy will put
                        // the gang. Sole tenancy takes the memoized
                        // uncontended path bit-for-bit.
                        let tenants = 1 + cluster.max_link_rings_excluding(i as u64);
                        Speed::placed_contended(
                            table,
                            cfg.placement,
                            spec.gpus_per_node,
                            Some(m.clone()),
                            cfg.link_contention,
                            tenants,
                        )
                    } else {
                        Speed::placed_memo(table, cfg.placement, spec.gpus_per_node, m.clone())
                    }
                }
                _ => table,
            };
            infos.push(JobInfo {
                id: i as u64,
                q: hot[i].remaining_epochs,
                speed,
                max_w: cfg.capacity,
            });
        }
        // Traced runs route through `allocate_traced`, which is the SAME
        // loop recording its pops; untraced runs keep the exact pre-
        // telemetry dispatch (golden-parity discipline).
        grant_steps.clear();
        let alloc: Allocation = if traced {
            match cfg.strategy {
                StrategyKind::Fixed(k) => {
                    Fixed(k).allocate_traced(&infos, capacity, &mut grant_steps)
                }
                StrategyKind::Optimus => {
                    OptimusGreedy.allocate_traced(&infos, capacity, &mut grant_steps)
                }
                StrategyKind::Precompute | StrategyKind::Exploratory => {
                    Doubling.allocate_traced(&infos, capacity, &mut grant_steps)
                }
            }
        } else {
            match cfg.strategy {
                StrategyKind::Fixed(k) => Fixed(k).allocate(&infos, capacity),
                StrategyKind::Optimus => OptimusGreedy.allocate(&infos, capacity),
                StrategyKind::Precompute | StrategyKind::Exploratory => {
                    Doubling.allocate(&infos, capacity)
                }
            }
        };
        decisions.clear();
        for (&id, &w_new) in &alloc {
            let h = &mut hot[id as usize];
            if h.w != w_new {
                if traced {
                    decisions.push((id as usize, h.w, w_new, w_new > 0));
                }
                if w_new > 0 {
                    // stop/checkpoint/restart (or cold start) penalty
                    h.busy_until = now + cfg.restart_cost;
                    total_rescales += 1;
                }
                // every stop/restart passes through a checkpoint — the
                // durable boundary a later fault rolls back to (a pure
                // cold-state store; never read while faults are off)
                jobs[id as usize].ckpt_remaining = h.remaining_epochs;
                h.w = w_new;
                touched.push(id as usize);
            }
        }
        if traced && !infos.is_empty() {
            sink.count("allocs", 1);
            sink.sample("alloc_jobs", infos.len() as f64);
            sink.sample("grant_steps", grant_steps.len() as f64);
            // Scoring tenancy re-reads the same ledger bound the infos
            // were priced with (pure, so the re-read is exact); execution
            // tenancy is observed after the ledger sync below and lands
            // in the `place` snapshot for the audit to diff against.
            let dec: Vec<Json> = decisions
                .iter()
                .map(|&(i, from, to, restart)| {
                    let scoring = if contended {
                        1 + cluster.max_link_rings_excluding(i as u64)
                    } else {
                        1
                    };
                    Json::obj(vec![
                        ("job", Json::num(i as f64)),
                        ("from", Json::num(from as f64)),
                        ("to", Json::num(to as f64)),
                        ("restart", Json::Bool(restart)),
                        ("scoring_tenancy", Json::num(scoring as f64)),
                    ])
                })
                .collect();
            let steps: Vec<Json> = grant_steps
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("job", Json::num(s.job as f64)),
                        ("from", Json::num(s.from_w as f64)),
                        ("to", Json::num(s.to_w as f64)),
                        ("gain", Json::num(s.gain)),
                        ("outcome", Json::str(s.outcome.name())),
                    ])
                })
                .collect();
            sink.emit(event(
                "alloc",
                now,
                vec![
                    ("free", Json::num(capacity as f64)),
                    ("n", Json::num(infos.len() as f64)),
                    ("decisions", Json::Arr(dec)),
                    ("steps", Json::Arr(steps)),
                ],
            ));
        }

        // ---- 2b. sync the placement ledger (dirty jobs only) -------------
        // A job's desired holding changes only when its state or width
        // did — i.e. it is in `touched` — so reconciliation never looks
        // at the untouched majority. Jobs keeping their width keep
        // their slots (no phantom migrations); everything released here
        // is re-placed in one largest-first batch, in ascending job
        // order, exactly like the scan engine's index-order walk. Flat
        // pools skip the ledger entirely: `nodes` stays 0 and
        // `placed_epoch_secs` is an identity, so results are bit-equal
        // at zero hot-path cost.
        if !flat {
            touched.sort_unstable();
            touched.dedup();
            movers.clear();
            for &i in touched.iter() {
                let desired = match jobs[i].state {
                    State::Exploring => explore_reserve,
                    State::Ready if hot[i].w > 0 => hot[i].w,
                    _ => 0,
                };
                if desired == jobs[i].held {
                    continue; // e.g. re-granted at the held width
                }
                if jobs[i].held > 0 {
                    cluster.release(i as u64).expect("ledger holds what it reported");
                }
                if desired > 0 {
                    movers.push((i as u64, desired));
                } else {
                    jobs[i].held = 0;
                    jobs[i].nodes = 0;
                }
            }
            cluster.place_batch(&movers).expect("granted widths never exceed capacity");
            for &(id, w) in &movers {
                let i = id as usize;
                jobs[i].held = w;
                jobs[i].nodes = cluster.nodes_spanned(id);
            }
        }
        // refresh cached speeds wherever (w, nodes) may have moved —
        // this also voids those jobs' completion-scan prune bounds
        for &i in touched.iter() {
            if hot[i].w > 0 {
                refresh_secs(&jobs[i], cfg, &mut hot[i]);
            }
        }
        // Contention-on: any place/release can change the tenancy of
        // rings that did NOT move (a new neighbour on their uplink), so
        // re-read the ledger for every running job and re-price the ones
        // whose tenancy moved. Execution speed is therefore piecewise-
        // constant between events at the *current* link population —
        // the same approximation the DES already makes for placement.
        // O(active × nodes) per event, paid only when the law is on.
        if contended {
            for &i in ready.iter() {
                if hot[i].w == 0 {
                    continue;
                }
                let j = &mut jobs[i];
                let t = if j.nodes > 1 { cluster.tenancy_of(i as u64) } else { 1 };
                if t != j.tenants {
                    j.tenants = t;
                    refresh_secs(&jobs[i], cfg, &mut hot[i]);
                }
            }
        }

        if traced {
            // Full placement snapshot whenever the ledger may have moved
            // (grid only; flat pools have no ledger). Placed jobs never
            // exceed capacity GPUs, so the snapshot is O(capacity) — the
            // audit replays per-node occupancy and crossing-ring counts
            // from these and cross-checks the incremental `links` ledger.
            if !flat && !touched.is_empty() {
                let mut placements: Vec<Json> = Vec::new();
                for (id, w) in cluster.placed_jobs() {
                    let i = id as usize;
                    let gpus: Vec<Json> = cluster
                        .node_gpu_counts(id)
                        .into_iter()
                        .map(|(n, c)| {
                            Json::Arr(vec![Json::num(n as f64), Json::num(c as f64)])
                        })
                        .collect();
                    placements.push(Json::obj(vec![
                        ("job", Json::num(i as f64)),
                        ("w", Json::num(w as f64)),
                        ("probe", Json::Bool(matches!(jobs[i].state, State::Exploring))),
                        ("gpus", Json::Arr(gpus)),
                        ("tenancy", Json::num(cluster.tenancy_of(id) as f64)),
                    ]));
                }
                let links: Vec<Json> = cluster
                    .link_rings()
                    .iter()
                    .enumerate()
                    .filter(|&(_, &r)| r > 0)
                    .map(|(n, &r)| Json::Arr(vec![Json::num(n as f64), Json::num(r as f64)]))
                    .collect();
                sink.sample("ledger_touched", touched.len() as f64);
                sink.emit(event(
                    "place",
                    now,
                    vec![
                        ("placements", Json::Arr(placements)),
                        ("links", Json::Arr(links)),
                    ],
                ));
            }
            let used: usize = ready.iter().map(|&i| hot[i].w).sum::<usize>()
                + explore_reserve * exploring_count;
            sink.sample("ready_len", ready.len() as f64);
            sink.sample("explore_heap", exploring.len() as f64);
            sink.emit(event(
                "util",
                now,
                vec![
                    ("used", Json::num(used as f64)),
                    ("capacity", Json::num(cfg.capacity as f64)),
                    ("running", Json::num(ready.iter().filter(|&&i| hot[i].w > 0).count() as f64)),
                    ("queued", Json::num(ready.iter().filter(|&&i| hot[i].w == 0).count() as f64)),
                    ("waiting", Json::num(waiting.len() as f64)),
                    ("exploring", Json::num(exploring_count as f64)),
                ],
            ));
        }
        if let Some(m) = mark.as_mut() {
            let t = std::time::Instant::now();
            sink.phase_secs("reallocate", t.duration_since(*m).as_secs_f64());
            *m = t;
        }

        let concurrent = ready.len() + exploring_count + waiting.len();
        peak_concurrent = peak_concurrent.max(concurrent);

        // ---- 3. find the next event --------------------------------------
        // The completion scan, optionally pruned by each job's finish
        // lower bound. A skipped job's true candidate provably cannot
        // lower `next`, so the `f64::min` chain over the survivors is
        // the historical chain over a superset — same winner, same bits
        // (invariant spelled out on `Hot::finish_bound`; both paths
        // CI-tested via RINGMASTER_PRUNE and the golden-parity matrix).
        let mut next = f64::INFINITY;
        if next_arrival < arrival_order.len() {
            next = next.min(jobs[arrival_order[next_arrival]].profile.arrival);
        }
        if let Some(&Reverse(k)) = exploring.peek() {
            next = next.min(k.t);
        }
        if next_fault < fault_timeline.len() {
            // Faults only matter while there is work to disturb: once
            // every job is done, draining the repair tail would just
            // inflate events and makespan for nothing.
            let work_left = next_arrival < arrival_order.len()
                || !ready.is_empty()
                || !waiting.is_empty()
                || exploring_count > 0;
            if work_left {
                next = next.min(fault_timeline[next_fault].t);
            }
        }
        for &i in &ready {
            let h = &mut hot[i];
            if h.w > 0 {
                scan_candidates += 1;
                if prune && h.finish_bound >= next && h.bound_age < BOUND_MAX_AGE {
                    h.bound_age += 1;
                    scan_skipped += 1;
                    continue;
                }
                let start = now.max(h.busy_until);
                let finish = start + h.remaining_epochs * h.secs_placed;
                h.finish_bound = finish * BOUND_DISCOUNT;
                h.bound_age = 0;
                next = next.min(finish);
            }
        }
        if let Some(m) = mark.as_mut() {
            let t = std::time::Instant::now();
            sink.phase_secs("scan", t.duration_since(*m).as_secs_f64());
            *m = t;
        }
        if !next.is_finite() {
            break; // nothing left to happen
        }
        let next = next.max(now + EPS);

        // ---- 4. progress running jobs to `next` ---------------------------
        for &i in &ready {
            let h = &mut hot[i];
            if h.w > 0 {
                let start = now.max(h.busy_until);
                let dt = (next - start).max(0.0);
                h.remaining_epochs = (h.remaining_epochs - dt / h.secs_placed).max(0.0);
            }
        }
        if let Some(m) = mark.as_ref() {
            sink.phase_secs("advance", m.elapsed().as_secs_f64());
        }
        now = next;
    }

    let completion_secs: Vec<f64> = jobs
        .iter()
        .map(|j| match j.state {
            State::Done { finish } => finish - j.profile.arrival,
            _ => f64::NAN,
        })
        .collect();
    let completed = completion_secs.iter().filter(|v| v.is_finite()).count();
    let avg = completion_secs.iter().filter(|v| v.is_finite()).sum::<f64>()
        / completed.max(1) as f64;

    if traced {
        sink.emit(event(
            "run_end",
            now,
            vec![
                ("completed", Json::num(completed as f64)),
                ("rescales", Json::num(total_rescales as f64)),
                ("events", Json::num(events as f64)),
                ("peak_concurrent", Json::num(peak_concurrent as f64)),
            ],
        ));
    }

    SimResult {
        strategy: cfg.strategy.name(),
        avg_completion_hours: avg / 3600.0,
        completed,
        makespan_hours: now / 3600.0,
        peak_concurrent,
        total_rescales,
        completion_secs,
        events,
        scan_candidates,
        scan_skipped,
        evictions: total_evictions,
    }
}

#[cfg(test)]
mod tests {
    use super::super::workload::{FaultPlan, WorkloadGen};
    use super::super::{Contention, SimConfig, StrategyKind};
    use super::*;

    fn run(strategy: StrategyKind, contention: Contention, seed: u64) -> SimResult {
        let cfg = SimConfig::paper(strategy, contention, seed);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
        simulate(&cfg, &jobs)
    }

    #[test]
    fn all_jobs_complete() {
        for s in StrategyKind::table3_rows() {
            let r = run(s, Contention::None, 42);
            assert_eq!(r.completed, 44, "{}", r.strategy);
        }
    }

    #[test]
    fn single_job_no_contention_matches_serial_time() {
        let cfg = SimConfig::paper(StrategyKind::Fixed(4), Contention::None, 1);
        let mut cfg = cfg;
        cfg.n_jobs = 1;
        let jobs = WorkloadGen::default().generate(1, 1000.0, 1);
        let r = simulate(&cfg, &jobs);
        let want = jobs[0].serial_secs(4) + cfg.restart_cost;
        assert!(
            (r.completion_secs[0] - want).abs() < 1.0,
            "{} vs {}",
            r.completion_secs[0],
            want
        );
    }

    #[test]
    fn fixed8_fast_without_contention() {
        let r8 = run(StrategyKind::Fixed(8), Contention::None, 7);
        let r1 = run(StrategyKind::Fixed(1), Contention::None, 7);
        assert!(r8.avg_completion_hours < r1.avg_completion_hours / 2.0);
    }

    #[test]
    fn fixed8_poor_under_extreme_contention() {
        // Table 3: fixed-8 is the *worst* strategy at extreme contention
        let r8 = run(StrategyKind::Fixed(8), Contention::Extreme, 11);
        let r1 = run(StrategyKind::Fixed(1), Contention::Extreme, 11);
        assert!(r8.avg_completion_hours > r1.avg_completion_hours);
    }

    #[test]
    fn precompute_beats_or_ties_everything_moderate() {
        // §7: "the precompute algorithm always outperforms or ties"
        let pre = run(StrategyKind::Precompute, Contention::Moderate, 13);
        for s in [
            StrategyKind::Exploratory,
            StrategyKind::Fixed(8),
            StrategyKind::Fixed(4),
            StrategyKind::Fixed(2),
            StrategyKind::Fixed(1),
        ] {
            let r = run(s, Contention::Moderate, 13);
            assert!(
                pre.avg_completion_hours <= r.avg_completion_hours * 1.02,
                "precompute {:.2}h vs {} {:.2}h",
                pre.avg_completion_hours,
                r.strategy,
                r.avg_completion_hours
            );
        }
    }

    #[test]
    fn exploratory_pays_under_extreme_contention() {
        // §7: explore-optimize tradeoff works poorly under extreme load
        let exp = run(StrategyKind::Exploratory, Contention::Extreme, 17);
        let pre = run(StrategyKind::Precompute, Contention::Extreme, 17);
        assert!(exp.avg_completion_hours > pre.avg_completion_hours);
    }

    #[test]
    fn rescales_happen_for_adaptive_strategies() {
        let r = run(StrategyKind::Precompute, Contention::Moderate, 19);
        assert!(r.total_rescales > r.completed as u64);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(StrategyKind::Precompute, Contention::Moderate, 23);
        let b = run(StrategyKind::Precompute, Contention::Moderate, 23);
        assert_eq!(a.avg_completion_hours, b.avg_completion_hours);
        assert_eq!(a.total_rescales, b.total_rescales);
    }

    #[test]
    fn optimus_strategy_runs_and_completes() {
        // the +1-greedy baseline rides the same engine: every job done,
        // and on the paper workload it should not beat precompute by
        // more than noise (doubling escapes the 8->9 cliff it cannot)
        let opt = run(StrategyKind::Optimus, Contention::Moderate, 13);
        assert_eq!(opt.completed, 114);
        assert!(opt.events > 0);
    }

    #[test]
    fn events_are_counted() {
        let r = run(StrategyKind::Fixed(8), Contention::None, 42);
        // at minimum one arrival + one completion instant per job,
        // minus coalesced instants; far more than jobs/2, far fewer
        // than the guard
        assert!(r.events as usize > r.completed / 2, "{}", r.events);
    }

    #[test]
    fn single_node_grid_reproduces_flat_bit_for_bit() {
        // Topology::Cluster(1 x 64) is the degenerate case: every ring
        // spans one node, so results must equal the flat pool exactly.
        let flat = run(StrategyKind::Precompute, Contention::Moderate, 29);
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 29)
            .with_topology(1, 64);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 29);
        let grid = simulate(&cfg, &jobs);
        assert_eq!(flat.avg_completion_hours.to_bits(), grid.avg_completion_hours.to_bits());
        assert_eq!(flat.total_rescales, grid.total_rescales);
        assert_eq!(flat.makespan_hours.to_bits(), grid.makespan_hours.to_bits());
        for (a, b) in flat.completion_secs.iter().zip(&grid.completion_secs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn topology_awareness_never_speeds_jobs_up() {
        use crate::perfmodel::PlacementModel;
        // Fixed-8 consults no speed model, so flat and grid worlds make
        // identical allocation decisions and differ only by the span
        // penalty — JCT degradation is guaranteed, not just likely.
        // (Adaptive strategies can legitimately reorder around the
        // penalty, so monotonicity is only provable for fixed-k.) On
        // 4-wide nodes every 8-gang must span 2, so with a comm-bound
        // payload the degradation is strict.
        let flat = run(StrategyKind::Fixed(8), Contention::Moderate, 31);
        let mut cfg = SimConfig::paper(StrategyKind::Fixed(8), Contention::Moderate, 31)
            .with_topology(16, 4);
        cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 31);
        let topo = simulate(&cfg, &jobs);
        assert_eq!(topo.completed, flat.completed);
        assert!(
            topo.avg_completion_hours > flat.avg_completion_hours,
            "topo {:.3}h did not degrade vs flat {:.3}h",
            topo.avg_completion_hours,
            flat.avg_completion_hours
        );
    }

    #[test]
    fn exploratory_probes_pay_the_internode_penalty_on_a_grid() {
        use crate::perfmodel::PlacementModel;
        // One comm-bound job; the probe ladder reaches 16, so the
        // exploration reservation is the whole 2x8 grid and the
        // 16-probe *must* span both nodes (smaller probes pack into one
        // reserved node and pay nothing). The job's profile is flat
        // beyond w=8, so after exploring, doubling settles at w=8 in
        // both worlds and the 8-gang packs into a single node on the
        // grid — post-explore speeds are identical, and the completion
        // gap is exactly the probes' lost progress.
        let mk = |flat: bool| -> SimResult {
            let mut cfg = SimConfig::paper(StrategyKind::Exploratory, Contention::None, 1);
            cfg.n_jobs = 1;
            cfg.explore_sizes = vec![1, 2, 4, 8, 16];
            if flat {
                cfg.capacity = 16;
                cfg.topology = Topology::flat(16);
            } else {
                cfg = cfg.with_topology(2, 8);
                cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
            }
            let jobs = WorkloadGen::default().generate(1, 1000.0, 1);
            simulate(&cfg, &jobs)
        };
        let flat = mk(true);
        let grid = mk(false);
        assert_eq!(flat.completed, 1);
        assert_eq!(grid.completed, 1);
        assert!(
            grid.completion_secs[0] > flat.completion_secs[0] + 1.0,
            "probes on the grid must make strictly less progress: \
             grid {:.1}s vs flat {:.1}s",
            grid.completion_secs[0],
            flat.completion_secs[0]
        );
    }

    #[test]
    fn exploratory_single_node_grid_is_bit_identical_to_flat() {
        // Cluster(1 x 64) is the degenerate grid: the reservation and
        // every probe span one node, so the exploratory strategy must
        // reproduce the flat pool exactly — the probe-placement change
        // costs flat worlds nothing.
        let flat = run(StrategyKind::Exploratory, Contention::Moderate, 41);
        let cfg = SimConfig::paper(StrategyKind::Exploratory, Contention::Moderate, 41)
            .with_topology(1, 64);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 41);
        let grid = simulate(&cfg, &jobs);
        assert_eq!(flat.avg_completion_hours.to_bits(), grid.avg_completion_hours.to_bits());
        assert_eq!(flat.total_rescales, grid.total_rescales);
        for (a, b) in flat.completion_secs.iter().zip(&grid.completion_secs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn deterministic_given_seed_on_a_grid() {
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 37)
            .with_topology(8, 8);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 37);
        let a = simulate(&cfg, &jobs);
        let b = simulate(&cfg, &jobs);
        assert_eq!(a.avg_completion_hours.to_bits(), b.avg_completion_hours.to_bits());
        assert_eq!(a.total_rescales, b.total_rescales);
    }

    #[test]
    fn link_contention_degrades_jct_when_rings_share_uplinks() {
        use crate::perfmodel::{LinkContention, PlacementModel};
        // Fixed-6 on 4-wide nodes: every gang is 4+2, so Pack's best-fit
        // remainder rule stacks concurrent gangs' remainders onto the
        // same partial node — shared uplinks whenever two jobs overlap.
        // Fixed-k consults no speed model, so the contention law only
        // slows execution; average JCT must strictly degrade.
        let mk = |law: LinkContention| {
            let mut cfg = SimConfig::paper(StrategyKind::Fixed(6), Contention::Moderate, 47)
                .with_topology(4, 4);
            cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
            cfg.link_contention = law;
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 47);
            simulate(&cfg, &jobs)
        };
        let off = mk(LinkContention::OFF);
        let on = mk(LinkContention::fair_share());
        assert_eq!(off.completed, on.completed);
        assert!(
            on.avg_completion_hours > off.avg_completion_hours,
            "contention on {:.3}h did not degrade vs off {:.3}h",
            on.avg_completion_hours,
            off.avg_completion_hours
        );
    }

    #[test]
    fn spread_policy_recovers_contention_losses() {
        use crate::cluster::PlacePolicy;
        use crate::perfmodel::{LinkContention, PlacementModel};
        // Same contended world, blind vs aware placement: Spread gives
        // concurrent 6-gangs disjoint link groups, so it must not lose
        // to Pack's stacked remainders.
        let mk = |policy: PlacePolicy| {
            let mut cfg = SimConfig::paper(StrategyKind::Fixed(6), Contention::Moderate, 53)
                .with_topology(4, 4);
            cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
            cfg.link_contention = LinkContention::fair_share();
            cfg.place_policy = policy;
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 53);
            simulate(&cfg, &jobs)
        };
        let pack = mk(PlacePolicy::Pack);
        let spread = mk(PlacePolicy::Spread);
        assert_eq!(pack.completed, spread.completed);
        assert!(
            spread.avg_completion_hours <= pack.avg_completion_hours,
            "spread {:.3}h lost to pack {:.3}h under contention",
            spread.avg_completion_hours,
            pack.avg_completion_hours
        );
    }

    #[test]
    fn contention_on_single_node_grid_is_still_bit_identical_to_flat() {
        use crate::perfmodel::LinkContention;
        // 1x64: no ring can ever cross a link, so even with the law
        // enabled every job is sole tenant and the engine must
        // reproduce the flat pool bit for bit — the engine-level form
        // of "intra-node jobs are unaffected by link contention".
        let flat = run(StrategyKind::Precompute, Contention::Moderate, 59);
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 59)
            .with_topology(1, 64);
        cfg.link_contention = LinkContention::fair_share();
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 59);
        let grid = simulate(&cfg, &jobs);
        assert_eq!(flat.avg_completion_hours.to_bits(), grid.avg_completion_hours.to_bits());
        assert_eq!(flat.total_rescales, grid.total_rescales);
        for (a, b) in flat.completion_secs.iter().zip(&grid.completion_secs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pruner_on_and_off_are_bit_identical_and_it_actually_skips() {
        // The completion-scan pruner's whole contract: flipping it must
        // not move a single bit, and on a busy workload it must earn
        // its keep. fixed-1 at extreme contention keeps the most jobs
        // running concurrently — the scan-heaviest regime.
        for (s, topo) in [
            (StrategyKind::Fixed(1), None),
            (StrategyKind::Precompute, Some((8usize, 8usize))),
            (StrategyKind::Exploratory, Some((8, 8))),
        ] {
            let mut cfg = SimConfig::paper(s, Contention::Extreme, 3);
            if let Some((n, g)) = topo {
                cfg = cfg.with_topology(n, g);
            }
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 3);
            let on = simulate(&cfg, &jobs);
            cfg.completion_prune = false;
            let off = simulate(&cfg, &jobs);
            assert_eq!(
                on.avg_completion_hours.to_bits(),
                off.avg_completion_hours.to_bits(),
                "{}: avg moved under pruning",
                on.strategy
            );
            assert_eq!(on.total_rescales, off.total_rescales, "{}", on.strategy);
            assert_eq!(on.events, off.events, "{}", on.strategy);
            for (i, (a, b)) in on.completion_secs.iter().zip(&off.completion_secs).enumerate()
            {
                assert_eq!(a.to_bits(), b.to_bits(), "{} job {i}", on.strategy);
            }
            // candidate counts are prune-invariant; skips only exist
            // on the pruned path
            assert_eq!(on.scan_candidates, off.scan_candidates, "{}", on.strategy);
            assert_eq!(off.scan_skipped, 0, "{}", off.strategy);
            assert!(
                on.scan_skipped > 0,
                "{}: pruner never skipped on a scan-heavy run ({} candidates)",
                on.strategy,
                on.scan_candidates
            );
        }
    }

    #[test]
    fn prune_bound_slack_dominates_drift_over_max_age() {
        // The invariant's arithmetic: the slack must exceed the worst
        // per-event drift (≲4 ulps relative) accumulated over the age
        // cap, with at least 10x margin (DESIGN.md §15.2).
        let drift_per_event = 4.0 * f64::EPSILON;
        let worst = drift_per_event * BOUND_MAX_AGE as f64;
        assert!(
            (1.0 - BOUND_DISCOUNT) >= 10.0 * worst,
            "slack {} vs worst-case drift {}",
            1.0 - BOUND_DISCOUNT,
            worst
        );
    }

    #[test]
    fn faults_evict_and_every_job_still_completes() {
        // Steady per-node failures on an 8x8 grid: gangs get evicted,
        // roll back to their checkpoints, and — because every Down is
        // paired with a repair — the whole trace still drains.
        for s in [
            StrategyKind::Precompute,
            StrategyKind::Exploratory,
            StrategyKind::Fixed(8),
        ] {
            let mut cfg =
                SimConfig::paper(s, Contention::Moderate, 61).with_topology(8, 8);
            cfg.faults = FaultPlan::steady(20_000.0, 600.0, 400_000.0, 61);
            let jobs =
                WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 61);
            let r = simulate(&cfg, &jobs);
            assert_eq!(r.completed, cfg.n_jobs, "{}", r.strategy);
            assert!(r.evictions > 0, "{}: the plan never fired", r.strategy);
            for c in &r.completion_secs {
                assert!(c.is_finite());
            }
        }
    }

    #[test]
    fn fault_runs_are_bit_deterministic() {
        let mk = || {
            let mut cfg =
                SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 67)
                    .with_topology(8, 8);
            cfg.faults = FaultPlan::burst(400_000.0, 67);
            let jobs =
                WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 67);
            simulate(&cfg, &jobs)
        };
        let a = mk();
        let b = mk();
        assert!(a.evictions > 0, "burst preset never fired");
        assert_eq!(a.evictions, b.evictions);
        assert_eq!(a.total_rescales, b.total_rescales);
        assert_eq!(a.events, b.events);
        assert_eq!(a.avg_completion_hours.to_bits(), b.avg_completion_hours.to_bits());
        for (x, y) in a.completion_secs.iter().zip(&b.completion_secs) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn faults_never_speed_up_a_fixed_strategy() {
        // Fixed-k consults no speed model, so the only effect of faults
        // is lost progress and lost capacity: average JCT must not
        // improve, and with evictions observed it strictly degrades.
        let mut clean = SimConfig::paper(StrategyKind::Fixed(8), Contention::Moderate, 71)
            .with_topology(8, 8);
        let jobs =
            WorkloadGen::default().generate(clean.n_jobs, clean.mean_interarrival, 71);
        let base = simulate(&clean, &jobs);
        clean.faults = FaultPlan::steady(15_000.0, 900.0, 400_000.0, 71);
        let faulted = simulate(&clean, &jobs);
        assert_eq!(base.completed, faulted.completed);
        assert!(faulted.evictions > 0);
        assert!(
            faulted.avg_completion_hours > base.avg_completion_hours,
            "faulted {:.3}h did not degrade vs clean {:.3}h ({} evictions)",
            faulted.avg_completion_hours,
            base.avg_completion_hours,
            faulted.evictions
        );
        assert_eq!(base.evictions, 0);
    }

    #[test]
    fn zero_rate_plan_is_the_off_plan() {
        // mtbf == 0 means "never fails" (rate-0), and the engine must
        // treat it as structurally off: same bits as the default OFF.
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 73)
            .with_topology(8, 8);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 73);
        let off = simulate(&cfg, &jobs);
        let mut zero = cfg.clone();
        zero.faults = FaultPlan::steady(0.0, 600.0, 400_000.0, 73);
        assert!(zero.faults.is_off());
        let z = simulate(&zero, &jobs);
        assert_eq!(off.avg_completion_hours.to_bits(), z.avg_completion_hours.to_bits());
        assert_eq!(off.events, z.events);
        assert_eq!(z.evictions, 0);
        for (a, b) in off.completion_secs.iter().zip(&z.completion_secs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn nan_arrival_degrades_to_never_arriving_not_a_panic() {
        // Malformed traces must not wedge the arrival cursor or poison
        // the sorts: the NaN job simply never arrives (completion NaN),
        // every well-formed job still completes.
        let cfg = SimConfig::paper(StrategyKind::Precompute, Contention::None, 5);
        let mut jobs = WorkloadGen::default().generate(10, 1000.0, 5);
        jobs[3].arrival = f64::NAN;
        let r = simulate(&cfg, &jobs);
        assert_eq!(r.completed, 9);
        assert!(r.completion_secs[3].is_nan());
        for (i, c) in r.completion_secs.iter().enumerate() {
            if i != 3 {
                assert!(c.is_finite(), "job {i} should have completed");
            }
        }
    }
}
