//! Synthetic training corpus (substrate for the E2E workload).
//!
//! The paper trains ResNet-110 on CIFAR-10; our workload is a causal LM
//! (DESIGN.md §2), so the substrate is a token stream with *learnable*
//! structure: a noisy bigram process — with probability `1 - noise` the
//! next token is a fixed random permutation of the current one, else
//! uniform. A model that learns the permutation drives cross-entropy
//! from `ln(V)` down to `≈ H(noise)`, giving a real, paper-shaped 1/k
//! loss curve for the convergence model to fit.

use crate::rngx::Rng;

/// Deterministic synthetic corpus.
#[derive(Clone, Debug)]
pub struct Corpus {
    vocab: usize,
    perm: Vec<u32>,
    noise: f64,
    seed: u64,
}

impl Corpus {
    /// `noise` in [0,1): probability a token ignores the bigram rule.
    pub fn new(vocab: usize, noise: f64, seed: u64) -> Self {
        assert!(vocab >= 2 && (0.0..1.0).contains(&noise));
        // Fisher-Yates with the deterministic RNG.
        let mut perm: Vec<u32> = (0..vocab as u32).collect();
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        for i in (1..vocab).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        Corpus { vocab, perm, noise, seed }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Entropy floor of the process in nats (best achievable mean NLL,
    /// by the chain rule of the noisy-bigram construction).
    pub fn entropy_floor(&self) -> f64 {
        let v = self.vocab as f64;
        let p_hit = (1.0 - self.noise) + self.noise / v;
        let p_other = self.noise / v;
        let mut h = -p_hit * p_hit.ln();
        if p_other > 0.0 {
            h -= (v - 1.0) * p_other * p_other.ln();
        }
        h
    }

    /// Generate one `(inputs, targets)` window of length `t` for a given
    /// (worker, step) coordinate. Streams are disjoint across coordinates
    /// and deterministic — the data-parallel sharding contract.
    pub fn window(&self, worker: usize, step: u64, row: usize, t: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ step.wrapping_mul(0xD1B54A32D192ED03)
                ^ (row as u64).wrapping_mul(0x2545F4914F6CDD1D),
        );
        let mut cur = rng.below(self.vocab) as u32;
        let mut seq = Vec::with_capacity(t + 1);
        seq.push(cur as i32);
        for _ in 0..t {
            cur = if rng.uniform() < self.noise {
                rng.below(self.vocab) as u32
            } else {
                self.perm[cur as usize]
            };
            seq.push(cur as i32);
        }
        (seq[..t].to_vec(), seq[1..].to_vec())
    }

    /// A full `(inputs, targets)` minibatch, flattened row-major
    /// `(batch*t,)` — the layout the PJRT literals expect.
    pub fn batch(&self, worker: usize, step: u64, batch: usize, t: usize) -> (Vec<i32>, Vec<i32>) {
        let mut inputs = Vec::with_capacity(batch * t);
        let mut targets = Vec::with_capacity(batch * t);
        for row in 0..batch {
            let (i, tg) = self.window(worker, step, row, t);
            inputs.extend(i);
            targets.extend(tg);
        }
        (inputs, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let c = Corpus::new(256, 0.1, 7);
        assert_eq!(c.batch(0, 3, 4, 16), c.batch(0, 3, 4, 16));
    }

    #[test]
    fn distinct_across_workers_and_steps() {
        let c = Corpus::new(256, 0.1, 7);
        let a = c.batch(0, 0, 2, 16);
        assert_ne!(a, c.batch(1, 0, 2, 16));
        assert_ne!(a, c.batch(0, 1, 2, 16));
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = Corpus::new(100, 0.2, 1);
        let (i, t) = c.batch(2, 5, 4, 32);
        for &tok in i.iter().chain(&t) {
            assert!((0..100).contains(&tok));
        }
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let c = Corpus::new(64, 0.0, 3);
        let (i, t) = c.window(0, 0, 0, 16);
        // noise=0: target[j] == perm[input[j]] and input[j+1] == target[j]
        for j in 0..15 {
            assert_eq!(i[j + 1], t[j]);
        }
    }

    #[test]
    fn zero_noise_is_fully_predictable() {
        let c = Corpus::new(64, 0.0, 3);
        assert!(c.entropy_floor() < 1e-9);
    }

    #[test]
    fn entropy_floor_below_uniform() {
        let c = Corpus::new(256, 0.2, 3);
        assert!(c.entropy_floor() < (256f64).ln());
        assert!(c.entropy_floor() > 0.0);
    }

    #[test]
    fn bigram_structure_dominates() {
        // with noise 0.1, ~90% of transitions follow the permutation
        let c = Corpus::new(128, 0.1, 11);
        let (i, t) = c.batch(0, 0, 8, 64);
        let hits = i
            .iter()
            .zip(&t)
            .filter(|&(&a, &b)| c.perm[a as usize] == b as u32)
            .count();
        let frac = hits as f64 / i.len() as f64;
        assert!(frac > 0.85, "frac={frac}");
    }
}
