//! Analytic all-reduce cost models — eqs 2–4 of the paper (§3.2).
//!
//! `α` is the per-message latency, `β` the transfer time per byte, `γ`
//! the reduction compute cost per byte, `n` the model size in bytes, `m`
//! the per-worker minibatch, `w` the worker count. The coefficients come
//! from the underlying collective primitives (Thakur & Rabenseifner '05):
//!
//! - eq 2 (ring):            `(w-1)·4α + (w-1)·(n/w)·4β + (w-1)·(n/w)·2γ`
//! - eq 3 (doubling-halving):`4·log2(w)·α + 4nβ + (5/2)nγ`
//! - eq 4 (binary blocks):   `(5 + 4⌈log2 w⌉)α + 7nβ + 3nγ`
//!
//! These models drive everything downstream: the resource model f(w)
//! (eq 5) mirrors their structure, the doubling heuristic exists because
//! eq 4 > eq 3 at equal w, and the simulator's job speeds derive from
//! them. Unit tests cross-check the models against the *measured*
//! message/byte counters of the real implementations.


/// Which all-reduce algorithm a job of `w` workers runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Eq 2 — bandwidth-optimal, latency linear in `w`.
    Ring,
    /// Eq 3 — power-of-two worlds only.
    DoublingHalving,
    /// Eq 4 — any world size; pays fold/unfold overhead.
    BinaryBlocks,
}

impl Algorithm {
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Ring => "ring",
            Algorithm::DoublingHalving => "doubling-halving",
            Algorithm::BinaryBlocks => "binary-blocks",
        }
    }
}

/// Machine constants of the interconnect + reduction units.
///
/// Defaults approximate the paper's testbed: 4xEDR InfiniBand
/// (100 Gbit/s ≈ 12.5 GB/s → β = 8e-11 s/B), ~5 µs message latency, and
/// a memory-bandwidth-bound vector sum (~10 GB/s → γ = 1e-10 s/B).
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// Latency per message (seconds).
    pub alpha: f64,
    /// Transfer time per byte (seconds).
    pub beta: f64,
    /// Reduction compute per byte (seconds).
    pub gamma: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams { alpha: 5e-6, beta: 8e-11, gamma: 1e-10 }
    }
}

impl CostParams {
    /// Intra-node interconnect: NVLink/PCIe-class links plus shared
    /// memory — ~1 µs message latency, ~50 GB/s per link.
    pub fn intra_node() -> CostParams {
        CostParams { alpha: 1e-6, beta: 2e-11, gamma: 1e-10 }
    }

    /// Commodity inter-node network (the setting where placement is
    /// first-order, cf. GADGET): 10 GbE-class — ~50 µs latency,
    /// ~1.25 GB/s. The paper's own EDR InfiniBand testbed sits between
    /// this and `intra_node`; pick explicit α/β to model it.
    pub fn inter_node() -> CostParams {
        CostParams { alpha: 5e-5, beta: 8e-10, gamma: 1e-10 }
    }
}

fn log2f(w: usize) -> f64 {
    (w as f64).log2()
}

fn log2ceil(w: usize) -> f64 {
    (w as f64).log2().ceil()
}

/// Communication time of one all-reduce over `n_bytes` with `w` workers
/// (the α/β/γ terms of eqs 2–4; zero for `w <= 1`).
pub fn comm_time(alg: Algorithm, w: usize, n_bytes: f64, p: &CostParams) -> f64 {
    if w <= 1 {
        return 0.0;
    }
    let wf = w as f64;
    match alg {
        Algorithm::Ring => {
            (wf - 1.0) * 4.0 * p.alpha
                + (wf - 1.0) * (n_bytes / wf) * 4.0 * p.beta
                + (wf - 1.0) * (n_bytes / wf) * 2.0 * p.gamma
        }
        Algorithm::DoublingHalving => {
            4.0 * log2f(w) * p.alpha + 4.0 * n_bytes * p.beta + 2.5 * n_bytes * p.gamma
        }
        Algorithm::BinaryBlocks => {
            (5.0 + 4.0 * log2ceil(w)) * p.alpha + 7.0 * n_bytes * p.beta + 3.0 * n_bytes * p.gamma
        }
    }
}

/// Full per-minibatch step time — eqs 2–4 complete: compute + all-reduce.
///
/// `m` is the per-worker minibatch size, `t_fwd`/`t_back` per-example
/// forward/backward seconds.
pub fn step_time(
    alg: Algorithm,
    m: f64,
    t_fwd: f64,
    t_back: f64,
    w: usize,
    n_bytes: f64,
    p: &CostParams,
) -> f64 {
    m * (t_fwd + t_back) + comm_time(alg, w, n_bytes, p)
}

/// The algorithm the runtime picks for `w` workers (§2.1 policy).
pub fn algorithm_for(w: usize, n_bytes: f64) -> Algorithm {
    const RING_BYTES: f64 = 4.0e7; // ~1e7 f32 params
    if n_bytes > RING_BYTES {
        Algorithm::Ring
    } else if w.is_power_of_two() {
        Algorithm::DoublingHalving
    } else {
        Algorithm::BinaryBlocks
    }
}

/// Step time with the runtime's own algorithm choice.
pub fn step_time_auto(m: f64, t_fwd: f64, t_back: f64, w: usize, n_bytes: f64, p: &CostParams) -> f64 {
    step_time(algorithm_for(w, n_bytes), m, t_fwd, t_back, w, n_bytes, p)
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: CostParams = CostParams { alpha: 5e-6, beta: 8e-11, gamma: 1e-10 };

    #[test]
    fn single_worker_costs_nothing() {
        for alg in [Algorithm::Ring, Algorithm::DoublingHalving, Algorithm::BinaryBlocks] {
            assert_eq!(comm_time(alg, 1, 1e6, &P), 0.0);
        }
    }

    #[test]
    fn dh_beats_ring_for_small_payloads_at_scale() {
        // §2.1: latency term dominates for small n; dh has log(w) msgs.
        // (With eq 2/3's coefficient conventions dh's bandwidth term is a
        // flat 4nβ vs ring's (w-1)/w·4nβ, so dh's win lives where α rules.)
        let n = 4.0 * 1e4; // 10k params
        for w in [4usize, 8, 16, 32] {
            assert!(
                comm_time(Algorithm::DoublingHalving, w, n, &P)
                    < comm_time(Algorithm::Ring, w, n, &P),
                "w={w}"
            );
        }
    }

    #[test]
    fn ring_wins_for_huge_payloads_at_scale() {
        // ring moves (w-1)/w * 4n bytes vs dh's flat 4n, and for big n the
        // bandwidth term dwarfs latency — but the gap only matters once
        // n/w terms differ; check the crossover direction at large w & n.
        let n = 4.0 * 5e8; // 500M params
        let w = 64;
        assert!(
            comm_time(Algorithm::Ring, w, n, &P) < comm_time(Algorithm::BinaryBlocks, w, n, &P)
        );
    }

    #[test]
    fn bb_always_costs_more_than_dh_at_same_w() {
        let n = 4.0 * 1e6;
        for w in [2usize, 4, 8, 16, 64] {
            assert!(
                comm_time(Algorithm::BinaryBlocks, w, n, &P)
                    > comm_time(Algorithm::DoublingHalving, w, n, &P),
                "w={w}"
            );
        }
    }

    #[test]
    fn eight_to_nine_cliff() {
        // §4.2: 9 workers forces binary-blocks, costing more than 8 with dh
        let n = 4.0 * 1e6;
        let t8 = comm_time(Algorithm::DoublingHalving, 8, n, &P);
        let t9 = comm_time(Algorithm::BinaryBlocks, 9, n, &P);
        let t16 = comm_time(Algorithm::DoublingHalving, 16, n, &P);
        assert!(t9 > t8);
        // and 16 (power of two) is barely worse than 8 — the heuristic's point
        assert!(t16 - t8 < t9 - t8);
    }

    #[test]
    fn step_time_includes_compute() {
        let t = step_time(Algorithm::DoublingHalving, 128.0, 1e-3, 2e-3, 4, 4e6, &P);
        assert!(t > 128.0 * 3e-3);
    }

    #[test]
    fn auto_policy_matches_module_selector() {
        for w in 1..20 {
            for n in [1000usize, 100_000, 20_000_000] {
                let got = algorithm_for(w, (n * 4) as f64);
                let want = super::super::select_algorithm(w, n);
                assert_eq!(got, want, "w={w} n={n}");
            }
        }
    }

    #[test]
    fn models_track_measured_traffic_shape() {
        // The β terms of eqs 2-4 must rank algorithms the same way the
        // real implementations' measured bytes do (w=8, latency-bound n).
        use super::super::{bb, dh, ring};
        let w = 8;
        let n = 1 << 14; // elements
        let nb = (n * 4) as f64;
        let per_rank = |total: u64| total as f64 / w as f64;
        let measured_ring = per_rank(ring::predicted_bytes(w, n));
        let measured_dh = per_rank(dh::predicted_bytes(w, n));
        // ring per-rank bytes: 2n(w-1)/w*4 ; dh: 2n(1-1/w)*4 — equal here;
        // the *latency* term separates them, which the model captures:
        assert!((measured_ring - measured_dh).abs() < 1e-6);
        let model_ring = comm_time(Algorithm::Ring, w, nb, &P);
        let model_dh = comm_time(Algorithm::DoublingHalving, w, nb, &P);
        assert!(model_dh < model_ring);
    }
}
