//! Shared-memory all-reduce transport (§Perf optimization).
//!
//! The ring / doubling-halving / binary-blocks implementations in this
//! module's siblings are faithful *message-passing* algorithms — each
//! send allocates and copies, exactly like wire traffic, which is what
//! makes their byte counters comparable to eqs 2–4. But our ranks are
//! threads in one address space, so the trainer's hot path can use the
//! transport NCCL would use intra-node: a shared reduction buffer.
//!
//! Protocol (reduce-scatter + broadcast over shared slots):
//!  1. every rank publishes a read-only view of its vector, barrier;
//!  2. rank `r` reduces segment `r` (over all published views) into the
//!     shared accumulator, barrier;
//!  3. every rank copies the accumulator back into its own vector.
//!
//! Three linear passes over the data per rank vs the channel transport's
//! allocate+copy per message — measured before/after lives in
//! EXPERIMENTS.md §Perf.

use std::sync::{Arc, Barrier, Mutex};

use super::segment_bounds;

struct Shared {
    barrier: Barrier,
    /// Published per-rank input snapshots (slot per rank).
    slots: Vec<Mutex<Vec<f32>>>,
    /// The reduced result, written segment-wise by all ranks.
    result: Mutex<Vec<f32>>,
}

/// One world's shared-memory reducer; clone a handle per rank.
pub struct ShmemWorld {
    inner: Arc<Shared>,
    size: usize,
}

impl ShmemWorld {
    pub fn new(size: usize) -> ShmemWorld {
        assert!(size > 0);
        ShmemWorld {
            inner: Arc::new(Shared {
                barrier: Barrier::new(size),
                slots: (0..size).map(|_| Mutex::new(Vec::new())).collect(),
                result: Mutex::new(Vec::new()),
            }),
            size,
        }
    }

    /// Handle for one rank (move into its thread).
    pub fn rank(&self, rank: usize) -> ShmemRank {
        assert!(rank < self.size);
        ShmemRank { shared: self.inner.clone(), rank, size: self.size }
    }
}

/// Per-rank endpoint of the shared-memory all-reduce.
pub struct ShmemRank {
    shared: Arc<Shared>,
    rank: usize,
    size: usize,
}

impl ShmemRank {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// In-place sum all-reduce. Every rank must call with equal lengths.
    pub fn all_reduce(&self, data: &mut [f32]) {
        let w = self.size;
        if w == 1 || data.is_empty() {
            return;
        }
        let n = data.len();

        // 1. publish (one copy; slot buffers are reused across calls)
        {
            let mut slot = self.shared.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        if self.rank == 0 {
            // length only; every element is overwritten in step 2
            self.shared.result.lock().unwrap().resize(n, 0.0);
        }
        self.shared.barrier.wait();

        // 2. write my fully-reduced segment (copy, not accumulate — no
        // zeroing pass needed; segments partition [0, n))
        let (lo, hi) = segment_bounds(n, w, self.rank);
        if hi > lo {
            let mut acc = vec![0.0f32; hi - lo];
            for s in 0..w {
                let slot = self.shared.slots[s].lock().unwrap();
                debug_assert_eq!(slot.len(), n, "ranks disagree on length");
                for (a, v) in acc.iter_mut().zip(&slot[lo..hi]) {
                    *a += v;
                }
            }
            let mut result = self.shared.result.lock().unwrap();
            result[lo..hi].copy_from_slice(&acc);
        }
        self.shared.barrier.wait();

        // 3. read back, then a final barrier so no rank can start the
        // next call's mutation while a peer is still reading
        {
            let result = self.shared.result.lock().unwrap();
            data.copy_from_slice(&result);
        }
        self.shared.barrier.wait();
    }

    /// All-reduce then divide by world size (gradient averaging).
    pub fn all_reduce_mean(&self, data: &mut [f32]) {
        self.all_reduce(data);
        let inv = 1.0 / self.size as f32;
        for v in data.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn run_shmem(payloads: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let w = payloads.len();
        let world = ShmemWorld::new(w);
        let handles: Vec<_> = payloads
            .into_iter()
            .enumerate()
            .map(|(r, mut data)| {
                let rank = world.rank(r);
                std::thread::spawn(move || {
                    rank.all_reduce(&mut data);
                    (r, data)
                })
            })
            .collect();
        let mut out: Vec<(usize, Vec<f32>)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        out.sort_by_key(|(r, _)| *r);
        out.into_iter().map(|(_, d)| d).collect()
    }

    #[test]
    fn matches_serial_sum() {
        let mut rng = Rng::new(1);
        for (w, n) in [(2usize, 100usize), (3, 999), (8, 4096), (5, 1)] {
            let payloads: Vec<Vec<f32>> = (0..w).map(|_| rng.vec_f32(n)).collect();
            let mut want = vec![0.0f32; n];
            for p in &payloads {
                for (a, b) in want.iter_mut().zip(p) {
                    *a += b;
                }
            }
            for out in run_shmem(payloads) {
                for (g, t) in out.iter().zip(&want) {
                    assert!((g - t).abs() <= 1e-3 * t.abs().max(1.0), "w={w} n={n}");
                }
            }
        }
    }

    #[test]
    fn agrees_with_channel_dh() {
        let mut rng = Rng::new(2);
        let w = 4;
        let n = 1000;
        let payloads: Vec<Vec<f32>> = (0..w).map(|_| rng.vec_f32(n)).collect();
        let shmem = run_shmem(payloads.clone());
        let (chan, _) = super::super::comm::run_world(w, payloads, |rank, data| {
            super::super::dh::all_reduce(rank, data).unwrap();
        });
        for (a, b) in shmem.iter().zip(&chan) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-3 * y.abs().max(1.0));
            }
        }
    }

    #[test]
    fn reusable_across_calls() {
        let world = ShmemWorld::new(2);
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let rank = world.rank(r);
                std::thread::spawn(move || {
                    let mut data = vec![r as f32 + 1.0; 8];
                    for _ in 0..5 {
                        rank.all_reduce_mean(&mut data);
                    }
                    data
                })
            })
            .collect();
        for h in handles {
            for v in h.join().unwrap() {
                assert!((v - 1.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn single_rank_noop() {
        let world = ShmemWorld::new(1);
        let rank = world.rank(0);
        let mut data = vec![3.0f32; 4];
        rank.all_reduce(&mut data);
        assert_eq!(data, vec![3.0f32; 4]);
    }
}
