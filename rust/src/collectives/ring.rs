//! Ring all-reduce (§2.1, eq 2).
//!
//! The vector is split into `w` near-equal segments. Phase 1
//! (reduce-scatter): `w-1` steps; at each step every rank sends one
//! segment to its right neighbour and accumulates the segment arriving
//! from the left. Phase 2 (all-gather): `w-1` more steps circulating the
//! fully-reduced segments. Per rank: `2(w-1)` messages and
//! `~2n(w-1)/w` elements on the wire — bandwidth-optimal, latency linear
//! in `w`, which is why the paper prefers doubling-halving for small
//! payloads (§2.1).

use super::comm::Rank;
use super::segment_bounds;
use crate::Result;

/// Tag space: phase << 16 | step, so concurrent all-reduces on the same
/// world (different calls) must be externally serialized — matching MPI
/// collective semantics.
const REDUCE_PHASE: u32 = 1 << 16;
const GATHER_PHASE: u32 = 2 << 16;

/// In-place sum all-reduce over all ranks of the world.
pub fn all_reduce(rank: &mut Rank, data: &mut [f32]) -> Result<()> {
    let w = rank.size();
    let r = rank.rank();
    let n = data.len();
    if w == 1 || n == 0 {
        return Ok(());
    }
    let right = (r + 1) % w;
    let left = (r + w - 1) % w;

    // Phase 1: reduce-scatter. At step s, send segment (r - s) mod w,
    // receive and accumulate segment (r - s - 1) mod w from the left.
    for s in 0..w - 1 {
        let send_seg = (r + w - s) % w;
        let recv_seg = (r + w - s - 1) % w;
        let (ss, se) = segment_bounds(n, w, send_seg);
        rank.send(right, REDUCE_PHASE | s as u32, data[ss..se].to_vec());
        let incoming = rank.recv(left, REDUCE_PHASE | s as u32);
        let (rs, re) = segment_bounds(n, w, recv_seg);
        debug_assert_eq!(incoming.len(), re - rs);
        for (dst, src) in data[rs..re].iter_mut().zip(&incoming) {
            *dst += src;
        }
    }

    // After w-1 steps this rank owns the fully-reduced segment (r+1) mod w.
    // Phase 2: all-gather. At step s, forward segment (r + 1 - s) mod w.
    for s in 0..w - 1 {
        let send_seg = (r + 1 + w - s) % w;
        let recv_seg = (r + w - s) % w;
        let (ss, se) = segment_bounds(n, w, send_seg);
        rank.send(right, GATHER_PHASE | s as u32, data[ss..se].to_vec());
        let incoming = rank.recv(left, GATHER_PHASE | s as u32);
        let (rs, re) = segment_bounds(n, w, recv_seg);
        debug_assert_eq!(incoming.len(), re - rs);
        data[rs..re].copy_from_slice(&incoming);
    }
    Ok(())
}

/// Predicted per-world message count for the traffic meter (all ranks).
pub fn predicted_messages(w: usize) -> u64 {
    if w <= 1 {
        0
    } else {
        (2 * w * (w - 1)) as u64
    }
}

/// Predicted per-world payload bytes (all ranks), exact for `n % w == 0`.
pub fn predicted_bytes(w: usize, n: usize) -> u64 {
    if w <= 1 {
        return 0;
    }
    let mut total = 0u64;
    // each rank sends each of the other ranks' segments exactly twice
    for seg in 0..w {
        let (s, e) = segment_bounds(n, w, seg);
        total += (e - s) as u64;
    }
    total * 2 * (w as u64 - 1) * 4
}

#[cfg(test)]
mod tests {
    use super::super::comm::run_world;
    use super::*;

    fn check_sum(w: usize, n: usize) {
        let payloads: Vec<Vec<f32>> = (0..w)
            .map(|r| (0..n).map(|i| (r * n + i) as f32 * 0.25).collect())
            .collect();
        let mut expected = vec![0.0f32; n];
        for p in &payloads {
            for (e, v) in expected.iter_mut().zip(p) {
                *e += v;
            }
        }
        let (out, _) = run_world(w, payloads, |rank, data| {
            all_reduce(rank, data).unwrap();
        });
        for (r, result) in out.iter().enumerate() {
            for (i, (got, want)) in result.iter().zip(&expected).enumerate() {
                assert!(
                    (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "w={w} n={n} rank={r} i={i}: {got} != {want}"
                );
            }
        }
    }

    #[test]
    fn sums_across_world_sizes() {
        for w in 1..=8 {
            check_sum(w, 64);
        }
    }

    #[test]
    fn handles_uneven_segments() {
        check_sum(3, 10);
        check_sum(5, 7);
        check_sum(7, 13);
    }

    #[test]
    fn handles_vector_shorter_than_world() {
        check_sum(6, 3);
        check_sum(4, 1);
    }

    #[test]
    fn empty_vector_is_noop() {
        check_sum(4, 0);
    }

    #[test]
    fn traffic_matches_prediction() {
        let w = 4;
        let n = 64;
        let payloads: Vec<Vec<f32>> = (0..w).map(|_| vec![1.0; n]).collect();
        let (_, traffic) = run_world(w, payloads, |rank, data| {
            all_reduce(rank, data).unwrap();
        });
        assert_eq!(traffic.messages(), predicted_messages(w));
        assert_eq!(traffic.bytes(), predicted_bytes(w, n));
    }
}
