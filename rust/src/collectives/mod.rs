//! MPI-like collective communication substrate.
//!
//! The paper's training jobs run Horovod over OpenMPI + NCCL; its
//! scheduling math (§3, eqs 2–4) depends only on the *algorithms* those
//! libraries run for `allreduce`. This module implements the substrate
//! from scratch: point-to-point message passing between in-process ranks
//! ([`comm`]) and the three all-reduce algorithms the paper models —
//!
//! - [`ring`] — the bandwidth-optimal ring all-reduce (eq 2),
//! - [`dh`] — Rabenseifner's recursive doubling-halving for power-of-two
//!   rank counts (eq 3),
//! - [`bb`] — the non-power-of-two variant ("binary blocks" in the paper;
//!   we implement the MPICH-style 2r-fold + halving/doubling elimination,
//!   whose cost eq 4 upper-bounds — see `bb.rs` docs),
//!
//! plus the analytic α/β/γ cost models ([`cost`]) and wire-traffic
//! accounting used by tests to verify the models against reality.

pub mod bb;
pub mod comm;
pub mod shmem;
pub mod cost;
pub mod dh;
pub mod ring;

pub use comm::{Rank, Traffic, World};
pub use cost::{Algorithm, CostParams};

use crate::Result;

/// Sum-all-reduce `data` in place across all ranks of the world using the
/// given algorithm. Every rank must call this with the same `n` and
/// algorithm; on return every rank holds the elementwise sum.
pub fn all_reduce(alg: Algorithm, rank: &mut Rank, data: &mut [f32]) -> Result<()> {
    match alg {
        Algorithm::Ring => ring::all_reduce(rank, data),
        Algorithm::DoublingHalving => dh::all_reduce(rank, data),
        Algorithm::BinaryBlocks => bb::all_reduce(rank, data),
    }
}

/// Convenience for the trainer: sum-all-reduce then divide by world size
/// (gradient averaging across data-parallel workers).
pub fn all_reduce_mean(alg: Algorithm, rank: &mut Rank, data: &mut [f32]) -> Result<()> {
    all_reduce(alg, rank, data)?;
    let inv = 1.0 / rank.size() as f32;
    for v in data.iter_mut() {
        *v *= inv;
    }
    Ok(())
}

/// Pick the algorithm the runtime would use for a given world size, the
/// same policy Horovod/MPICH apply (§2.1): doubling-halving for powers of
/// two, the fold variant otherwise; plain ring for very large payloads
/// where bandwidth dominates latency.
pub fn select_algorithm(world: usize, n_elems: usize) -> Algorithm {
    // §2.1: "For parameter sizes up to 1e7, the doubling-halving algorithm
    // for powers of 2 has been found to be significantly more efficient."
    const RING_THRESHOLD: usize = 10_000_000;
    if n_elems > RING_THRESHOLD {
        Algorithm::Ring
    } else if world.is_power_of_two() {
        Algorithm::DoublingHalving
    } else {
        Algorithm::BinaryBlocks
    }
}

/// Split `n` elements into `parts` contiguous near-equal ranges; returns
/// the `[start, end)` of range `i`. The first `n % parts` ranges get one
/// extra element, matching MPI segment conventions.
pub fn segment_bounds(n: usize, parts: usize, i: usize) -> (usize, usize) {
    debug_assert!(i < parts);
    let base = n / parts;
    let extra = n % parts;
    let start = i * base + i.min(extra);
    let len = base + usize::from(i < extra);
    (start, start + len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_cover_exactly() {
        for n in [0usize, 1, 7, 64, 101] {
            for parts in 1..=9 {
                let mut total = 0;
                let mut prev_end = 0;
                for i in 0..parts {
                    let (s, e) = segment_bounds(n, parts, i);
                    assert_eq!(s, prev_end, "n={n} parts={parts} i={i}");
                    assert!(e >= s);
                    total += e - s;
                    prev_end = e;
                }
                assert_eq!(total, n);
            }
        }
    }

    #[test]
    fn segment_sizes_differ_by_at_most_one() {
        for n in [13usize, 100, 1001] {
            for parts in 1..=8 {
                let sizes: Vec<usize> = (0..parts)
                    .map(|i| {
                        let (s, e) = segment_bounds(n, parts, i);
                        e - s
                    })
                    .collect();
                let min = *sizes.iter().min().unwrap();
                let max = *sizes.iter().max().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn algorithm_selection_policy() {
        assert_eq!(select_algorithm(8, 1000), Algorithm::DoublingHalving);
        assert_eq!(select_algorithm(6, 1000), Algorithm::BinaryBlocks);
        assert_eq!(select_algorithm(8, 20_000_000), Algorithm::Ring);
        assert_eq!(select_algorithm(1, 10), Algorithm::DoublingHalving);
    }
}
