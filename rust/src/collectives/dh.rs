//! Recursive doubling-halving all-reduce (Rabenseifner '04; §2.1, eq 3).
//!
//! Phase 1 (recursive halving reduce-scatter): `log2(w)` steps; at step
//! with mask `m`, rank `r` exchanges half of its current working range
//! with `r ^ m` and accumulates the half it keeps. After the phase each
//! rank owns a fully-reduced `n/w` range. Phase 2 (recursive doubling
//! all-gather) replays the splits in reverse, doubling the owned range
//! each step.
//!
//! Per rank: `2*log2(w)` messages and `2n(1-1/w)` elements — the
//! low-latency algorithm the paper's doubling heuristic is built around
//! (worker counts stay powers of two so this path always applies).

use super::comm::Rank;
use crate::Result;

const REDUCE_PHASE: u32 = 3 << 16;
const GATHER_PHASE: u32 = 4 << 16;

/// In-place sum all-reduce across the whole world (requires power-of-two
/// world size; the scheduler's doubling heuristic guarantees this).
pub fn all_reduce(rank: &mut Rank, data: &mut [f32]) -> Result<()> {
    let w = rank.size();
    anyhow::ensure!(
        w.is_power_of_two(),
        "doubling-halving requires a power-of-two world, got {w}"
    );
    let group: Vec<usize> = (0..w).collect();
    all_reduce_group(rank, data, &group)
}

/// Sum all-reduce among the subset `group` of physical ranks (used by the
/// binary-blocks fold for the power-of-two core). `group.len()` must be a
/// power of two and contain `rank.rank()`.
pub(super) fn all_reduce_group(rank: &mut Rank, data: &mut [f32], group: &[usize]) -> Result<()> {
    let w = group.len();
    if w <= 1 || data.is_empty() {
        return Ok(());
    }
    anyhow::ensure!(w.is_power_of_two(), "group size {w} not a power of two");
    let me = group
        .iter()
        .position(|&g| g == rank.rank())
        .ok_or_else(|| anyhow::anyhow!("rank {} not in group", rank.rank()))?;

    // Phase 1: recursive halving. Partners at matching steps share the
    // same working range because they agree on every higher mask bit.
    let (mut lo, mut hi) = (0usize, data.len());
    let mut parents: Vec<(usize, usize)> = Vec::new();
    let mut mask = w / 2;
    let mut step = 0u32;
    while mask >= 1 {
        let partner = group[me ^ mask];
        let mid = lo + (hi - lo) / 2;
        let (keep, send) = if me & mask == 0 {
            ((lo, mid), (mid, hi))
        } else {
            ((mid, hi), (lo, mid))
        };
        let incoming = rank.sendrecv(partner, REDUCE_PHASE | step, data[send.0..send.1].to_vec());
        debug_assert_eq!(incoming.len(), keep.1 - keep.0);
        for (dst, src) in data[keep.0..keep.1].iter_mut().zip(&incoming) {
            *dst += src;
        }
        parents.push((lo, hi));
        lo = keep.0;
        hi = keep.1;
        if mask == 1 {
            break;
        }
        mask /= 2;
        step += 1;
    }

    // Phase 2: recursive doubling, replaying splits in reverse.
    let mut mask = 1usize;
    let mut step = 0u32;
    while mask < w {
        let partner = group[me ^ mask];
        let (plo, phi) = parents.pop().expect("parent stack underflow");
        let incoming = rank.sendrecv(partner, GATHER_PHASE | step, data[lo..hi].to_vec());
        if lo == plo {
            // we own the lower half; sibling fills (hi, phi)
            debug_assert_eq!(incoming.len(), phi - hi);
            data[hi..phi].copy_from_slice(&incoming);
        } else {
            debug_assert_eq!(incoming.len(), lo - plo);
            data[plo..lo].copy_from_slice(&incoming);
        }
        lo = plo;
        hi = phi;
        mask *= 2;
        step += 1;
    }
    debug_assert_eq!((lo, hi), (0, data.len()));
    Ok(())
}

/// Predicted world-total messages: `2 log2(w)` per rank.
pub fn predicted_messages(w: usize) -> u64 {
    if w <= 1 {
        0
    } else {
        (w * 2 * w.trailing_zeros() as usize) as u64
    }
}

/// Predicted world-total payload bytes: `2n(1 - 1/w)` elements per rank
/// (exact when `n` is divisible by `w`).
pub fn predicted_bytes(w: usize, n: usize) -> u64 {
    if w <= 1 {
        return 0;
    }
    (w as u64) * 2 * ((n - n / w) as u64) * 4
}

#[cfg(test)]
mod tests {
    use super::super::comm::run_world;
    use super::*;

    fn check_sum(w: usize, n: usize) {
        let payloads: Vec<Vec<f32>> = (0..w)
            .map(|r| (0..n).map(|i| ((r + 1) * (i + 1)) as f32 * 0.125).collect())
            .collect();
        let mut expected = vec![0.0f32; n];
        for p in &payloads {
            for (e, v) in expected.iter_mut().zip(p) {
                *e += v;
            }
        }
        let (out, _) = run_world(w, payloads, |rank, data| {
            all_reduce(rank, data).unwrap();
        });
        for (r, result) in out.iter().enumerate() {
            for (i, (got, want)) in result.iter().zip(&expected).enumerate() {
                assert!(
                    (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "w={w} n={n} rank={r} i={i}: {got} != {want}"
                );
            }
        }
    }

    #[test]
    fn sums_for_powers_of_two() {
        for w in [1, 2, 4, 8, 16] {
            check_sum(w, 64);
        }
    }

    #[test]
    fn handles_odd_lengths() {
        check_sum(4, 7);
        check_sum(8, 13);
        check_sum(2, 1);
    }

    #[test]
    fn handles_vector_shorter_than_world() {
        check_sum(8, 3);
    }

    #[test]
    fn rejects_non_power_of_two() {
        let payloads: Vec<Vec<f32>> = (0..3).map(|_| vec![1.0; 8]).collect();
        let mut world = super::super::comm::World::new(3);
        let mut ranks = world.take_ranks();
        let mut r = ranks.remove(0);
        let mut d = payloads[0].clone();
        assert!(all_reduce(&mut r, &mut d).is_err());
    }

    #[test]
    fn traffic_matches_prediction() {
        let (w, n) = (8, 64);
        let payloads: Vec<Vec<f32>> = (0..w).map(|_| vec![1.0; n]).collect();
        let (_, traffic) = run_world(w, payloads, |rank, data| {
            all_reduce(rank, data).unwrap();
        });
        assert_eq!(traffic.messages(), predicted_messages(w));
        assert_eq!(traffic.bytes(), predicted_bytes(w, n));
    }

    #[test]
    fn fewer_messages_than_ring_for_large_worlds() {
        // the latency advantage the paper's heuristic exploits
        assert!(predicted_messages(16) < super::super::ring::predicted_messages(16));
    }
}
