//! Non-power-of-two all-reduce — the paper's "binary blocks" case (eq 4).
//!
//! Rabenseifner's binary-blocks algorithm decomposes `w` into a sum of
//! powers of two and aggregates the inexact matches with extra steps. We
//! implement the standard fold variant (MPICH's non-power-of-two
//! handling, Thakur & Rabenseifner '05): with `r = w - 2^⌊log2 w⌋`,
//!
//!  1. *fold*: each of the `r` surplus ranks (odd ranks below `2r`) sends
//!     its full vector to its even partner, which pre-reduces,
//!  2. the remaining power-of-two core runs recursive doubling-halving,
//!  3. *unfold*: results are sent back to the surplus ranks.
//!
//! The extra full-vector sends are exactly why eq 4 carries `7nβ + 3nγ`
//! against doubling-halving's `4nβ + 2.5nγ`, and why the paper's doubling
//! heuristic keeps allocations at powers of two: eq 4's cost is worst
//! when `w` is just above a power of two (r large relative to the core),
//! the "8→9 GPU" cliff of §4.2.

use super::comm::Rank;
use super::dh;
use crate::Result;

const FOLD_TAG: u32 = 5 << 16;
const UNFOLD_TAG: u32 = 6 << 16;

/// In-place sum all-reduce for any world size.
pub fn all_reduce(rank: &mut Rank, data: &mut [f32]) -> Result<()> {
    let w = rank.size();
    if w <= 1 || data.is_empty() {
        return Ok(());
    }
    if w.is_power_of_two() {
        return dh::all_reduce(rank, data);
    }
    let pow = 1usize << (usize::BITS - 1 - w.leading_zeros());
    let r = w - pow;
    let me = rank.rank();

    // Fold: odd ranks below 2r hand their vector to the even partner.
    if me < 2 * r {
        if me % 2 == 1 {
            rank.send(me - 1, FOLD_TAG, data.to_vec());
            let result = rank.recv(me - 1, UNFOLD_TAG);
            data.copy_from_slice(&result);
            return Ok(());
        }
        let incoming = rank.recv(me + 1, FOLD_TAG);
        for (dst, src) in data.iter_mut().zip(&incoming) {
            *dst += src;
        }
    }

    // Power-of-two core: evens below 2r plus everyone from 2r up.
    let group: Vec<usize> = (0..2 * r).step_by(2).chain(2 * r..w).collect();
    debug_assert!(group.len().is_power_of_two());
    dh::all_reduce_group(rank, data, &group)?;

    // Unfold: return the result to the folded-out ranks.
    if me < 2 * r {
        rank.send(me + 1, UNFOLD_TAG, data.to_vec());
    }
    Ok(())
}

/// Surplus rank count `r = w - 2^⌊log2 w⌋`.
pub fn surplus(w: usize) -> usize {
    if w == 0 {
        return 0;
    }
    w - (1usize << (usize::BITS - 1 - w.leading_zeros()))
}

/// Predicted world-total messages.
pub fn predicted_messages(w: usize) -> u64 {
    if w <= 1 {
        return 0;
    }
    if w.is_power_of_two() {
        return dh::predicted_messages(w);
    }
    let r = surplus(w);
    let core = w - r;
    // fold + unfold (2 msgs per surplus pair) + dh among the core
    2 * r as u64 + dh::predicted_messages(core)
}

/// Predicted world-total payload bytes (exact for `n % core == 0`).
pub fn predicted_bytes(w: usize, n: usize) -> u64 {
    if w <= 1 {
        return 0;
    }
    if w.is_power_of_two() {
        return dh::predicted_bytes(w, n);
    }
    let r = surplus(w);
    let core = w - r;
    (2 * r * n * 4) as u64 + dh::predicted_bytes(core, n)
}

#[cfg(test)]
mod tests {
    use super::super::comm::run_world;
    use super::*;

    fn check_sum(w: usize, n: usize) {
        let payloads: Vec<Vec<f32>> = (0..w)
            .map(|r| (0..n).map(|i| ((r * 31 + i * 7) % 17) as f32 - 8.0).collect())
            .collect();
        let mut expected = vec![0.0f32; n];
        for p in &payloads {
            for (e, v) in expected.iter_mut().zip(p) {
                *e += v;
            }
        }
        let (out, _) = run_world(w, payloads, |rank, data| {
            all_reduce(rank, data).unwrap();
        });
        for (r, result) in out.iter().enumerate() {
            for (i, (got, want)) in result.iter().zip(&expected).enumerate() {
                assert!(
                    (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "w={w} n={n} rank={r} i={i}: {got} != {want}"
                );
            }
        }
    }

    #[test]
    fn sums_for_all_world_sizes_up_to_17() {
        for w in 1..=17 {
            check_sum(w, 48);
        }
    }

    #[test]
    fn handles_odd_lengths_and_non_powers() {
        check_sum(3, 7);
        check_sum(5, 13);
        check_sum(6, 1);
        check_sum(9, 100);
    }

    #[test]
    fn power_of_two_delegates_to_dh() {
        assert_eq!(predicted_messages(8), dh::predicted_messages(8));
        assert_eq!(predicted_bytes(8, 64), dh::predicted_bytes(8, 64));
    }

    #[test]
    fn surplus_values() {
        assert_eq!(surplus(8), 0);
        assert_eq!(surplus(9), 1);
        assert_eq!(surplus(12), 4);
        assert_eq!(surplus(15), 7);
    }

    #[test]
    fn traffic_matches_prediction() {
        for (w, n) in [(6usize, 64usize), (9, 64), (12, 96)] {
            let payloads: Vec<Vec<f32>> = (0..w).map(|_| vec![1.0; n]).collect();
            let (_, traffic) = run_world(w, payloads, |rank, data| {
                all_reduce(rank, data).unwrap();
            });
            assert_eq!(traffic.messages(), predicted_messages(w), "w={w}");
            assert_eq!(traffic.bytes(), predicted_bytes(w, n), "w={w}");
        }
    }

    #[test]
    fn nine_costs_more_than_eight_per_rank() {
        // the 8->9 cliff that motivates the doubling heuristic (§4.2)
        let per_rank_9 = predicted_bytes(9, 1 << 20) as f64 / 9.0;
        let per_rank_8 = predicted_bytes(8, 1 << 20) as f64 / 8.0;
        assert!(per_rank_9 > per_rank_8);
    }
}
