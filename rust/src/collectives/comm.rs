//! Point-to-point message transport between in-process ranks.
//!
//! Plays the role OpenMPI plays for Horovod: each rank can `send` to and
//! `recv` from any other rank, with `(from, tag)` selective receive
//! semantics (messages arriving out of order are parked in a pending
//! buffer). Channels are unbounded, so a send never blocks and the
//! sendrecv pairs inside the all-reduce algorithms cannot deadlock.
//!
//! All traffic is metered through a shared [`Traffic`] — the tests in
//! `cost.rs` verify the analytic models of eqs 2–4 against these counters.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Wire-traffic counters for one world (shared by all its ranks).
#[derive(Debug, Default)]
pub struct Traffic {
    messages: AtomicU64,
    bytes: AtomicU64,
}

impl Traffic {
    /// Total point-to-point messages sent (all ranks).
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Total payload bytes sent (all ranks).
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.messages.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
    }

    fn record(&self, payload_bytes: u64) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(payload_bytes, Ordering::Relaxed);
    }
}

struct Msg {
    from: usize,
    tag: u32,
    data: Vec<f32>,
}

/// A world of `size` communicating ranks.
pub struct World {
    ranks: Vec<Rank>,
    traffic: Arc<Traffic>,
}

impl World {
    /// Create a world; returns the rank handles to move into worker threads.
    pub fn new(size: usize) -> World {
        assert!(size > 0, "world must have at least one rank");
        let traffic = Arc::new(Traffic::default());
        let mut senders = Vec::with_capacity(size);
        let mut receivers = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(rx);
        }
        let ranks = receivers
            .into_iter()
            .enumerate()
            .map(|(i, rx)| Rank {
                rank: i,
                size,
                senders: senders.clone(),
                rx,
                pending: HashMap::new(),
                traffic: traffic.clone(),
            })
            .collect();
        World { ranks, traffic }
    }

    /// Take ownership of all rank handles (once).
    pub fn take_ranks(&mut self) -> Vec<Rank> {
        std::mem::take(&mut self.ranks)
    }

    /// The world's shared traffic meter.
    pub fn traffic(&self) -> Arc<Traffic> {
        self.traffic.clone()
    }
}

/// One rank's endpoint: owned by exactly one thread.
pub struct Rank {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    pending: HashMap<(usize, u32), Vec<Vec<f32>>>,
    traffic: Arc<Traffic>,
}

impl Rank {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn traffic(&self) -> &Traffic {
        &self.traffic
    }

    /// Send `data` to rank `to` with a tag identifying the algorithm step.
    pub fn send(&self, to: usize, tag: u32, data: Vec<f32>) {
        debug_assert!(to < self.size && to != self.rank);
        self.traffic.record((data.len() * 4) as u64);
        // Receiver hung up => its thread panicked; surface as panic here too.
        self.senders[to]
            .send(Msg { from: self.rank, tag, data })
            .expect("peer rank dropped its receiver");
    }

    /// Blocking selective receive of the next message from `from` with `tag`.
    pub fn recv(&mut self, from: usize, tag: u32) -> Vec<f32> {
        if let Some(queue) = self.pending.get_mut(&(from, tag)) {
            if !queue.is_empty() {
                return queue.remove(0);
            }
        }
        loop {
            let msg = self.rx.recv().expect("all senders dropped");
            if msg.from == from && msg.tag == tag {
                return msg.data;
            }
            self.pending
                .entry((msg.from, msg.tag))
                .or_default()
                .push(msg.data);
        }
    }

    /// Exchange with a partner: send ours, receive theirs (same tag).
    pub fn sendrecv(&mut self, peer: usize, tag: u32, data: Vec<f32>) -> Vec<f32> {
        self.send(peer, tag, data);
        self.recv(peer, tag)
    }
}

/// Test/bench harness: run `f(rank, payload)` on `w` threads over fresh
/// per-rank payload vectors, returning the final per-rank vectors in rank
/// order along with the world traffic meter.
pub fn run_world<F>(w: usize, payloads: Vec<Vec<f32>>, f: F) -> (Vec<Vec<f32>>, Arc<Traffic>)
where
    F: Fn(&mut Rank, &mut Vec<f32>) + Send + Sync + 'static,
{
    assert_eq!(payloads.len(), w);
    let mut world = World::new(w);
    let traffic = world.traffic();
    let f = Arc::new(f);
    let handles: Vec<_> = world
        .take_ranks()
        .into_iter()
        .zip(payloads)
        .map(|(mut rank, mut data)| {
            let f = f.clone();
            std::thread::spawn(move || {
                f(&mut rank, &mut data);
                (rank.rank(), data)
            })
        })
        .collect();
    let mut out: Vec<(usize, Vec<f32>)> =
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect();
    out.sort_by_key(|(r, _)| *r);
    (out.into_iter().map(|(_, d)| d).collect(), traffic)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong() {
        let mut world = World::new(2);
        let mut ranks = world.take_ranks();
        let mut r1 = ranks.pop().unwrap();
        let mut r0 = ranks.pop().unwrap();
        let t0 = std::thread::spawn(move || {
            r0.send(1, 7, vec![1.0, 2.0]);
            r0.recv(1, 8)
        });
        let t1 = std::thread::spawn(move || {
            let got = r1.recv(0, 7);
            r1.send(0, 8, vec![got[0] + 10.0, got[1] + 10.0]);
        });
        t1.join().unwrap();
        assert_eq!(t0.join().unwrap(), vec![11.0, 12.0]);
    }

    #[test]
    fn selective_receive_out_of_order() {
        let mut world = World::new(2);
        let mut ranks = world.take_ranks();
        let mut r1 = ranks.pop().unwrap();
        let r0 = ranks.pop().unwrap();
        // Send tag 2 then tag 1; receiver asks for tag 1 first.
        r0.send(1, 2, vec![2.0]);
        r0.send(1, 1, vec![1.0]);
        assert_eq!(r1.recv(0, 1), vec![1.0]);
        assert_eq!(r1.recv(0, 2), vec![2.0]);
    }

    #[test]
    fn pending_fifo_per_key() {
        let mut world = World::new(2);
        let mut ranks = world.take_ranks();
        let mut r1 = ranks.pop().unwrap();
        let r0 = ranks.pop().unwrap();
        r0.send(1, 5, vec![1.0]);
        r0.send(1, 5, vec![2.0]);
        r0.send(1, 9, vec![9.0]);
        assert_eq!(r1.recv(0, 9), vec![9.0]); // parks the two tag-5 msgs
        assert_eq!(r1.recv(0, 5), vec![1.0]);
        assert_eq!(r1.recv(0, 5), vec![2.0]);
    }

    #[test]
    fn traffic_counts_messages_and_bytes() {
        let mut world = World::new(2);
        let traffic = world.traffic();
        let mut ranks = world.take_ranks();
        let mut r1 = ranks.pop().unwrap();
        let r0 = ranks.pop().unwrap();
        r0.send(1, 0, vec![0.0; 10]);
        let _ = r1.recv(0, 0);
        assert_eq!(traffic.messages(), 1);
        assert_eq!(traffic.bytes(), 40);
        traffic.reset();
        assert_eq!(traffic.messages(), 0);
    }

    #[test]
    fn run_world_returns_in_rank_order() {
        let payloads = vec![vec![0.0f32], vec![1.0], vec![2.0]];
        let (out, _) = run_world(3, payloads, |rank, data| {
            data[0] += rank.rank() as f32 * 100.0;
        });
        assert_eq!(out, vec![vec![0.0], vec![101.0], vec![202.0]]);
    }
}
