//! Job coordinator: drives the real trainer through stop/restart rescales.
//!
//! Two entry points:
//!
//! - [`run_with_rescales`] — execute an explicit rescale plan (the
//!   Table 2 experiment: train at 4, checkpoint at step k, restart at 8
//!   with eq 7 LR scaling) and measure every restart's cost.
//! - [`train_to_target`] — the paper's full closed loop on real
//!   hardware: train in segments, fit the convergence (eq 1) and speed
//!   (eq 5) models online from observed samples, and let the doubling
//!   heuristic pick the next worker count after every segment.

use std::path::Path;
use std::time::Instant;

use crate::perfmodel::{ConvergenceModel, SpeedModel};
use crate::scheduler::{doubling::Doubling, JobInfo, Scheduler, Speed};
use crate::store::CkptStore;
use crate::trainer::{train, Checkpoint, TrainConfig, TrainReport};
use crate::Result;

/// Round-trip a checkpoint through disk — the stop→restart boundary of
/// §6, shared by [`run_with_rescales`] and the orchestrator's executor.
/// Uses the atomic save path and returns the reloaded checkpoint, the
/// measured I/O seconds (part of the restart cost the paper budgets
/// ~10 s for), and the bytes written. The round-trip file is removed on
/// *both* load outcomes — earlier revisions skipped removal whenever the
/// load failed, leaking one `.ckpt` per failed restart into temp_dir.
pub fn checkpoint_roundtrip(ck: &Checkpoint, path: &Path) -> Result<(Checkpoint, f64, u64)> {
    let t = Instant::now();
    let bytes = ck.save(path)?;
    let loaded = Checkpoint::load(path);
    let _ = std::fs::remove_file(path);
    Ok((loaded?, t.elapsed().as_secs_f64(), bytes))
}

/// The same §6 boundary through the content-addressed store: persist
/// `ck` as `key`'s snapshot and read it back. Only chunks the store does
/// not already hold touch disk, so restart N of a job dedups against
/// restart N-1 (and against every other job sharing content) — the
/// returned bytes-written is the O(delta) cost `--ckpt-store` buys.
pub fn checkpoint_roundtrip_store(
    ck: &Checkpoint,
    store: &CkptStore,
    key: &str,
) -> Result<(Checkpoint, f64, u64)> {
    let t = Instant::now();
    let stats = store.save(key, ck)?;
    let loaded = store.load(key)?;
    Ok((loaded, t.elapsed().as_secs_f64(), stats.bytes_written))
}

/// One executed segment of a coordinated run.
#[derive(Debug)]
pub struct Segment {
    pub workers: usize,
    pub steps: u64,
    pub report: TrainReport,
    /// Checkpoint-save + restart (client/compile) seconds charged at the
    /// boundary *before* this segment (0 for the first).
    pub restart_secs: f64,
}

/// Outcome of a multi-segment coordinated run.
#[derive(Debug)]
pub struct RunOutcome {
    pub segments: Vec<Segment>,
    pub checkpoint: Checkpoint,
    /// Wall time including restarts.
    pub total_secs: f64,
    /// All loss samples across segments.
    pub logs: Vec<crate::trainer::StepLog>,
}

impl RunOutcome {
    pub fn final_loss(&self) -> Option<f32> {
        self.logs.last().map(|l| l.loss)
    }

    pub fn total_steps(&self) -> u64 {
        self.segments.iter().map(|s| s.steps).sum()
    }
}

/// Execute an explicit `(workers, steps)` plan, carrying the checkpoint
/// across boundaries. Eq 7 is enforced structurally: the LR schedule is
/// `base · w`, so restarting at 2× workers doubles the LR exactly as §5
/// prescribes.
pub fn run_with_rescales(base: &TrainConfig, plan: &[(usize, u64)]) -> Result<RunOutcome> {
    anyhow::ensure!(!plan.is_empty(), "empty rescale plan");
    let mut ck: Option<Checkpoint> = None;
    let mut segments = Vec::new();
    let mut logs = Vec::new();
    let total_t = Instant::now();

    for (i, &(w, steps)) in plan.iter().enumerate() {
        let mut cfg = base.clone();
        cfg.workers = w;
        let boundary_t = Instant::now();
        // Checkpoint save/load across the boundary (disk round trip, like
        // the paper's TF checkpoint restore).
        let resume = match ck.take() {
            Some(prev) => {
                let path = std::env::temp_dir()
                    .join(format!("ringmaster-rescale-{}-{i}.ckpt", std::process::id()));
                let (loaded, _, _) = checkpoint_roundtrip(&prev, &path)?;
                Some(loaded)
            }
            None => None,
        };
        let io_secs = boundary_t.elapsed().as_secs_f64();
        let (new_ck, report) = train(&cfg, resume, steps)?;
        logs.extend(report.logs.iter().copied());
        let restart_secs = if i == 0 { 0.0 } else { io_secs + report.startup_secs };
        segments.push(Segment { workers: w, steps, report, restart_secs });
        ck = Some(new_ck);
    }

    Ok(RunOutcome {
        segments,
        checkpoint: ck.unwrap(),
        total_secs: total_t.elapsed().as_secs_f64(),
        logs,
    })
}

/// Options for the adaptive closed loop.
#[derive(Clone, Debug)]
pub struct AdaptiveOptions {
    /// Steps per segment between scheduling decisions.
    pub segment_steps: u64,
    /// GPU capacity available to this job.
    pub capacity: usize,
    /// Stop when the fitted/observed loss reaches this value.
    pub target_loss: f64,
    /// Hard cap on segments (safety).
    pub max_segments: usize,
    /// Initial worker count (before any model exists).
    pub initial_workers: usize,
}

/// The paper's loop on the real trainer: train → fit eq 1 + eq 5 → let
/// the doubling heuristic choose `w` → rescale → repeat.
pub fn train_to_target(base: &TrainConfig, opts: &AdaptiveOptions) -> Result<RunOutcome> {
    let mut ck: Option<Checkpoint> = None;
    let mut segments: Vec<Segment> = Vec::new();
    let mut logs: Vec<crate::trainer::StepLog> = Vec::new();
    let mut speed_samples: Vec<(usize, f64)> = Vec::new();
    let mut w = opts.initial_workers.max(1);
    let total_t = Instant::now();

    for seg_idx in 0..opts.max_segments {
        let mut cfg = base.clone();
        cfg.workers = w;
        let (new_ck, report) = train(&cfg, ck.take(), opts.segment_steps)?;
        // observed speed sample at this w: epochs/sec over the segment
        let seg_epochs = opts.segment_steps as f64
            * (preset_batch(base)? * w) as f64
            / base.dataset_examples as f64;
        speed_samples.push((w, seg_epochs / report.wall_secs.max(1e-9)));
        logs.extend(report.logs.iter().copied());
        let restart = if seg_idx == 0 { 0.0 } else { report.startup_secs };
        segments.push(Segment { workers: w, steps: opts.segment_steps, report, restart_secs: restart });
        let cur = segments.last().unwrap();
        ck = Some(new_ck);

        // converged?
        if let Some(l) = cur.report.logs.last() {
            if (l.loss as f64) <= opts.target_loss {
                break;
            }
        }

        // fit models and ask the doubling heuristic for the next w
        let conv_samples: Vec<(f64, f64)> =
            logs.iter().map(|l| (l.epoch, l.loss as f64)).collect();
        let conv = ConvergenceModel::fit(&conv_samples).ok();
        let epochs_now = ck.as_ref().unwrap().epochs;
        let q = conv
            .as_ref()
            .and_then(|c| c.epochs_to_loss(opts.target_loss))
            .map(|e| (e - epochs_now).max(0.1))
            .unwrap_or(10.0);
        let speed = fit_speed(&speed_samples, base)?;
        let info = JobInfo { id: 0, q, speed, max_w: opts.capacity };
        let alloc = Doubling.allocate(std::slice::from_ref(&info), opts.capacity);
        let next_w = alloc[&0].max(1);
        w = next_w;
    }

    Ok(RunOutcome {
        segments,
        checkpoint: ck.unwrap(),
        total_secs: total_t.elapsed().as_secs_f64(),
        logs,
    })
}

/// Eq-5 fit when we have >= 2 distinct worker counts, otherwise a flat
/// table (no scaling information yet — the heuristic will explore by
/// doubling because a flat table still shows gain ∝ 1/w ≥ 0… it does
/// not; a flat table yields zero gain, keeping w until more data. That
/// conservatism is the precompute-vs-explore tradeoff of §7).
fn fit_speed(samples: &[(usize, f64)], base: &TrainConfig) -> Result<Speed> {
    let distinct: std::collections::BTreeSet<usize> = samples.iter().map(|&(w, _)| w).collect();
    if distinct.len() >= 2 {
        let m = base.dataset_examples as f64;
        let artifacts = crate::runtime::Artifacts::resolve(&base.artifacts_dir)?;
        let n_bytes = artifacts.preset(&base.preset)?.n_bytes();
        if let Ok(model) = SpeedModel::fit(samples, m, n_bytes) {
            return Ok(Speed::Fitted(model));
        }
    }
    // optimistic near-linear prior: assume compute-bound scaling so the
    // heuristic explores upward; real samples correct it next segment.
    let (w0, f0) = samples.last().copied().unwrap_or((1, 1.0));
    let table: Vec<(usize, f64)> = (0..7)
        .map(|i| {
            let w = 1usize << i;
            (w, f0 * w as f64 / w0 as f64 * 0.9f64.powi(i))
        })
        .collect();
    Ok(Speed::Table(table))
}

fn preset_batch(cfg: &TrainConfig) -> Result<usize> {
    let artifacts = crate::runtime::Artifacts::resolve(&cfg.artifacts_dir)?;
    Ok(artifacts.preset(&cfg.preset)?.batch)
}
