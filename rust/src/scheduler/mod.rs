//! Dynamic scheduling — §4 of the paper.
//!
//! The resource-allocation problem (§4.1):
//!
//! ```text
//!   minimize    Σ_j t_j
//!   subject to  t_j = Q_j / f(w_j)          ∀ j ∈ J
//!               Σ_j w_j ≤ C
//!               w_j ∈ Z+                    ∀ j ∈ J
//! ```
//!
//! non-convex, non-linear, NP-hard integer program. Solvers:
//!
//! - [`doubling`] — the paper's contribution: power-of-two allocations
//!   chosen by max marginal gain per GPU (eq 6). Escapes the 8→9 local
//!   optimum that traps the greedy heuristic and keeps every job on the
//!   latency-optimal doubling-halving all-reduce.
//! - [`optimus`] — the Optimus baseline: +1 worker greedy.
//! - [`fixed`] — static request sizes (the One/Two/Four/Eight rows of
//!   Table 3) with FIFO queueing.
//! - [`exact`] — brute-force DP for small instances; used by tests to
//!   measure heuristic optimality gaps.

pub mod doubling;
pub mod exact;
pub mod fixed;
pub mod optimus;

use std::collections::BTreeMap;

use crate::perfmodel::SpeedModel;

/// Training speed f(w) as the scheduler sees it: either the smooth eq-5
/// fit, or a piecewise table (ground truth in simulations — eqs 2–4 are
/// piecewise across the dh/bb boundary, which eq 5 cannot represent).
#[derive(Clone, Debug)]
pub enum Speed {
    /// Eq-5 NNLS fit.
    Fitted(SpeedModel),
    /// `(w, epochs_per_sec)` samples, w ascending; linear interpolation
    /// between entries, flat extrapolation outside.
    Table(Vec<(usize, f64)>),
}

impl Speed {
    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        match self {
            Speed::Fitted(m) => m.epochs_per_sec(w),
            Speed::Table(t) => {
                debug_assert!(!t.is_empty());
                if w <= t[0].0 {
                    return t[0].1;
                }
                for pair in t.windows(2) {
                    let (w0, f0) = pair[0];
                    let (w1, f1) = pair[1];
                    if w == w0 {
                        return f0;
                    }
                    if w < w1 {
                        let frac = (w - w0) as f64 / (w1 - w0) as f64;
                        return f0 + frac * (f1 - f0);
                    }
                }
                t.last().unwrap().1
            }
        }
    }
}

/// What the scheduler knows about one schedulable job.
#[derive(Clone, Debug)]
pub struct JobInfo {
    pub id: u64,
    /// Remaining epochs Q_j (from the convergence model).
    pub q: f64,
    /// Resource-to-speed model f(w) (eq 5 fit or truth table).
    pub speed: Speed,
    /// Hard cap on workers for this job (e.g. 8 in the paper's runs).
    pub max_w: usize,
}

impl JobInfo {
    /// Predicted remaining runtime at `w` workers.
    pub fn time_at(&self, w: usize) -> f64 {
        if w == 0 {
            return f64::INFINITY;
        }
        self.q / self.speed.epochs_per_sec(w)
    }
}

/// Allocation: job id -> worker count (0 = queued this interval).
pub type Allocation = BTreeMap<u64, usize>;

/// Total predicted remaining time of an allocation (the IP objective).
/// Jobs allocated 0 workers contribute nothing here — queueing cost is
/// the simulator's concern (they make no progress, so their completion
/// time grows, which Table 3 measures).
pub fn objective(jobs: &[JobInfo], alloc: &Allocation) -> f64 {
    jobs.iter()
        .map(|j| match alloc.get(&j.id) {
            Some(&w) if w > 0 => j.time_at(w),
            _ => 0.0,
        })
        .sum()
}

/// Total workers granted.
pub fn total_allocated(alloc: &Allocation) -> usize {
    alloc.values().sum()
}

/// A scheduling strategy: map job demands + capacity to an allocation.
pub trait Scheduler {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// A job whose epoch time follows the ring cost shape; `scale`
    /// controls how compute-heavy (parallelizable) it is.
    pub fn job(id: u64, q: f64, scale: f64) -> JobInfo {
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&w| {
                let t = scale / w as f64 + 1.5 * (w as f64 - 1.0) + 2.0;
                (w, 1.0 / t)
            })
            .collect();
        JobInfo {
            id,
            q,
            speed: Speed::Fitted(SpeedModel::fit(&samples, 128.0, 4.0e6).unwrap()),
            max_w: 64,
        }
    }

    pub fn check_within_capacity(alloc: &Allocation, capacity: usize) {
        assert!(
            total_allocated(alloc) <= capacity,
            "allocation {:?} exceeds capacity {capacity}",
            alloc
        );
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::job;
    use super::*;

    #[test]
    fn objective_sums_remaining_times() {
        let jobs = vec![job(1, 10.0, 100.0), job(2, 20.0, 100.0)];
        let mut alloc = Allocation::new();
        alloc.insert(1, 2);
        alloc.insert(2, 4);
        let want = jobs[0].time_at(2) + jobs[1].time_at(4);
        assert!((objective(&jobs, &alloc) - want).abs() < 1e-9);
    }

    #[test]
    fn time_at_zero_workers_is_infinite() {
        assert!(job(1, 10.0, 100.0).time_at(0).is_infinite());
    }

    #[test]
    fn time_at_decreases_with_workers_for_compute_bound_jobs() {
        let j = job(1, 10.0, 400.0);
        assert!(j.time_at(8) < j.time_at(4));
        assert!(j.time_at(4) < j.time_at(1));
    }
}
