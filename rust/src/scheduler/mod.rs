//! Dynamic scheduling — §4 of the paper.
//!
//! The resource-allocation problem (§4.1):
//!
//! ```text
//!   minimize    Σ_j t_j
//!   subject to  t_j = Q_j / f(w_j)          ∀ j ∈ J
//!               Σ_j w_j ≤ C
//!               w_j ∈ Z+                    ∀ j ∈ J
//! ```
//!
//! non-convex, non-linear, NP-hard integer program. Solvers:
//!
//! - [`doubling`] — the paper's contribution: power-of-two allocations
//!   chosen by max marginal gain per GPU (eq 6). Escapes the 8→9 local
//!   optimum that traps the greedy heuristic and keeps every job on the
//!   latency-optimal doubling-halving all-reduce.
//! - [`optimus`] — the Optimus baseline: +1 worker greedy.
//! - [`fixed`] — static request sizes (the One/Two/Four/Eight rows of
//!   Table 3) with FIFO queueing.
//! - [`exact`] — brute-force DP for small instances; used by tests to
//!   measure heuristic optimality gaps.

pub mod doubling;
pub mod exact;
pub mod fixed;
pub mod optimus;

use std::collections::BTreeMap;

use crate::perfmodel::{PlacementModel, SpeedModel};

/// Training speed f(w) as the scheduler sees it: the smooth eq-5 fit, a
/// piecewise table (ground truth in simulations — eqs 2–4 are piecewise
/// across the dh/bb boundary, which eq 5 cannot represent), a
/// live-learned fit with a fallback prior, or any of those adjusted for
/// gang placement (`f(w, placement)`).
#[derive(Clone, Debug)]
pub enum Speed {
    /// Eq-5 NNLS fit.
    Fitted(SpeedModel),
    /// `(w, epochs_per_sec)` samples, w ascending; linear interpolation
    /// between entries, flat extrapolation outside.
    Table(Vec<(usize, f64)>),
    /// Topology-adjusted speed: the base profile assumes a single-node
    /// ring; widths whose gang must span several nodes pay the eq-2
    /// inter-node delta. This is what schedulers see on a non-flat
    /// topology, so eq-6 gains are scored against the placement the
    /// cluster would actually grant.
    Placed(PlacedSpeed),
    /// Online-learned speed: the confidence-gated eq-5 fit from a job's
    /// finished live segments once the gate opens, the submission-time
    /// prior until then. This is what strategies see under the
    /// orchestrator's `--online-model` — widths are scored against
    /// *measured* behavior, not assumed tables.
    Learned(LearnedSpeed),
}

/// Live-learned speed with its pre-gate fallback.
#[derive(Clone, Debug)]
pub struct LearnedSpeed {
    /// The gate-opened eq-5 fit (single-node base, like the tables —
    /// wrap the whole `Learned` in [`Speed::placed`] on a grid).
    /// `None` while the confidence gate is closed.
    pub fit: Option<SpeedModel>,
    /// Speed consulted until the gate opens (the trace table under
    /// `--online-model`).
    pub prior: Box<Speed>,
}

impl LearnedSpeed {
    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        match &self.fit {
            Some(m) => m.epochs_per_sec(w),
            None => self.prior.epochs_per_sec(w),
        }
    }

    /// True once the scheduler is running on the learned fit.
    pub fn gate_open(&self) -> bool {
        self.fit.is_some()
    }
}

/// Placement-aware wrapper around a base [`Speed`].
#[derive(Clone, Debug)]
pub struct PlacedSpeed {
    pub base: Box<Speed>,
    pub model: PlacementModel,
    /// Node width of the target topology; the scheduler scores `w`
    /// against the contiguous best case `ceil(w / gpus_per_node)`.
    pub gpus_per_node: usize,
}

impl PlacedSpeed {
    /// Nodes a gang of `w` spans in the contiguous best case.
    pub fn span(&self, w: usize) -> usize {
        crate::cluster::contiguous_span(w, self.gpus_per_node)
    }

    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        let base = self.base.epochs_per_sec(w);
        if base <= 0.0 {
            return 0.0;
        }
        let extra = self.model.extra_epoch_secs(w, self.span(w));
        if extra <= 0.0 {
            // exact flat identity (1/(1/x) is not bit-stable)
            return base;
        }
        1.0 / (1.0 / base + extra)
    }
}

impl Speed {
    /// Wrap a base speed with the placement penalty of `topology`
    /// (identity wrapper for a single-node span).
    pub fn placed(base: Speed, model: PlacementModel, gpus_per_node: usize) -> Speed {
        Speed::Placed(PlacedSpeed { base: Box::new(base), model, gpus_per_node })
    }

    /// Wrap an online-learned fit (possibly still gate-closed) over its
    /// fallback prior.
    pub fn learned(fit: Option<SpeedModel>, prior: Speed) -> Speed {
        Speed::Learned(LearnedSpeed { fit, prior: Box::new(prior) })
    }

    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        match self {
            Speed::Fitted(m) => m.epochs_per_sec(w),
            Speed::Placed(p) => p.epochs_per_sec(w),
            Speed::Learned(l) => l.epochs_per_sec(w),
            Speed::Table(t) => {
                debug_assert!(!t.is_empty());
                if w <= t[0].0 {
                    return t[0].1;
                }
                for pair in t.windows(2) {
                    let (w0, f0) = pair[0];
                    let (w1, f1) = pair[1];
                    if w == w0 {
                        return f0;
                    }
                    if w < w1 {
                        let frac = (w - w0) as f64 / (w1 - w0) as f64;
                        return f0 + frac * (f1 - f0);
                    }
                }
                t.last().unwrap().1
            }
        }
    }
}

/// What the scheduler knows about one schedulable job.
#[derive(Clone, Debug)]
pub struct JobInfo {
    pub id: u64,
    /// Remaining epochs Q_j (from the convergence model).
    pub q: f64,
    /// Resource-to-speed model f(w) (eq 5 fit or truth table).
    pub speed: Speed,
    /// Hard cap on workers for this job (e.g. 8 in the paper's runs).
    pub max_w: usize,
}

impl JobInfo {
    /// Predicted remaining runtime at `w` workers.
    pub fn time_at(&self, w: usize) -> f64 {
        if w == 0 {
            return f64::INFINITY;
        }
        self.q / self.speed.epochs_per_sec(w)
    }
}

/// Allocation: job id -> worker count (0 = queued this interval).
pub type Allocation = BTreeMap<u64, usize>;

/// Total predicted remaining time of an allocation (the IP objective).
/// Jobs allocated 0 workers contribute nothing here — queueing cost is
/// the simulator's concern (they make no progress, so their completion
/// time grows, which Table 3 measures).
pub fn objective(jobs: &[JobInfo], alloc: &Allocation) -> f64 {
    jobs.iter()
        .map(|j| match alloc.get(&j.id) {
            Some(&w) if w > 0 => j.time_at(w),
            _ => 0.0,
        })
        .sum()
}

/// Total workers granted.
pub fn total_allocated(alloc: &Allocation) -> usize {
    alloc.values().sum()
}

/// A scheduling strategy: map job demands + capacity to an allocation.
pub trait Scheduler {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// A job whose epoch time follows the ring cost shape; `scale`
    /// controls how compute-heavy (parallelizable) it is.
    pub fn job(id: u64, q: f64, scale: f64) -> JobInfo {
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&w| {
                let t = scale / w as f64 + 1.5 * (w as f64 - 1.0) + 2.0;
                (w, 1.0 / t)
            })
            .collect();
        JobInfo {
            id,
            q,
            speed: Speed::Fitted(SpeedModel::fit(&samples, 128.0, 4.0e6).unwrap()),
            max_w: 64,
        }
    }

    pub fn check_within_capacity(alloc: &Allocation, capacity: usize) {
        assert!(
            total_allocated(alloc) <= capacity,
            "allocation {:?} exceeds capacity {capacity}",
            alloc
        );
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::job;
    use super::*;

    #[test]
    fn objective_sums_remaining_times() {
        let jobs = vec![job(1, 10.0, 100.0), job(2, 20.0, 100.0)];
        let mut alloc = Allocation::new();
        alloc.insert(1, 2);
        alloc.insert(2, 4);
        let want = jobs[0].time_at(2) + jobs[1].time_at(4);
        assert!((objective(&jobs, &alloc) - want).abs() < 1e-9);
    }

    #[test]
    fn time_at_zero_workers_is_infinite() {
        assert!(job(1, 10.0, 100.0).time_at(0).is_infinite());
    }

    #[test]
    fn time_at_decreases_with_workers_for_compute_bound_jobs() {
        let j = job(1, 10.0, 400.0);
        assert!(j.time_at(8) < j.time_at(4));
        assert!(j.time_at(4) < j.time_at(1));
    }

    mod placed {
        use super::super::*;
        use crate::perfmodel::PlacementModel;

        /// Strong-scaling truth table out to w=16 (flat world).
        fn strong_table() -> Vec<(usize, f64)> {
            [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&w| (w, 1.0 / (200.0 / w as f64 + 1.0 * (w as f64 - 1.0) + 2.0)))
                .collect()
        }

        fn placed_speed(gpus_per_node: usize) -> Speed {
            // communication-bound payload so the span penalty bites
            let model = PlacementModel::paper().with_model_bytes(1.0e8);
            Speed::placed(Speed::Table(strong_table()), model, gpus_per_node)
        }

        #[test]
        fn identity_while_the_gang_fits_one_node() {
            let flat = Speed::Table(strong_table());
            let placed = placed_speed(8);
            for w in [1usize, 2, 4, 8] {
                assert_eq!(
                    placed.epochs_per_sec(w).to_bits(),
                    flat.epochs_per_sec(w).to_bits(),
                    "w={w}"
                );
            }
        }

        #[test]
        fn slower_once_the_ring_spans_nodes() {
            let flat = Speed::Table(strong_table());
            let placed = placed_speed(8);
            assert!(placed.epochs_per_sec(16) < flat.epochs_per_sec(16));
            assert!(placed.epochs_per_sec(9) < flat.epochs_per_sec(9));
        }

        #[test]
        fn learned_speed_composes_with_placement() {
            // A learned fit wrapped in Placed pays the span penalty just
            // like a table does: gate open, w=16 spans 2 nodes -> slower
            // than the bare learned fit.
            let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&w| (w, 1.0 / (200.0 / w as f64 + 2.0)))
                .collect();
            let fit = crate::perfmodel::SpeedModel::fit(&samples, 200.0, 1.0e8).unwrap();
            let bare = Speed::learned(Some(fit.clone()), Speed::Table(strong_table()));
            let placed = Speed::placed(
                bare.clone(),
                PlacementModel::paper().with_model_bytes(1.0e8),
                8,
            );
            for w in [1usize, 2, 4, 8] {
                assert_eq!(placed.epochs_per_sec(w).to_bits(), bare.epochs_per_sec(w).to_bits());
            }
            assert!(placed.epochs_per_sec(16) < bare.epochs_per_sec(16));
        }

        #[test]
        fn doubling_stops_at_the_node_boundary() {
            // Flat sees strong scaling to 16 and doubles past 8; the
            // placement-adjusted view knows 16 means spanning 2 nodes on
            // a 10 GbE network and keeps the gang inside one node.
            let flat_job = JobInfo {
                id: 1,
                q: 100.0,
                speed: Speed::Table(strong_table()),
                max_w: 16,
            };
            let placed_job = JobInfo { speed: placed_speed(8), ..flat_job.clone() };
            let flat_alloc = doubling::Doubling.allocate(std::slice::from_ref(&flat_job), 16);
            let placed_alloc =
                doubling::Doubling.allocate(std::slice::from_ref(&placed_job), 16);
            assert_eq!(flat_alloc[&1], 16, "flat should chase the strong scaling");
            assert_eq!(placed_alloc[&1], 8, "placed should refuse to span nodes");
        }
    }

    mod learned {
        use super::super::*;
        use crate::perfmodel::SpeedModel;

        fn strong_fit() -> SpeedModel {
            let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&w| (w, 1.0 / (400.0 / w as f64 + 1.0 * (w as f64 - 1.0) + 2.0)))
                .collect();
            SpeedModel::fit(&samples, 400.0, 4.0e6).unwrap()
        }

        /// Pessimistic prior: no scaling at all past w=1.
        fn flat_prior() -> Speed {
            Speed::Table(vec![(1, 1.0 / 50.0), (16, 1.0 / 50.0)])
        }

        #[test]
        fn closed_gate_consults_the_prior_bit_for_bit() {
            let learned = Speed::learned(None, flat_prior());
            for w in [1usize, 2, 7, 16, 64] {
                assert_eq!(
                    learned.epochs_per_sec(w).to_bits(),
                    flat_prior().epochs_per_sec(w).to_bits(),
                    "w={w}"
                );
            }
            match &learned {
                Speed::Learned(l) => assert!(!l.gate_open()),
                _ => unreachable!(),
            }
        }

        #[test]
        fn open_gate_overrides_the_prior() {
            let fit = strong_fit();
            let learned = Speed::learned(Some(fit.clone()), flat_prior());
            assert_eq!(learned.epochs_per_sec(8).to_bits(), fit.epochs_per_sec(8).to_bits());
            assert!(learned.epochs_per_sec(8) > flat_prior().epochs_per_sec(8));
        }
    }
}
