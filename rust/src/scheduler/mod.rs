//! Dynamic scheduling — §4 of the paper.
//!
//! The resource-allocation problem (§4.1):
//!
//! ```text
//!   minimize    Σ_j t_j
//!   subject to  t_j = Q_j / f(w_j)          ∀ j ∈ J
//!               Σ_j w_j ≤ C
//!               w_j ∈ Z+                    ∀ j ∈ J
//! ```
//!
//! non-convex, non-linear, NP-hard integer program. Solvers:
//!
//! - [`doubling`] — the paper's contribution: power-of-two allocations
//!   chosen by max marginal gain per GPU (eq 6). Escapes the 8→9 local
//!   optimum that traps the greedy heuristic and keeps every job on the
//!   latency-optimal doubling-halving all-reduce.
//! - [`optimus`] — the Optimus baseline: +1 worker greedy.
//! - [`fixed`] — static request sizes (the One/Two/Four/Eight rows of
//!   Table 3) with FIFO queueing.
//! - [`exact`] — brute-force DP for small instances; used by tests to
//!   measure heuristic optimality gaps.

pub mod doubling;
pub mod exact;
pub mod fixed;
pub mod optimus;

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::perfmodel::{LinkContention, PlacementModel, SpeedModel};

/// Training speed f(w) as the scheduler sees it: the smooth eq-5 fit, a
/// piecewise table (ground truth in simulations — eqs 2–4 are piecewise
/// across the dh/bb boundary, which eq 5 cannot represent), a
/// live-learned fit with a fallback prior, or any of those adjusted for
/// gang placement (`f(w, placement)`).
#[derive(Clone, Debug)]
pub enum Speed {
    /// Eq-5 NNLS fit.
    Fitted(SpeedModel),
    /// `(w, epochs_per_sec)` samples, w strictly ascending; linear
    /// interpolation between entries, flat extrapolation outside.
    Table(Vec<(usize, f64)>),
    /// [`Speed::Table`] backed by a shared, immutable sample set — what
    /// hot loops (the DES, the orchestrator) hand to every scheduler
    /// call, so per-event `JobInfo` construction is an `Arc` bump
    /// instead of a table copy. Lookup semantics are bit-identical to
    /// `Table`.
    Shared(Arc<Vec<(usize, f64)>>),
    /// Topology-adjusted speed: the base profile assumes a single-node
    /// ring; widths whose gang must span several nodes pay the eq-2
    /// inter-node delta. This is what schedulers see on a non-flat
    /// topology, so eq-6 gains are scored against the placement the
    /// cluster would actually grant.
    Placed(PlacedSpeed),
    /// Online-learned speed: the confidence-gated eq-5 fit from a job's
    /// finished live segments once the gate opens, the submission-time
    /// prior until then. This is what strategies see under the
    /// orchestrator's `--online-model` — widths are scored against
    /// *measured* behavior, not assumed tables.
    Learned(LearnedSpeed),
}

/// Live-learned speed with its pre-gate fallback.
#[derive(Clone, Debug)]
pub struct LearnedSpeed {
    /// The gate-opened eq-5 fit (single-node base, like the tables —
    /// wrap the whole `Learned` in [`Speed::placed`] on a grid).
    /// `None` while the confidence gate is closed.
    pub fit: Option<SpeedModel>,
    /// Speed consulted until the gate opens (the trace table under
    /// `--online-model`).
    pub prior: Box<Speed>,
}

impl LearnedSpeed {
    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        match &self.fit {
            Some(m) => m.epochs_per_sec(w),
            None => self.prior.epochs_per_sec(w),
        }
    }

    /// True once the scheduler is running on the learned fit.
    pub fn gate_open(&self) -> bool {
        self.fit.is_some()
    }
}

/// Placement-aware wrapper around a base [`Speed`].
#[derive(Clone, Debug)]
pub struct PlacedSpeed {
    pub base: Box<Speed>,
    pub model: PlacementModel,
    /// Node width of the target topology; the scheduler scores `w`
    /// against the contiguous best case `ceil(w / gpus_per_node)`.
    pub gpus_per_node: usize,
    /// Memoized `extra_epoch_secs(w, span(w))` indexed by `w - 1` —
    /// eqs 2–4 sum per-chunk comm times, far too hot to recompute for
    /// every (job, width) probe of a scheduler's inner loop. `None`
    /// computes on demand; the values are bit-identical either way.
    memo: Option<Arc<Vec<f64>>>,
    /// Shared-bandwidth law ([`LinkContention::OFF`] unless built via
    /// [`Speed::placed_contended`]).
    law: LinkContention,
    /// Rings the scheduler assumes a cross-node gang for this job would
    /// share its busiest link with (1 = sole tenant). Only consulted
    /// when the law is enabled *and* tenants > 1 — otherwise the memo /
    /// uncontended path runs unchanged, so contention-off scoring is
    /// bit-identical to PR 3.
    tenants: usize,
}

impl PlacedSpeed {
    /// Nodes a gang of `w` spans in the contiguous best case.
    pub fn span(&self, w: usize) -> usize {
        crate::cluster::contiguous_span(w, self.gpus_per_node)
    }

    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        let base = self.base.epochs_per_sec(w);
        if base <= 0.0 {
            return 0.0;
        }
        let extra = if self.law.enabled() && self.tenants > 1 {
            // memo entries price a sole-tenant ring; a contended score
            // must re-price at the assumed tenancy (intra-node widths
            // still come out 0.0 — contention never touches them)
            self.model.contended_extra_epoch_secs(w, self.span(w), self.law, self.tenants)
        } else {
            match &self.memo {
                Some(m) if w >= 1 && w <= m.len() => m[w - 1],
                _ => self.model.extra_epoch_secs(w, self.span(w)),
            }
        };
        if extra <= 0.0 {
            // exact flat identity (1/(1/x) is not bit-stable)
            return base;
        }
        1.0 / (1.0 / base + extra)
    }
}

impl Speed {
    /// Wrap a base speed with the placement penalty of `topology`
    /// (identity wrapper for a single-node span).
    pub fn placed(base: Speed, model: PlacementModel, gpus_per_node: usize) -> Speed {
        Speed::Placed(PlacedSpeed {
            base: Box::new(base),
            model,
            gpus_per_node,
            memo: None,
            law: LinkContention::OFF,
            tenants: 1,
        })
    }

    /// [`Speed::placed`] with the span penalty precomputed for widths
    /// `1..=memo.len()` (see [`PlacementModel::contiguous_extra_table`]).
    /// Build the memo once per (model, topology) and share it across
    /// every job wrapped at the same placement — the DES does this once
    /// per run instead of re-pricing eq 2–4 at every event.
    pub fn placed_memo(
        base: Speed,
        model: PlacementModel,
        gpus_per_node: usize,
        memo: Arc<Vec<f64>>,
    ) -> Speed {
        Speed::Placed(PlacedSpeed {
            base: Box::new(base),
            model,
            gpus_per_node,
            memo: Some(memo),
            law: LinkContention::OFF,
            tenants: 1,
        })
    }

    /// [`Speed::placed`]/[`Speed::placed_memo`] under a shared-bandwidth
    /// law: cross-node widths are scored as if their ring shared its
    /// busiest link with `tenants - 1` other rings. With `tenants <= 1`
    /// (or the law disabled) every lookup takes the exact uncontended
    /// path — including the memo — so this wrapper is bit-identical to
    /// its plain counterparts in the sole-tenant case.
    pub fn placed_contended(
        base: Speed,
        model: PlacementModel,
        gpus_per_node: usize,
        memo: Option<Arc<Vec<f64>>>,
        law: LinkContention,
        tenants: usize,
    ) -> Speed {
        Speed::Placed(PlacedSpeed {
            base: Box::new(base),
            model,
            gpus_per_node,
            memo,
            law,
            tenants: tenants.max(1),
        })
    }

    /// Wrap an online-learned fit (possibly still gate-closed) over its
    /// fallback prior.
    pub fn learned(fit: Option<SpeedModel>, prior: Speed) -> Speed {
        Speed::Learned(LearnedSpeed { fit, prior: Box::new(prior) })
    }

    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        match self {
            Speed::Fitted(m) => m.epochs_per_sec(w),
            Speed::Placed(p) => p.epochs_per_sec(w),
            Speed::Learned(l) => l.epochs_per_sec(w),
            Speed::Table(t) => table_epochs_per_sec(t, w),
            Speed::Shared(t) => table_epochs_per_sec(t, w),
        }
    }
}

/// Interpolating `(w, epochs/sec)` lookup shared by [`Speed::Table`] and
/// [`Speed::Shared`]: binary search over the sample widths (strictly
/// ascending), linear interpolation between neighbours, flat
/// extrapolation outside — the same piecewise curve the old linear walk
/// produced, bit for bit, at O(log n) per probe.
fn table_epochs_per_sec(t: &[(usize, f64)], w: usize) -> f64 {
    debug_assert!(!t.is_empty());
    debug_assert!(t.windows(2).all(|p| p[0].0 < p[1].0), "table widths must strictly ascend");
    if w <= t[0].0 {
        return t[0].1;
    }
    match t.binary_search_by(|probe| probe.0.cmp(&w)) {
        Ok(i) => t[i].1,
        Err(i) if i == t.len() => t[t.len() - 1].1,
        Err(i) => {
            let (w0, f0) = t[i - 1];
            let (w1, f1) = t[i];
            let frac = (w - w0) as f64 / (w1 - w0) as f64;
            f0 + frac * (f1 - f0)
        }
    }
}

/// What the scheduler knows about one schedulable job.
#[derive(Clone, Debug)]
pub struct JobInfo {
    pub id: u64,
    /// Remaining epochs Q_j (from the convergence model).
    pub q: f64,
    /// Resource-to-speed model f(w) (eq 5 fit or truth table).
    pub speed: Speed,
    /// Hard cap on workers for this job (e.g. 8 in the paper's runs).
    pub max_w: usize,
}

impl JobInfo {
    /// Predicted remaining runtime at `w` workers.
    pub fn time_at(&self, w: usize) -> f64 {
        if w == 0 {
            return f64::INFINITY;
        }
        self.q / self.speed.epochs_per_sec(w)
    }
}

/// Allocation: job id -> worker count (0 = queued this interval).
pub type Allocation = BTreeMap<u64, usize>;

/// Total predicted remaining time of an allocation (the IP objective).
/// Jobs allocated 0 workers contribute nothing here — queueing cost is
/// the simulator's concern (they make no progress, so their completion
/// time grows, which Table 3 measures).
pub fn objective(jobs: &[JobInfo], alloc: &Allocation) -> f64 {
    jobs.iter()
        .map(|j| match alloc.get(&j.id) {
            Some(&w) if w > 0 => j.time_at(w),
            _ => 0.0,
        })
        .sum()
}

/// Total workers granted.
pub fn total_allocated(alloc: &Allocation) -> usize {
    alloc.values().sum()
}

/// What happened to one candidate step of an allocation — the decision
/// provenance telemetry records so `ringmaster report` can answer "why
/// width w" for every grant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GrantOutcome {
    /// Baseline grant (the 1-GPU seed of the gain heaps, or a fixed
    /// strategy's static request).
    Seed,
    /// The pop won: the job stepped from `from_w` to `to_w`.
    Grant,
    /// The pop was stale (the job's width moved past the scored `w`
    /// before this entry surfaced) and was discarded.
    Stale,
    /// The step didn't fit in the remaining free GPUs.
    NoFit,
}

impl GrantOutcome {
    pub fn name(self) -> &'static str {
        match self {
            GrantOutcome::Seed => "seed",
            GrantOutcome::Grant => "grant",
            GrantOutcome::Stale => "stale",
            GrantOutcome::NoFit => "nofit",
        }
    }
}

/// One recorded step of an allocation: the candidate considered (job,
/// `from_w` → `to_w`), its marginal gain per GPU at pop time (0 for
/// seeds), and what became of it. A traced allocation records *every*
/// heap pop, so the audit can replay the argmax argument behind each
/// granted width.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GrantStep {
    pub job: u64,
    pub from_w: usize,
    pub to_w: usize,
    pub gain: f64,
    pub outcome: GrantOutcome,
}

/// A scheduling strategy: map job demands + capacity to an allocation.
pub trait Scheduler {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation;

    /// [`Scheduler::allocate`] with decision provenance: identical math,
    /// identical result (strategies implement both off one inner loop),
    /// plus every candidate step appended to `trace`. The default
    /// records nothing — a strategy without provenance still allocates
    /// correctly, it just can't explain itself in the audit.
    fn allocate_traced(
        &self,
        jobs: &[JobInfo],
        capacity: usize,
        trace: &mut Vec<GrantStep>,
    ) -> Allocation {
        let _ = trace;
        self.allocate(jobs, capacity)
    }

    fn name(&self) -> &'static str;
}

/// One candidate step in a greedy allocator's gain heap: job at slice
/// position `idx`, scored at width `w` — stale once the job's width
/// moved past `w`. Shared by [`doubling`] (×2 steps) and [`optimus`]
/// (+1 steps) so the load-bearing tie-break lives in exactly one place.
pub(crate) struct Gain {
    pub(crate) gain: f64,
    pub(crate) idx: usize,
    pub(crate) w: usize,
}

impl PartialEq for Gain {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Gain {}
impl PartialOrd for Gain {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Gain {
    /// Max-heap on gain; ties go to the earlier (FIFO) job — exactly the
    /// candidate a full O(J) rescan's strict-`>` argmax would keep.
    /// Callers only push finite gains, so `total_cmp` is a plain order.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.gain.total_cmp(&other.gain).then_with(|| other.idx.cmp(&self.idx))
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// A job whose epoch time follows the ring cost shape; `scale`
    /// controls how compute-heavy (parallelizable) it is.
    pub fn job(id: u64, q: f64, scale: f64) -> JobInfo {
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&w| {
                let t = scale / w as f64 + 1.5 * (w as f64 - 1.0) + 2.0;
                (w, 1.0 / t)
            })
            .collect();
        JobInfo {
            id,
            q,
            speed: Speed::Fitted(SpeedModel::fit(&samples, 128.0, 4.0e6).unwrap()),
            max_w: 64,
        }
    }

    pub fn check_within_capacity(alloc: &Allocation, capacity: usize) {
        assert!(
            total_allocated(alloc) <= capacity,
            "allocation {:?} exceeds capacity {capacity}",
            alloc
        );
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::job;
    use super::*;

    #[test]
    fn scheduler_inputs_are_send_sync_for_the_sweep_runner() {
        // `sim::sweep` races whole scheduler invocations across
        // threads, sharing memo tables (`PlacedSpeed::memo`,
        // `Speed::Shared`) through `Arc`s. That is sound only while
        // every scheduler input stays plain data — no `Rc`, `RefCell`,
        // or un-`Sync` trait object smuggled into a `Speed` variant.
        // Pin the contract at compile time, next to the types it
        // constrains.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Speed>();
        assert_send_sync::<JobInfo>();
        assert_send_sync::<Allocation>();
        assert_send_sync::<GrantStep>();
    }

    #[test]
    fn objective_sums_remaining_times() {
        let jobs = vec![job(1, 10.0, 100.0), job(2, 20.0, 100.0)];
        let mut alloc = Allocation::new();
        alloc.insert(1, 2);
        alloc.insert(2, 4);
        let want = jobs[0].time_at(2) + jobs[1].time_at(4);
        assert!((objective(&jobs, &alloc) - want).abs() < 1e-9);
    }

    #[test]
    fn time_at_zero_workers_is_infinite() {
        assert!(job(1, 10.0, 100.0).time_at(0).is_infinite());
    }

    #[test]
    fn time_at_decreases_with_workers_for_compute_bound_jobs() {
        let j = job(1, 10.0, 400.0);
        assert!(j.time_at(8) < j.time_at(4));
        assert!(j.time_at(4) < j.time_at(1));
    }

    /// The old linear walk, kept verbatim as the lookup oracle.
    fn linear_epochs_per_sec(t: &[(usize, f64)], w: usize) -> f64 {
        if w <= t[0].0 {
            return t[0].1;
        }
        for pair in t.windows(2) {
            let (w0, f0) = pair[0];
            let (w1, f1) = pair[1];
            if w == w0 {
                return f0;
            }
            if w < w1 {
                let frac = (w - w0) as f64 / (w1 - w0) as f64;
                return f0 + frac * (f1 - f0);
            }
        }
        t.last().unwrap().1
    }

    #[test]
    fn binary_search_lookup_matches_linear_walk_bit_for_bit() {
        use crate::rngx::Rng;
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..200 {
            // random strictly-ascending table, 1..=9 entries
            let len = 1 + (rng.uniform_range(0.0, 9.0) as usize).min(8);
            let mut t: Vec<(usize, f64)> = Vec::with_capacity(len);
            let mut w = 1 + rng.uniform_range(0.0, 3.0) as usize;
            for _ in 0..len {
                t.push((w, rng.uniform_range(1e-4, 1.0)));
                w += 1 + rng.uniform_range(0.0, 7.0) as usize;
            }
            let table = Speed::Table(t.clone());
            let shared = Speed::Shared(std::sync::Arc::new(t.clone()));
            for probe in 0..=(w + 4) {
                let want = linear_epochs_per_sec(&t, probe);
                assert_eq!(table.epochs_per_sec(probe).to_bits(), want.to_bits(), "w={probe}");
                assert_eq!(shared.epochs_per_sec(probe).to_bits(), want.to_bits(), "w={probe}");
            }
        }
    }

    #[test]
    fn shared_table_is_one_arc_not_a_copy() {
        let t = std::sync::Arc::new(vec![(1usize, 0.1f64), (8, 0.5)]);
        let a = Speed::Shared(t.clone());
        let b = a.clone();
        drop(b);
        assert!(std::sync::Arc::strong_count(&t) >= 2);
        assert_eq!(a.epochs_per_sec(8), 0.5);
    }

    mod placed {
        use super::super::*;
        use crate::perfmodel::PlacementModel;

        /// Strong-scaling truth table out to w=16 (flat world).
        fn strong_table() -> Vec<(usize, f64)> {
            [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&w| (w, 1.0 / (200.0 / w as f64 + 1.0 * (w as f64 - 1.0) + 2.0)))
                .collect()
        }

        fn placed_speed(gpus_per_node: usize) -> Speed {
            // communication-bound payload so the span penalty bites
            let model = PlacementModel::paper().with_model_bytes(1.0e8);
            Speed::placed(Speed::Table(strong_table()), model, gpus_per_node)
        }

        #[test]
        fn identity_while_the_gang_fits_one_node() {
            let flat = Speed::Table(strong_table());
            let placed = placed_speed(8);
            for w in [1usize, 2, 4, 8] {
                assert_eq!(
                    placed.epochs_per_sec(w).to_bits(),
                    flat.epochs_per_sec(w).to_bits(),
                    "w={w}"
                );
            }
        }

        #[test]
        fn slower_once_the_ring_spans_nodes() {
            let flat = Speed::Table(strong_table());
            let placed = placed_speed(8);
            assert!(placed.epochs_per_sec(16) < flat.epochs_per_sec(16));
            assert!(placed.epochs_per_sec(9) < flat.epochs_per_sec(9));
        }

        #[test]
        fn learned_speed_composes_with_placement() {
            // A learned fit wrapped in Placed pays the span penalty just
            // like a table does: gate open, w=16 spans 2 nodes -> slower
            // than the bare learned fit.
            let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&w| (w, 1.0 / (200.0 / w as f64 + 2.0)))
                .collect();
            let fit = crate::perfmodel::SpeedModel::fit(&samples, 200.0, 1.0e8).unwrap();
            let bare = Speed::learned(Some(fit.clone()), Speed::Table(strong_table()));
            let placed = Speed::placed(
                bare.clone(),
                PlacementModel::paper().with_model_bytes(1.0e8),
                8,
            );
            for w in [1usize, 2, 4, 8] {
                assert_eq!(placed.epochs_per_sec(w).to_bits(), bare.epochs_per_sec(w).to_bits());
            }
            assert!(placed.epochs_per_sec(16) < bare.epochs_per_sec(16));
        }

        #[test]
        fn memoized_placement_is_bit_identical_to_on_demand() {
            let model = PlacementModel::paper().with_model_bytes(1.0e8);
            let memo = std::sync::Arc::new(model.contiguous_extra_table(8, 16));
            let plain = Speed::placed(Speed::Table(strong_table()), model, 8);
            let memod =
                Speed::placed_memo(Speed::Table(strong_table()), model, 8, memo);
            // inside the memo, past its end (falls back to on-demand), and w=0
            for w in [0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 33, 64] {
                assert_eq!(
                    memod.epochs_per_sec(w).to_bits(),
                    plain.epochs_per_sec(w).to_bits(),
                    "w={w}"
                );
            }
        }

        #[test]
        fn contended_sole_tenant_is_bit_identical_to_plain_and_memo() {
            use crate::perfmodel::LinkContention;
            let model = PlacementModel::paper().with_model_bytes(1.0e8);
            let memo = std::sync::Arc::new(model.contiguous_extra_table(8, 16));
            let plain = Speed::placed(Speed::Table(strong_table()), model, 8);
            let memod =
                Speed::placed_memo(Speed::Table(strong_table()), model, 8, memo.clone());
            let sole = Speed::placed_contended(
                Speed::Table(strong_table()),
                model,
                8,
                Some(memo.clone()),
                LinkContention::fair_share(),
                1,
            );
            let off = Speed::placed_contended(
                Speed::Table(strong_table()),
                model,
                8,
                Some(memo),
                LinkContention::OFF,
                4,
            );
            for w in [0usize, 1, 2, 7, 8, 9, 16, 17, 33] {
                let want = memod.epochs_per_sec(w).to_bits();
                assert_eq!(sole.epochs_per_sec(w).to_bits(), want, "tenants=1 w={w}");
                assert_eq!(off.epochs_per_sec(w).to_bits(), want, "law off w={w}");
                assert_eq!(plain.epochs_per_sec(w).to_bits(), want, "plain w={w}");
            }
        }

        #[test]
        fn contended_cross_node_widths_score_slower() {
            use crate::perfmodel::LinkContention;
            let model = PlacementModel::paper().with_model_bytes(1.0e8);
            let sole = placed_speed(8);
            let shared = Speed::placed_contended(
                Speed::Table(strong_table()),
                model,
                8,
                None,
                LinkContention::fair_share(),
                2,
            );
            // intra-node widths: no link, no degradation, bit-identical
            for w in [1usize, 2, 4, 8] {
                assert_eq!(
                    shared.epochs_per_sec(w).to_bits(),
                    sole.epochs_per_sec(w).to_bits(),
                    "w={w}"
                );
            }
            // cross-node widths: sharing the uplink must score slower
            for w in [9usize, 16] {
                assert!(
                    shared.epochs_per_sec(w) < sole.epochs_per_sec(w),
                    "w={w}: contended not slower"
                );
            }
        }

        #[test]
        fn doubling_refuses_node_boundary_sooner_under_contention() {
            use crate::perfmodel::LinkContention;
            // A mildly comm-bound job where doubling 8 -> 16 is *just*
            // worth it alone: adding a second tenant on the uplink must
            // flip the decision back to the single-node width. This is
            // the f(w, placement, contention) the marginal-gain heaps
            // are supposed to see.
            let model = PlacementModel::paper().with_model_bytes(3.0e7);
            let mk = |tenants: usize| JobInfo {
                id: 1,
                q: 100.0,
                speed: Speed::placed_contended(
                    Speed::Table(strong_table()),
                    model,
                    8,
                    None,
                    LinkContention::fair_share(),
                    tenants,
                ),
                max_w: 16,
            };
            let alone = doubling::Doubling.allocate(std::slice::from_ref(&mk(1)), 16);
            let crowded = doubling::Doubling.allocate(std::slice::from_ref(&mk(4)), 16);
            assert_eq!(alone[&1], 16, "sole tenant should still cross");
            assert_eq!(crowded[&1], 8, "4 tenants must keep the gang on one node");
        }

        #[test]
        fn doubling_stops_at_the_node_boundary() {
            // Flat sees strong scaling to 16 and doubles past 8; the
            // placement-adjusted view knows 16 means spanning 2 nodes on
            // a 10 GbE network and keeps the gang inside one node.
            let flat_job = JobInfo {
                id: 1,
                q: 100.0,
                speed: Speed::Table(strong_table()),
                max_w: 16,
            };
            let placed_job = JobInfo { speed: placed_speed(8), ..flat_job.clone() };
            let flat_alloc = doubling::Doubling.allocate(std::slice::from_ref(&flat_job), 16);
            let placed_alloc =
                doubling::Doubling.allocate(std::slice::from_ref(&placed_job), 16);
            assert_eq!(flat_alloc[&1], 16, "flat should chase the strong scaling");
            assert_eq!(placed_alloc[&1], 8, "placed should refuse to span nodes");
        }
    }

    mod learned {
        use super::super::*;
        use crate::perfmodel::SpeedModel;

        fn strong_fit() -> SpeedModel {
            let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
                .iter()
                .map(|&w| (w, 1.0 / (400.0 / w as f64 + 1.0 * (w as f64 - 1.0) + 2.0)))
                .collect();
            SpeedModel::fit(&samples, 400.0, 4.0e6).unwrap()
        }

        /// Pessimistic prior: no scaling at all past w=1.
        fn flat_prior() -> Speed {
            Speed::Table(vec![(1, 1.0 / 50.0), (16, 1.0 / 50.0)])
        }

        #[test]
        fn closed_gate_consults_the_prior_bit_for_bit() {
            let learned = Speed::learned(None, flat_prior());
            for w in [1usize, 2, 7, 16, 64] {
                assert_eq!(
                    learned.epochs_per_sec(w).to_bits(),
                    flat_prior().epochs_per_sec(w).to_bits(),
                    "w={w}"
                );
            }
            match &learned {
                Speed::Learned(l) => assert!(!l.gate_open()),
                _ => unreachable!(),
            }
        }

        #[test]
        fn open_gate_overrides_the_prior() {
            let fit = strong_fit();
            let learned = Speed::learned(Some(fit.clone()), flat_prior());
            assert_eq!(learned.epochs_per_sec(8).to_bits(), fit.epochs_per_sec(8).to_bits());
            assert!(learned.epochs_per_sec(8) > flat_prior().epochs_per_sec(8));
        }
    }
}
