//! Exact DP solver for the §4.1 integer program (small instances).
//!
//! `dp[c]` after processing jobs `0..j` = minimum Σ t over those jobs
//! using exactly ≤ c GPUs, with every processed job getting ≥ 1. O(J·C²)
//! — fine for the test/bench instances (J ≤ 16, C ≤ 64) where we measure
//! the heuristics' optimality gap.

use super::{Allocation, JobInfo, Scheduler, Speed};

/// Brute-force-optimal allocator (requires capacity >= job count).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExactDp;

impl Scheduler for ExactDp {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation {
        let mut alloc = Allocation::new();
        if jobs.is_empty() {
            return alloc;
        }
        if capacity < jobs.len() {
            // infeasible for the IP (w_j >= 1); FIFO-grant singles like the
            // heuristics do so the result is still a valid allocation.
            let mut free = capacity;
            for j in jobs {
                alloc.insert(j.id, if free > 0 { 1 } else { 0 });
                free = free.saturating_sub(1);
            }
            return alloc;
        }

        const INF: f64 = f64::INFINITY;
        let jn = jobs.len();
        // dp[j][c]: min cost covering jobs 0..j with c GPUs; choice[j][c]: w_j
        let mut dp = vec![vec![INF; capacity + 1]; jn + 1];
        let mut choice = vec![vec![0usize; capacity + 1]; jn + 1];
        dp[0][0] = 0.0;
        for j in 0..jn {
            let wmax = jobs[j].max_w.min(capacity);
            for c in 0..=capacity {
                if dp[j][c].is_infinite() {
                    continue;
                }
                for w in 1..=wmax {
                    if c + w > capacity {
                        break;
                    }
                    let cost = dp[j][c] + jobs[j].time_at(w);
                    if cost < dp[j + 1][c + w] {
                        dp[j + 1][c + w] = cost;
                        choice[j + 1][c + w] = w;
                    }
                }
            }
        }
        // best end state over total GPUs used
        let mut best_c = jn;
        for c in jn..=capacity {
            if dp[jn][c] < dp[jn][best_c] {
                best_c = c;
            }
        }
        // walk back
        let mut c = best_c;
        for j in (0..jn).rev() {
            let w = choice[j + 1][c];
            alloc.insert(jobs[j].id, w);
            c -= w;
        }
        alloc
    }

    fn name(&self) -> &'static str {
        "exact-dp"
    }
}

/// A job whose speed is a piecewise truth table — used by tests/benches to
/// model the eq 3/eq 4 cliff that eq 5's smooth form cannot express.
pub fn table_job(id: u64, q: f64, samples: &[(usize, f64)], max_w: usize) -> JobInfo {
    let mut t = samples.to_vec();
    t.sort_by_key(|&(w, _)| w);
    JobInfo { id, q, speed: Speed::Table(t), max_w }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{check_within_capacity, job};
    use super::super::{objective, Scheduler};
    use super::*;

    #[test]
    fn optimal_never_worse_than_heuristics() {
        let jobs: Vec<_> = (0..4).map(|i| job(i, 50.0 + 40.0 * i as f64, 250.0)).collect();
        for cap in [4usize, 8, 16, 32] {
            let exact = ExactDp.allocate(&jobs, cap);
            let d = super::super::doubling::Doubling.allocate(&jobs, cap);
            let g = super::super::optimus::OptimusGreedy.allocate(&jobs, cap);
            check_within_capacity(&exact, cap);
            let oe = objective(&jobs, &exact);
            assert!(oe <= objective(&jobs, &d) + 1e-9, "cap={cap}");
            assert!(oe <= objective(&jobs, &g) + 1e-9, "cap={cap}");
        }
    }

    #[test]
    fn every_job_gets_at_least_one_when_feasible() {
        let jobs: Vec<_> = (0..5).map(|i| job(i, 100.0, 300.0)).collect();
        let alloc = ExactDp.allocate(&jobs, 8);
        assert!(alloc.values().all(|&w| w >= 1));
    }

    #[test]
    fn infeasible_capacity_degrades_to_fifo_singles() {
        let jobs: Vec<_> = (0..5).map(|i| job(i, 100.0, 300.0)).collect();
        let alloc = ExactDp.allocate(&jobs, 3);
        assert_eq!(alloc.values().filter(|&&w| w == 1).count(), 3);
        assert_eq!(alloc.values().filter(|&&w| w == 0).count(), 2);
    }

    #[test]
    fn single_job_takes_its_optimum() {
        let jobs = vec![job(1, 100.0, 400.0)];
        let alloc = ExactDp.allocate(&jobs, 32);
        // optimum = argmin over w of time_at(w)
        let best_w = (1..=32).min_by(|&a, &b| {
            jobs[0].time_at(a).total_cmp(&jobs[0].time_at(b))
        });
        assert_eq!(alloc[&1], best_w.unwrap());
    }

    #[test]
    fn exact_dp_optimizes_against_the_learned_fit() {
        // The DP must find the learned curve's argmin width, not the
        // prior's. Learned truth: t(w) = 400/w + 4(w-1) + 2, minimized
        // at w = 10 over integers (sqrt(100) = 10).
        use super::super::Speed;
        use crate::perfmodel::SpeedModel;
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16, 32]
            .iter()
            .map(|&w| (w, 1.0 / (400.0 / w as f64 + 4.0 * (w as f64 - 1.0) + 2.0)))
            .collect();
        let fit = SpeedModel::fit(&samples, 400.0, 4.0e6).unwrap();
        let prior = Speed::Table(vec![(1, 1.0 / 30.0), (32, 1.0 / 30.0)]);
        let j = JobInfo { id: 1, q: 100.0, speed: Speed::learned(Some(fit), prior), max_w: 32 };
        let alloc = ExactDp.allocate(std::slice::from_ref(&j), 32);
        let best_w = (1..=32)
            .min_by(|&a, &b| j.time_at(a).total_cmp(&j.time_at(b)))
            .unwrap();
        assert_eq!(alloc[&1], best_w);
        assert!((6..=14).contains(&best_w), "fit should minimize near w=10, got {best_w}");
    }

    #[test]
    fn table_job_interpolates() {
        let tj = table_job(1, 10.0, &[(1, 0.1), (4, 0.4)], 8);
        let f2 = tj.speed.epochs_per_sec(2);
        assert!(f2 > 0.1 && f2 < 0.4);
        assert_eq!(tj.speed.epochs_per_sec(8), 0.4); // flat extrapolation
        assert_eq!(tj.speed.epochs_per_sec(1), 0.1);
    }
}
