//! Optimus' greedy heuristic (the baseline this paper extends).
//!
//! After seeding one worker per job, repeatedly add a *single* worker to
//! the job with the highest marginal gain `Q_j/f(w) − Q_j/f(w+1)` until
//! no positive gain remains or capacity is exhausted.
//!
//! With ring-architecture cost models this gets stuck: the step 8→9
//! switches the job from doubling-halving (eq 3) to binary-blocks (eq 4),
//! which can make `f(9) < f(8)` — a negative gain that blocks the path
//! to 16 even when `f(16) ≫ f(8)` (§4.2). The ablation bench
//! (`ablation_heuristic`) measures exactly this gap.

use std::collections::BinaryHeap;

use super::{Allocation, Gain, GrantOutcome, GrantStep, JobInfo, Scheduler};

/// Marginal gain of one more worker for job `i`, pushed only while the
/// job is a live candidate (finite positive gain; non-finite values from
/// degenerate models are dropped so they never win the heap).
fn push_gain(heap: &mut BinaryHeap<Gain>, jobs: &[JobInfo], w: &[usize], i: usize) {
    let wi = w[i];
    if wi == 0 || wi + 1 > jobs[i].max_w {
        return;
    }
    let gain = jobs[i].time_at(wi) - jobs[i].time_at(wi + 1);
    if gain.is_finite() && gain > 0.0 {
        heap.push(Gain { gain, idx: i, w: wi });
    }
}

/// Greedy +1 allocator (Optimus).
#[derive(Clone, Copy, Debug, Default)]
pub struct OptimusGreedy;

impl OptimusGreedy {
    /// The one allocation loop behind both trait entry points; `trace`
    /// records decisions without influencing them (see
    /// [`Doubling::allocate_inner`](super::doubling::Doubling)).
    fn allocate_inner(
        &self,
        jobs: &[JobInfo],
        capacity: usize,
        mut trace: Option<&mut Vec<GrantStep>>,
    ) -> Allocation {
        let mut w = vec![0usize; jobs.len()];
        let mut free = capacity;

        for (i, slot) in w.iter_mut().enumerate() {
            if free == 0 {
                break;
            }
            *slot = 1;
            free -= 1;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(GrantStep {
                    job: jobs[i].id,
                    from_w: 0,
                    to_w: 1,
                    gain: 0.0,
                    outcome: GrantOutcome::Seed,
                });
            }
        }

        // A grant only changes the winner's own gain, so the per-round
        // O(J) rescan collapses to a max-heap with lazy staleness checks
        // (same trick as `doubling`, stepping +1 instead of ×2).
        let mut heap: BinaryHeap<Gain> = BinaryHeap::with_capacity(jobs.len());
        for i in 0..jobs.len() {
            push_gain(&mut heap, jobs, &w, i);
        }
        while free > 0 {
            let Some(g) = heap.pop() else { break };
            if w[g.idx] != g.w {
                // stale: this job already grew
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(GrantStep {
                        job: jobs[g.idx].id,
                        from_w: g.w,
                        to_w: g.w + 1,
                        gain: g.gain,
                        outcome: GrantOutcome::Stale,
                    });
                }
                continue;
            }
            w[g.idx] += 1;
            free -= 1;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(GrantStep {
                    job: jobs[g.idx].id,
                    from_w: g.w,
                    to_w: g.w + 1,
                    gain: g.gain,
                    outcome: GrantOutcome::Grant,
                });
            }
            push_gain(&mut heap, jobs, &w, g.idx);
        }

        jobs.iter().zip(&w).map(|(j, &w)| (j.id, w)).collect()
    }
}

impl Scheduler for OptimusGreedy {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation {
        self.allocate_inner(jobs, capacity, None)
    }

    fn allocate_traced(
        &self,
        jobs: &[JobInfo],
        capacity: usize,
        trace: &mut Vec<GrantStep>,
    ) -> Allocation {
        self.allocate_inner(jobs, capacity, Some(trace))
    }

    fn name(&self) -> &'static str {
        "optimus-greedy"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{check_within_capacity, job};
    use super::super::Scheduler;
    use super::*;
    use crate::perfmodel::SpeedModel;

    #[test]
    fn stays_within_capacity() {
        let jobs: Vec<_> = (0..5).map(|i| job(i, 100.0, 400.0)).collect();
        let alloc = OptimusGreedy.allocate(&jobs, 16);
        check_within_capacity(&alloc, 16);
    }

    #[test]
    fn gives_more_to_more_demanding_jobs() {
        // job 2 has much more remaining work -> larger marginal gains
        let jobs = vec![job(1, 10.0, 400.0), job(2, 500.0, 400.0)];
        let alloc = OptimusGreedy.allocate(&jobs, 12);
        assert!(alloc[&2] > alloc[&1], "{alloc:?}");
    }

    #[test]
    fn stops_at_zero_marginal_gain() {
        // communication-bound: adding workers hurts past w=1
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8]
            .iter()
            .map(|&w| (w, 1.0 / (10.0 + 20.0 * (w as f64 - 1.0))))
            .collect();
        let j = super::super::JobInfo {
            id: 1,
            q: 100.0,
            speed: super::super::Speed::Fitted(SpeedModel::fit(&samples, 128.0, 4e6).unwrap()),
            max_w: 64,
        };
        let alloc = OptimusGreedy.allocate(&[j], 64);
        assert_eq!(alloc[&1], 1);
    }

    #[test]
    fn learned_fit_redirects_the_greedy() {
        // Two identical-prior jobs; only job 2's gate is open, revealing
        // strong measured scaling. The +1 greedy must pour the extra
        // workers into the job whose *measured* gains are real.
        use super::super::Speed;
        let prior = || Speed::Table(vec![(1, 1.0 / 60.0), (16, 1.0 / 60.0)]);
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&w| (w, 1.0 / (600.0 / w as f64 + 1.0 * (w as f64 - 1.0) + 2.0)))
            .collect();
        let fit = SpeedModel::fit(&samples, 600.0, 4.0e6).unwrap();
        let jobs = vec![
            super::super::JobInfo { id: 1, q: 100.0, speed: Speed::learned(None, prior()), max_w: 16 },
            super::super::JobInfo {
                id: 2,
                q: 100.0,
                speed: Speed::learned(Some(fit), prior()),
                max_w: 16,
            },
        ];
        let alloc = OptimusGreedy.allocate(&jobs, 12);
        assert_eq!(alloc[&1], 1, "flat prior offers no marginal gain");
        assert!(alloc[&2] > alloc[&1], "{alloc:?}");
    }

    /// The pre-heap greedy, kept verbatim as the equivalence oracle.
    fn reference_allocate(jobs: &[super::super::JobInfo], capacity: usize) -> Allocation {
        let mut alloc = Allocation::new();
        let mut free = capacity;
        for j in jobs {
            if free > 0 {
                alloc.insert(j.id, 1);
                free -= 1;
            } else {
                alloc.insert(j.id, 0);
            }
        }
        while free > 0 {
            let mut best: Option<(u64, f64)> = None;
            for j in jobs {
                let w = alloc[&j.id];
                if w == 0 || w + 1 > j.max_w {
                    continue;
                }
                let gain = j.time_at(w) - j.time_at(w + 1);
                if gain <= 0.0 {
                    continue;
                }
                if best.map_or(true, |(_, g)| gain > g) {
                    best = Some((j.id, gain));
                }
            }
            match best {
                Some((id, _)) => {
                    *alloc.get_mut(&id).unwrap() += 1;
                    free -= 1;
                }
                None => break,
            }
        }
        alloc
    }

    /// Randomized instances (eq-5 fits and cliffy tables, duplicates for
    /// tie-break coverage): the heap rewrite must match the rescan loop.
    #[test]
    fn gain_heap_matches_reference_rescan_on_random_instances() {
        use crate::rngx::Rng;
        let mut rng = Rng::new(0x0971);
        for case in 0..300 {
            let n = 1 + rng.uniform_range(0.0, 10.0) as usize;
            let capacity = rng.uniform_range(0.0, 60.0) as usize;
            let mut jobs: Vec<super::super::JobInfo> = Vec::with_capacity(n);
            for i in 0..n {
                let q = rng.uniform_range(1.0, 300.0);
                let mut j = if rng.uniform_range(0.0, 1.0) < 0.5 {
                    job(i as u64, q, rng.uniform_range(5.0, 1500.0))
                } else {
                    let base = rng.uniform_range(10.0, 500.0);
                    let comm = rng.uniform_range(0.0, 30.0);
                    let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16, 32]
                        .iter()
                        .map(|&w| (w, 1.0 / (base / w as f64 + comm * (w as f64 - 1.0) + 2.0)))
                        .collect();
                    super::super::exact::table_job(i as u64, q, &samples, 64)
                };
                if rng.uniform_range(0.0, 1.0) < 0.3 {
                    j.max_w = 1 + rng.uniform_range(0.0, 20.0) as usize;
                }
                if i > 0 && rng.uniform_range(0.0, 1.0) < 0.25 {
                    let prev = jobs[i - 1].clone();
                    j = super::super::JobInfo { id: i as u64, ..prev };
                }
                jobs.push(j);
            }
            assert_eq!(
                OptimusGreedy.allocate(&jobs, capacity),
                reference_allocate(&jobs, capacity),
                "case {case} (n={n}, capacity={capacity})"
            );
        }
    }

    /// The §4.2 trap: a speed model with a cliff at w=9 (fit through the
    /// eq 3/eq 4 boundary) blocks the +1 greedy below 16 while the
    /// doubling heuristic jumps it. This is the paper's motivating case.
    #[test]
    fn gets_stuck_at_cliff_where_doubling_escapes() {
        use crate::collectives::cost::{comm_time, Algorithm, CostParams};
        // α exaggerated so the dh->bb switch is a real cliff relative to
        // per-step compute (as on latency-bound interconnects).
        let p = CostParams { alpha: 2e-2, beta: 8e-11, gamma: 1e-10 };
        let n_bytes = 4.0e6;
        // epoch time under the *true* piecewise cost model
        let true_epoch = |w: usize| -> f64 {
            let alg = if w.is_power_of_two() {
                Algorithm::DoublingHalving
            } else {
                Algorithm::BinaryBlocks
            };
            let steps = 400.0 / w as f64; // dataset/(batch*w) steps per epoch
            steps * (0.4 + comm_time(alg, w, n_bytes, &p))
        };
        // The greedy evaluates w+1 through an eq-5 fit; feed it samples
        // that include the cliff so its fitted f() reflects the trap.
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 9, 16]
            .iter()
            .map(|&w| (w, 1.0 / true_epoch(w)))
            .collect();
        // piecewise truth can't be captured by eq 5's smooth form; use a
        // direct table-backed JobInfo via exact::TableJob instead.
        let tj = super::super::exact::table_job(1, 100.0, &samples, 64);
        let greedy = OptimusGreedy.allocate(std::slice::from_ref(&tj), 64);
        let doubling = super::super::doubling::Doubling.allocate(std::slice::from_ref(&tj), 64);
        assert!(greedy[&1] <= 9, "greedy should stall near 8, got {}", greedy[&1]);
        assert!(doubling[&1] >= 16, "doubling should jump to 16, got {}", doubling[&1]);
    }
}
