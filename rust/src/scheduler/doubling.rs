//! The paper's doubling heuristic (§4.2).
//!
//! 1. Give every job 1 worker (FIFO by job order when capacity is short;
//!    leftover jobs queue at 0).
//! 2. Repeatedly compute, for each job, the *average marginal gain per
//!    GPU* of doubling (eq 6):
//!
//!    `gain_j = (Q_j/f(w_j) − Q_j/f(2·w_j)) / w_j`
//!
//!    and grant `w_j` extra workers to the argmax, provided they fit in
//!    the remaining capacity and the gain is positive.
//!
//! Why doubling instead of Optimus' +1: eq 4 makes 9 workers *slower
//! per GPU* than 8 (binary-blocks vs doubling-halving), so a +1 greedy
//! scores 8→9 badly and never reaches 16 even when 16 is a large win —
//! the local optimum of §4.2. Power-of-two jumps skip over every
//! non-power-of-two cliff, and bound the precompute table to log2(C)
//! entries per job.

use super::{Allocation, JobInfo, Scheduler};

/// The paper's scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct Doubling;

impl Scheduler for Doubling {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation {
        let mut alloc = Allocation::new();
        let mut free = capacity;

        // Step 1: one worker each, FIFO until capacity runs out.
        for j in jobs {
            if free > 0 {
                alloc.insert(j.id, 1);
                free -= 1;
            } else {
                alloc.insert(j.id, 0);
            }
        }

        // Step 2: double the best per-GPU gain while anything fits.
        loop {
            let mut best: Option<(u64, usize, f64)> = None; // (job, add, gain)
            for j in jobs {
                let w = alloc[&j.id];
                if w == 0 || w > free || 2 * w > j.max_w {
                    continue;
                }
                let gain = (j.time_at(w) - j.time_at(2 * w)) / w as f64;
                if gain <= 0.0 {
                    continue;
                }
                if best.map_or(true, |(_, _, g)| gain > g) {
                    best = Some((j.id, w, gain));
                }
            }
            match best {
                Some((id, add, _)) => {
                    *alloc.get_mut(&id).unwrap() += add;
                    free -= add;
                }
                None => break,
            }
        }
        alloc
    }

    fn name(&self) -> &'static str {
        "doubling"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{check_within_capacity, job};
    use super::super::{total_allocated, Scheduler};
    use super::*;

    #[test]
    fn all_allocations_are_powers_of_two() {
        let jobs: Vec<_> = (0..5).map(|i| job(i, 50.0 + i as f64 * 30.0, 300.0)).collect();
        let alloc = Doubling.allocate(&jobs, 64);
        check_within_capacity(&alloc, 64);
        for (&id, &w) in &alloc {
            assert!(w == 0 || w.is_power_of_two(), "job {id} got {w}");
        }
    }

    #[test]
    fn every_job_gets_one_when_capacity_allows() {
        let jobs: Vec<_> = (0..4).map(|i| job(i, 100.0, 200.0)).collect();
        let alloc = Doubling.allocate(&jobs, 4);
        assert!(alloc.values().all(|&w| w == 1));
    }

    #[test]
    fn queues_fifo_when_oversubscribed() {
        let jobs: Vec<_> = (0..6).map(|i| job(i, 100.0, 200.0)).collect();
        let alloc = Doubling.allocate(&jobs, 3);
        for i in 0..3u64 {
            assert_eq!(alloc[&i], 1);
        }
        for i in 3..6u64 {
            assert_eq!(alloc[&i], 0);
        }
    }

    #[test]
    fn compute_bound_job_scales_up() {
        // single very parallelizable job on a roomy cluster
        let jobs = vec![job(1, 200.0, 2000.0)];
        let alloc = Doubling.allocate(&jobs, 64);
        assert!(alloc[&1] >= 8, "got {}", alloc[&1]);
    }

    #[test]
    fn respects_max_w() {
        let mut j = job(1, 200.0, 2000.0);
        j.max_w = 4;
        let alloc = Doubling.allocate(&[j], 64);
        assert_eq!(alloc[&1], 4);
    }

    #[test]
    fn uses_capacity_productively() {
        let jobs: Vec<_> = (0..3).map(|i| job(i, 100.0, 500.0)).collect();
        let alloc = Doubling.allocate(&jobs, 16);
        // with strong scaling the heuristic should hand out most GPUs
        assert!(total_allocated(&alloc) > 8, "{alloc:?}");
    }

    #[test]
    fn learned_fit_unlocks_doubling_the_prior_would_refuse() {
        // Prior says the job does not scale (flat table -> zero eq-6
        // gain); the live-learned fit shows strong scaling. With the
        // gate closed the heuristic holds at 1; once it opens, the same
        // job is doubled up — schedulers act on measured behavior.
        use super::super::Speed;
        use crate::perfmodel::SpeedModel;
        let flat_prior = || Speed::Table(vec![(1, 1.0 / 50.0), (16, 1.0 / 50.0)]);
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&w| (w, 1.0 / (800.0 / w as f64 + 0.5 * (w as f64 - 1.0) + 2.0)))
            .collect();
        let fit = SpeedModel::fit(&samples, 800.0, 4.0e6).unwrap();
        let mk = |fit| super::super::JobInfo {
            id: 1,
            q: 100.0,
            speed: Speed::learned(fit, flat_prior()),
            max_w: 16,
        };
        let closed = Doubling.allocate(&[mk(None)], 16);
        assert_eq!(closed[&1], 1, "closed gate must follow the flat prior");
        let open = Doubling.allocate(&[mk(Some(fit))], 16);
        assert!(open[&1] >= 8, "open gate should chase the measured scaling, got {}", open[&1]);
    }

    #[test]
    fn empty_jobs_empty_allocation() {
        let alloc = Doubling.allocate(&[], 64);
        assert!(alloc.is_empty());
    }

    #[test]
    fn zero_capacity_queues_everything() {
        let jobs = vec![job(1, 10.0, 100.0)];
        let alloc = Doubling.allocate(&jobs, 0);
        assert_eq!(alloc[&1], 0);
    }
}
