//! The paper's doubling heuristic (§4.2).
//!
//! 1. Give every job 1 worker (FIFO by job order when capacity is short;
//!    leftover jobs queue at 0).
//! 2. Repeatedly compute, for each job, the *average marginal gain per
//!    GPU* of doubling (eq 6):
//!
//!    `gain_j = (Q_j/f(w_j) − Q_j/f(2·w_j)) / w_j`
//!
//!    and grant `w_j` extra workers to the argmax, provided they fit in
//!    the remaining capacity and the gain is positive.
//!
//! Why doubling instead of Optimus' +1: eq 4 makes 9 workers *slower
//! per GPU* than 8 (binary-blocks vs doubling-halving), so a +1 greedy
//! scores 8→9 badly and never reaches 16 even when 16 is a large win —
//! the local optimum of §4.2. Power-of-two jumps skip over every
//! non-power-of-two cliff, and bound the precompute table to log2(C)
//! entries per job.

use std::collections::BinaryHeap;

use super::{Allocation, Gain, GrantOutcome, GrantStep, JobInfo, Scheduler};

/// Eq-6 average marginal gain per GPU of doubling job `i`, pushed only
/// while it is a live candidate (non-zero width, cap respected, finite
/// positive gain — non-finite gains from degenerate speed models are
/// dropped, so a malformed table degrades to "no grant" instead of
/// winning every round).
fn push_gain(heap: &mut BinaryHeap<Gain>, jobs: &[JobInfo], w: &[usize], i: usize) {
    let wi = w[i];
    if wi == 0 || 2 * wi > jobs[i].max_w {
        return;
    }
    let gain = (jobs[i].time_at(wi) - jobs[i].time_at(2 * wi)) / wi as f64;
    if gain.is_finite() && gain > 0.0 {
        heap.push(Gain { gain, idx: i, w: wi });
    }
}

/// The paper's scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct Doubling;

impl Doubling {
    /// The one allocation loop behind both trait entry points. `trace`
    /// only ever *records* decisions already taken — the math and the
    /// grant order are identical with and without it, so a traced
    /// allocation equals the untraced one by construction.
    fn allocate_inner(
        &self,
        jobs: &[JobInfo],
        capacity: usize,
        mut trace: Option<&mut Vec<GrantStep>>,
    ) -> Allocation {
        let mut w = vec![0usize; jobs.len()];
        let mut free = capacity;

        // Step 1: one worker each, FIFO until capacity runs out.
        for (i, slot) in w.iter_mut().enumerate() {
            if free == 0 {
                break;
            }
            *slot = 1;
            free -= 1;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(GrantStep {
                    job: jobs[i].id,
                    from_w: 0,
                    to_w: 1,
                    gain: 0.0,
                    outcome: GrantOutcome::Seed,
                });
            }
        }

        // Step 2: double the best per-GPU gain while anything fits.
        //
        // A grant only changes the *winner's* own gain, so instead of a
        // full O(J) rescan per round we keep a max-heap of (gain, job)
        // entries and lazily discard stale ones. `free` only shrinks and
        // a doubling needs `w` extra GPUs, so an entry that no longer
        // fits can be dropped outright — it can never fit again.
        let mut heap: BinaryHeap<Gain> = BinaryHeap::with_capacity(jobs.len());
        for i in 0..jobs.len() {
            push_gain(&mut heap, jobs, &w, i);
        }
        while let Some(g) = heap.pop() {
            if w[g.idx] != g.w {
                // stale: this job was already doubled
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(GrantStep {
                        job: jobs[g.idx].id,
                        from_w: g.w,
                        to_w: 2 * g.w,
                        gain: g.gain,
                        outcome: GrantOutcome::Stale,
                    });
                }
                continue;
            }
            if g.w > free {
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(GrantStep {
                        job: jobs[g.idx].id,
                        from_w: g.w,
                        to_w: 2 * g.w,
                        gain: g.gain,
                        outcome: GrantOutcome::NoFit,
                    });
                }
                continue;
            }
            w[g.idx] *= 2;
            free -= g.w;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(GrantStep {
                    job: jobs[g.idx].id,
                    from_w: g.w,
                    to_w: 2 * g.w,
                    gain: g.gain,
                    outcome: GrantOutcome::Grant,
                });
            }
            push_gain(&mut heap, jobs, &w, g.idx);
        }

        jobs.iter().zip(&w).map(|(j, &w)| (j.id, w)).collect()
    }
}

impl Scheduler for Doubling {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation {
        self.allocate_inner(jobs, capacity, None)
    }

    fn allocate_traced(
        &self,
        jobs: &[JobInfo],
        capacity: usize,
        trace: &mut Vec<GrantStep>,
    ) -> Allocation {
        self.allocate_inner(jobs, capacity, Some(trace))
    }

    fn name(&self) -> &'static str {
        "doubling"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{check_within_capacity, job};
    use super::super::{total_allocated, Scheduler};
    use super::*;

    #[test]
    fn all_allocations_are_powers_of_two() {
        let jobs: Vec<_> = (0..5).map(|i| job(i, 50.0 + i as f64 * 30.0, 300.0)).collect();
        let alloc = Doubling.allocate(&jobs, 64);
        check_within_capacity(&alloc, 64);
        for (&id, &w) in &alloc {
            assert!(w == 0 || w.is_power_of_two(), "job {id} got {w}");
        }
    }

    #[test]
    fn every_job_gets_one_when_capacity_allows() {
        let jobs: Vec<_> = (0..4).map(|i| job(i, 100.0, 200.0)).collect();
        let alloc = Doubling.allocate(&jobs, 4);
        assert!(alloc.values().all(|&w| w == 1));
    }

    #[test]
    fn queues_fifo_when_oversubscribed() {
        let jobs: Vec<_> = (0..6).map(|i| job(i, 100.0, 200.0)).collect();
        let alloc = Doubling.allocate(&jobs, 3);
        for i in 0..3u64 {
            assert_eq!(alloc[&i], 1);
        }
        for i in 3..6u64 {
            assert_eq!(alloc[&i], 0);
        }
    }

    #[test]
    fn compute_bound_job_scales_up() {
        // single very parallelizable job on a roomy cluster
        let jobs = vec![job(1, 200.0, 2000.0)];
        let alloc = Doubling.allocate(&jobs, 64);
        assert!(alloc[&1] >= 8, "got {}", alloc[&1]);
    }

    #[test]
    fn respects_max_w() {
        let mut j = job(1, 200.0, 2000.0);
        j.max_w = 4;
        let alloc = Doubling.allocate(&[j], 64);
        assert_eq!(alloc[&1], 4);
    }

    #[test]
    fn uses_capacity_productively() {
        let jobs: Vec<_> = (0..3).map(|i| job(i, 100.0, 500.0)).collect();
        let alloc = Doubling.allocate(&jobs, 16);
        // with strong scaling the heuristic should hand out most GPUs
        assert!(total_allocated(&alloc) > 8, "{alloc:?}");
    }

    #[test]
    fn learned_fit_unlocks_doubling_the_prior_would_refuse() {
        // Prior says the job does not scale (flat table -> zero eq-6
        // gain); the live-learned fit shows strong scaling. With the
        // gate closed the heuristic holds at 1; once it opens, the same
        // job is doubled up — schedulers act on measured behavior.
        use super::super::Speed;
        use crate::perfmodel::SpeedModel;
        let flat_prior = || Speed::Table(vec![(1, 1.0 / 50.0), (16, 1.0 / 50.0)]);
        let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&w| (w, 1.0 / (800.0 / w as f64 + 0.5 * (w as f64 - 1.0) + 2.0)))
            .collect();
        let fit = SpeedModel::fit(&samples, 800.0, 4.0e6).unwrap();
        let mk = |fit| super::super::JobInfo {
            id: 1,
            q: 100.0,
            speed: Speed::learned(fit, flat_prior()),
            max_w: 16,
        };
        let closed = Doubling.allocate(&[mk(None)], 16);
        assert_eq!(closed[&1], 1, "closed gate must follow the flat prior");
        let open = Doubling.allocate(&[mk(Some(fit))], 16);
        assert!(open[&1] >= 8, "open gate should chase the measured scaling, got {}", open[&1]);
    }

    /// The pre-heap allocator, kept verbatim as the equivalence oracle:
    /// full rescan of every job per round, strict-`>` argmax.
    fn reference_allocate(jobs: &[super::super::JobInfo], capacity: usize) -> Allocation {
        let mut alloc = Allocation::new();
        let mut free = capacity;
        for j in jobs {
            if free > 0 {
                alloc.insert(j.id, 1);
                free -= 1;
            } else {
                alloc.insert(j.id, 0);
            }
        }
        loop {
            let mut best: Option<(u64, usize, f64)> = None;
            for j in jobs {
                let w = alloc[&j.id];
                if w == 0 || w > free || 2 * w > j.max_w {
                    continue;
                }
                let gain = (j.time_at(w) - j.time_at(2 * w)) / w as f64;
                if gain <= 0.0 {
                    continue;
                }
                if best.map_or(true, |(_, _, g)| gain > g) {
                    best = Some((j.id, w, gain));
                }
            }
            match best {
                Some((id, add, _)) => {
                    *alloc.get_mut(&id).unwrap() += add;
                    free -= add;
                }
                None => break,
            }
        }
        alloc
    }

    /// Randomized instances (mixed eq-5 fits and piecewise tables,
    /// deliberate duplicates so equal gains exercise the tie-break):
    /// the gain-heap rewrite must reproduce the rescan loop exactly.
    #[test]
    fn gain_heap_matches_reference_rescan_on_random_instances() {
        use crate::rngx::Rng;
        let mut rng = Rng::new(0xD0B1);
        for case in 0..300 {
            let n = 1 + rng.uniform_range(0.0, 12.0) as usize;
            let capacity = rng.uniform_range(0.0, 90.0) as usize;
            let mut jobs: Vec<super::super::JobInfo> = Vec::with_capacity(n);
            for i in 0..n {
                let q = rng.uniform_range(1.0, 300.0);
                let mut j = if rng.uniform_range(0.0, 1.0) < 0.5 {
                    job(i as u64, q, rng.uniform_range(5.0, 2000.0))
                } else {
                    // piecewise table with a random cliff shape
                    let base = rng.uniform_range(10.0, 500.0);
                    let comm = rng.uniform_range(0.0, 30.0);
                    let samples: Vec<(usize, f64)> = [1usize, 2, 4, 8, 16, 32]
                        .iter()
                        .map(|&w| (w, 1.0 / (base / w as f64 + comm * (w as f64 - 1.0) + 2.0)))
                        .collect();
                    super::super::exact::table_job(i as u64, q, &samples, 64)
                };
                if rng.uniform_range(0.0, 1.0) < 0.3 {
                    j.max_w = 1 << (rng.uniform_range(0.0, 6.0) as usize);
                }
                // duplicate the previous job's shape now and then: equal
                // gains must fall to the FIFO tie-break in both solvers
                if i > 0 && rng.uniform_range(0.0, 1.0) < 0.25 {
                    let prev = jobs[i - 1].clone();
                    j = super::super::JobInfo { id: i as u64, ..prev };
                }
                jobs.push(j);
            }
            assert_eq!(
                Doubling.allocate(&jobs, capacity),
                reference_allocate(&jobs, capacity),
                "case {case} (n={n}, capacity={capacity})"
            );
        }
    }

    /// Replaying only the effective steps (seeds + grants) of a traced
    /// allocation must land every job exactly on its granted width, and
    /// the traced allocation must equal the untraced one.
    #[test]
    fn traced_allocation_matches_and_steps_replay_to_granted_widths() {
        use super::super::GrantOutcome;
        use crate::rngx::Rng;
        let mut rng = Rng::new(0x7AC3);
        for case in 0..50 {
            let n = 1 + rng.uniform_range(0.0, 10.0) as usize;
            let capacity = rng.uniform_range(0.0, 70.0) as usize;
            let jobs: Vec<super::super::JobInfo> = (0..n)
                .map(|i| job(i as u64, rng.uniform_range(1.0, 300.0), rng.uniform_range(5.0, 2000.0)))
                .collect();
            let mut steps = Vec::new();
            let traced = Doubling.allocate_traced(&jobs, capacity, &mut steps);
            assert_eq!(traced, Doubling.allocate(&jobs, capacity), "case {case}");
            let mut replay: Allocation = jobs.iter().map(|j| (j.id, 0usize)).collect();
            for s in &steps {
                match s.outcome {
                    GrantOutcome::Seed | GrantOutcome::Grant => {
                        assert_eq!(replay[&s.job], s.from_w, "case {case}: step from_w mismatch");
                        *replay.get_mut(&s.job).unwrap() = s.to_w;
                    }
                    GrantOutcome::Stale | GrantOutcome::NoFit => {}
                }
            }
            assert_eq!(replay, traced, "case {case}: replayed steps disagree with grants");
        }
    }

    #[test]
    fn empty_jobs_empty_allocation() {
        let alloc = Doubling.allocate(&[], 64);
        assert!(alloc.is_empty());
    }

    #[test]
    fn zero_capacity_queues_everything() {
        let jobs = vec![job(1, 10.0, 100.0)];
        let alloc = Doubling.allocate(&jobs, 0);
        assert_eq!(alloc[&1], 0);
    }
}
