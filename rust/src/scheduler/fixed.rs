//! Fixed-request strategies — the One/Two/Four/Eight baselines of Table 3.
//!
//! Every job requests exactly `k` GPUs and is granted all-or-nothing in
//! FIFO order; jobs that don't fit queue at 0 until capacity frees up.
//! No performance model is consulted (which is the point of the
//! comparison: these are what users do by hand today).

use super::{Allocation, GrantOutcome, GrantStep, JobInfo, Scheduler};

/// Fixed `k`-GPU allocator.
#[derive(Clone, Copy, Debug)]
pub struct Fixed(pub usize);

impl Fixed {
    fn allocate_inner(
        &self,
        jobs: &[JobInfo],
        capacity: usize,
        mut trace: Option<&mut Vec<GrantStep>>,
    ) -> Allocation {
        let k = self.0;
        let mut alloc = Allocation::new();
        let mut free = capacity;
        for j in jobs {
            let want = k.min(j.max_w).max(1);
            if want <= free {
                alloc.insert(j.id, want);
                free -= want;
                if let Some(tr) = trace.as_deref_mut() {
                    // no gain model to cite: a static request is its own
                    // provenance, recorded as a 0 -> want seed
                    tr.push(GrantStep {
                        job: j.id,
                        from_w: 0,
                        to_w: want,
                        gain: 0.0,
                        outcome: GrantOutcome::Seed,
                    });
                }
            } else {
                alloc.insert(j.id, 0);
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(GrantStep {
                        job: j.id,
                        from_w: 0,
                        to_w: want,
                        gain: 0.0,
                        outcome: GrantOutcome::NoFit,
                    });
                }
            }
        }
        alloc
    }
}

impl Scheduler for Fixed {
    fn allocate(&self, jobs: &[JobInfo], capacity: usize) -> Allocation {
        self.allocate_inner(jobs, capacity, None)
    }

    fn allocate_traced(
        &self,
        jobs: &[JobInfo],
        capacity: usize,
        trace: &mut Vec<GrantStep>,
    ) -> Allocation {
        self.allocate_inner(jobs, capacity, Some(trace))
    }

    fn name(&self) -> &'static str {
        match self.0 {
            1 => "fixed-1",
            2 => "fixed-2",
            4 => "fixed-4",
            8 => "fixed-8",
            _ => "fixed-k",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{check_within_capacity, job};
    use super::super::{total_allocated, Scheduler};
    use super::*;

    #[test]
    fn grants_k_in_fifo_order() {
        let jobs: Vec<_> = (0..5).map(|i| job(i, 100.0, 300.0)).collect();
        let alloc = Fixed(4).allocate(&jobs, 10);
        assert_eq!(alloc[&0], 4);
        assert_eq!(alloc[&1], 4);
        assert_eq!(alloc[&2], 0); // only 2 left, all-or-nothing
        assert_eq!(alloc[&3], 0);
        check_within_capacity(&alloc, 10);
    }

    #[test]
    fn later_small_jobs_do_not_jump_queue() {
        // all-or-nothing FIFO: remaining capacity stays idle rather than
        // being handed to later jobs out of order (simple FIFO semantics;
        // the simulator retries every interval).
        let jobs: Vec<_> = (0..3).map(|i| job(i, 100.0, 300.0)).collect();
        let alloc = Fixed(8).allocate(&jobs, 12);
        assert_eq!(alloc[&0], 8);
        assert_eq!(alloc[&1], 0);
        assert_eq!(alloc[&2], 0);
        assert_eq!(total_allocated(&alloc), 8);
    }

    #[test]
    fn respects_job_max_w() {
        let mut j = job(1, 100.0, 300.0);
        j.max_w = 2;
        let alloc = Fixed(8).allocate(&[j], 64);
        assert_eq!(alloc[&1], 2);
    }

    #[test]
    fn fixed_ignores_the_learned_model_by_design() {
        // Fixed-k consults no performance model, so gate state must not
        // change its grants — the baseline stays a baseline under
        // --online-model.
        use super::super::Speed;
        use crate::perfmodel::SpeedModel;
        let prior = || Speed::Table(vec![(1, 1.0 / 50.0), (8, 1.0 / 10.0)]);
        let samples: Vec<(usize, f64)> =
            [1usize, 2, 4, 8].iter().map(|&w| (w, 1.0 / (100.0 / w as f64 + 3.0))).collect();
        let fit = SpeedModel::fit(&samples, 100.0, 4.0e6).unwrap();
        let mk = |id, fit| JobInfo { id, q: 50.0, speed: Speed::learned(fit, prior()), max_w: 8 };
        let closed = Fixed(4).allocate(&[mk(1, None), mk(2, None)], 8);
        let open = Fixed(4).allocate(&[mk(1, Some(fit.clone())), mk(2, Some(fit))], 8);
        assert_eq!(closed, open);
    }

    #[test]
    fn names_match_table3_rows() {
        assert_eq!(Fixed(1).name(), "fixed-1");
        assert_eq!(Fixed(8).name(), "fixed-8");
    }
}
