//! # ringmaster
//!
//! Production-quality reproduction of **"Dynamic Scheduling of MPI-based
//! Distributed Deep Learning Training Jobs"** (Capes, Raheja, Kemertas,
//! Mohomed — 2019): a dynamic scheduler for ring-architecture (Horovod-style)
//! data-parallel training, built as a three-layer rust + JAX + Pallas stack.
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)** — the paper's scheduling contribution plus every
//!   substrate it depends on: MPI-like collectives ([`collectives`]),
//!   Lawson–Hanson NNLS ([`nnls`]), performance models ([`perfmodel`]),
//!   scheduling strategies ([`scheduler`]), a discrete-event cluster
//!   simulator ([`sim`]), a real data-parallel training runtime
//!   ([`trainer`], [`coordinator`]), and a live multi-job orchestrator
//!   ([`orchestrator`]) that runs any scheduling strategy as an online
//!   service over concurrent real trainers; the model executes through a
//!   pluggable backend ([`runtime`]): a pure-rust reference
//!   implementation by default, or PJRT execution of the AOT artifacts
//!   behind the `pjrt` cargo feature.
//! - **L2/L1 (python, build-time only)** — the transformer model and Pallas
//!   kernels lowered once to `artifacts/*.hlo.txt` by `make artifacts`.
//!
//! The request path is pure rust: python never runs after artifacts exist,
//! and with the reference backend python never needs to run at all.

pub mod cluster;
pub mod collectives;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod fsx;
pub mod jsonx;
pub mod linalg;
pub mod metrics;
pub mod nnls;
pub mod orchestrator;
pub mod perfmodel;
pub mod rngx;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod store;
pub mod telemetry;
pub mod trainer;

/// Crate-wide result type (`anyhow::Result` — the offline shim in
/// `vendor/anyhow` by default; API-compatible with the registry crate).
pub type Result<T> = anyhow::Result<T>;
