//! # ringmaster
//!
//! Production-quality reproduction of **"Dynamic Scheduling of MPI-based
//! Distributed Deep Learning Training Jobs"** (Capes, Raheja, Kemertas,
//! Mohomed — 2019): a dynamic scheduler for ring-architecture (Horovod-style)
//! data-parallel training, built as a three-layer rust + JAX + Pallas stack.
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)** — the paper's scheduling contribution plus every
//!   substrate it depends on: MPI-like collectives ([`collectives`]),
//!   Lawson–Hanson NNLS ([`nnls`]), performance models ([`perfmodel`]),
//!   scheduling strategies ([`scheduler`]), a discrete-event cluster
//!   simulator ([`sim`]), and a real data-parallel training runtime
//!   ([`trainer`], [`coordinator`]) that executes AOT-compiled JAX programs
//!   through PJRT ([`runtime`]).
//! - **L2/L1 (python, build-time only)** — the transformer model and Pallas
//!   kernels lowered once to `artifacts/*.hlo.txt` by `make artifacts`.
//!
//! The request path is pure rust: python never runs after artifacts exist.

pub mod cluster;
pub mod collectives;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod jsonx;
pub mod linalg;
pub mod metrics;
pub mod nnls;
pub mod perfmodel;
pub mod rngx;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod trainer;

/// Crate-wide result type (eyre for rich error context).
pub type Result<T> = anyhow::Result<T>;
