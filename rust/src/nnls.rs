//! Lawson–Hanson non-negative least squares.
//!
//! Both of the paper's fits require non-negativity: the convergence model
//! `l = 1/(b0*k + b1) + b2` needs `b0 > 0` (§3.1), and the resource model
//! `f(w)` needs all four `theta >= 0` (§3.2). Optimus fits the same way.
//!
//! Solves `min ||A x - b||_2  s.t.  x >= 0` by active-set iteration
//! (Lawson & Hanson 1974, ch. 23), using the QR least squares from
//! [`crate::linalg`] for the passive-set subproblems.

use crate::linalg::{dot, norm2, sub, Matrix};

/// Outcome of an NNLS solve.
#[derive(Clone, Debug)]
pub struct NnlsSolution {
    /// Coefficients, all >= 0.
    pub x: Vec<f64>,
    /// Final residual norm ||Ax - b||.
    pub residual: f64,
    /// Outer iterations used.
    pub iterations: usize,
}

/// Maximum outer iterations as a multiple of the column count.
const MAX_ITER_FACTOR: usize = 10;
/// Dual-feasibility tolerance.
const TOL: f64 = 1e-10;

/// Solve `min ||A x - b||  s.t.  x >= 0`.
///
/// Returns an error if a passive-set subproblem is singular beyond
/// recovery (degenerate designs — e.g. duplicate all-zero columns).
pub fn nnls(a: &Matrix, b: &[f64]) -> crate::Result<NnlsSolution> {
    assert_eq!(b.len(), a.rows, "rhs length must match rows");
    let n = a.cols;
    let mut x = vec![0.0; n];
    let mut passive = vec![false; n];
    let at = a.transpose();
    let max_iter = MAX_ITER_FACTOR * n.max(3);
    let mut iterations = 0;

    loop {
        iterations += 1;
        if iterations > max_iter {
            break; // return best-so-far; callers treat fit quality via residual
        }

        // Gradient of 1/2||Ax-b||^2 is A^T(Ax - b); w = -grad.
        let resid = sub(b, &a.matvec(&x));
        let w: Vec<f64> = (0..n).map(|j| dot(at.row(j), &resid)).collect();

        // Pick the most-violated zero coefficient.
        let candidate = (0..n)
            .filter(|&j| !passive[j])
            .max_by(|&i, &j| w[i].partial_cmp(&w[j]).unwrap());
        let t = match candidate {
            Some(t) if w[t] > TOL => t,
            _ => break, // KKT satisfied
        };
        passive[t] = true;

        // Inner loop: solve on the passive set; clip negative entries.
        loop {
            let p: Vec<usize> = (0..n).filter(|&j| passive[j]).collect();
            let ap = a.select_cols(&p);
            let z = match ap.lstsq(b) {
                Some(z) => z,
                None => {
                    // Singular subproblem: drop the newest column and stop
                    // considering it this round.
                    passive[t] = false;
                    break;
                }
            };

            if z.iter().all(|&v| v > TOL) {
                for (idx, &j) in p.iter().enumerate() {
                    x[j] = z[idx];
                }
                break;
            }

            // Step toward z only as far as feasibility allows.
            let mut alpha = f64::INFINITY;
            for (idx, &j) in p.iter().enumerate() {
                if z[idx] <= TOL {
                    let denom = x[j] - z[idx];
                    if denom > 0.0 {
                        alpha = alpha.min(x[j] / denom);
                    }
                }
            }
            if !alpha.is_finite() {
                alpha = 0.0;
            }
            for (idx, &j) in p.iter().enumerate() {
                x[j] += alpha * (z[idx] - x[j]);
            }
            for j in 0..n {
                if passive[j] && x[j].abs() <= TOL {
                    passive[j] = false;
                    x[j] = 0.0;
                }
            }
        }
    }

    let residual = norm2(&sub(b, &a.matvec(&x)));
    Ok(NnlsSolution { x, residual, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn design(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.uniform_range(0.0, 1.0))
    }

    #[test]
    fn recovers_nonnegative_truth() {
        let a = design(50, 3, 1);
        let truth = vec![2.0, 0.5, 1.5];
        let b = a.matvec(&truth);
        let sol = nnls(&a, &b).unwrap();
        for (got, want) in sol.x.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-6, "{:?}", sol.x);
        }
        assert!(sol.residual < 1e-8);
    }

    #[test]
    fn clips_negative_truth_to_zero() {
        // b generated with a negative coefficient: NNLS must zero it.
        let a = design(60, 2, 2);
        let b_raw = a.matvec(&vec![3.0, -2.0]);
        let sol = nnls(&a, &b_raw).unwrap();
        assert!(sol.x.iter().all(|&v| v >= 0.0));
        // second coefficient pinned at the boundary
        assert!(sol.x[1].abs() < 1e-9, "{:?}", sol.x);
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let a = design(20, 4, 3);
        let sol = nnls(&a, &vec![0.0; 20]).unwrap();
        assert!(sol.x.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn solution_never_negative_on_noisy_data() {
        let mut rng = Rng::new(9);
        for trial in 0..20 {
            let a = design(40, 4, 100 + trial);
            let truth: Vec<f64> = (0..4).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
            let mut b = a.matvec(&truth);
            for v in &mut b {
                *v += 0.05 * rng.normal();
            }
            let sol = nnls(&a, &b).unwrap();
            assert!(sol.x.iter().all(|&v| v >= 0.0), "trial {trial}: {:?}", sol.x);
        }
    }

    #[test]
    fn residual_no_worse_than_zero_vector() {
        let a = design(30, 3, 5);
        let b: Vec<f64> = (0..30).map(|i| (i as f64 * 0.3).sin() + 1.0).collect();
        let sol = nnls(&a, &b).unwrap();
        assert!(sol.residual <= norm2(&b) + 1e-12);
    }

    #[test]
    fn kkt_dual_feasibility_at_solution() {
        // For inactive coords (x=0), gradient must be >= -tol;
        // for active coords, gradient ~ 0.
        let a = design(50, 4, 7);
        let b = a.matvec(&vec![1.0, 0.0, 2.0, 0.0]);
        let sol = nnls(&a, &b).unwrap();
        let at = a.transpose();
        let resid = sub(&b, &a.matvec(&sol.x));
        for j in 0..4 {
            let w = dot(at.row(j), &resid);
            if sol.x[j] > 1e-9 {
                assert!(w.abs() < 1e-6, "active coord {j} grad {w}");
            } else {
                assert!(w < 1e-6, "inactive coord {j} grad {w}");
            }
        }
    }
}
