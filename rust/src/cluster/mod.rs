//! Cluster inventory and task placement (§4.3).
//!
//! Ring architectures have no parameter servers, so placement reduces to
//! picking GPUs for each job while using as few nodes as possible (fewer
//! nodes → more intra-node NVLink/PCIe hops instead of network hops).
//! The paper notes this is "solved straightforwardly by standard
//! algorithms"; we implement best-fit-decreasing over per-node free
//! counts and track every allocation so invariants (no double-booking,
//! exact frees) are checkable.
//!
//! [`Topology`] is how the rest of the system names the pool shape: the
//! degenerate [`Topology::Flat`] case (every GPU one hop from every
//! other — the pre-placement behavior, preserved bit-for-bit) or a real
//! `nodes × gpus_per_node` grid where a ring spanning more than one node
//! pays the eq-2 inter-node α/β (see `perfmodel::placement`).

use std::collections::BTreeMap;

use crate::Result;

/// Static shape of the cluster (the paper simulates 64 GPUs; their
/// testbed node is 8x K40m).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl ClusterSpec {
    pub fn new(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec { nodes, gpus_per_node }
    }

    /// The paper's simulated cluster: 8 nodes x 8 GPUs = 64.
    pub fn paper_sim() -> Self {
        ClusterSpec::new(8, 8)
    }

    pub fn capacity(&self) -> usize {
        self.nodes * self.gpus_per_node
    }
}

/// Pool shape as seen by the scheduler, the DES, and the orchestrator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Undifferentiated pool: placement can never affect speed. This is
    /// the degenerate case every pre-topology code path maps onto.
    Flat { capacity: usize },
    /// Real `nodes × gpus_per_node` grid; rings spanning >1 node pay the
    /// inter-node all-reduce cost.
    Cluster(ClusterSpec),
}

impl Topology {
    pub fn flat(capacity: usize) -> Topology {
        Topology::Flat { capacity }
    }

    pub fn cluster(nodes: usize, gpus_per_node: usize) -> Topology {
        Topology::Cluster(ClusterSpec::new(nodes, gpus_per_node))
    }

    pub fn capacity(&self) -> usize {
        match *self {
            Topology::Flat { capacity } => capacity,
            Topology::Cluster(spec) => spec.capacity(),
        }
    }

    pub fn is_flat(&self) -> bool {
        matches!(self, Topology::Flat { .. })
    }

    /// Spec backing the placement ledger (Flat = one giant node, so
    /// every gang trivially spans 1 node and no penalty ever applies).
    pub fn spec(&self) -> ClusterSpec {
        match *self {
            Topology::Flat { capacity } => ClusterSpec::new(1, capacity),
            Topology::Cluster(spec) => spec,
        }
    }

    /// Reconcile with a caller-set capacity: Flat follows `capacity`
    /// (it carries no information beyond the pool size), a grid must
    /// already agree. Shared by every execution layer so the
    /// "capacity was mutated directly" case behaves the same way
    /// everywhere.
    pub fn reconciled(self, capacity: usize) -> Result<Topology> {
        match self {
            Topology::Flat { .. } => Ok(Topology::flat(capacity)),
            t => {
                anyhow::ensure!(
                    t.capacity() == capacity,
                    "topology capacity {} != capacity {capacity} (use with_topology)",
                    t.capacity()
                );
                Ok(t)
            }
        }
    }

    /// Human-readable shape for reports: `flat(8)` or `2x8`.
    pub fn label(&self) -> String {
        match *self {
            Topology::Flat { capacity } => format!("flat({capacity})"),
            Topology::Cluster(spec) => format!("{}x{}", spec.nodes, spec.gpus_per_node),
        }
    }

    /// Fewest nodes a gang of `w` can span (the contiguous best case the
    /// scheduler assumes when scoring candidate widths).
    pub fn min_span(&self, w: usize) -> usize {
        match *self {
            Topology::Flat { .. } => 1,
            Topology::Cluster(spec) => contiguous_span(w, spec.gpus_per_node),
        }
    }
}

/// Nodes a contiguous gang of `w` spans on `gpus_per_node`-wide nodes —
/// the best-case span both [`Topology::min_span`] and the scheduler's
/// placement-adjusted speed score against.
pub fn contiguous_span(w: usize, gpus_per_node: usize) -> usize {
    w.div_ceil(gpus_per_node.max(1)).max(1)
}

/// How `place` picks slots.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacePolicy {
    /// Best-fit-decreasing: minimize nodes spanned (locality-aware).
    #[default]
    Pack,
    /// Round-robin across the emptiest nodes: maximize span — the
    /// locality-blind strawman the placement ablation measures against.
    Scatter,
    /// Contention-aware pack: identical to [`PlacePolicy::Pack`] while a
    /// gang fits one node (an intra-node ring never touches a link), but
    /// a gang that must cross nodes prefers nodes whose uplinks carry
    /// the fewest rings — unavoidable cross-node rings are spread across
    /// link groups instead of stacking on the uplinks Pack's best-fit
    /// remainder rule gravitates to (the partially-filled nodes, which
    /// are exactly the nodes already carrying a crossing ring).
    Spread,
}

/// Compact placement summary a speed lookup needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub gpus: usize,
    pub nodes: usize,
}

/// One allocated GPU: (node index, slot index within node).
pub type Gpu = (usize, usize);

/// Mutable allocation state of a cluster.
#[derive(Clone, Debug)]
pub struct ClusterState {
    spec: ClusterSpec,
    policy: PlacePolicy,
    /// busy[node][slot] = owning job id (None = free).
    busy: Vec<Vec<Option<u64>>>,
    /// job id -> GPUs held.
    allocations: BTreeMap<u64, Vec<Gpu>>,
    /// Per-link ring ledger: `link_rings[n]` = rings currently crossing
    /// node `n`'s uplink. Each node has one uplink into the shared
    /// switch fabric; a node-contiguous ring spanning `k >= 2` nodes
    /// crosses the uplink of each node it occupies exactly once per
    /// chunk round, so the ledger increments once per occupied node per
    /// crossing job. Single-node gangs never register: an intra-node
    /// ring has no link to share. Maintained by every place/release, so
    /// `sum(link_rings)` always equals the summed span of the jobs
    /// spanning more than one node.
    link_rings: Vec<usize>,
    /// Failed-node mask (DESIGN.md §17): `down[n]` means node `n` is
    /// out of the pool — placement never picks its slots and its free
    /// GPUs do not count toward [`Self::available_gpus`]. Marking a
    /// node down does *not* evict its tenants; the engine owning the
    /// ledger evicts (releases) victims itself so their loss of
    /// progress is charged at one well-defined point. All-false (the
    /// only state a fault-off run can be in) makes every accessor
    /// degenerate to its pre-fault form.
    down: Vec<bool>,
}

impl ClusterState {
    pub fn new(spec: ClusterSpec) -> Self {
        ClusterState::with_policy(spec, PlacePolicy::Pack)
    }

    pub fn with_policy(spec: ClusterSpec, policy: PlacePolicy) -> Self {
        ClusterState {
            spec,
            policy,
            busy: vec![vec![None; spec.gpus_per_node]; spec.nodes],
            allocations: BTreeMap::new(),
            link_rings: vec![0; spec.nodes],
            down: vec![false; spec.nodes],
        }
    }

    pub fn spec(&self) -> ClusterSpec {
        self.spec
    }

    pub fn policy(&self) -> PlacePolicy {
        self.policy
    }

    pub fn free_gpus(&self) -> usize {
        self.busy.iter().flatten().filter(|s| s.is_none()).count()
    }

    pub fn used_gpus(&self) -> usize {
        self.spec.capacity() - self.free_gpus()
    }

    /// Free GPUs on *up* nodes — what placement can actually grant.
    /// Equal to [`Self::free_gpus`] whenever no node is down (every
    /// fault-off run), so pre-fault callers may keep using either.
    pub fn available_gpus(&self) -> usize {
        (0..self.spec.nodes)
            .filter(|&n| !self.down[n])
            .map(|n| self.busy[n].iter().filter(|s| s.is_none()).count())
            .sum()
    }

    /// Mark `node` failed: placement skips it until [`Self::set_node_up`].
    /// Tenants are left in the ledger for the caller to evict.
    pub fn set_node_down(&mut self, node: usize) {
        self.down[node] = true;
    }

    /// Repair `node`: its free slots re-enter the placeable pool.
    pub fn set_node_up(&mut self, node: usize) {
        self.down[node] = false;
    }

    pub fn is_node_down(&self, node: usize) -> bool {
        self.down[node]
    }

    /// Nodes currently down, ascending.
    pub fn down_nodes(&self) -> Vec<usize> {
        (0..self.spec.nodes).filter(|&n| self.down[n]).collect()
    }

    /// Jobs with at least one GPU on `node`, ascending by id — the
    /// eviction set when `node` fails.
    pub fn jobs_on_node(&self, node: usize) -> Vec<u64> {
        self.allocations
            .iter()
            .filter(|(_, gpus)| gpus.iter().any(|&(n, _)| n == node))
            .map(|(&j, _)| j)
            .collect()
    }

    /// GPUs currently held by `job`.
    pub fn allocation_of(&self, job: u64) -> Option<&[Gpu]> {
        self.allocations.get(&job).map(|v| v.as_slice())
    }

    /// Every `(job, width)` currently placed, ascending by job id.
    pub fn placed_jobs(&self) -> Vec<(u64, usize)> {
        self.allocations.iter().map(|(&j, g)| (j, g.len())).collect()
    }

    /// Number of distinct nodes `job` spans.
    pub fn nodes_spanned(&self, job: u64) -> usize {
        self.node_set(job).len()
    }

    /// Per-node GPU counts of `job`'s allocation, ascending by node —
    /// the shape telemetry placement snapshots record (compact where a
    /// raw slot list would be O(gpus) noise the audit never needs).
    pub fn node_gpu_counts(&self, job: u64) -> Vec<(usize, usize)> {
        let mut per: BTreeMap<usize, usize> = BTreeMap::new();
        for &(n, _) in self.allocation_of(job).unwrap_or(&[]) {
            *per.entry(n).or_insert(0) += 1;
        }
        per.into_iter().collect()
    }

    /// Sorted distinct nodes `job` occupies (empty if unplaced). Two
    /// placements with the same node set run the same ring topology, so
    /// this is what restart/continuation logic compares.
    pub fn node_set(&self, job: u64) -> Vec<usize> {
        let Some(gpus) = self.allocations.get(&job) else { return Vec::new() };
        let mut nodes: Vec<usize> = gpus.iter().map(|&(n, _)| n).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    /// Placement summary for speed lookups.
    pub fn span_of(&self, job: u64) -> Span {
        Span {
            gpus: self.allocations.get(&job).map_or(0, |g| g.len()),
            nodes: self.nodes_spanned(job),
        }
    }

    /// Rings currently crossing each node's uplink (the shared-bandwidth
    /// ledger the contention model prices against).
    pub fn link_rings(&self) -> &[usize] {
        &self.link_rings
    }

    /// Tenancy of `job`'s ring: rings (including its own) on the busiest
    /// uplink it traverses. `1` for single-node gangs, unplaced jobs,
    /// and sole tenants — exactly the cases the contention law leaves
    /// bit-identical to the uncontended model.
    pub fn tenancy_of(&self, job: u64) -> usize {
        let nodes = self.node_set(job);
        if nodes.len() <= 1 {
            return 1;
        }
        nodes.iter().map(|&n| self.link_rings[n]).max().unwrap_or(1).max(1)
    }

    /// Worst-case rings any uplink carries, not counting `job`'s own
    /// contribution — what a scheduler assumes a *candidate* cross-node
    /// ring for `job` would have to share a link with (pessimistic: the
    /// placement policy may dodge the busiest link, but the score must
    /// not promise that).
    pub fn max_link_rings_excluding(&self, job: u64) -> usize {
        let own = self.node_set(job);
        let crosses = own.len() > 1;
        (0..self.spec.nodes)
            .map(|n| {
                let r = self.link_rings[n];
                if crosses && own.binary_search(&n).is_ok() {
                    r.saturating_sub(1)
                } else {
                    r
                }
            })
            .max()
            .unwrap_or(0)
    }

    /// Register `job`'s ring on the uplinks of every node it occupies
    /// (no-op for single-node gangs).
    fn ledger_add(&mut self, job: u64) {
        let nodes = self.node_set(job);
        if nodes.len() > 1 {
            for n in nodes {
                self.link_rings[n] += 1;
            }
        }
    }

    /// Inverse of [`Self::ledger_add`]; called before the allocation is
    /// dropped so the node set is still known.
    fn ledger_sub(&mut self, job: u64) {
        let nodes = self.node_set(job);
        if nodes.len() > 1 {
            for n in nodes {
                debug_assert!(self.link_rings[n] > 0, "link ledger underflow at node {n}");
                self.link_rings[n] = self.link_rings[n].saturating_sub(1);
            }
        }
    }

    /// Allocate `w` GPUs to `job` under the state's placement policy:
    /// [`PlacePolicy::Pack`] minimizes the number of nodes used —
    /// best-fit (a node whose free count exactly matches the remainder)
    /// first, otherwise the node with the most free GPUs;
    /// [`PlacePolicy::Scatter`] spreads one GPU at a time across the
    /// emptiest nodes (the locality-blind baseline).
    pub fn place(&mut self, job: u64, w: usize) -> Result<Vec<Gpu>> {
        self.place_with_affinity(job, w, &[])
    }

    /// [`Self::place`] with slot affinity: the exact `preferred` GPUs
    /// that are still free are taken first, the policy places any
    /// remainder. Used to hand a job resuming at an unchanged width its
    /// previous ring back, so a segment boundary is not a migration —
    /// and, because each job prefers only its *own* former slots,
    /// sibling continuations at the same instant can never steal from
    /// one another.
    pub fn place_with_affinity(
        &mut self,
        job: u64,
        w: usize,
        preferred: &[Gpu],
    ) -> Result<Vec<Gpu>> {
        anyhow::ensure!(w > 0, "cannot place zero GPUs");
        anyhow::ensure!(
            !self.allocations.contains_key(&job),
            "job {job} already placed; release first"
        );
        anyhow::ensure!(
            w <= self.available_gpus(),
            "insufficient capacity: want {w}, available {} ({} free, {} nodes down)",
            self.available_gpus(),
            self.free_gpus(),
            self.down.iter().filter(|&&d| d).count()
        );

        let mut picked: Vec<Gpu> = Vec::with_capacity(w);
        let mut remaining = w;
        for &(node, slot) in preferred {
            if remaining == 0 {
                break;
            }
            if node < self.spec.nodes
                && slot < self.spec.gpus_per_node
                && !self.down[node]
                && self.busy[node][slot].is_none()
            {
                self.busy[node][slot] = Some(job);
                picked.push((node, slot));
                remaining -= 1;
            }
        }
        while remaining > 0 {
            // a down node reports zero free slots, so every policy
            // (and the capacity-checked expect below) skips it without
            // any fault-specific branch
            let busy = &self.busy;
            let down = &self.down;
            let free_of = |n: usize| {
                if down[n] {
                    0
                } else {
                    busy[n].iter().filter(|s| s.is_none()).count()
                }
            };
            let node = match self.policy {
                PlacePolicy::Pack => {
                    // best fit: smallest free count still >= remaining…
                    let exact = (0..self.spec.nodes)
                        .filter(|&n| free_of(n) >= remaining)
                        .min_by_key(|&n| free_of(n));
                    // …else the fullest-free node to minimize node count.
                    exact.or_else(|| {
                        (0..self.spec.nodes)
                            .filter(|&n| free_of(n) > 0)
                            .max_by_key(|&n| free_of(n))
                    })
                }
                // emptiest node first, one GPU per visit (ties -> lowest
                // index, so scatter is deterministic too)
                PlacePolicy::Scatter => (0..self.spec.nodes)
                    .filter(|&n| free_of(n) > 0)
                    .max_by(|&a, &b| free_of(a).cmp(&free_of(b)).then(b.cmp(&a))),
                PlacePolicy::Spread => {
                    // A gang that still fits one node is an intra-node
                    // ring — no link, no contention — so locality wins
                    // and the choice is exactly Pack's best fit. Only a
                    // ring forced to cross (a partial pick already made,
                    // or no node can hold the remainder) weighs uplink
                    // tenancy: fewest rings first, then best fit, then
                    // lowest index — all deterministic.
                    let crossing = !picked.is_empty()
                        || (0..self.spec.nodes).all(|n| free_of(n) < remaining);
                    if !crossing {
                        (0..self.spec.nodes)
                            .filter(|&n| free_of(n) >= remaining)
                            .min_by_key(|&n| free_of(n))
                    } else {
                        let exact = (0..self.spec.nodes)
                            .filter(|&n| free_of(n) >= remaining)
                            .min_by_key(|&n| (self.link_rings[n], free_of(n), n));
                        exact.or_else(|| {
                            (0..self.spec.nodes)
                                .filter(|&n| free_of(n) > 0)
                                .min_by_key(|&n| {
                                    (
                                        self.link_rings[n],
                                        std::cmp::Reverse(free_of(n)),
                                        n,
                                    )
                                })
                        })
                    }
                }
            };
            let node = node.expect("capacity checked above");
            let mut take = match self.policy {
                PlacePolicy::Pack | PlacePolicy::Spread => remaining,
                PlacePolicy::Scatter => 1,
            };
            for slot in 0..self.spec.gpus_per_node {
                if take == 0 {
                    break;
                }
                if self.busy[node][slot].is_none() {
                    self.busy[node][slot] = Some(job);
                    picked.push((node, slot));
                    remaining -= 1;
                    take -= 1;
                }
            }
        }
        self.allocations.insert(job, picked.clone());
        self.ledger_add(job);
        Ok(picked)
    }

    /// Place a batch of `(job, w)` gangs largest-first — the
    /// defragmenting re-pack used at reallocation points: every job that
    /// is being (re)placed at this instant has already been released, so
    /// best-fit-decreasing over the whole movable set minimizes the
    /// fragmentation a one-at-a-time FIFO placement accumulates.
    pub fn place_batch(&mut self, gangs: &[(u64, usize)]) -> Result<()> {
        let mut order: Vec<(u64, usize)> = gangs.to_vec();
        // decreasing width; FIFO (input order) inside a width class
        order.sort_by(|a, b| b.1.cmp(&a.1));
        for (job, w) in order {
            self.place(job, w)?;
        }
        Ok(())
    }

    /// Release every GPU held by `job`.
    pub fn release(&mut self, job: u64) -> Result<usize> {
        anyhow::ensure!(
            self.allocations.contains_key(&job),
            "job {job} holds no allocation"
        );
        self.ledger_sub(job);
        let gpus = self.allocations.remove(&job).expect("checked above");
        let count = gpus.len();
        for (n, s) in gpus {
            debug_assert_eq!(self.busy[n][s], Some(job));
            self.busy[n][s] = None;
        }
        Ok(count)
    }

    /// Resize in place: release + place (the checkpoint-restart rescale).
    pub fn rescale(&mut self, job: u64, new_w: usize) -> Result<Vec<Gpu>> {
        if self.allocations.contains_key(&job) {
            self.release(job)?;
        }
        self.place(job, new_w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_and_free_accounting() {
        let mut c = ClusterState::new(ClusterSpec::paper_sim());
        assert_eq!(c.free_gpus(), 64);
        c.place(1, 10).unwrap();
        assert_eq!(c.free_gpus(), 54);
        assert_eq!(c.used_gpus(), 10);
        assert_eq!(c.release(1).unwrap(), 10);
        assert_eq!(c.free_gpus(), 64);
    }

    #[test]
    fn exact_fit_prefers_single_node() {
        let mut c = ClusterState::new(ClusterSpec::new(4, 8));
        c.place(1, 8).unwrap();
        assert_eq!(c.nodes_spanned(1), 1);
        c.place(2, 4).unwrap();
        assert_eq!(c.nodes_spanned(2), 1);
    }

    #[test]
    fn small_job_packs_into_fragmented_node() {
        let mut c = ClusterState::new(ClusterSpec::new(2, 4));
        c.place(1, 3).unwrap(); // node A: 1 free
        c.place(2, 1).unwrap(); // best fit: the 1-free node
        assert_eq!(c.nodes_spanned(2), 1);
        // full node B still untouched
        c.place(3, 4).unwrap();
        assert_eq!(c.nodes_spanned(3), 1);
    }

    #[test]
    fn spans_minimum_nodes_when_fragmented() {
        let mut c = ClusterState::new(ClusterSpec::new(3, 4));
        c.place(1, 2).unwrap();
        c.place(2, 10).unwrap(); // needs to span all three nodes
        assert_eq!(c.nodes_spanned(2), 3);
        assert_eq!(c.free_gpus(), 0);
    }

    #[test]
    fn rejects_overcommit_and_double_place() {
        let mut c = ClusterState::new(ClusterSpec::new(1, 4));
        assert!(c.place(1, 5).is_err());
        c.place(1, 2).unwrap();
        assert!(c.place(1, 1).is_err());
        assert!(c.place(2, 3).is_err());
        assert!(c.release(99).is_err());
    }

    #[test]
    fn rescale_moves_to_new_size() {
        let mut c = ClusterState::new(ClusterSpec::new(2, 8));
        c.place(7, 4).unwrap();
        let gpus = c.rescale(7, 8).unwrap();
        assert_eq!(gpus.len(), 8);
        assert_eq!(c.used_gpus(), 8);
        assert_eq!(c.nodes_spanned(7), 1);
    }

    #[test]
    fn no_double_booking_across_many_ops() {
        let mut c = ClusterState::new(ClusterSpec::new(4, 4));
        c.place(1, 3).unwrap();
        c.place(2, 5).unwrap();
        c.place(3, 2).unwrap();
        c.release(2).unwrap();
        c.place(4, 6).unwrap();
        // every busy slot owned by exactly one job
        let mut owned = std::collections::HashSet::new();
        for job in [1u64, 3, 4] {
            for g in c.allocation_of(job).unwrap() {
                assert!(owned.insert(*g), "double booked {g:?}");
            }
        }
        assert_eq!(owned.len(), c.used_gpus());
    }

    #[test]
    fn topology_flat_and_cluster_shapes() {
        let flat = Topology::flat(8);
        assert!(flat.is_flat());
        assert_eq!(flat.capacity(), 8);
        assert_eq!(flat.spec(), ClusterSpec::new(1, 8));
        for w in [1usize, 5, 8] {
            assert_eq!(flat.min_span(w), 1);
        }
        let grid = Topology::cluster(4, 8);
        assert!(!grid.is_flat());
        assert_eq!(grid.capacity(), 32);
        assert_eq!(grid.min_span(1), 1);
        assert_eq!(grid.min_span(8), 1);
        assert_eq!(grid.min_span(9), 2);
        assert_eq!(grid.min_span(32), 4);
    }

    #[test]
    fn scatter_policy_maximizes_span() {
        let mut c = ClusterState::with_policy(ClusterSpec::new(4, 4), PlacePolicy::Scatter);
        c.place(1, 4).unwrap();
        assert_eq!(c.nodes_spanned(1), 4, "scatter should touch every node");
        // pack would have kept the same gang on one node
        let mut p = ClusterState::new(ClusterSpec::new(4, 4));
        p.place(1, 4).unwrap();
        assert_eq!(p.nodes_spanned(1), 1);
    }

    #[test]
    fn span_and_node_set_report_placements() {
        let mut c = ClusterState::new(ClusterSpec::new(3, 4));
        c.place(1, 6).unwrap();
        let s = c.span_of(1);
        assert_eq!(s.gpus, 6);
        assert_eq!(s.nodes, 2);
        assert_eq!(c.node_set(1).len(), 2);
        assert_eq!(c.span_of(99), Span { gpus: 0, nodes: 0 });
        assert!(c.node_set(99).is_empty());
    }

    /// Full ledger consistency: every allocation's slots are owned by
    /// that job, busy/free counts reconcile, no slot has two owners.
    fn assert_consistent(c: &ClusterState) {
        let mut owned = std::collections::HashSet::new();
        let mut total = 0usize;
        for (&job, gpus) in &c.allocations {
            for &(n, s) in gpus {
                assert_eq!(c.busy[n][s], Some(job), "slot ({n},{s}) owner mismatch");
                assert!(owned.insert((n, s)), "double booked ({n},{s})");
            }
            total += gpus.len();
        }
        assert_eq!(total, c.used_gpus());
        assert_eq!(c.free_gpus() + c.used_gpus(), c.spec().capacity());
        // no orphaned busy slots
        let busy_count = c.busy.iter().flatten().filter(|s| s.is_some()).count();
        assert_eq!(busy_count, total);
        // link ledger conservation: each uplink carries exactly the
        // crossing rings occupying its node, and the sum equals the
        // summed span of crossing jobs
        let mut want = vec![0usize; c.spec().nodes];
        let mut crossing_span = 0usize;
        for &job in c.allocations.keys() {
            let nodes = c.node_set(job);
            if nodes.len() > 1 {
                crossing_span += nodes.len();
                for n in nodes {
                    want[n] += 1;
                }
            }
        }
        assert_eq!(c.link_rings(), want.as_slice(), "per-link ring counts drifted");
        assert_eq!(c.link_rings().iter().sum::<usize>(), crossing_span);
    }

    #[test]
    fn churn_sequence_preserves_invariants() {
        // alloc/free/rescale/re-pack churn over a 4x4 grid; the ledger
        // must stay exact at every step under every policy.
        for policy in [PlacePolicy::Pack, PlacePolicy::Scatter, PlacePolicy::Spread] {
            let mut c = ClusterState::with_policy(ClusterSpec::new(4, 4), policy);
            c.place(1, 5).unwrap();
            c.place(2, 3).unwrap();
            c.place(3, 4).unwrap();
            assert_consistent(&c);
            assert_eq!(c.release(2).unwrap(), 3);
            c.rescale(1, 7).unwrap();
            assert_consistent(&c);
            c.place(4, 2).unwrap();
            c.rescale(3, 1).unwrap();
            assert_consistent(&c);
            c.release(4).unwrap();
            c.rescale(1, 2).unwrap();
            c.place_batch(&[(5, 6), (6, 4), (7, 1)]).unwrap();
            assert_consistent(&c);
            // exact frees: releasing everything restores full capacity
            for job in [1u64, 3, 5, 6, 7] {
                c.release(job).unwrap();
            }
            assert_consistent(&c);
            assert_eq!(c.free_gpus(), 16, "policy {policy:?}");
        }
    }

    #[test]
    fn repack_bounds_fragmentation() {
        // FIFO one-at-a-time placement of (3,3,2) on 2x4 leaves the
        // 2-gang straddling; the decreasing re-pack keeps every gang
        // that fits a node on a single node.
        let mut c = ClusterState::new(ClusterSpec::new(2, 4));
        c.place_batch(&[(1, 3), (2, 3), (3, 2)]).unwrap();
        // largest-first: 3 -> node A, 3 -> node B, 2 -> a 1-free... must
        // split; release 3 and re-pack the movable set to verify BFD
        // heals the fragmentation it can.
        c.release(3).unwrap();
        c.release(2).unwrap();
        c.place_batch(&[(2, 3), (3, 2)]).unwrap();
        // after re-pack: no gang of w <= 4 spans more nodes than the
        // minimal possible given what was pinned (job 1 holds 3 slots)
        assert_eq!(c.nodes_spanned(2), 1, "3-gang must fit the empty node");
        assert!(c.nodes_spanned(3) <= 2);
        assert_consistent(&c);
    }

    #[test]
    fn affinity_reclaims_exact_previous_slots() {
        let mut c = ClusterState::new(ClusterSpec::new(2, 4));
        let prev = c.place(1, 2).unwrap();
        c.release(1).unwrap();
        // without affinity a bigger gang would best-fit onto job 1's
        // old node; with affinity job 1 reclaims its exact slots first
        let again = c.place_with_affinity(1, 2, &prev).unwrap();
        assert_eq!(again, prev);
        // sibling continuations cannot steal each other's slots: two
        // jobs released at the same instant each reclaim their own ring
        let mut c = ClusterState::new(ClusterSpec::new(2, 4));
        let a = c.place(1, 5).unwrap(); // spans both nodes
        let b = c.place(2, 3).unwrap();
        c.release(1).unwrap();
        c.release(2).unwrap();
        assert_eq!(c.place_with_affinity(1, 5, &a).unwrap(), a);
        assert_eq!(c.place_with_affinity(2, 3, &b).unwrap(), b);
        // affinity overflows gracefully into the policy path, and
        // out-of-range preferred slots are ignored, not a panic
        let mut c = ClusterState::new(ClusterSpec::new(2, 4));
        c.place(9, 7).unwrap();
        c.place_with_affinity(1, 1, &[(99, 0), (0, 99)]).unwrap();
        assert_eq!(c.span_of(1).gpus, 1);
        assert_consistent(&c);
    }

    #[test]
    fn link_ledger_tracks_crossing_rings_only() {
        let mut c = ClusterState::new(ClusterSpec::new(4, 4));
        c.place(1, 4).unwrap(); // one node: no ring on any uplink
        assert_eq!(c.link_rings().iter().sum::<usize>(), 0);
        assert_eq!(c.tenancy_of(1), 1);
        c.place(2, 6).unwrap(); // crosses: registers on each node it spans
        assert_eq!(c.nodes_spanned(2), 2);
        assert_eq!(c.link_rings().iter().sum::<usize>(), 2);
        assert_eq!(c.tenancy_of(2), 1, "sole crossing ring is sole tenant");
        c.release(2).unwrap();
        assert_eq!(c.link_rings().iter().sum::<usize>(), 0);
        assert_consistent(&c);
    }

    #[test]
    fn tenancy_counts_shared_uplinks() {
        // 3x4: job 1 takes a full node + 2; job 2's crossing remainder
        // lands on job 1's partial node under Pack -> both rings cross
        // that node's uplink.
        let mut c = ClusterState::new(ClusterSpec::new(3, 4));
        c.place(1, 6).unwrap();
        c.place(2, 6).unwrap();
        assert_consistent(&c);
        let shared: Vec<usize> =
            c.node_set(1).into_iter().filter(|n| c.node_set(2).contains(n)).collect();
        assert!(!shared.is_empty(), "pack should co-locate the remainders");
        assert_eq!(c.tenancy_of(1), 2);
        assert_eq!(c.tenancy_of(2), 2);
        // excluding a job's own contribution still sees the other ring
        assert_eq!(c.max_link_rings_excluding(1), 1);
        assert_eq!(c.max_link_rings_excluding(99), 2, "outsider sees both rings");
    }

    #[test]
    fn spread_matches_pack_until_a_ring_must_cross() {
        // single-node-fit gangs: Spread is Pack (locality first)
        let mut p = ClusterState::new(ClusterSpec::new(4, 4));
        let mut s = ClusterState::with_policy(ClusterSpec::new(4, 4), PlacePolicy::Spread);
        for (job, w) in [(1u64, 3), (2, 1), (3, 4), (4, 2)] {
            assert_eq!(p.place(job, w).unwrap(), s.place(job, w).unwrap(), "job {job}");
        }
        assert_consistent(&s);
    }

    #[test]
    fn spread_avoids_sharing_uplinks_when_it_can() {
        // 4x4, two 6-gangs. Pack's best-fit remainder rule stacks the
        // second gang's remainder onto the first gang's partial node
        // (shared uplink); Spread gives the gangs disjoint node sets.
        let mut p = ClusterState::new(ClusterSpec::new(4, 4));
        p.place(1, 6).unwrap();
        p.place(2, 6).unwrap();
        let overlap: Vec<usize> =
            p.node_set(1).into_iter().filter(|n| p.node_set(2).contains(n)).collect();
        assert!(!overlap.is_empty(), "pack stacks remainders on a shared node");
        assert_eq!(p.tenancy_of(2), 2);

        let mut s = ClusterState::with_policy(ClusterSpec::new(4, 4), PlacePolicy::Spread);
        s.place(1, 6).unwrap();
        s.place(2, 6).unwrap();
        let overlap: Vec<usize> =
            s.node_set(1).into_iter().filter(|n| s.node_set(2).contains(n)).collect();
        assert!(overlap.is_empty(), "spread must pick disjoint link groups");
        assert_eq!(s.tenancy_of(1), 1);
        assert_eq!(s.tenancy_of(2), 1);
        assert_consistent(&s);
    }

    #[test]
    fn down_nodes_are_unplaceable_until_repair() {
        let mut c = ClusterState::new(ClusterSpec::new(2, 4));
        assert_eq!(c.available_gpus(), 8);
        assert!(c.down_nodes().is_empty());
        c.set_node_down(0);
        assert!(c.is_node_down(0));
        assert_eq!(c.down_nodes(), vec![0]);
        assert_eq!(c.available_gpus(), 4);
        assert_eq!(c.free_gpus(), 8, "free counts raw slots; available excludes down");
        // placement lands entirely on the surviving node
        c.place(1, 4).unwrap();
        assert_eq!(c.node_set(1), vec![1]);
        // and a gang that no longer fits is refused, not split onto the
        // dead node
        let err = c.place(2, 1).unwrap_err().to_string();
        assert!(err.contains("nodes down"), "{err}");
        // affinity must not resurrect slots on a down node
        c.release(1).unwrap();
        c.set_node_down(1);
        c.set_node_up(0);
        let picked = c.place_with_affinity(1, 2, &[(1, 0), (1, 1)]).unwrap();
        assert!(picked.iter().all(|&(n, _)| n == 0), "{picked:?}");
        c.release(1).unwrap();
        // repair restores the full pool
        c.set_node_up(1);
        assert_eq!(c.available_gpus(), 8);
        c.place(3, 8).unwrap();
        assert_eq!(c.nodes_spanned(3), 2);
    }

    #[test]
    fn jobs_on_node_names_the_eviction_set() {
        let mut c = ClusterState::new(ClusterSpec::new(3, 4));
        c.place(1, 4).unwrap(); // node 0
        c.place(2, 6).unwrap(); // nodes 1+2
        c.place(3, 2).unwrap(); // node 2 (best fit into the remainder)
        assert_eq!(c.jobs_on_node(0), vec![1]);
        assert_eq!(c.jobs_on_node(1), vec![2]);
        assert_eq!(c.jobs_on_node(2), vec![2, 3]);
        // marking a node down does not evict: the engine owns eviction
        c.set_node_down(2);
        assert_eq!(c.jobs_on_node(2), vec![2, 3]);
        assert_consistent(&c);
    }

    #[test]
    fn place_batch_is_largest_first_and_fifo_within_width() {
        let mut c = ClusterState::new(ClusterSpec::new(2, 8));
        c.place_batch(&[(1, 2), (2, 8), (3, 2)]).unwrap();
        // the 8-gang got the empty node; both 2-gangs share the other
        assert_eq!(c.nodes_spanned(2), 1);
        assert_eq!(c.nodes_spanned(1), 1);
        assert_eq!(c.nodes_spanned(3), 1);
        let n8 = c.node_set(2)[0];
        assert_ne!(c.node_set(1)[0], n8);
        assert_ne!(c.node_set(3)[0], n8);
    }
}
