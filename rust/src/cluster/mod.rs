//! Cluster inventory and task placement (§4.3).
//!
//! Ring architectures have no parameter servers, so placement reduces to
//! picking GPUs for each job while using as few nodes as possible (fewer
//! nodes → more intra-node NVLink/PCIe hops instead of network hops).
//! The paper notes this is "solved straightforwardly by standard
//! algorithms"; we implement best-fit-decreasing over per-node free
//! counts and track every allocation so invariants (no double-booking,
//! exact frees) are checkable.

use std::collections::BTreeMap;

use crate::Result;

/// Static shape of the cluster (the paper simulates 64 GPUs; their
/// testbed node is 8x K40m).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl ClusterSpec {
    pub fn new(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec { nodes, gpus_per_node }
    }

    /// The paper's simulated cluster: 8 nodes x 8 GPUs = 64.
    pub fn paper_sim() -> Self {
        ClusterSpec::new(8, 8)
    }

    pub fn capacity(&self) -> usize {
        self.nodes * self.gpus_per_node
    }
}

/// One allocated GPU: (node index, slot index within node).
pub type Gpu = (usize, usize);

/// Mutable allocation state of a cluster.
#[derive(Clone, Debug)]
pub struct ClusterState {
    spec: ClusterSpec,
    /// busy[node][slot] = owning job id (None = free).
    busy: Vec<Vec<Option<u64>>>,
    /// job id -> GPUs held.
    allocations: BTreeMap<u64, Vec<Gpu>>,
}

impl ClusterState {
    pub fn new(spec: ClusterSpec) -> Self {
        ClusterState {
            spec,
            busy: vec![vec![None; spec.gpus_per_node]; spec.nodes],
            allocations: BTreeMap::new(),
        }
    }

    pub fn spec(&self) -> ClusterSpec {
        self.spec
    }

    pub fn free_gpus(&self) -> usize {
        self.busy.iter().flatten().filter(|s| s.is_none()).count()
    }

    pub fn used_gpus(&self) -> usize {
        self.spec.capacity() - self.free_gpus()
    }

    /// GPUs currently held by `job`.
    pub fn allocation_of(&self, job: u64) -> Option<&[Gpu]> {
        self.allocations.get(&job).map(|v| v.as_slice())
    }

    /// Number of distinct nodes `job` spans.
    pub fn nodes_spanned(&self, job: u64) -> usize {
        let Some(gpus) = self.allocations.get(&job) else { return 0 };
        let mut nodes: Vec<usize> = gpus.iter().map(|&(n, _)| n).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes.len()
    }

    /// Allocate `w` GPUs to `job`, minimizing the number of nodes used:
    /// best-fit (a node whose free count exactly matches the remainder)
    /// first, otherwise the node with the most free GPUs.
    pub fn place(&mut self, job: u64, w: usize) -> Result<Vec<Gpu>> {
        anyhow::ensure!(w > 0, "cannot place zero GPUs");
        anyhow::ensure!(
            !self.allocations.contains_key(&job),
            "job {job} already placed; release first"
        );
        anyhow::ensure!(
            w <= self.free_gpus(),
            "insufficient capacity: want {w}, free {}",
            self.free_gpus()
        );

        let mut picked: Vec<Gpu> = Vec::with_capacity(w);
        let mut remaining = w;
        while remaining > 0 {
            let free_of = |node: &Vec<Option<u64>>| node.iter().filter(|s| s.is_none()).count();
            // best fit: smallest free count still >= remaining…
            let exact = (0..self.spec.nodes)
                .filter(|&n| free_of(&self.busy[n]) >= remaining)
                .min_by_key(|&n| free_of(&self.busy[n]));
            // …else the fullest-free node to minimize node count.
            let node = exact.or_else(|| {
                (0..self.spec.nodes)
                    .filter(|&n| free_of(&self.busy[n]) > 0)
                    .max_by_key(|&n| free_of(&self.busy[n]))
            });
            let node = node.expect("capacity checked above");
            for slot in 0..self.spec.gpus_per_node {
                if remaining == 0 {
                    break;
                }
                if self.busy[node][slot].is_none() {
                    self.busy[node][slot] = Some(job);
                    picked.push((node, slot));
                    remaining -= 1;
                }
            }
        }
        self.allocations.insert(job, picked.clone());
        Ok(picked)
    }

    /// Release every GPU held by `job`.
    pub fn release(&mut self, job: u64) -> Result<usize> {
        let gpus = self
            .allocations
            .remove(&job)
            .ok_or_else(|| anyhow::anyhow!("job {job} holds no allocation"))?;
        let count = gpus.len();
        for (n, s) in gpus {
            debug_assert_eq!(self.busy[n][s], Some(job));
            self.busy[n][s] = None;
        }
        Ok(count)
    }

    /// Resize in place: release + place (the checkpoint-restart rescale).
    pub fn rescale(&mut self, job: u64, new_w: usize) -> Result<Vec<Gpu>> {
        if self.allocations.contains_key(&job) {
            self.release(job)?;
        }
        self.place(job, new_w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_and_free_accounting() {
        let mut c = ClusterState::new(ClusterSpec::paper_sim());
        assert_eq!(c.free_gpus(), 64);
        c.place(1, 10).unwrap();
        assert_eq!(c.free_gpus(), 54);
        assert_eq!(c.used_gpus(), 10);
        assert_eq!(c.release(1).unwrap(), 10);
        assert_eq!(c.free_gpus(), 64);
    }

    #[test]
    fn exact_fit_prefers_single_node() {
        let mut c = ClusterState::new(ClusterSpec::new(4, 8));
        c.place(1, 8).unwrap();
        assert_eq!(c.nodes_spanned(1), 1);
        c.place(2, 4).unwrap();
        assert_eq!(c.nodes_spanned(2), 1);
    }

    #[test]
    fn small_job_packs_into_fragmented_node() {
        let mut c = ClusterState::new(ClusterSpec::new(2, 4));
        c.place(1, 3).unwrap(); // node A: 1 free
        c.place(2, 1).unwrap(); // best fit: the 1-free node
        assert_eq!(c.nodes_spanned(2), 1);
        // full node B still untouched
        c.place(3, 4).unwrap();
        assert_eq!(c.nodes_spanned(3), 1);
    }

    #[test]
    fn spans_minimum_nodes_when_fragmented() {
        let mut c = ClusterState::new(ClusterSpec::new(3, 4));
        c.place(1, 2).unwrap();
        c.place(2, 10).unwrap(); // needs to span all three nodes
        assert_eq!(c.nodes_spanned(2), 3);
        assert_eq!(c.free_gpus(), 0);
    }

    #[test]
    fn rejects_overcommit_and_double_place() {
        let mut c = ClusterState::new(ClusterSpec::new(1, 4));
        assert!(c.place(1, 5).is_err());
        c.place(1, 2).unwrap();
        assert!(c.place(1, 1).is_err());
        assert!(c.place(2, 3).is_err());
        assert!(c.release(99).is_err());
    }

    #[test]
    fn rescale_moves_to_new_size() {
        let mut c = ClusterState::new(ClusterSpec::new(2, 8));
        c.place(7, 4).unwrap();
        let gpus = c.rescale(7, 8).unwrap();
        assert_eq!(gpus.len(), 8);
        assert_eq!(c.used_gpus(), 8);
        assert_eq!(c.nodes_spanned(7), 1);
    }

    #[test]
    fn no_double_booking_across_many_ops() {
        let mut c = ClusterState::new(ClusterSpec::new(4, 4));
        c.place(1, 3).unwrap();
        c.place(2, 5).unwrap();
        c.place(3, 2).unwrap();
        c.release(2).unwrap();
        c.place(4, 6).unwrap();
        // every busy slot owned by exactly one job
        let mut owned = std::collections::HashSet::new();
        for job in [1u64, 3, 4] {
            for g in c.allocation_of(job).unwrap() {
                assert!(owned.insert(*g), "double booked {g:?}");
            }
        }
        assert_eq!(owned.len(), c.used_gpus());
    }
}
