//! Content-addressed deduplicated checkpoint store — the §6 restart
//! substrate at fleet scale (ROADMAP item; content/snapshot split after
//! the `pwil3058__ergibus` design).
//!
//! Layout under a store root:
//!
//! ```text
//! root/
//!   chunks/<32-hex-fnv1a128>.chunk   # unique content, stored once
//!   snaps/<key>.snap                 # versioned snapshot envelope
//! ```
//!
//! A checkpoint is saved as fixed-size chunks of its theta‖mu payload.
//! Each chunk lands at its content address — identical content across
//! restarts of one job, across jobs, or within one payload hits disk
//! once — and the snapshot envelope (one version byte + a JSON manifest
//! of checkpoint metadata and chunk refs, unknown versions rejected)
//! is committed atomically via [`crate::fsx::atomic_write`]. A restart
//! whose payload barely changed therefore rewrites only the changed
//! chunks plus a few hundred bytes of manifest, instead of the full
//! n_params·8-byte file `Checkpoint::save` pays.
//!
//! Refcounts are *derived*, never persisted: the on-disk truth is the
//! set of snapshot manifests, and the in-memory map counts references
//! from live manifests. [`CkptStore::open`] rebuilds it by scanning
//! `snaps/` and garbage-collects orphan chunks left by a crash.
//!
//! Crash-safety argument (detail in DESIGN.md §16): chunks are written
//! and fsynced *before* the manifest that references them commits, and
//! the manifest commit is a single atomic+durable rename. So at every
//! instant the store holds, per key, either the previous complete
//! snapshot or the new one — never a manifest pointing at missing
//! content. The only crash residue is unreferenced chunks, which the
//! next `open` removes. `free` removes the manifest first, then
//! decrements; a crash between the two leaves orphans, same story.
//!
//! One store root belongs to one orchestration at a time: handles share
//! refcounts through `&self` locking, not through the filesystem.

pub mod chunk;

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::fsx;
use crate::jsonx::Json;
use crate::trainer::Checkpoint;
use crate::Result;

pub use chunk::{fnv1a_128, hash_hex, parse_hash_hex};

/// Snapshot envelope version byte (SNIPPETS.md snippet-1 style: the
/// first byte names the format; unknown versions are rejected loudly
/// instead of misread).
pub const SNAPSHOT_VERSION: u8 = 1;

/// Default payload chunk size. 64 KiB keeps manifests tiny (a 10M-param
/// payload is ~1200 refs) while still splitting fleet-preset payloads
/// into enough chunks that a localized weight delta dirties few of them.
pub const DEFAULT_CHUNK_BYTES: usize = 64 * 1024;

/// What one `save` actually cost.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SaveStats {
    /// Bytes that hit disk: new chunks + the manifest. The dedup win is
    /// this number vs the full file image `Checkpoint::save` writes.
    pub bytes_written: u64,
    /// Chunk refs in the new snapshot's manifest.
    pub chunks_total: usize,
    /// Chunks that were not already live in the store (actually written).
    pub chunks_new: usize,
}

#[derive(Default)]
struct Inner {
    /// Content address -> number of references from live manifests.
    /// An address is in this map iff its refcount is >= 1.
    refs: BTreeMap<u128, u64>,
    /// Key -> chunk refs of that key's current snapshot, manifest order.
    snaps: BTreeMap<String, Vec<u128>>,
}

/// A content-addressed checkpoint repository rooted at one directory.
pub struct CkptStore {
    root: PathBuf,
    chunk_bytes: usize,
    inner: Mutex<Inner>,
}

impl CkptStore {
    /// Open (creating if needed) the store at `root` with the default
    /// chunk size, rebuilding refcounts from the on-disk manifests and
    /// garbage-collecting any orphan chunks a crash left behind.
    pub fn open(root: impl AsRef<Path>) -> Result<CkptStore> {
        Self::open_with_chunk_bytes(root, DEFAULT_CHUNK_BYTES)
    }

    /// `open` with an explicit chunk size (tests use tiny chunks so a
    /// few floats span several chunks). The chunk size only shapes new
    /// saves; loading uses each manifest's own ref list.
    pub fn open_with_chunk_bytes(root: impl AsRef<Path>, chunk_bytes: usize) -> Result<CkptStore> {
        anyhow::ensure!(chunk_bytes >= 16, "chunk_bytes must be >= 16, got {chunk_bytes}");
        let store = CkptStore {
            root: root.as_ref().to_path_buf(),
            chunk_bytes,
            inner: Mutex::new(Inner::default()),
        };
        std::fs::create_dir_all(store.chunks_dir())
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", store.chunks_dir().display()))?;
        std::fs::create_dir_all(store.snaps_dir())
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", store.snaps_dir().display()))?;

        let mut inner = Inner::default();
        let mut snap_files: Vec<PathBuf> = Vec::new();
        for entry in std::fs::read_dir(store.snaps_dir())? {
            let p = entry?.path();
            if p.extension().and_then(|e| e.to_str()) == Some("snap") {
                snap_files.push(p);
            }
        }
        snap_files.sort();
        for f in &snap_files {
            let key = f
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| anyhow::anyhow!("unreadable snapshot name {}", f.display()))?
                .to_string();
            let env = std::fs::read(f)?;
            let (_meta, hashes) = decode_snapshot(&env)
                .map_err(|e| anyhow::anyhow!("snapshot {}: {e}", f.display()))?;
            for h in &hashes {
                // a manifest may only commit after its chunks are durable,
                // so a missing referenced chunk means real corruption
                anyhow::ensure!(
                    store.chunk_path(*h).exists(),
                    "snapshot {} references missing chunk {} (corrupt store)",
                    f.display(),
                    hash_hex(*h)
                );
                *inner.refs.entry(*h).or_insert(0) += 1;
            }
            inner.snaps.insert(key, hashes);
        }
        // GC crash residue: chunk files no live manifest references
        for entry in std::fs::read_dir(store.chunks_dir())? {
            let p = entry?.path();
            if p.extension().and_then(|e| e.to_str()) != Some("chunk") {
                continue;
            }
            let orphan = p
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(parse_hash_hex)
                .map(|h| !inner.refs.contains_key(&h))
                .unwrap_or(false);
            if orphan {
                let _ = std::fs::remove_file(&p);
            }
        }
        *store.lock()? = inner;
        Ok(store)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn chunks_dir(&self) -> PathBuf {
        self.root.join("chunks")
    }

    fn snaps_dir(&self) -> PathBuf {
        self.root.join("snaps")
    }

    fn chunk_path(&self, h: u128) -> PathBuf {
        self.chunks_dir().join(format!("{}.chunk", hash_hex(h)))
    }

    fn snap_path(&self, key: &str) -> PathBuf {
        self.snaps_dir().join(format!("{key}.snap"))
    }

    fn lock(&self) -> Result<std::sync::MutexGuard<'_, Inner>> {
        self.inner
            .lock()
            .map_err(|_| anyhow::anyhow!("checkpoint store lock poisoned"))
    }

    /// Persist `ck` as the snapshot for `key`, replacing any previous
    /// snapshot under that key. Only chunks not already live in the
    /// store touch disk; chunks the replaced snapshot no longer needs
    /// are garbage-collected. The manifest write is the commit point.
    pub fn save(&self, key: &str, ck: &Checkpoint) -> Result<SaveStats> {
        check_key(key)?;
        let payload = ck.payload_bytes();
        let hashes: Vec<u128> = payload.chunks(self.chunk_bytes).map(fnv1a_128).collect();

        let mut inner = self.lock()?;
        // pass 1: write content that is not already live (a failure here
        // leaves only unreferenced chunks — open() residue, no refs moved)
        let mut bytes_written = 0u64;
        let mut chunks_new = 0usize;
        let mut written: std::collections::BTreeSet<u128> = std::collections::BTreeSet::new();
        for (h, c) in hashes.iter().zip(payload.chunks(self.chunk_bytes)) {
            if inner.refs.contains_key(h) || written.contains(h) {
                continue;
            }
            write_chunk(&self.chunk_path(*h), c)?;
            written.insert(*h);
            bytes_written += c.len() as u64;
            chunks_new += 1;
        }
        if chunks_new > 0 {
            fsx::fsync_dir(&self.chunks_dir())?;
        }
        // pass 2, the commit point: atomically replace the manifest
        let env = encode_snapshot(ck, self.chunk_bytes, &hashes);
        bytes_written += fsx::atomic_write(self.snap_path(key), &env)?;
        // pass 3: flip refcounts — increment the new snapshot first so a
        // chunk shared with the replaced one never transits through zero
        for h in &hashes {
            *inner.refs.entry(*h).or_insert(0) += 1;
        }
        if let Some(old) = inner.snaps.insert(key.to_string(), hashes.clone()) {
            self.release(&mut inner, &old);
        }
        Ok(SaveStats { bytes_written, chunks_total: hashes.len(), chunks_new })
    }

    /// Load the current snapshot for `key`, re-hashing every chunk so
    /// corruption (or an FNV collision) fails loudly here instead of
    /// silently restoring the wrong weights.
    pub fn load(&self, key: &str) -> Result<Checkpoint> {
        check_key(key)?;
        // hold the lock so a concurrent free/GC can't remove chunk files
        // out from under the read
        let _inner = self.lock()?;
        let snap = self.snap_path(key);
        let env = std::fs::read(&snap)
            .map_err(|e| anyhow::anyhow!("reading snapshot {}: {e}", snap.display()))?;
        let (meta, hashes) = decode_snapshot(&env)
            .map_err(|e| anyhow::anyhow!("snapshot {}: {e}", snap.display()))?;
        let n = meta.get("n_params")?.as_usize()?;
        let mut payload = Vec::with_capacity(n.saturating_mul(8));
        for h in &hashes {
            let p = self.chunk_path(*h);
            let c = std::fs::read(&p)
                .map_err(|e| anyhow::anyhow!("reading chunk {}: {e}", p.display()))?;
            anyhow::ensure!(
                fnv1a_128(&c) == *h,
                "chunk {} content does not match its address (corrupt store)",
                hash_hex(*h)
            );
            payload.extend_from_slice(&c);
        }
        let (theta, mu) = Checkpoint::split_payload(&payload, n)?;
        Checkpoint::from_meta_json(&meta, theta, mu)
    }

    /// Whether `key` has a live snapshot.
    pub fn contains(&self, key: &str) -> bool {
        self.lock().map(|i| i.snaps.contains_key(key)).unwrap_or(false)
    }

    /// Drop `key`'s snapshot and garbage-collect chunks nothing else
    /// references. Returns whether the key existed; freeing an absent
    /// key is an idempotent no-op.
    pub fn free(&self, key: &str) -> Result<bool> {
        check_key(key)?;
        let mut inner = self.lock()?;
        let Some(hashes) = inner.snaps.remove(key) else {
            return Ok(false);
        };
        let snap = self.snap_path(key);
        if let Err(e) = std::fs::remove_file(&snap) {
            // put the snapshot back so memory still mirrors disk
            inner.snaps.insert(key.to_string(), hashes);
            anyhow::bail!("removing snapshot {}: {e}", snap.display());
        }
        self.release(&mut inner, &hashes);
        Ok(true)
    }

    /// Decrement refs for one retired manifest and delete chunks that
    /// hit zero. Deletion is best-effort: a chunk that cannot be removed
    /// is exactly the orphan residue `open` already cleans.
    fn release(&self, inner: &mut Inner, hashes: &[u128]) {
        for h in hashes {
            let gone = match inner.refs.get_mut(h) {
                Some(r) if *r > 1 => {
                    *r -= 1;
                    false
                }
                _ => {
                    inner.refs.remove(h);
                    true
                }
            };
            if gone {
                let _ = std::fs::remove_file(self.chunk_path(*h));
            }
        }
    }

    /// Live unique chunks.
    pub fn chunk_count(&self) -> usize {
        self.lock().map(|i| i.refs.len()).unwrap_or(0)
    }

    /// Live snapshots.
    pub fn snapshot_count(&self) -> usize {
        self.lock().map(|i| i.snaps.len()).unwrap_or(0)
    }

    /// Sum of all refcounts == sum of manifest lengths over live
    /// snapshots (the conservation law the property tests pin down).
    pub fn total_refs(&self) -> u64 {
        self.lock().map(|i| i.refs.values().sum()).unwrap_or(0)
    }

    /// If the store is fully drained (no snapshots, no chunks), remove
    /// its directories. Returns whether the root itself was removed;
    /// a root holding unrelated user files is left in place.
    pub fn remove_if_empty(&self) -> Result<bool> {
        let inner = self.lock()?;
        if !inner.snaps.is_empty() || !inner.refs.is_empty() {
            return Ok(false);
        }
        drop(inner);
        let _ = std::fs::remove_dir(self.chunks_dir());
        let _ = std::fs::remove_dir(self.snaps_dir());
        Ok(std::fs::remove_dir(&self.root).is_ok())
    }
}

/// Snapshot keys become file stems; keep them to a portable charset.
fn check_key(key: &str) -> Result<()> {
    anyhow::ensure!(
        !key.is_empty()
            && key.len() <= 128
            && !key.starts_with('.')
            && key.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
        "bad store key {key:?}: want 1-128 chars of [A-Za-z0-9._-], not starting with '.'"
    );
    Ok(())
}

/// Write one chunk at its final content address, fsynced. No tmp+rename
/// needed: nothing references the address until a manifest commits, so
/// a torn write here is unreferenced residue that `open` removes.
fn write_chunk(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("creating chunk {}: {e}", path.display()))?;
    f.write_all(bytes)?;
    f.flush()?;
    f.sync_all()?;
    Ok(())
}

/// Envelope: `[SNAPSHOT_VERSION]` + compact JSON manifest (checkpoint
/// metadata + chunk size + content addresses, keys sorted by jsonx).
fn encode_snapshot(ck: &Checkpoint, chunk_bytes: usize, hashes: &[u128]) -> Vec<u8> {
    let manifest = Json::obj(vec![
        ("preset", Json::str(ck.preset.clone())),
        ("step", Json::num(ck.step as f64)),
        ("epochs", Json::num(ck.epochs)),
        ("workers", Json::num(ck.workers as f64)),
        ("lr", Json::num(ck.lr as f64)),
        ("n_params", Json::num(ck.theta.len() as f64)),
        ("chunk_bytes", Json::num(chunk_bytes as f64)),
        (
            "chunks",
            Json::arr(hashes.iter().map(|h| Json::str(hash_hex(*h))).collect()),
        ),
    ])
    .dump();
    let mut env = Vec::with_capacity(1 + manifest.len());
    env.push(SNAPSHOT_VERSION);
    env.extend_from_slice(manifest.as_bytes());
    env
}

fn decode_snapshot(env: &[u8]) -> Result<(Json, Vec<u128>)> {
    anyhow::ensure!(!env.is_empty(), "empty snapshot envelope");
    let version = env[0];
    anyhow::ensure!(
        version == SNAPSHOT_VERSION,
        "unsupported snapshot envelope version {version} (this build reads {SNAPSHOT_VERSION})"
    );
    let meta = crate::jsonx::parse(std::str::from_utf8(&env[1..])?)?;
    let hashes = meta
        .get("chunks")?
        .as_arr()?
        .iter()
        .map(|j| {
            let s = j.as_str()?;
            parse_hash_hex(s)
                .ok_or_else(|| anyhow::anyhow!("bad chunk address {s:?} in snapshot manifest"))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((meta, hashes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ck(step: u64, fill: impl Fn(usize) -> f32, n: usize) -> Checkpoint {
        Checkpoint {
            preset: "tiny".into(),
            step,
            epochs: 0.5,
            workers: 2,
            lr: 0.25,
            theta: (0..n).map(&fill).collect(),
            mu: (0..n).map(|i| fill(i) * -0.5).collect(),
        }
    }

    fn tmproot(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("rm-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn disk_chunks(store: &CkptStore) -> usize {
        std::fs::read_dir(store.root().join("chunks")).map(|d| d.count()).unwrap_or(0)
    }

    #[test]
    fn save_load_round_trips_exactly() {
        let root = tmproot("rt");
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        let a = ck(7, |i| i as f32 * 0.125, 100);
        let stats = store.save("job-1", &a).unwrap();
        assert_eq!(stats.chunks_total, (100 * 8 + 63) / 64);
        assert_eq!(stats.chunks_new, stats.chunks_total);
        assert_eq!(store.load("job-1").unwrap(), a);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn identical_content_under_two_keys_is_stored_once() {
        let root = tmproot("dedup");
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        let a = ck(7, |i| i as f32, 64);
        let first = store.save("job-1", &a).unwrap();
        let second = store.save("job-2", &a).unwrap();
        assert_eq!(second.chunks_new, 0, "shared content must not be rewritten");
        assert!(second.bytes_written < first.bytes_written);
        assert_eq!(store.chunk_count(), first.chunks_total);
        assert_eq!(disk_chunks(&store), first.chunks_total);
        assert_eq!(store.total_refs() as usize, 2 * first.chunks_total);
        assert_eq!(store.load("job-2").unwrap(), a);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn resave_of_unchanged_content_writes_only_the_manifest() {
        let root = tmproot("resave");
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        let a = ck(7, |i| i as f32, 512);
        store.save("job-1", &a).unwrap();
        let again = store.save("job-1", &a).unwrap();
        assert_eq!(again.chunks_new, 0);
        // the whole cost of a width-only rescale restart: the manifest
        assert!(
            again.bytes_written < a.payload_bytes().len() as u64 / 2,
            "manifest-only rewrite wrote {} bytes",
            again.bytes_written
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn replacing_a_snapshot_gcs_chunks_it_no_longer_needs() {
        let root = tmproot("replace");
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        store.save("job-1", &ck(1, |i| i as f32, 64)).unwrap();
        let b = ck(2, |i| (i + 9999) as f32, 64);
        let stats = store.save("job-1", &b).unwrap();
        assert_eq!(store.load("job-1").unwrap(), b);
        assert_eq!(store.chunk_count(), stats.chunks_total, "old chunks must be GC'd");
        assert_eq!(disk_chunks(&store), stats.chunks_total);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn free_drains_and_remove_if_empty_removes_the_root() {
        let root = tmproot("drain");
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        store.save("job-1", &ck(1, |i| i as f32, 64)).unwrap();
        store.save("job-2", &ck(2, |i| i as f32 + 0.5, 64)).unwrap();
        assert!(!store.remove_if_empty().unwrap(), "non-empty store must survive");
        assert!(store.free("job-1").unwrap());
        assert!(!store.free("job-1").unwrap(), "double free is a no-op");
        assert!(store.free("job-2").unwrap());
        assert_eq!((store.chunk_count(), store.snapshot_count(), store.total_refs()), (0, 0, 0));
        assert_eq!(disk_chunks(&store), 0);
        assert!(store.remove_if_empty().unwrap());
        assert!(!root.exists());
    }

    #[test]
    fn reopen_rebuilds_refcounts_and_gcs_orphans() {
        let root = tmproot("reopen");
        let a = ck(7, |i| i as f32, 64);
        {
            let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
            store.save("job-1", &a).unwrap();
            store.save("job-2", &a).unwrap();
            // crash residue: a chunk no manifest references
            std::fs::write(root.join("chunks").join(format!("{}.chunk", hash_hex(12345))), b"orphan")
                .unwrap();
        }
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        assert_eq!(store.snapshot_count(), 2);
        assert_eq!(store.total_refs() as usize, 2 * store.chunk_count());
        assert_eq!(disk_chunks(&store), store.chunk_count(), "orphan must be GC'd at open");
        assert_eq!(store.load("job-1").unwrap(), a);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn open_rejects_manifest_referencing_missing_chunk() {
        let root = tmproot("missing");
        {
            let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
            store.save("job-1", &ck(1, |i| i as f32, 64)).unwrap();
        }
        // violate the commit ordering by hand
        let chunks_dir = root.join("chunks");
        for e in std::fs::read_dir(&chunks_dir).unwrap() {
            std::fs::remove_file(e.unwrap().path()).unwrap();
        }
        let err = CkptStore::open_with_chunk_bytes(&root, 64).unwrap_err().to_string();
        assert!(err.contains("missing chunk"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rejects_bad_keys() {
        let root = tmproot("keys");
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        for bad in ["", "../evil", "a/b", ".hidden", "sp ace"] {
            assert!(store.save(bad, &ck(1, |i| i as f32, 16)).is_err(), "accepted {bad:?}");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn empty_payload_is_representable() {
        let root = tmproot("empty");
        let store = CkptStore::open_with_chunk_bytes(&root, 64).unwrap();
        let a = ck(1, |i| i as f32, 0);
        let stats = store.save("job-1", &a).unwrap();
        assert_eq!((stats.chunks_total, stats.chunks_new), (0, 0));
        assert_eq!(store.load("job-1").unwrap(), a);
        let _ = std::fs::remove_dir_all(&root);
    }
}
