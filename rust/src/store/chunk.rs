//! Content addressing for the checkpoint store: fixed-size chunking plus
//! a self-contained 128-bit FNV-1a hash (no external deps — the repo
//! rule is that everything builds from std + the vendored shims).
//!
//! FNV-1a is not cryptographic; the store uses it purely as a content
//! address for dedup, and `CkptStore::load` re-hashes every chunk it
//! reads, so a corrupted or colliding chunk surfaces as a loud error at
//! restore time rather than silently restoring the wrong weights. At
//! 128 bits, accidental collisions across a fleet-scale store (millions
//! of chunks) are vanishingly unlikely.

/// 128-bit FNV-1a offset basis.
const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// 128-bit FNV prime: 2^88 + 2^8 + 0x3b.
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

/// Hash `bytes` with 128-bit FNV-1a — the store's content address.
pub fn fnv1a_128(bytes: &[u8]) -> u128 {
    let mut h = FNV128_OFFSET;
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

/// Render a content address as the 32-hex-char chunk file stem.
pub fn hash_hex(h: u128) -> String {
    format!("{h:032x}")
}

/// Parse a 32-hex-char chunk file stem back into a content address.
pub fn parse_hash_hex(s: &str) -> Option<u128> {
    if s.len() != 32 {
        return None;
    }
    u128::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_hashes_to_the_offset_basis() {
        assert_eq!(fnv1a_128(b""), FNV128_OFFSET);
    }

    #[test]
    fn known_vectors_match_the_reference_implementation() {
        // cross-checked against python/tools/gen_store_fixture.py, which
        // reimplements the same constants for fixture generation
        assert_eq!(hash_hex(fnv1a_128(b"a")), "d228cb696f1a8caf78912b704e4a8964");
        assert_eq!(hash_hex(fnv1a_128(b"foobar")), "343e1662793c64bf6f0d3597ba446f18");
    }

    #[test]
    fn nearby_inputs_diverge() {
        assert_ne!(fnv1a_128(b"chunk-0"), fnv1a_128(b"chunk-1"));
        assert_ne!(fnv1a_128(&[0u8; 64]), fnv1a_128(&[0u8; 65]));
    }

    #[test]
    fn hex_round_trips() {
        for payload in [&b""[..], b"a", b"ringmaster", &[0xff; 100]] {
            let h = fnv1a_128(payload);
            assert_eq!(parse_hash_hex(&hash_hex(h)), Some(h));
        }
        assert_eq!(parse_hash_hex("not-hex"), None);
        assert_eq!(parse_hash_hex("abc"), None);
    }
}
