//! Job registry substrate: specs, the lifecycle state machine, and the
//! per-job accounting the orchestrator keeps while jobs move through it.
//!
//! States follow the paper's operational story: a job is submitted
//! (`Pending` until its arrival time), waits for workers (`Queued`),
//! trains a segment on real worker threads (`Running`), is stopped at a
//! segment boundary holding a checkpoint (`Preempted`), and eventually
//! completes (`Done`). Every transition is validated — an illegal edge is
//! an orchestrator bug, not a recoverable condition, so it surfaces as an
//! error immediately.

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use super::executor::SegmentOutcome;
use crate::perfmodel::placement::PAPER_MODEL_BYTES;
use crate::sim::workload::JobProfile;
use crate::trainer::Checkpoint;
use crate::Result;

/// What the orchestrator is told about one submitted job — one row of a
/// JSONL trace, or one draw from the workload generator. The profile's
/// `epoch_secs` table is the precompute-strategy assumption of §4: the
/// resource-to-speed model is known at submission.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub id: u64,
    /// Arrival time, speed table, and epochs-to-converge.
    pub profile: JobProfile,
    /// Hard cap on workers for this job (paper: 8).
    pub max_w: usize,
    /// Gradient payload per all-reduce (bytes) — sizes the eq-2
    /// inter-node penalty when this job's ring spans nodes (trace schema
    /// v2; defaults to the paper's ResNet-110).
    pub model_bytes: f64,
}

impl JobSpec {
    pub fn from_profile(id: u64, profile: JobProfile, max_w: usize) -> JobSpec {
        JobSpec { id, profile, max_w, model_bytes: PAPER_MODEL_BYTES }
    }
}

/// Lifecycle of one job inside the orchestrator.
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    /// Submitted but the virtual clock is before its arrival time.
    Pending,
    /// Arrived and waiting for its first allocation.
    Queued,
    /// A training segment is in flight on real worker threads.
    Running { workers: usize },
    /// Stopped at a segment boundary (checkpoint held), awaiting workers.
    Preempted,
    /// A segment died (injected fault or a real runner failure); the job
    /// sits out its recovery backoff until the queued `Retry` event
    /// fires, then resumes from its last durable checkpoint.
    Recovering,
    /// Finished; `finish` is the virtual completion time.
    Done { finish: f64 },
    /// Gave up after exhausting the fault plan's retry budget; `at` is
    /// the virtual give-up instant. Terminal, like `Done`.
    Failed { at: f64 },
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Pending => "pending",
            JobState::Queued => "queued",
            JobState::Running { .. } => "running",
            JobState::Preempted => "preempted",
            JobState::Recovering => "recovering",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
        }
    }
}

/// Virtual-clock bookkeeping of the in-flight segment — everything the
/// orchestrator needs to preempt it mid-flight and stay deterministic.
#[derive(Clone, Debug)]
pub struct SegmentMeta {
    /// Virtual end (the queued SegmentEnd event; moves earlier on
    /// preemption — an event not matching this is stale and ignored).
    pub end: f64,
    /// Virtual launch instant.
    pub start: f64,
    /// §6 charge paid at the head of this segment (0 for continuations).
    pub restart_pay: f64,
    /// Virtual seconds per training step at this width and placement.
    pub step_secs: f64,
    pub planned_steps: u64,
    pub epochs_per_step: f64,
    /// Progress counters at launch (the base preempted credit adds to).
    pub launch_epochs: f64,
    pub launch_steps: u64,
    /// Shared stop flag the real trainer polls each step (present only
    /// when mid-segment preemption is on).
    pub stop: Option<Arc<AtomicBool>>,
    /// Set on preemption: whole steps credited on the virtual clock.
    pub preempted_steps: Option<u64>,
    /// Virtual instant of this segment's queued `BudgetCheck` (None
    /// when the segment fits its budget); a check event not matching
    /// this is stale and ignored.
    pub budget_deadline: Option<f64>,
    /// Drawn at launch from the job's fault clock (`--faults` only):
    /// this segment dies at its virtual end instead of committing its
    /// progress. Always false on the default path.
    pub fail_injected: bool,
}

/// One registered job: spec, lifecycle state, the in-memory checkpoint
/// between segments, and metric accumulators.
pub struct Job {
    pub spec: JobSpec,
    /// `(w, 1/epoch_secs)` scheduler table, built once at registration
    /// and `Arc`-shared into every reallocation's `JobInfo` — the
    /// per-event `speed_table()` clone was the orchestrator's hottest
    /// allocation (one Vec per schedulable job per event).
    pub speed_shared: Arc<Vec<(usize, f64)>>,
    pub state: JobState,
    /// Worker count of the most recently finished segment (0 = never ran).
    pub last_w: usize,
    /// Node set of the most recently finished segment's ring; a
    /// continuation must resume on the same nodes, not just the same
    /// width (restarts may change placement, not just width).
    pub last_nodes: Vec<usize>,
    /// Exact GPUs of that ring — the affinity a continuation reclaims.
    pub last_gpus: Vec<crate::cluster::Gpu>,
    /// Bookkeeping of the in-flight segment (None between segments).
    pub segment: Option<SegmentMeta>,
    /// Cumulative training progress (trainer accounting: steps·batch·w/M).
    pub epochs_done: f64,
    pub steps_done: u64,
    /// Checkpoint held between segments (rank 0 state).
    pub checkpoint: Option<Checkpoint>,
    /// Receiver for the in-flight segment's outcome.
    pub inflight: Option<Receiver<Result<SegmentOutcome>>>,
    /// Virtual time of the most recent segment end; a relaunch at the
    /// same width at exactly this instant is a continuation (the job was
    /// never stopped), anything else is a real stop→restart.
    pub boundary_time: Option<f64>,
    /// Whether the in-flight segment took the restart path (its measured
    /// startup counts as restart overhead; continuations' startup is an
    /// artifact of segment-wise execution and is excluded).
    pub last_segment_restarted: bool,
    /// Online eq-1/eq-5 learner (`--online-model` only): accumulates
    /// this job's finished-segment observations and serves the
    /// confidence-gated fit the scheduler consumes.
    pub online: Option<crate::perfmodel::OnlineModel>,
    /// Last durable checkpoint — the rank-0 state as of the most recent
    /// *successful* segment boundary, the state a failed segment rolls
    /// back to. Kept only while a fault plan is active (`None` on the
    /// default path, which never rolls back).
    pub recovery_ckpt: Option<Checkpoint>,
    /// Seeded per-job fault clock (`--faults` only): one draw per
    /// segment launch decides whether that segment dies. Per-job streams
    /// make each job's fate independent of how other jobs' launches
    /// interleave.
    pub fault_rng: Option<crate::rngx::Rng>,
    /// Consecutive failed segments since the last successful boundary;
    /// exceeding the plan's `max_retries` marks the job `Failed`.
    pub fail_attempts: u32,
    // ---- metrics ----
    pub first_start: Option<f64>,
    pub segments: u64,
    /// Segments lost to faults (injected or real runner death) over the
    /// job's whole lifetime — rework, not the consecutive-retry counter.
    pub failures: u64,
    /// Cold starts + worker-count changes (each pays the restart cost).
    pub restarts: u64,
    /// Virtual seconds charged for restarts.
    pub virtual_restart_secs: f64,
    /// Measured seconds: checkpoint disk round-trips + engine startup.
    pub measured_restart_secs: f64,
    /// Measured wall seconds spent inside `trainer::train`.
    pub measured_train_secs: f64,
    /// Measured seconds of all checkpoint I/O: restart round trips plus,
    /// in store mode, boundary park-saves and the completion free.
    pub ckpt_io_secs: f64,
    /// Measured checkpoint bytes written (round trips + store parks).
    pub ckpt_bytes_written: u64,
    /// Bytes written by restart round trips only — the apples-to-apples
    /// whole-file-vs-store dedup metric.
    pub restart_ckpt_bytes: u64,
    pub final_loss: Option<f32>,
    pub max_w_granted: usize,
    /// Widest node span any of this job's segments ever had.
    pub max_nodes_spanned: usize,
    /// Segments whose ring crossed a node boundary.
    pub cross_node_segments: u64,
    /// Model-vs-truth RMSE (secs/epoch over the trace table's widths)
    /// the first time the confidence gate was open, and the latest —
    /// the learned-vs-oracle gap and how it moved as segments accrued.
    pub model_rmse_first: Option<f64>,
    pub model_rmse_last: Option<f64>,
    /// Completed segments when the confidence gate first opened (None =
    /// the scheduler only ever saw the trace-table prior).
    pub learned_after_segments: Option<u64>,
}

impl Job {
    pub fn new(spec: JobSpec) -> Job {
        let speed_shared = Arc::new(spec.profile.speed_table());
        Job {
            spec,
            speed_shared,
            state: JobState::Pending,
            last_w: 0,
            last_nodes: Vec::new(),
            last_gpus: Vec::new(),
            segment: None,
            epochs_done: 0.0,
            steps_done: 0,
            checkpoint: None,
            inflight: None,
            boundary_time: None,
            last_segment_restarted: false,
            online: None,
            recovery_ckpt: None,
            fault_rng: None,
            fail_attempts: 0,
            first_start: None,
            segments: 0,
            failures: 0,
            restarts: 0,
            virtual_restart_secs: 0.0,
            measured_restart_secs: 0.0,
            measured_train_secs: 0.0,
            ckpt_io_secs: 0.0,
            ckpt_bytes_written: 0,
            restart_ckpt_bytes: 0,
            final_loss: None,
            max_w_granted: 0,
            max_nodes_spanned: 0,
            cross_node_segments: 0,
            model_rmse_first: None,
            model_rmse_last: None,
            learned_after_segments: None,
        }
    }

    /// Epochs left until this job's convergence target.
    pub fn remaining_epochs(&self) -> f64 {
        (self.spec.profile.total_epochs - self.epochs_done).max(0.0)
    }

    /// True for states the scheduler may hand workers to.
    pub fn is_schedulable(&self) -> bool {
        matches!(self.state, JobState::Queued | JobState::Preempted)
    }

    /// Validated state-machine edge. Legal edges:
    /// `Pending→Queued`, `Queued→Running`, `Preempted→Running`,
    /// `Running→Preempted`, `Running→Done`, plus the recovery cycle
    /// `Running→Recovering→{Queued, Preempted, Failed}` (back to
    /// `Queued` when no durable checkpoint exists — the retry is a cold
    /// start — `Preempted` when one does, `Failed` at give-up).
    pub fn transition(&mut self, to: JobState) -> Result<()> {
        let legal = matches!(
            (&self.state, &to),
            (JobState::Pending, JobState::Queued)
                | (JobState::Queued, JobState::Running { .. })
                | (JobState::Preempted, JobState::Running { .. })
                | (JobState::Running { .. }, JobState::Preempted)
                | (JobState::Running { .. }, JobState::Done { .. })
                | (JobState::Running { .. }, JobState::Recovering)
                | (JobState::Recovering, JobState::Queued)
                | (JobState::Recovering, JobState::Preempted)
                | (JobState::Recovering, JobState::Failed { .. })
        );
        anyhow::ensure!(
            legal,
            "job {}: illegal lifecycle transition {} -> {}",
            self.spec.id,
            self.state.name(),
            to.name()
        );
        self.state = to;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: u64) -> JobSpec {
        JobSpec::from_profile(
            id,
            JobProfile {
                arrival: 0.0,
                epoch_secs: vec![(1, 138.0), (2, 81.9), (4, 47.3), (8, 29.6)],
                total_epochs: 2.0,
            },
            8,
        )
    }

    #[test]
    fn full_lifecycle_is_legal() {
        let mut j = Job::new(spec(1));
        assert_eq!(j.state, JobState::Pending);
        j.transition(JobState::Queued).unwrap();
        assert!(j.is_schedulable());
        j.transition(JobState::Running { workers: 2 }).unwrap();
        assert!(!j.is_schedulable());
        j.transition(JobState::Preempted).unwrap();
        assert!(j.is_schedulable());
        j.transition(JobState::Running { workers: 4 }).unwrap();
        j.transition(JobState::Done { finish: 10.0 }).unwrap();
    }

    #[test]
    fn illegal_edges_error() {
        let mut j = Job::new(spec(1));
        assert!(j.transition(JobState::Running { workers: 1 }).is_err());
        assert!(j.transition(JobState::Done { finish: 0.0 }).is_err());
        j.transition(JobState::Queued).unwrap();
        assert!(j.transition(JobState::Preempted).is_err());
        j.transition(JobState::Running { workers: 1 }).unwrap();
        assert!(j.transition(JobState::Queued).is_err());
        j.transition(JobState::Done { finish: 1.0 }).unwrap();
        assert!(j.transition(JobState::Running { workers: 1 }).is_err());
    }

    #[test]
    fn recovery_cycle_is_legal_and_failed_is_terminal() {
        // fail -> backoff -> resume-from-checkpoint -> fail -> give up
        let mut j = Job::new(spec(2));
        j.transition(JobState::Queued).unwrap();
        j.transition(JobState::Running { workers: 2 }).unwrap();
        j.transition(JobState::Recovering).unwrap();
        assert!(!j.is_schedulable(), "recovering jobs must sit out the backoff");
        j.transition(JobState::Preempted).unwrap();
        j.transition(JobState::Running { workers: 2 }).unwrap();
        j.transition(JobState::Recovering).unwrap();
        j.transition(JobState::Failed { at: 99.0 }).unwrap();
        assert!(!j.is_schedulable());
        assert!(j.transition(JobState::Queued).is_err());
        assert!(j.transition(JobState::Running { workers: 1 }).is_err());

        // cold-start retry: no checkpoint -> back to Queued
        let mut c = Job::new(spec(3));
        c.transition(JobState::Queued).unwrap();
        c.transition(JobState::Running { workers: 1 }).unwrap();
        c.transition(JobState::Recovering).unwrap();
        c.transition(JobState::Queued).unwrap();
        assert!(c.is_schedulable());
    }

    #[test]
    fn remaining_epochs_clamps_at_zero() {
        let mut j = Job::new(spec(1));
        assert!((j.remaining_epochs() - 2.0).abs() < 1e-12);
        j.epochs_done = 1.5;
        assert!((j.remaining_epochs() - 0.5).abs() < 1e-12);
        j.epochs_done = 2.5; // overshoot from discrete steps
        assert_eq!(j.remaining_epochs(), 0.0);
    }
}
