//! Segment execution: one real `trainer::train` call per segment, run on
//! a detached runner thread so many jobs' segments train concurrently
//! while the event loop stays single-threaded and deterministic.
//!
//! Reallocation boundaries take the paper's stop→checkpoint→restart path
//! for real: the checkpoint is round-tripped through disk (atomic save +
//! load) before the trainer restarts at the new worker count, and eq 7's
//! LR rescaling happens structurally inside the trainer (`base·w`
//! schedule). Same-width boundaries resume from the in-memory checkpoint
//! — the job was not stopped, only observed.
//!
//! With `--ckpt-store` the round trip goes through the content-addressed
//! store instead of a throwaway temp file: the orchestrator parks every
//! job's checkpoint in the store at each segment end, so the restart's
//! save dedups against the parked snapshot and pays only the manifest
//! rewrite plus whatever chunks actually changed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{checkpoint_roundtrip, checkpoint_roundtrip_store};
use crate::store::CkptStore;
use crate::trainer::{train, Checkpoint, TrainConfig};
use crate::Result;

/// The store snapshot key for a job — shared by the executor's restart
/// round trip and the orchestrator's park/free at segment boundaries.
pub fn store_key(job: u64) -> String {
    format!("job-{job}")
}

/// Everything a runner thread needs to execute one training segment.
pub struct SegmentPlan {
    pub job: u64,
    pub workers: usize,
    /// Nodes the gang's ring spans (placement record; 1 on flat pools).
    pub nodes: usize,
    pub steps: u64,
    /// Checkpoint to resume from (None = cold start).
    pub resume: Option<Checkpoint>,
    /// Round-trip the checkpoint through disk before training — the
    /// stop→restart path, taken when the worker count changed.
    pub restart_from_disk: bool,
    /// Content-addressed store for the round trip (None = whole-file
    /// temp path, the default).
    pub store: Option<Arc<CkptStore>>,
    /// Trainer config with `workers` (and, under mid-segment preemption,
    /// the shared stop flag) already set for this segment.
    pub config: TrainConfig,
}

/// What a finished segment reports back to the event loop.
pub struct SegmentOutcome {
    pub job: u64,
    pub workers: usize,
    /// Nodes the segment's ring spanned (echoed from the plan).
    pub nodes: usize,
    /// Steps actually executed (≤ planned when the stop flag fired).
    pub steps: u64,
    /// Rank 0 state after the segment (cumulative step/epoch counters).
    pub checkpoint: Checkpoint,
    pub final_loss: Option<f32>,
    /// Measured wall seconds of the `train` call.
    pub train_secs: f64,
    /// Measured engine client+compile seconds (max across workers).
    pub startup_secs: f64,
    /// Measured checkpoint save+load seconds (0 unless restarted).
    pub ckpt_io_secs: f64,
    /// Measured checkpoint bytes written by the restart round trip
    /// (0 unless restarted; with a store, only the deduped delta).
    pub ckpt_bytes_written: u64,
    /// Measured mean wall seconds per optimizer step (trainer report).
    pub mean_step_secs: f64,
    /// Measured mean wall seconds per all-reduce (trainer report).
    pub mean_allreduce_secs: f64,
}

/// Launch the segment on a detached thread. The returned receiver yields
/// exactly one message when the segment's real training completes; the
/// event loop joins it when the segment's *virtual* end event fires.
pub fn spawn_segment(plan: SegmentPlan) -> Receiver<Result<SegmentOutcome>> {
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        let _ = tx.send(run_segment(plan));
    });
    rx
}

fn run_segment(plan: SegmentPlan) -> Result<SegmentOutcome> {
    let SegmentPlan { job, workers, nodes, steps, resume, restart_from_disk, store, config } =
        plan;
    anyhow::ensure!(config.workers == workers, "segment plan worker mismatch");

    // Process-unique nonce: concurrent orchestrations in one process
    // (e.g. parallel tests) must never share a round-trip path.
    static NONCE: AtomicU64 = AtomicU64::new(0);

    let mut ckpt_io_secs = 0.0;
    let mut ckpt_bytes_written = 0u64;
    let resume = match resume {
        Some(ck) if restart_from_disk => {
            let (loaded, io_secs, bytes) = match &store {
                Some(store) => checkpoint_roundtrip_store(&ck, store, &store_key(job))?,
                None => {
                    let path = std::env::temp_dir().join(format!(
                        "ringmaster-orch-{}-{}-job{job}.ckpt",
                        std::process::id(),
                        NONCE.fetch_add(1, Ordering::Relaxed)
                    ));
                    checkpoint_roundtrip(&ck, &path)?
                }
            };
            ckpt_io_secs = io_secs;
            ckpt_bytes_written = bytes;
            Some(loaded)
        }
        other => other,
    };

    let t = Instant::now();
    let (checkpoint, report) = train(&config, resume, steps)?;
    Ok(SegmentOutcome {
        job,
        workers,
        nodes,
        steps: report.steps,
        checkpoint,
        final_loss: report.logs.last().map(|l| l.loss),
        train_secs: t.elapsed().as_secs_f64(),
        startup_secs: report.startup_secs,
        ckpt_io_secs,
        ckpt_bytes_written,
        mean_step_secs: report.mean_step_secs,
        mean_allreduce_secs: report.mean_allreduce_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(workers: usize) -> TrainConfig {
        let mut c = TrainConfig::new(
            env!("CARGO_MANIFEST_DIR").to_string() + "/../artifacts",
            "tiny",
            workers,
        );
        c.dataset_examples = 128;
        c.log_every = u64::MAX;
        c
    }

    #[test]
    fn runs_a_cold_segment_and_reports() {
        let rx = spawn_segment(SegmentPlan {
            job: 7,
            workers: 1,
            nodes: 1,
            steps: 4,
            resume: None,
            restart_from_disk: false,
            store: None,
            config: cfg(1),
        });
        let out = rx.recv().expect("runner alive").expect("segment ok");
        assert_eq!(out.job, 7);
        assert_eq!(out.steps, 4);
        assert_eq!(out.checkpoint.step, 4);
        assert!(out.checkpoint.epochs > 0.0);
        assert!(out.final_loss.is_some());
        assert_eq!(out.ckpt_io_secs, 0.0);
        assert_eq!(out.ckpt_bytes_written, 0);
    }

    #[test]
    fn rescale_segment_roundtrips_checkpoint_through_disk() {
        let rx = spawn_segment(SegmentPlan {
            job: 8,
            workers: 1,
            nodes: 1,
            steps: 3,
            resume: None,
            restart_from_disk: false,
            store: None,
            config: cfg(1),
        });
        let first = rx.recv().unwrap().unwrap();
        let rx = spawn_segment(SegmentPlan {
            job: 8,
            workers: 2,
            nodes: 1,
            steps: 3,
            resume: Some(first.checkpoint.clone()),
            restart_from_disk: true,
            store: None,
            config: cfg(2),
        });
        let second = rx.recv().unwrap().unwrap();
        assert_eq!(second.checkpoint.step, 6);
        assert!(second.ckpt_io_secs > 0.0, "disk round trip not measured");
        assert!(second.ckpt_bytes_written > 0, "round-trip bytes not measured");
        assert_eq!(second.checkpoint.workers, 2);
        // eq 7 structurally: LR at the new width is base * w
        assert!(second.checkpoint.lr > first.checkpoint.lr);
    }

    #[test]
    fn rescale_segment_through_store_dedups_against_parked_snapshot() {
        let root = std::env::temp_dir()
            .join(format!("rm-exec-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let store = Arc::new(CkptStore::open(&root).unwrap());
        let rx = spawn_segment(SegmentPlan {
            job: 11,
            workers: 1,
            nodes: 1,
            steps: 3,
            resume: None,
            restart_from_disk: false,
            store: Some(store.clone()),
            config: cfg(1),
        });
        let first = rx.recv().unwrap().unwrap();
        // the orchestrator parks the checkpoint at the boundary; do the
        // same here so the restart round trip sees the parked snapshot
        let parked = store.save(&store_key(11), &first.checkpoint).unwrap();
        let rx = spawn_segment(SegmentPlan {
            job: 11,
            workers: 2,
            nodes: 1,
            steps: 3,
            resume: Some(first.checkpoint.clone()),
            restart_from_disk: true,
            store: Some(store.clone()),
            config: cfg(2),
        });
        let second = rx.recv().unwrap().unwrap();
        assert_eq!(second.checkpoint.step, 6);
        assert!(second.ckpt_io_secs > 0.0);
        // unchanged content -> the restart wrote only the manifest,
        // strictly less than the parked full payload
        assert!(
            second.ckpt_bytes_written < parked.bytes_written,
            "store round trip wrote {} vs parked {}",
            second.ckpt_bytes_written,
            parked.bytes_written
        );
        store.free(&store_key(11)).unwrap();
        assert_eq!(store.chunk_count(), 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn mismatched_worker_plan_is_rejected() {
        let rx = spawn_segment(SegmentPlan {
            job: 9,
            workers: 2,
            nodes: 1,
            steps: 1,
            resume: None,
            restart_from_disk: false,
            store: None,
            config: cfg(1), // says 1 worker
        });
        assert!(rx.recv().unwrap().is_err());
    }
}
