//! Job-trace I/O: JSONL submission streams for the orchestrator, plus a
//! paper-calibrated generator so `ringmaster orchestrate` runs without a
//! trace file.
//!
//! One JSON object per line:
//!
//! ```text
//! {"ringmaster_trace":2}
//! {"id":0,"arrival":0.0,"total_epochs":2.0,
//!  "epoch_secs":[[1,138.0],[2,81.9],[4,47.3],[8,29.6]],"max_w":8,
//!  "model_bytes":6900000.0}
//! ```
//!
//! `epoch_secs` is the job's true seconds/epoch at each measured worker
//! count (the precompute-strategy knowledge of §4); `id` and `max_w` are
//! optional (smallest unclaimed id, and 8, by default). Blank lines and
//! `#` comments are ignored, so traces can be annotated by hand.
//!
//! **Schema versioning.** The optional `{"ringmaster_trace":N}` preamble
//! names the schema; files without one are v1. v2 adds the per-job
//! `model_bytes` field (gradient payload, sizing the placement penalty),
//! which defaults to the paper's ResNet-110 when absent — every v1 trace
//! loads unchanged, and versions newer than [`TRACE_VERSION`] are
//! rejected instead of silently misread.

use std::collections::BTreeSet;
use std::path::Path;

use super::job::JobSpec;
use crate::jsonx::{self, Json};
use crate::perfmodel::placement::PAPER_MODEL_BYTES;
use crate::rngx::Rng;
use crate::sim::workload::{JobProfile, WorkloadGen};
use crate::Result;

/// Current JSONL trace schema version.
pub const TRACE_VERSION: u64 = 2;

/// Serialize a trace as JSONL (current schema, version preamble first).
/// Written atomically (tmp + fsync + rename + dir fsync): a crash
/// mid-write can never leave a torn trace that `load_trace` chokes on —
/// the destination either keeps its previous complete contents or holds
/// the new ones.
pub fn save_trace(path: impl AsRef<Path>, specs: &[JobSpec]) -> Result<()> {
    let mut out = String::new();
    out.push_str(&Json::obj(vec![("ringmaster_trace", Json::num(TRACE_VERSION as f64))]).dump());
    out.push('\n');
    for s in specs {
        out.push_str(&spec_to_json(s).dump());
        out.push('\n');
    }
    let path = path.as_ref();
    crate::fsx::atomic_write(path, out.as_bytes())
        .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))?;
    Ok(())
}

/// Parse a JSONL trace; jobs come back sorted by `(arrival, id)`.
/// Lines without an explicit `id` get the smallest ids not claimed by
/// any explicit one (assigned in line order), so mixing explicit and
/// defaulted ids never collides.
pub fn load_trace(path: impl AsRef<Path>) -> Result<Vec<JobSpec>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading trace {}: {e}", path.display()))?;
    let mut parsed: Vec<ParsedRow> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v = jsonx::parse(line)
            .map_err(|e| anyhow::anyhow!("trace {} line {}: {e}", path.display(), lineno + 1))?;
        if let Some(version) = v.opt("ringmaster_trace") {
            let version = version.as_usize().map_err(|e| {
                anyhow::anyhow!("trace {} line {}: {e}", path.display(), lineno + 1)
            })? as u64;
            // Same preamble lineage, different stream: v3+ telemetry
            // output announces itself with a `stream` key so a run
            // audit is never misread as a job-submission trace.
            if let Some(stream) = v.opt("stream") {
                let stream = stream.as_str().unwrap_or("?").to_string();
                anyhow::bail!(
                    "trace {} is a {stream:?} output stream (v{version}), not a \
                     job-submission trace; audit it with `ringmaster report`",
                    path.display()
                );
            }
            anyhow::ensure!(
                version <= TRACE_VERSION,
                "trace {} is schema v{version}; this build reads up to v{TRACE_VERSION}",
                path.display()
            );
            continue;
        }
        let row = parse_line(&v)
            .map_err(|e| anyhow::anyhow!("trace {} line {}: {e}", path.display(), lineno + 1))?;
        parsed.push(row);
    }
    anyhow::ensure!(!parsed.is_empty(), "trace {} contains no jobs", path.display());

    let mut taken = BTreeSet::new();
    for row in &parsed {
        if let Some(id) = row.id {
            anyhow::ensure!(taken.insert(id), "trace {}: duplicate job id {id}", path.display());
        }
    }
    let mut next_free = 0u64;
    let mut specs: Vec<JobSpec> = parsed
        .into_iter()
        .map(|row| {
            let id = row.id.unwrap_or_else(|| {
                while taken.contains(&next_free) {
                    next_free += 1;
                }
                taken.insert(next_free);
                next_free
            });
            JobSpec {
                id,
                profile: row.profile,
                max_w: row.max_w,
                model_bytes: row.model_bytes,
            }
        })
        .collect();
    specs.sort_by(|a, b| {
        a.profile
            .arrival
            .total_cmp(&b.profile.arrival)
            .then_with(|| a.id.cmp(&b.id))
    });
    Ok(specs)
}

fn spec_to_json(s: &JobSpec) -> Json {
    Json::obj(vec![
        ("id", Json::num(s.id as f64)),
        ("arrival", Json::num(s.profile.arrival)),
        ("total_epochs", Json::num(s.profile.total_epochs)),
        (
            "epoch_secs",
            Json::arr(
                s.profile
                    .epoch_secs
                    .iter()
                    .map(|&(w, secs)| Json::arr(vec![Json::num(w as f64), Json::num(secs)]))
                    .collect(),
            ),
        ),
        ("max_w", Json::num(s.max_w as f64)),
        ("model_bytes", Json::num(s.model_bytes)),
    ])
}

struct ParsedRow {
    id: Option<u64>,
    profile: JobProfile,
    max_w: usize,
    model_bytes: f64,
}

fn parse_line(v: &Json) -> Result<ParsedRow> {
    let id = match v.opt("id") {
        Some(j) => Some(j.as_usize()? as u64),
        None => None,
    };
    let arrival = v.get("arrival")?.as_f64()?;
    anyhow::ensure!(arrival.is_finite() && arrival >= 0.0, "bad arrival {arrival}");
    let total_epochs = v.get("total_epochs")?.as_f64()?;
    anyhow::ensure!(
        total_epochs.is_finite() && total_epochs > 0.0,
        "bad total_epochs {total_epochs}"
    );
    let mut epoch_secs = Vec::new();
    for pair in v.get("epoch_secs")?.as_arr()? {
        let pair = pair.as_arr()?;
        anyhow::ensure!(pair.len() == 2, "epoch_secs entries must be [w, secs]");
        let w = pair[0].as_usize()?;
        let secs = pair[1].as_f64()?;
        anyhow::ensure!(w >= 1 && secs.is_finite() && secs > 0.0, "bad epoch_secs entry");
        epoch_secs.push((w, secs));
    }
    anyhow::ensure!(!epoch_secs.is_empty(), "epoch_secs is empty");
    epoch_secs.sort_by_key(|&(w, _)| w);
    for pair in epoch_secs.windows(2) {
        anyhow::ensure!(pair[0].0 != pair[1].0, "duplicate w={} in epoch_secs", pair[0].0);
    }
    let max_w = match v.opt("max_w") {
        Some(j) => j.as_usize()?,
        None => 8,
    };
    anyhow::ensure!(max_w >= 1, "max_w must be >= 1");
    // v2: per-job gradient payload; v1 rows default to the paper's model
    let model_bytes = match v.opt("model_bytes") {
        Some(j) => j.as_f64()?,
        None => PAPER_MODEL_BYTES,
    };
    anyhow::ensure!(
        model_bytes.is_finite() && model_bytes > 0.0,
        "bad model_bytes {model_bytes}"
    );
    Ok(ParsedRow {
        id,
        profile: JobProfile { arrival, epoch_secs, total_epochs },
        max_w,
        model_bytes,
    })
}

/// Parameters for generated orchestrator workloads — the same
/// paper-calibrated profiles the simulator uses, with epochs scaled down
/// so live runs of real trainers finish quickly.
#[derive(Clone, Debug)]
pub struct TraceGen {
    pub n_jobs: usize,
    /// Mean exponential inter-arrival seconds; small values = a burst.
    pub mean_interarrival: f64,
    /// Per-job total epochs, jittered ±20% (the paper's ~165 epochs would
    /// mean hours of real training; live runs use a miniature target).
    pub total_epochs: f64,
    pub max_w: usize,
}

impl Default for TraceGen {
    fn default() -> Self {
        TraceGen { n_jobs: 6, mean_interarrival: 30.0, total_epochs: 1.0, max_w: 8 }
    }
}

/// Deterministically generate a trace from the paper-calibrated workload
/// generator.
pub fn generate(gen: &TraceGen, seed: u64) -> Vec<JobSpec> {
    let profiles = WorkloadGen::default().generate(gen.n_jobs, gen.mean_interarrival, seed);
    let mut rng = Rng::new(seed ^ 0x0C4E_57A7);
    profiles
        .into_iter()
        .enumerate()
        .map(|(i, mut p)| {
            p.total_epochs = (gen.total_epochs * rng.uniform_range(0.8, 1.2)).max(0.05);
            JobSpec::from_profile(i as u64, p, gen.max_w)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rm-trace-{tag}-{}.jsonl", std::process::id()))
    }

    #[test]
    fn round_trips_exactly() {
        let specs = generate(&TraceGen::default(), 7);
        let p = tmpfile("rt");
        save_trace(&p, &specs).unwrap();
        let back = load_trace(&p).unwrap();
        assert_eq!(back, specs);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn save_trace_is_atomic_and_cleans_tmp_on_failure() {
        let specs = generate(&TraceGen::default(), 7);
        let p = tmpfile("atomic");
        save_trace(&p, &specs).unwrap();
        let tmp = p.with_file_name(format!("{}.tmp", p.file_name().unwrap().to_string_lossy()));
        assert!(!tmp.exists(), "tmp sibling left behind");
        // a stale tmp from a torn earlier writer must not break a resave
        std::fs::write(&tmp, b"torn partial trace").unwrap();
        save_trace(&p, &specs).unwrap();
        assert!(!tmp.exists());
        assert_eq!(load_trace(&p).unwrap(), specs);
        let _ = std::fs::remove_file(&p);
        // rename failure (directory at the target): tmp removed, target intact
        let d = tmpfile("atomic-dir");
        std::fs::create_dir_all(&d).unwrap();
        assert!(save_trace(&d, &specs).is_err());
        let dtmp = d.with_file_name(format!("{}.tmp", d.file_name().unwrap().to_string_lossy()));
        assert!(!dtmp.exists(), "failed save leaked the tmp sibling");
        assert!(d.is_dir());
        let _ = std::fs::remove_dir(&d);
    }

    #[test]
    fn parses_hand_written_lines_with_comments() {
        let p = tmpfile("hand");
        std::fs::write(
            &p,
            "# two-job burst\n\
             {\"arrival\": 0.0, \"total_epochs\": 1.5, \"epoch_secs\": [[1, 100.0], [2, 60.0]]}\n\
             \n\
             {\"id\": 9, \"arrival\": 5.0, \"total_epochs\": 2.0, \
              \"epoch_secs\": [[2, 50.0], [1, 90.0]], \"max_w\": 4}\n",
        )
        .unwrap();
        let specs = load_trace(&p).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].id, 0); // default id = smallest unclaimed
        assert_eq!(specs[0].max_w, 8); // default
        assert_eq!(specs[1].id, 9);
        assert_eq!(specs[1].max_w, 4);
        // epoch_secs sorted by w regardless of file order
        assert_eq!(specs[1].profile.epoch_secs, vec![(1, 90.0), (2, 50.0)]);
        // v1 rows (no preamble, no model_bytes) default to the paper model
        assert_eq!(specs[0].model_bytes, crate::perfmodel::placement::PAPER_MODEL_BYTES);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn v2_model_bytes_round_trips_and_newer_schemas_are_rejected() {
        let p = tmpfile("v2");
        std::fs::write(
            &p,
            "{\"ringmaster_trace\": 2}\n\
             {\"arrival\": 0.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]], \
              \"model_bytes\": 1.0e8}\n",
        )
        .unwrap();
        let specs = load_trace(&p).unwrap();
        assert_eq!(specs[0].model_bytes, 1.0e8);
        // save writes the preamble + model_bytes; reload is exact
        save_trace(&p, &specs).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("{\"ringmaster_trace\":"), "{text}");
        assert!(text.contains("model_bytes"));
        assert_eq!(load_trace(&p).unwrap(), specs);
        // a future schema fails loudly instead of being misread
        std::fs::write(
            &p,
            "{\"ringmaster_trace\": 99}\n\
             {\"arrival\": 0.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]]}\n",
        )
        .unwrap();
        let err = load_trace(&p).unwrap_err().to_string();
        assert!(err.contains("v99"), "{err}");
        // bad model_bytes is rejected
        std::fs::write(
            &p,
            "{\"arrival\": 0.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]], \
              \"model_bytes\": 0.0}\n",
        )
        .unwrap();
        assert!(load_trace(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn telemetry_streams_are_redirected_to_report() {
        // a v3 telemetry stream shares the preamble lineage but must not
        // be misread as a job trace — the loader points at the audit tool
        let p = tmpfile("telemetry-redirect");
        std::fs::write(
            &p,
            "{\"ringmaster_trace\": 3, \"stream\": \"telemetry\"}\n\
             {\"ev\": \"run_start\", \"t\": 0.0}\n",
        )
        .unwrap();
        let err = load_trace(&p).unwrap_err().to_string();
        assert!(err.contains("ringmaster report"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn defaulted_ids_skip_explicit_ones() {
        // explicit id 1 on the first line; the two id-less lines must get
        // 0 and 2, not collide with 1
        let p = tmpfile("mixed-ids");
        std::fs::write(
            &p,
            "{\"id\": 1, \"arrival\": 0.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]]}\n\
             {\"arrival\": 1.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]]}\n\
             {\"arrival\": 2.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]]}\n",
        )
        .unwrap();
        let specs = load_trace(&p).unwrap();
        let ids: Vec<u64> = specs.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1, 0, 2]); // sorted by arrival; ids unique
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_bad_traces() {
        let cases = [
            ("", "empty"),
            ("{\"arrival\": -1.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]]}", "arrival"),
            ("{\"arrival\": 0.0, \"total_epochs\": 0.0, \"epoch_secs\": [[1, 10.0]]}", "epochs"),
            ("{\"arrival\": 0.0, \"total_epochs\": 1.0, \"epoch_secs\": []}", "no speeds"),
            ("{\"arrival\": 0.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0], [1, 9.0]]}", "dup w"),
            ("not json\n", "garbage"),
        ];
        for (i, (doc, tag)) in cases.iter().enumerate() {
            let p = tmpfile(&format!("bad{i}"));
            std::fs::write(&p, doc).unwrap();
            assert!(load_trace(&p).is_err(), "{tag} should fail");
            let _ = std::fs::remove_file(&p);
        }
        // duplicate ids across lines
        let p = tmpfile("dupid");
        std::fs::write(
            &p,
            "{\"id\": 1, \"arrival\": 0.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]]}\n\
             {\"id\": 1, \"arrival\": 1.0, \"total_epochs\": 1.0, \"epoch_secs\": [[1, 10.0]]}\n",
        )
        .unwrap();
        assert!(load_trace(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn generation_is_deterministic_and_burst_compresses_arrivals() {
        let gen = TraceGen { n_jobs: 10, mean_interarrival: 1.0, total_epochs: 1.0, max_w: 8 };
        let a = generate(&gen, 42);
        let b = generate(&gen, 42);
        assert_eq!(a, b);
        assert_ne!(a, generate(&gen, 43));
        // a 1s-mean process packs 10 arrivals into tens of seconds
        assert!(a.last().unwrap().profile.arrival < 60.0);
        for s in &a {
            assert!(s.profile.total_epochs >= 0.05);
            assert_eq!(s.max_w, 8);
        }
    }
}
