//! Deterministic event queue over the orchestrator's virtual clock.
//!
//! Events are totally ordered by `(time, kind, job)` — arrivals before
//! segment ends at equal times, ties broken by job id — so an
//! orchestrated run processes the same event sequence on every execution
//! with the same inputs, which is what makes the whole run
//! seed-deterministic even though real trainer threads run concurrently
//! underneath.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// What happened at an event's virtual time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A job's submission time was reached.
    Arrival,
    /// A running segment's virtual end (the real thread is joined when
    /// this event is processed).
    SegmentEnd,
    /// A running segment reached its virtual-seconds budget
    /// (`--segment-budget`): if it is still the same in-flight segment,
    /// it is cut at its next whole-step boundary. Ordered after
    /// `SegmentEnd` so a deadline that coincides with its own segment's
    /// end is trivially stale.
    BudgetCheck,
    /// A failed job's recovery backoff expired: the job re-enters the
    /// schedulable pool (`--faults` only). Ordered last at equal times
    /// so the instant's frees are pooled before the retry is admitted;
    /// appending the variant leaves every pre-fault ordering intact.
    Retry,
}

/// One scheduled event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    pub kind: EventKind,
    pub job: u64,
}

impl Eq for Event {}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then_with(|| (self.kind as u8).cmp(&(other.kind as u8)))
            .then_with(|| self.job.cmp(&other.job))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-queue of events; `pop_batch` drains every event sharing the
/// earliest time so the scheduler reallocates once per distinct instant
/// (all capacity freed at that instant is pooled before any decision).
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new() }
    }

    pub fn push(&mut self, ev: Event) {
        debug_assert!(ev.time.is_finite(), "non-finite event time");
        self.heap.push(Reverse(ev));
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Pop all events at the earliest queued time, in deterministic
    /// order; `None` when the queue is empty.
    pub fn pop_batch(&mut self) -> Option<(f64, Vec<Event>)> {
        let Reverse(first) = self.heap.pop()?;
        let mut batch = vec![first];
        while let Some(&Reverse(next)) = self.heap.peek() {
            if next.time.total_cmp(&first.time) == Ordering::Equal {
                let Reverse(ev) = self.heap.pop().unwrap();
                batch.push(ev);
            } else {
                break;
            }
        }
        Some((first.time, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, kind: EventKind, job: u64) -> Event {
        Event { time, kind, job }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ev(5.0, EventKind::SegmentEnd, 1));
        q.push(ev(1.0, EventKind::Arrival, 2));
        q.push(ev(3.0, EventKind::Arrival, 3));
        let (t1, b1) = q.pop_batch().unwrap();
        assert_eq!((t1, b1[0].job), (1.0, 2));
        let (t2, _) = q.pop_batch().unwrap();
        assert_eq!(t2, 3.0);
        let (t3, _) = q.pop_batch().unwrap();
        assert_eq!(t3, 5.0);
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn equal_times_batch_together_arrivals_first() {
        let mut q = EventQueue::new();
        q.push(ev(2.0, EventKind::SegmentEnd, 9));
        q.push(ev(2.0, EventKind::BudgetCheck, 1));
        q.push(ev(2.0, EventKind::Arrival, 4));
        q.push(ev(2.0, EventKind::SegmentEnd, 3));
        q.push(ev(2.0, EventKind::Arrival, 7));
        let (t, batch) = q.pop_batch().unwrap();
        assert_eq!(t, 2.0);
        let shape: Vec<(EventKind, u64)> = batch.iter().map(|e| (e.kind, e.job)).collect();
        assert_eq!(
            shape,
            vec![
                (EventKind::Arrival, 4),
                (EventKind::Arrival, 7),
                (EventKind::SegmentEnd, 3),
                (EventKind::SegmentEnd, 9),
                (EventKind::BudgetCheck, 1),
            ]
        );
    }

    #[test]
    fn deterministic_across_insertion_orders() {
        let evs = [
            ev(1.0, EventKind::Arrival, 1),
            ev(1.0, EventKind::SegmentEnd, 2),
            ev(2.0, EventKind::Arrival, 3),
            ev(1.0, EventKind::Arrival, 0),
        ];
        let drain = |order: &[usize]| -> Vec<(u64, f64)> {
            let mut q = EventQueue::new();
            for &i in order {
                q.push(evs[i]);
            }
            let mut out = Vec::new();
            while let Some((t, batch)) = q.pop_batch() {
                for e in batch {
                    out.push((e.job, t));
                }
            }
            out
        };
        assert_eq!(drain(&[0, 1, 2, 3]), drain(&[3, 2, 1, 0]));
        assert_eq!(drain(&[0, 1, 2, 3]), drain(&[2, 0, 3, 1]));
    }
}
