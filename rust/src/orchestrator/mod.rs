//! Live multi-job orchestrator: the doubling scheduler as an *online
//! service* over a stream of arriving jobs, executed against real
//! concurrent trainers.
//!
//! This is the piece that closes the gap between the two halves of the
//! repo: the DES ([`crate::sim`]) reaches the paper's Table-3 result but
//! never trains anything, while the coordinator
//! ([`crate::coordinator`]) drives the real trainer but only one job at
//! a time. The orchestrator owns a shared worker pool, admits jobs from
//! a JSONL trace ([`trace`]) or the paper-calibrated generators, and
//! runs every admitted job as a real in-process trainer
//! ([`crate::trainer`]) — many jobs training concurrently on real
//! worker threads, gradients moving through the real all-reduce.
//!
//! **Two clocks.** Real training wall time on a shared CPU says nothing
//! about a 64-GPU cluster, so the orchestrator separates execution from
//! accounting: segments *execute* for real (real parameters, real
//! checkpoints, real eq-7 LR rescaling), while scheduling and metrics
//! advance on a *virtual* clock where a segment of `e` epochs at `w`
//! workers costs `e · secs_per_epoch(w)` from the job's profile, plus
//! the §6 restart charge whenever the worker count changes. Every
//! decision is a pure function of trace + seed, so an orchestrated run
//! is deterministic end to end (asserted in tests) even though runner
//! threads race underneath — the event loop orders segment completions
//! by virtual time and joins each real thread only when its virtual end
//! event fires.
//!
//! **Decision points.** The configured [`Scheduler`] (doubling, optimus,
//! exact, fixed-k) runs after every event batch — arrival, finish, or
//! segment boundary — over the jobs that are actually stoppable: queued
//! jobs and jobs parked at a boundary. Workers committed to in-flight
//! segments are not available (a real cluster cannot preempt a Horovod
//! job mid-step; it stops it at the next boundary), which is the honest
//! live version of the DES's instant global reallocation — the measured
//! gap between the two is the boundary-granularity cost, and the
//! sim-vs-real experiment in EXPERIMENTS.md quantifies it.
//!
//! Reallocation executes the paper's mechanism for real: stop, atomic
//! checkpoint to disk, reload, restart the trainer at the new width with
//! eq 7's LR rescaling applied structurally by the `base·w` schedule.
//!
//! **Gang placement.** On a non-flat [`Topology`] the scheduler's grant
//! is only half the decision: the placement ledger maps each width to
//! concrete GPUs (best-fit-decreasing batch re-pack, or the scatter
//! strawman), and every segment's virtual duration is priced at
//! `f(w, placement)` — the eq 2–4 inter-node delta when the ring spans
//! more than one node (`perfmodel::placement`). Restarts may change
//! placement, not just width: a continuation must resume on the same
//! node set, and strategies see placement-adjusted [`Speed`]s so eq-6
//! gains already know that doubling past a node boundary is expensive.
//! [`Topology::Flat`] (the default) short-circuits all of it and
//! reproduces the pre-placement orchestrator bit-for-bit.
//!
//! **Online modelling.** Under `--online-model` the trace speed tables
//! stop being scheduler knowledge and become hidden ground truth: each
//! job's finished segments feed a per-job
//! [`crate::perfmodel::OnlineModel`] that refits eq 1/eq 5 after every
//! segment, and strategies consume [`Speed::Learned`] — the
//! confidence-gated fit once trustworthy, the trace-table prior until
//! then (DESIGN.md §11). The learned-vs-truth gap is reported per job as
//! model RMSE. A `--segment-budget` additionally cuts any segment whose
//! training time outruns the budget at its next whole-step boundary
//! (same machinery and determinism contract as `--preempt`), so wide
//! segments cannot starve the scheduler — or the learner — of decision
//! points.

pub mod event;
pub mod executor;
pub mod job;
pub mod report;
pub mod trace;

pub use job::{Job, JobSpec, JobState, SegmentMeta};
pub use report::{JobReport, OrchestratorReport};
pub use trace::{generate as generate_trace, load_trace, save_trace, TraceGen};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use event::{Event, EventKind, EventQueue};
use executor::{spawn_segment, store_key, SegmentPlan};

use crate::cluster::{ClusterState, PlacePolicy, Topology};
use crate::jsonx::Json;
use crate::perfmodel::online::PAPER_EXAMPLES_PER_EPOCH;
use crate::perfmodel::{LinkContention, OnlineModel, PlacementModel};
use crate::rngx::Rng;
use crate::runtime::Artifacts;
use crate::scheduler::{total_allocated, GrantStep, JobInfo, Scheduler, Speed};
use crate::sim::workload::FaultPlan;
use crate::store::CkptStore;
use crate::telemetry::{event, NullSink, Sink};
use crate::trainer::TrainConfig;
use crate::Result;

/// Progress below this epoch remainder counts as converged.
const EPOCH_EPS: f64 = 1e-9;

/// Configuration of one orchestrated run.
#[derive(Clone, Debug)]
pub struct OrchestratorConfig {
    /// Cluster worker capacity shared by all jobs.
    pub capacity: usize,
    /// Virtual seconds charged whenever a job (re)starts with a new
    /// worker count (§6: stop/checkpoint/restart ≈ 10 s).
    pub restart_cost: f64,
    /// Real trainer steps per segment between scheduling decisions.
    pub segment_steps: u64,
    /// Trainer template; per-segment copies get `workers` set and the
    /// seed mixed with the job id (distinct corpora per job).
    pub train: TrainConfig,
    /// Pool shape. [`Topology::Flat`] (the default) reproduces the
    /// pre-placement orchestrator bit-for-bit; a grid makes every
    /// segment's virtual duration depend on the nodes its ring spans.
    pub topology: Topology,
    /// Eq 2–4 intra/inter-node split (per-job `model_bytes` from the
    /// spec sizes the payload).
    pub placement: PlacementModel,
    /// Gang layout policy (pack = locality-aware best-fit-decreasing).
    pub place_policy: PlacePolicy,
    /// Shared-bandwidth law for inter-node links (`--contention`):
    /// when enabled on a grid, a segment whose ring shares an uplink
    /// with other rings at launch is priced at the degraded eq-2
    /// constants, and schedulers score cross-node widths against the
    /// worst-case uplink tenancy. [`LinkContention::OFF`] (the default)
    /// structurally delegates every call to the PR-3 path — bit-exact.
    pub link_contention: LinkContention,
    /// Mid-segment preemption: every arrival stops running segments at
    /// their next *step* boundary (shared stop flag into the real
    /// trainer) instead of waiting out the segment. The virtual schedule
    /// stays deterministic — preempted segments are credited
    /// whole-steps-elapsed on the virtual clock — but the *model bits*
    /// become execution-dependent (the real thread may have run a
    /// different number of steps than credited). Default off.
    pub preempt_on_arrival: bool,
    /// Segment budget: a running segment whose training time (restart
    /// charge excluded) would exceed this many virtual seconds is cut at
    /// its next whole-step boundary past the budget — so a wide-stepped
    /// segment can never starve the scheduler of decision points. Same
    /// determinism contract as `preempt_on_arrival` (whole-step virtual
    /// credit; model bits execution-dependent). Default `INFINITY` (off).
    pub segment_budget_secs: f64,
    /// Online modelling (§7's exploratory strategy as a service): treat
    /// each job's trace speed table as hidden ground truth, learn
    /// eq-1/eq-5 fits from its finished segments into a per-job
    /// [`crate::perfmodel::OnlineModel`], and hand schedulers
    /// [`Speed::Learned`] — the confidence-gated fit once trustworthy,
    /// the trace-table prior until then. Per-job model-vs-truth RMSE is
    /// reported in [`JobReport`]. Default off (oracle tables).
    pub online_model: bool,
    /// Content-addressed checkpoint store root (`--ckpt-store DIR`).
    /// When set, restart round trips go through [`crate::store`] instead
    /// of throwaway temp files, every segment end parks the job's
    /// checkpoint durably in the store (so restart N dedups against
    /// restart N-1 and pays only the delta), and job completion frees
    /// the snapshot + GCs its chunks. The scheduling clock never reads
    /// real I/O, so the schedule is bit-identical to the default
    /// whole-file path; only the *measured* ckpt metrics change.
    /// Default `None` — structurally the old path.
    pub ckpt_store: Option<std::path::PathBuf>,
    /// Seeded fault plan (`--faults`, DESIGN.md §17): each launched
    /// segment draws once from its job's fault clock and dies at its
    /// virtual end with the plan's hazard probability. A failed segment
    /// commits nothing — the job rolls back to its last durable
    /// checkpoint, sits out an exponential backoff
    /// (`backoff_base · 2^(attempt-1)`), and is marked `Failed` once
    /// consecutive failures exceed `max_retries`. [`FaultPlan::OFF`]
    /// (the default) is provably the fault-free orchestrator: no rng
    /// exists and every fault branch is a false boolean.
    pub faults: FaultPlan,
}

impl OrchestratorConfig {
    pub fn new(train: TrainConfig, capacity: usize) -> OrchestratorConfig {
        OrchestratorConfig {
            capacity,
            restart_cost: 10.0,
            segment_steps: 16,
            train,
            topology: Topology::flat(capacity),
            placement: PlacementModel::paper(),
            place_policy: PlacePolicy::Pack,
            link_contention: LinkContention::OFF,
            preempt_on_arrival: false,
            segment_budget_secs: f64::INFINITY,
            online_model: false,
            ckpt_store: None,
            faults: FaultPlan::OFF,
        }
    }

    /// Switch the pool to a `nodes × gpus_per_node` grid (capacity
    /// follows the grid).
    pub fn with_topology(mut self, nodes: usize, gpus_per_node: usize) -> OrchestratorConfig {
        self.topology = Topology::cluster(nodes, gpus_per_node);
        self.capacity = self.topology.capacity();
        self
    }
}

/// Resolve a strategy name to a scheduler:
/// `doubling | optimus | exact | fixed-K`.
pub fn scheduler_by_name(name: &str) -> Result<Box<dyn Scheduler>> {
    use crate::scheduler::{doubling::Doubling, exact::ExactDp, fixed::Fixed, optimus::OptimusGreedy};
    Ok(match name {
        "doubling" | "precompute" => Box::new(Doubling),
        "optimus" | "greedy" => Box::new(OptimusGreedy),
        "exact" => Box::new(ExactDp),
        other => match other.strip_prefix("fixed-") {
            Some(k) => {
                let k: usize =
                    k.parse().map_err(|e| anyhow::anyhow!("strategy {other:?}: {e}"))?;
                anyhow::ensure!(k >= 1, "strategy {other:?}: k must be >= 1");
                Box::new(Fixed(k))
            }
            None => anyhow::bail!(
                "unknown strategy {other:?}: want doubling|optimus|exact|fixed-K"
            ),
        },
    })
}

/// Run the full workload to completion under `scheduler`; returns the
/// per-job and cluster metrics. Errors if any job can never be placed.
pub fn orchestrate(
    cfg: &OrchestratorConfig,
    scheduler: &dyn Scheduler,
    specs: &[JobSpec],
) -> Result<OrchestratorReport> {
    orchestrate_traced(cfg, scheduler, specs, &mut NullSink)
}

/// [`orchestrate`] narrating segment lifecycle, decision provenance, and
/// placement into a telemetry [`Sink`]. Hooks only read engine state, so
/// the schedule (and with a [`NullSink`], the whole run) is bit-identical
/// to [`orchestrate`]. Events derived from *real* trainer threads
/// (wall-clock segment timings) carry `"measured": true` — they are
/// execution-dependent and the audit never feeds them into invariants.
pub fn orchestrate_traced(
    cfg: &OrchestratorConfig,
    scheduler: &dyn Scheduler,
    specs: &[JobSpec],
    sink: &mut dyn Sink,
) -> Result<OrchestratorReport> {
    Orchestrator::new(cfg, specs)?.run(scheduler, sink)
}

struct Orchestrator {
    cfg: OrchestratorConfig,
    /// Preset batch size (the epochs-per-step arithmetic shared with the
    /// trainer: one step advances `batch·w / dataset_examples` epochs).
    batch: usize,
    jobs: Vec<Job>,
    /// Spec id -> index into `jobs`.
    index: BTreeMap<u64, usize>,
    queue: EventQueue,
    /// Placement ledger (second line of defense against double-booking).
    cluster: ClusterState,
    /// Workers committed to in-flight segments.
    committed: usize,
    now: f64,
    busy_gpu_secs: f64,
    peak_allocated: usize,
    total_restarts: u64,
    total_preemptions: u64,
    cross_node_segments: u64,
    events: u64,
    /// Content-addressed checkpoint store (`--ckpt-store`), shared with
    /// every runner thread. None = whole-file temp-path round trips.
    store: Option<Arc<CkptStore>>,
}

impl Orchestrator {
    fn new(cfg: &OrchestratorConfig, specs: &[JobSpec]) -> Result<Orchestrator> {
        let mut cfg = cfg.clone();
        anyhow::ensure!(cfg.capacity >= 1, "capacity must be >= 1");
        cfg.topology = cfg.topology.reconciled(cfg.capacity)?;
        cfg.placement.checked()?;
        cfg.link_contention.checked()?;
        anyhow::ensure!(cfg.segment_steps >= 1, "segment_steps must be >= 1");
        anyhow::ensure!(cfg.restart_cost >= 0.0, "restart_cost must be >= 0");
        anyhow::ensure!(
            cfg.segment_budget_secs > 0.0,
            "segment_budget_secs must be > 0 (INFINITY = off)"
        );
        anyhow::ensure!(cfg.train.dataset_examples >= 1, "dataset_examples must be >= 1");
        anyhow::ensure!(!specs.is_empty(), "no jobs to orchestrate");
        anyhow::ensure!(
            cfg.faults.mtbf_secs >= 0.0
                && cfg.faults.mtbf_secs.is_finite()
                && cfg.faults.transient_mtbf_secs >= 0.0
                && cfg.faults.transient_mtbf_secs.is_finite()
                && cfg.faults.backoff_base_secs >= 0.0
                && cfg.faults.backoff_base_secs.is_finite(),
            "bad fault plan: mtbf/transient-mtbf/backoff must be finite and >= 0"
        );
        let faults_on = !cfg.faults.is_off();

        let batch = Artifacts::resolve(&cfg.train.artifacts_dir)?
            .preset(&cfg.train.preset)?
            .batch;

        let mut jobs = Vec::with_capacity(specs.len());
        let mut index = BTreeMap::new();
        let mut queue = EventQueue::new();
        for spec in specs {
            anyhow::ensure!(spec.max_w >= 1, "job {}: max_w must be >= 1", spec.id);
            anyhow::ensure!(
                spec.model_bytes > 0.0 && spec.model_bytes.is_finite(),
                "job {}: bad model_bytes",
                spec.id
            );
            anyhow::ensure!(
                spec.profile.arrival.is_finite() && spec.profile.arrival >= 0.0,
                "job {}: bad arrival",
                spec.id
            );
            anyhow::ensure!(
                index.insert(spec.id, jobs.len()).is_none(),
                "duplicate job id {}",
                spec.id
            );
            queue.push(Event {
                time: spec.profile.arrival,
                kind: EventKind::Arrival,
                job: spec.id,
            });
            let mut job = Job::new(spec.clone());
            if faults_on {
                // Per-job fault clock: one draw per segment launch, so a
                // job's fate depends only on the plan seed, its id, and
                // its own launch count — never on how other jobs'
                // launches interleave with it.
                job.fault_rng = Some(Rng::new(
                    cfg.faults.seed ^ 0xFA117 ^ spec.id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                ));
            }
            if cfg.online_model {
                // The learner knows the interconnect (cluster config) so
                // it can strip placement from samples; it must *not* know
                // the trace table — that is the truth it has to discover.
                job.online = Some(OnlineModel::new(
                    cfg.placement.with_model_bytes(spec.model_bytes),
                    PAPER_EXAMPLES_PER_EPOCH,
                    spec.model_bytes,
                ));
            }
            jobs.push(job);
        }

        let store = match &cfg.ckpt_store {
            Some(dir) => Some(Arc::new(CkptStore::open(dir)?)),
            None => None,
        };

        Ok(Orchestrator {
            cluster: ClusterState::with_policy(cfg.topology.spec(), cfg.place_policy),
            cfg,
            batch,
            jobs,
            index,
            queue,
            committed: 0,
            now: 0.0,
            busy_gpu_secs: 0.0,
            peak_allocated: 0,
            total_restarts: 0,
            total_preemptions: 0,
            cross_node_segments: 0,
            events: 0,
            store,
        })
    }

    fn run(mut self, scheduler: &dyn Scheduler, sink: &mut dyn Sink) -> Result<OrchestratorReport> {
        let wall = Instant::now();
        if sink.enabled() {
            let (t_nodes, t_gpn) = match self.cfg.topology {
                Topology::Flat { .. } => (0usize, 0usize),
                Topology::Cluster(spec) => (spec.nodes, spec.gpus_per_node),
            };
            sink.emit(event(
                "run_start",
                self.now,
                vec![
                    ("engine", Json::str("orchestrator")),
                    ("strategy", Json::str(scheduler.name())),
                    ("capacity", Json::num(self.cfg.capacity as f64)),
                    ("nodes", Json::num(t_nodes as f64)),
                    ("gpus_per_node", Json::num(t_gpn as f64)),
                    ("contended", Json::Bool(self.cfg.link_contention.enabled())),
                    ("restart_cost", Json::num(self.cfg.restart_cost)),
                    ("segment_steps", Json::num(self.cfg.segment_steps as f64)),
                    ("seed", Json::num(self.cfg.train.seed as f64)),
                    ("n_jobs", Json::num(self.jobs.len() as f64)),
                ],
            ));
        }
        while let Some((t, batch)) = self.queue.pop_batch() {
            self.now = t;
            let mut arrivals = false;
            for ev in batch {
                self.events += 1;
                match ev.kind {
                    EventKind::Arrival => {
                        arrivals = true;
                        self.on_arrival(ev.job)?;
                        if sink.enabled() {
                            sink.count("arrivals", 1);
                            sink.emit(event(
                                "arrival",
                                self.now,
                                vec![("job", Json::num(ev.job as f64))],
                            ));
                        }
                    }
                    EventKind::SegmentEnd => self.on_segment_end(ev.job, sink)?,
                    EventKind::BudgetCheck => self.on_budget_check(ev.job, sink)?,
                    EventKind::Retry => self.on_retry(ev.job, sink)?,
                }
            }
            if self.cfg.preempt_on_arrival && arrivals {
                let cut = self.preempt_running(sink);
                // When everything is committed, defer the decision to
                // the cut segments' step-boundary ends (queued just
                // ahead) so all freed workers pool into one pass. With
                // idle workers on hand, still reallocate now — an
                // arrival must never wait longer *because* preemption
                // is on.
                if cut > 0 && self.committed >= self.cfg.capacity {
                    continue;
                }
            }
            self.reallocate(scheduler, sink)?;
        }

        let stuck: Vec<u64> = self
            .jobs
            .iter()
            .filter(|j| !matches!(j.state, JobState::Done { .. } | JobState::Failed { .. }))
            .map(|j| j.spec.id)
            .collect();
        anyhow::ensure!(
            stuck.is_empty(),
            "orchestration stalled with jobs {stuck:?} unfinished (strategy {:?} can never \
             place them within capacity {})",
            scheduler.name(),
            self.cfg.capacity
        );

        // Store invariant at run end: every job completed (or was freed
        // at give-up), so every snapshot was freed and every chunk GC'd
        // — a leak here means the store would grow without bound across
        // fleet runs.
        if let Some(store) = &self.store {
            anyhow::ensure!(
                store.snapshot_count() == 0 && store.chunk_count() == 0,
                "checkpoint store not drained at run end: {} snapshots, {} chunks live",
                store.snapshot_count(),
                store.chunk_count()
            );
            let _ = store.remove_if_empty();
        }

        let mut job_reports = Vec::with_capacity(self.jobs.len());
        for j in &self.jobs {
            // A failed job's `finish` is its give-up instant; `failed`
            // flags it so the JCT aggregates exclude it (it never
            // completed — averaging its lifetime in would reward giving
            // up early).
            let (finish, failed) = match j.state {
                JobState::Done { finish } => (finish, false),
                JobState::Failed { at } => (at, true),
                _ => unreachable!("checked above"),
            };
            let first_start = j.first_start.expect("terminal job must have started");
            job_reports.push(JobReport {
                id: j.spec.id,
                arrival: j.spec.profile.arrival,
                first_start,
                finish,
                queue_secs: first_start - j.spec.profile.arrival,
                jct_secs: finish - j.spec.profile.arrival,
                failed,
                failures: j.failures,
                segments: j.segments,
                restarts: j.restarts,
                virtual_restart_secs: j.virtual_restart_secs,
                measured_restart_secs: j.measured_restart_secs,
                measured_train_secs: j.measured_train_secs,
                ckpt_io_secs: j.ckpt_io_secs,
                ckpt_bytes_written: j.ckpt_bytes_written,
                restart_ckpt_bytes: j.restart_ckpt_bytes,
                steps: j.steps_done,
                epochs: j.epochs_done,
                max_w: j.max_w_granted,
                max_nodes: j.max_nodes_spanned,
                cross_node_segments: j.cross_node_segments,
                final_loss: j.final_loss,
                model_rmse_first: j.model_rmse_first,
                model_rmse: j.model_rmse_last,
                learned_after_segments: j.learned_after_segments,
            });
        }

        let makespan = self.now;
        let done_jobs = self
            .jobs
            .iter()
            .filter(|j| matches!(j.state, JobState::Done { .. }))
            .count();
        if sink.enabled() {
            sink.phase_secs("run", wall.elapsed().as_secs_f64());
            sink.emit(event(
                "run_end",
                makespan,
                vec![
                    ("completed", Json::num(done_jobs as f64)),
                    ("restarts", Json::num(self.total_restarts as f64)),
                    ("preemptions", Json::num(self.total_preemptions as f64)),
                    ("events", Json::num(self.events as f64)),
                    ("peak_allocated", Json::num(self.peak_allocated as f64)),
                    (
                        "utilization",
                        Json::num(
                            self.busy_gpu_secs
                                / (self.cfg.capacity as f64 * makespan).max(1e-9),
                        ),
                    ),
                ],
            ));
        }
        Ok(OrchestratorReport {
            strategy: scheduler.name().to_string(),
            capacity: self.cfg.capacity,
            topology: self.cfg.topology,
            jobs: job_reports,
            makespan_secs: makespan,
            utilization: self.busy_gpu_secs / (self.cfg.capacity as f64 * makespan).max(1e-9),
            peak_allocated: self.peak_allocated,
            total_restarts: self.total_restarts,
            total_preemptions: self.total_preemptions,
            cross_node_segments: self.cross_node_segments,
            events: self.events,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }

    fn on_arrival(&mut self, id: u64) -> Result<()> {
        let idx = self.idx(id)?;
        self.jobs[idx].transition(JobState::Queued)
    }

    /// True when any mode may cut running segments short — segments then
    /// carry stop flags and progress is credited purely on the virtual
    /// clock (real checkpoints stop being a deterministic function of
    /// the trace the moment any segment can be cut).
    fn preempt_capable(&self) -> bool {
        self.cfg.preempt_on_arrival || self.cfg.segment_budget_secs.is_finite()
    }

    /// Join the real runner thread for this job's segment (it finished at
    /// this virtual instant), fold its outcome into the registry, and
    /// park the job at the boundary (or complete it).
    fn on_segment_end(&mut self, id: u64, sink: &mut dyn Sink) -> Result<()> {
        let idx = self.idx(id)?;
        let now = self.now;
        let preempt_capable = self.preempt_capable();
        let job = &mut self.jobs[idx];
        // Stale event: a preemption moved this segment's end earlier and
        // the original event still fires later — ignore it.
        let current = job
            .segment
            .as_ref()
            .map_or(false, |m| m.end.to_bits() == now.to_bits());
        if !current {
            return Ok(());
        }
        let meta = job.segment.take().expect("checked above");
        let workers = match job.state {
            JobState::Running { workers } => workers,
            ref other => {
                anyhow::bail!("job {id}: segment end while {}", other.name())
            }
        };
        let rx = job
            .inflight
            .take()
            .ok_or_else(|| anyhow::anyhow!("job {id}: no in-flight segment"))?;
        // Both failure layers are recoverable, never fatal: a vanished
        // runner thread (panicked or dropped its sender) and a segment-
        // level error surface as a failed segment the recovery path
        // consumes — exactly like a plan-injected fault. The old
        // double-unwrap here took the whole orchestrator down with the
        // first dead trainer.
        let received = rx.recv();
        let failure: Option<String> = if meta.fail_injected {
            Some("injected fault".to_string())
        } else {
            match &received {
                Err(_) => Some("segment runner thread vanished".to_string()),
                Ok(Err(e)) => Some(format!("{e:#}")),
                Ok(Ok(_)) => None,
            }
        };
        if let Some(reason) = failure {
            return self.on_segment_failed(idx, workers, &meta, reason, sink);
        }
        let outcome = match received {
            Ok(Ok(o)) => o,
            _ => unreachable!("failure handled above"),
        };
        let job = &mut self.jobs[idx];

        if preempt_capable {
            // Preemption-capable modes (arrival preemption or a segment
            // budget): progress is credited purely on the virtual clock
            // (whole steps elapsed), never from the racing real thread —
            // once any segment can be cut short, real checkpoints stop
            // being a deterministic function of the trace, so the
            // schedule must not read them. Model bits may differ across
            // runs; JCTs cannot.
            let steps_v = meta.preempted_steps.unwrap_or(meta.planned_steps);
            job.epochs_done = meta.launch_epochs + steps_v as f64 * meta.epochs_per_step;
            job.steps_done = meta.launch_steps + steps_v;
        } else {
            job.epochs_done = outcome.checkpoint.epochs;
            job.steps_done = outcome.checkpoint.step;
        }
        job.checkpoint = Some(outcome.checkpoint);
        // The boundary checkpoint is durable: it is what a later failed
        // segment rolls back to (`--faults`). Any successful segment
        // also resets the *consecutive*-failure counter the give-up
        // policy counts.
        if !self.cfg.faults.is_off() {
            job.recovery_ckpt = job.checkpoint.clone();
            job.fail_attempts = 0;
        }
        job.last_w = workers;
        job.last_nodes = self.cluster.node_set(id);
        job.last_gpus = self.cluster.allocation_of(id).unwrap_or(&[]).to_vec();
        // the executor's span record must agree with the ledger — the
        // placement a segment *ran on* is the one that was priced
        anyhow::ensure!(
            outcome.nodes == job.last_nodes.len(),
            "job {id}: executor recorded {} nodes but the ledger says {}",
            outcome.nodes,
            job.last_nodes.len()
        );
        job.boundary_time = Some(now);
        job.measured_train_secs += outcome.train_secs;
        // Startup is paid on every segment (each is a fresh `train` call)
        // but only counts as *restart* overhead when the job was actually
        // stopped — continuations' startup is an execution artifact.
        if job.last_segment_restarted {
            job.measured_restart_secs += outcome.ckpt_io_secs + outcome.startup_secs;
        }
        job.ckpt_io_secs += outcome.ckpt_io_secs;
        job.ckpt_bytes_written += outcome.ckpt_bytes_written;
        // restart-only bytes: the apples-to-apples dedup metric (the
        // park writes below are bounded by it on the whole-file path,
        // which has no parks at all)
        job.restart_ckpt_bytes += outcome.ckpt_bytes_written;
        if let Some(l) = outcome.final_loss {
            job.final_loss = Some(l);
        }

        // Online modelling: fold this finished segment into the job's
        // learner. The speed sample is the segment's virtual-clock price
        // at the placement it ran on (f(w, placement) — exactly what a
        // real cluster would measure); the loss sample is the trainer's
        // real reported loss at the cumulative epoch. Loss samples never
        // feed back into the schedule, so determinism is untouched even
        // where model bits are execution-dependent.
        if let Some(online) = job.online.as_mut() {
            if meta.epochs_per_step > 0.0 {
                let placed_secs_per_epoch = meta.step_secs / meta.epochs_per_step;
                online.observe_speed(workers, job.last_nodes.len().max(1), placed_secs_per_epoch);
            }
            if let Some(l) = outcome.final_loss {
                online.observe_loss(job.epochs_done, l as f64);
            }
            if let Some(rmse) = online.speed_rmse_vs(&job.spec.profile.epoch_secs) {
                if job.model_rmse_first.is_none() {
                    job.model_rmse_first = Some(rmse);
                }
                job.model_rmse_last = Some(rmse);
            }
            if online.gate_open() && job.learned_after_segments.is_none() {
                job.learned_after_segments = Some(job.segments);
            }
        }

        let done = job.remaining_epochs() <= EPOCH_EPS;
        // Durable park/free at the boundary (store mode only): parking
        // the checkpoint now means the *next* restart's store save finds
        // every unchanged chunk already live and pays only the delta +
        // manifest; completion frees the snapshot and GCs its chunks so
        // a finished fleet leaves the store fully drained. Real I/O on
        // the measured clock only — the virtual schedule never sees it.
        if let Some(store) = &self.store {
            let t = Instant::now();
            if done {
                store.free(&store_key(id))?;
            } else {
                let ck = job.checkpoint.as_ref().expect("folded above");
                let stats = store.save(&store_key(id), ck)?;
                job.ckpt_bytes_written += stats.bytes_written;
            }
            job.ckpt_io_secs += t.elapsed().as_secs_f64();
        }
        if sink.enabled() {
            sink.count("segments", 1);
            sink.emit(event(
                "seg_end",
                now,
                vec![
                    ("job", Json::num(id as f64)),
                    ("w", Json::num(workers as f64)),
                    ("steps", Json::num((job.steps_done - meta.launch_steps) as f64)),
                    ("epochs", Json::num(job.epochs_done)),
                    ("preempted", Json::Bool(meta.preempted_steps.is_some())),
                    ("done", Json::Bool(done)),
                ],
            ));
            // Wall-clock truth from the racing real thread: flagged so
            // the audit reports it but never replays invariants over it.
            sink.emit(event(
                "seg_measured",
                now,
                vec![
                    ("job", Json::num(id as f64)),
                    ("measured", Json::Bool(true)),
                    ("train_secs", Json::num(outcome.train_secs)),
                    ("startup_secs", Json::num(outcome.startup_secs)),
                    ("ckpt_io_secs", Json::num(outcome.ckpt_io_secs)),
                    ("ckpt_bytes", Json::num(outcome.ckpt_bytes_written as f64)),
                    ("mean_step_secs", Json::num(outcome.mean_step_secs)),
                    ("mean_allreduce_secs", Json::num(outcome.mean_allreduce_secs)),
                ],
            ));
            if done {
                sink.count("completions", 1);
                sink.emit(event(
                    "complete",
                    now,
                    vec![
                        ("job", Json::num(id as f64)),
                        ("jct", Json::num(now - job.spec.profile.arrival)),
                    ],
                ));
            }
        }
        if done {
            job.transition(JobState::Done { finish: now })?;
        } else {
            job.transition(JobState::Preempted)?;
        }
        self.committed -= workers;
        self.cluster.release(id)?;
        Ok(())
    }

    /// A segment died at its virtual end — plan-injected or a real
    /// runner failure. Nothing the segment did commits: progress rolls
    /// back to the launch boundary, the resume image to the last durable
    /// checkpoint, and the job either waits out an exponential backoff
    /// (`base · 2^(attempt-1)`) before rejoining the schedulable pool or
    /// — past the plan's retry budget — is marked `Failed` for good.
    fn on_segment_failed(
        &mut self,
        idx: usize,
        workers: usize,
        meta: &SegmentMeta,
        reason: String,
        sink: &mut dyn Sink,
    ) -> Result<()> {
        let now = self.now;
        let plan = self.cfg.faults;
        let job = &mut self.jobs[idx];
        let id = job.spec.id;
        // Roll back: the failed segment's work is rework, not progress.
        // `launch` took `checkpoint` as the resume image, so without the
        // restore here a retry would silently cold-start from epoch 0.
        job.epochs_done = meta.launch_epochs;
        job.steps_done = meta.launch_steps;
        job.checkpoint = job.recovery_ckpt.clone();
        // A retry is never a continuation — the ring died.
        job.boundary_time = None;
        job.last_w = 0;
        job.last_nodes = Vec::new();
        job.last_gpus = Vec::new();
        job.failures += 1;
        job.fail_attempts += 1;
        let attempt = job.fail_attempts;
        let ckpt_epochs = job.epochs_done;
        job.transition(JobState::Recovering)?;
        self.committed -= workers;
        self.cluster.release(id)?;
        let give_up = attempt > plan.max_retries;
        if sink.enabled() {
            sink.count("seg_failures", 1);
            sink.emit(event(
                "seg_failed",
                now,
                vec![
                    ("job", Json::num(id as f64)),
                    ("w", Json::num(workers as f64)),
                    ("attempt", Json::num(attempt as f64)),
                    ("ckpt_epochs", Json::num(ckpt_epochs)),
                    ("reason", Json::str(&reason)),
                    ("gave_up", Json::Bool(give_up)),
                ],
            ));
        }
        if give_up {
            self.jobs[idx].transition(JobState::Failed { at: now })?;
            // Store mode: drop any parked snapshot so the run-end drain
            // invariant still holds (no-op when nothing was parked).
            if let Some(store) = &self.store {
                store.free(&store_key(id))?;
            }
            if sink.enabled() {
                sink.count("jobs_failed", 1);
                sink.emit(event(
                    "job_failed",
                    now,
                    vec![
                        ("job", Json::num(id as f64)),
                        ("attempts", Json::num(attempt as f64)),
                    ],
                ));
            }
            return Ok(());
        }
        let delay = (plan.backoff_base_secs * 2f64.powi(attempt as i32 - 1)).max(EPOCH_EPS);
        self.queue.push(Event { time: now + delay, kind: EventKind::Retry, job: id });
        Ok(())
    }

    /// A failed job's backoff expired: re-enter the schedulable pool,
    /// resuming from the last durable checkpoint (cold if none exists).
    /// The batch loop's post-event reallocation hands it workers like
    /// any other parked job.
    fn on_retry(&mut self, id: u64, sink: &mut dyn Sink) -> Result<()> {
        let idx = self.idx(id)?;
        let now = self.now;
        let job = &mut self.jobs[idx];
        if !matches!(job.state, JobState::Recovering) {
            return Ok(()); // stale — the job already gave up
        }
        let to = if job.checkpoint.is_some() { JobState::Preempted } else { JobState::Queued };
        job.transition(to)?;
        if sink.enabled() {
            sink.count("recoveries", 1);
            sink.emit(event(
                "recovered",
                now,
                vec![
                    ("job", Json::num(id as f64)),
                    ("attempt", Json::num(job.fail_attempts as f64)),
                    ("resume_epochs", Json::num(job.epochs_done)),
                ],
            ));
        }
        Ok(())
    }

    /// Cut `jobs[idx]`'s in-flight segment at its next whole-step
    /// boundary after `self.now`: flip the real trainer's stop flag (it
    /// finishes its current step before honoring it) and pull the
    /// segment's virtual end forward to the matching whole-step instant.
    /// Returns the new end, or `None` when there is nothing to cut (not
    /// running, already cut, or already effectively at its boundary).
    fn cut_segment(&mut self, idx: usize) -> Option<f64> {
        let now = self.now;
        let job = &mut self.jobs[idx];
        let workers = match job.state {
            JobState::Running { workers } => workers,
            _ => return None,
        };
        let meta = job.segment.as_mut()?;
        if meta.preempted_steps.is_some() || meta.end <= now {
            return None;
        }
        // whole steps the virtual clock has elapsed
        let worked = now - meta.start - meta.restart_pay;
        let steps_v = if worked <= 0.0 || meta.step_secs <= 0.0 {
            0
        } else {
            ((worked / meta.step_secs).ceil() as u64).min(meta.planned_steps)
        };
        let new_end = meta.start + meta.restart_pay + steps_v as f64 * meta.step_secs;
        if new_end >= meta.end {
            return None; // already effectively at its boundary
        }
        if let Some(stop) = &meta.stop {
            stop.store(true, Ordering::Relaxed);
        }
        self.busy_gpu_secs -= workers as f64 * (meta.end - new_end);
        meta.end = new_end;
        meta.preempted_steps = Some(steps_v);
        Some(new_end)
    }

    /// Mid-segment preemption (opt-in): cut every running segment so the
    /// freed workers are schedulable now instead of at the old segment
    /// end. Returns how many were cut.
    fn preempt_running(&mut self, sink: &mut dyn Sink) -> u64 {
        let mut cut = 0;
        for idx in 0..self.jobs.len() {
            let id = self.jobs[idx].spec.id;
            if let Some(new_end) = self.cut_segment(idx) {
                self.queue.push(Event { time: new_end, kind: EventKind::SegmentEnd, job: id });
                cut += 1;
                if sink.enabled() {
                    sink.count("preemptions", 1);
                    sink.emit(event(
                        "preempt",
                        self.now,
                        vec![
                            ("job", Json::num(id as f64)),
                            ("new_end", Json::num(new_end)),
                            ("cause", Json::str("arrival")),
                        ],
                    ));
                }
            }
        }
        self.total_preemptions += cut;
        cut
    }

    /// A segment's virtual-seconds budget expired. If the same segment
    /// is still in flight (the deadline matches and nothing cut it
    /// already), cut it at its next whole-step boundary; stale checks —
    /// the segment ended, or an arrival preemption got there first — are
    /// ignored, exactly like stale `SegmentEnd` events.
    fn on_budget_check(&mut self, id: u64, sink: &mut dyn Sink) -> Result<()> {
        let idx = self.idx(id)?;
        let now = self.now;
        let current = self.jobs[idx].segment.as_ref().map_or(false, |m| {
            m.budget_deadline.map_or(false, |d| d.to_bits() == now.to_bits())
                && m.preempted_steps.is_none()
        });
        if !current {
            return Ok(());
        }
        if let Some(new_end) = self.cut_segment(idx) {
            self.queue.push(Event { time: new_end, kind: EventKind::SegmentEnd, job: id });
            self.total_preemptions += 1;
            if sink.enabled() {
                sink.count("preemptions", 1);
                sink.emit(event(
                    "preempt",
                    now,
                    vec![
                        ("job", Json::num(id as f64)),
                        ("new_end", Json::num(new_end)),
                        ("cause", Json::str("budget")),
                    ],
                ));
            }
        }
        Ok(())
    }

    /// Invoke the strategy over every stoppable job, then launch the
    /// granted segments. Workers held by in-flight segments are off the
    /// table; the hard capacity invariant is re-checked on every launch.
    fn reallocate(&mut self, scheduler: &dyn Scheduler, sink: &mut dyn Sink) -> Result<()> {
        let mut schedulable: Vec<usize> = (0..self.jobs.len())
            .filter(|&i| self.jobs[i].is_schedulable())
            .collect();
        if schedulable.is_empty() {
            return Ok(());
        }
        // FIFO by (arrival, id) — the order every strategy sees.
        schedulable.sort_by(|&a, &b| {
            let ja = &self.jobs[a].spec;
            let jb = &self.jobs[b].spec;
            ja.profile
                .arrival
                .total_cmp(&jb.profile.arrival)
                .then_with(|| ja.id.cmp(&jb.id))
        });

        let free = self.cfg.capacity - self.committed;
        let infos: Vec<JobInfo> = schedulable
            .iter()
            .map(|&i| {
                let j = &self.jobs[i];
                // Under --online-model the trace table is only the
                // pre-gate prior: once the job's learner passes its
                // confidence gate, strategies score widths against the
                // *measured* eq-5 fit instead. The table itself is the
                // job's Arc-shared copy — built once at registration,
                // never cloned per event.
                let table = Speed::Shared(j.speed_shared.clone());
                let base = if self.cfg.online_model {
                    let fit = j.online.as_ref().and_then(|o| o.speed().cloned());
                    Speed::learned(fit, table)
                } else {
                    table
                };
                // On a grid the strategy scores each width against the
                // placement it would get: f(w, placement), eq 2–4 split
                // — and under `--contention` against the worst-case
                // uplink tenancy a cross-node ring could land on:
                // f(w, placement, contention).
                let speed = match self.cfg.topology {
                    Topology::Flat { .. } => base,
                    Topology::Cluster(spec) => {
                        let pm = self.cfg.placement.with_model_bytes(j.spec.model_bytes);
                        if self.cfg.link_contention.enabled() {
                            let tenants = 1 + self.cluster.max_link_rings_excluding(j.spec.id);
                            Speed::placed_contended(
                                base,
                                pm,
                                spec.gpus_per_node,
                                None,
                                self.cfg.link_contention,
                                tenants,
                            )
                        } else {
                            Speed::placed(base, pm, spec.gpus_per_node)
                        }
                    }
                };
                JobInfo {
                    id: j.spec.id,
                    q: j.remaining_epochs().max(1e-6),
                    speed,
                    max_w: j.spec.max_w.min(self.cfg.capacity),
                }
            })
            .collect();
        // Traced runs take `allocate_traced` — the same loop recording
        // its pops — so provenance can never drift from the decision.
        let mut grant_steps: Vec<GrantStep> = Vec::new();
        let alloc = if sink.enabled() {
            scheduler.allocate_traced(&infos, free, &mut grant_steps)
        } else {
            scheduler.allocate(&infos, free)
        };
        anyhow::ensure!(
            total_allocated(&alloc) <= free,
            "scheduler {:?} over-allocated: {} granted, {free} free",
            scheduler.name(),
            total_allocated(&alloc)
        );
        if sink.enabled() {
            sink.count("allocs", 1);
            sink.sample("alloc_jobs", infos.len() as f64);
            sink.sample("free_at_alloc", free as f64);
            let dec: Vec<Json> = infos
                .iter()
                .map(|info| {
                    // Same pessimistic bound the candidate was scored
                    // with (pure ledger read, so the re-read is exact);
                    // execution tenancy lands in each `seg_launch`.
                    let scoring = if self.cfg.link_contention.enabled()
                        && !self.cfg.topology.is_flat()
                    {
                        1 + self.cluster.max_link_rings_excluding(info.id)
                    } else {
                        1
                    };
                    Json::obj(vec![
                        ("job", Json::num(info.id as f64)),
                        ("q", Json::num(info.q)),
                        ("to", Json::num(alloc.get(&info.id).copied().unwrap_or(0) as f64)),
                        ("scoring_tenancy", Json::num(scoring as f64)),
                    ])
                })
                .collect();
            let steps: Vec<Json> = grant_steps
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("job", Json::num(s.job as f64)),
                        ("from", Json::num(s.from_w as f64)),
                        ("to", Json::num(s.to_w as f64)),
                        ("gain", Json::num(s.gain)),
                        ("outcome", Json::str(s.outcome.name())),
                    ])
                })
                .collect();
            sink.emit(event(
                "alloc",
                self.now,
                vec![
                    ("free", Json::num(free as f64)),
                    ("n", Json::num(infos.len() as f64)),
                    ("decisions", Json::Arr(dec)),
                    ("steps", Json::Arr(steps)),
                ],
            ));
        }

        // Place and launch continuations first (a job resuming at an
        // unchanged width at its own boundary reclaims its ring — its
        // old slots are still free, so a segment boundary is never a
        // migration), then the rest widest-first (FIFO within a width
        // class): big gangs pick their nodes before smaller ones
        // fragment the grid.
        let mut grants: Vec<(u64, usize)> = infos
            .iter()
            .filter_map(|info| {
                alloc.get(&info.id).copied().filter(|&w| w > 0).map(|w| (info.id, w))
            })
            .collect();
        grants.sort_by(|a, b| b.1.cmp(&a.1));
        let (continuations, fresh): (Vec<_>, Vec<_>) =
            grants.into_iter().partition(|&(id, w)| self.resumes_unchanged(id, w));
        for (id, w) in continuations.into_iter().chain(fresh) {
            self.launch(id, w, sink)?;
        }
        if sink.enabled() {
            // Post-launch placement snapshot (grid only) + a utilization/
            // queue-depth sample — the audit replays per-node occupancy
            // and crossing-ring counts from these.
            if !self.cfg.topology.is_flat() {
                let mut placements: Vec<Json> = Vec::new();
                for (id, w) in self.cluster.placed_jobs() {
                    let gpus: Vec<Json> = self
                        .cluster
                        .node_gpu_counts(id)
                        .into_iter()
                        .map(|(n, c)| {
                            Json::Arr(vec![Json::num(n as f64), Json::num(c as f64)])
                        })
                        .collect();
                    placements.push(Json::obj(vec![
                        ("job", Json::num(id as f64)),
                        ("w", Json::num(w as f64)),
                        ("probe", Json::Bool(false)),
                        ("gpus", Json::Arr(gpus)),
                        ("tenancy", Json::num(self.cluster.tenancy_of(id) as f64)),
                    ]));
                }
                let links: Vec<Json> = self
                    .cluster
                    .link_rings()
                    .iter()
                    .enumerate()
                    .filter(|&(_, &r)| r > 0)
                    .map(|(n, &r)| Json::Arr(vec![Json::num(n as f64), Json::num(r as f64)]))
                    .collect();
                sink.emit(event(
                    "place",
                    self.now,
                    vec![
                        ("placements", Json::Arr(placements)),
                        ("links", Json::Arr(links)),
                    ],
                ));
            }
            let queued = self
                .jobs
                .iter()
                .filter(|j| matches!(j.state, JobState::Queued | JobState::Preempted))
                .count();
            sink.sample("committed", self.committed as f64);
            sink.emit(event(
                "util",
                self.now,
                vec![
                    ("used", Json::num(self.committed as f64)),
                    ("capacity", Json::num(self.cfg.capacity as f64)),
                    ("queued", Json::num(queued as f64)),
                ],
            ));
        }
        Ok(())
    }

    /// True when `id` would resume at its just-ended segment's width at
    /// this very instant — the candidate-continuation predicate shared
    /// by placement priority, affinity, and the §6 charge.
    fn resumes_unchanged(&self, id: u64, w: usize) -> bool {
        let Some(&idx) = self.index.get(&id) else { return false };
        let job = &self.jobs[idx];
        job.last_w == w
            && job
                .boundary_time
                .map(|t| t.to_bits() == self.now.to_bits())
                .unwrap_or(false)
    }

    /// Start one training segment for `id` at `w` workers: map the grant
    /// to concrete GPUs, charge the §6 restart cost if the width *or
    /// placement* changed (or cold start), size the segment, spawn the
    /// real runner thread, and enqueue the segment's virtual end event —
    /// priced at `f(w, placement)`.
    fn launch(&mut self, id: u64, w: usize, sink: &mut dyn Sink) -> Result<()> {
        anyhow::ensure!(
            self.committed + w <= self.cfg.capacity,
            "capacity invariant violated launching job {id}: {} committed + {w} > {}",
            self.committed,
            self.cfg.capacity
        );
        let idx = self.idx(id)?;
        // A candidate continuation asks for its exact previous GPUs
        // back; it is placed before any fresh grant and siblings only
        // reclaim their own former slots, so the reclaim succeeds and
        // the node-set comparison below sees an unchanged ring.
        let prefer: Vec<crate::cluster::Gpu> = if self.resumes_unchanged(id, w) {
            self.jobs[idx].last_gpus.clone()
        } else {
            Vec::new()
        };
        self.cluster.place_with_affinity(id, w, &prefer)?;
        let nodes_now = self.cluster.node_set(id);
        let nodes = nodes_now.len();

        let now = self.now;
        let restart_cost = self.cfg.restart_cost;
        let segment_steps = self.cfg.segment_steps;
        let dataset = self.cfg.train.dataset_examples;
        let batch = self.batch;
        let preempt = self.preempt_capable();

        // f(w, placement): the profile's epoch seconds are single-node
        // truth; a ring spanning nodes pays the eq-2 inter-node delta.
        // Under `--contention` the segment is additionally priced at the
        // uplink tenancy the ledger shows *at launch* — a segment is one
        // committed unit of work, so later-arriving sharers slow their
        // own segments, not this one (launch-time sampling; DESIGN §13).
        let base_epoch_secs = self.jobs[idx].spec.profile.secs_per_epoch(w);
        let epoch_secs = if self.cfg.topology.is_flat() {
            base_epoch_secs
        } else {
            let pm = self
                .cfg
                .placement
                .with_model_bytes(self.jobs[idx].spec.model_bytes);
            if self.cfg.link_contention.enabled() {
                let tenants = self.cluster.tenancy_of(id);
                pm.contended_epoch_secs(base_epoch_secs, w, nodes, self.cfg.link_contention, tenants)
            } else {
                pm.placed_epoch_secs(base_epoch_secs, w, nodes)
            }
        };

        let mut tcfg = self.cfg.train.clone();
        tcfg.workers = w;
        tcfg.seed = self.cfg.train.seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let stop = if preempt { Some(Arc::new(AtomicBool::new(false))) } else { None };
        tcfg.stop_flag = stop.clone();

        let job = &mut self.jobs[idx];
        // A segment is a *continuation* (the job was never stopped) only
        // when it resumes at the same width, on the same nodes, at the
        // very instant its last segment ended. Everything else — cold
        // start, width change, migration to different nodes, or sitting
        // parked while its workers ran other jobs — is a real
        // stop→restart and pays the §6 cost, exactly like the DES
        // (sim/des.rs charges on every `w` transition, including 0→w).
        let continued = job.last_w == w
            && job.last_nodes == nodes_now
            && job
                .boundary_time
                .map(|t| t.to_bits() == now.to_bits())
                .unwrap_or(false);
        let pay_restart = !continued;

        // One step advances batch·w/M epochs — identical to the trainer's
        // own accounting, so virtual progress and real checkpoints agree.
        let epochs_per_step = (batch * w) as f64 / dataset as f64;
        let needed = (job.remaining_epochs() / epochs_per_step).ceil().max(1.0) as u64;
        let steps = needed.min(segment_steps);
        let seg_epochs = steps as f64 * epochs_per_step;
        let restart_pay = if pay_restart { restart_cost } else { 0.0 };
        let duration = restart_pay + seg_epochs * epoch_secs;
        let end = now + duration;

        // One fault-clock draw per launch (`--faults` only): does this
        // segment survive its own duration? The per-job rng is consumed
        // in launch order, so the fault pattern is a pure function of
        // (plan seed, schedule) — bit-reproducible across runs. Fault-off
        // jobs carry no rng and never draw.
        let fail_injected = match job.fault_rng.as_mut() {
            Some(rng) => rng.uniform() < self.cfg.faults.segment_fail_probability(duration),
            None => false,
        };

        // Segment budget: if the training part of this segment outruns
        // the budget, schedule a check at the deadline; firing, it cuts
        // the segment at the first whole-step boundary past the budget
        // (so the scheduler regains a decision point, and an overrunning
        // segment can never monopolize its workers between decisions).
        let step_secs = epochs_per_step * epoch_secs;
        let budget = self.cfg.segment_budget_secs;
        let budget_deadline = if budget.is_finite()
            && step_secs > 0.0
            && ((budget / step_secs).ceil() as u64) < steps
        {
            Some(now + restart_pay + budget)
        } else {
            None
        };

        let restart_from_disk = pay_restart && job.checkpoint.is_some();
        let plan = SegmentPlan {
            job: id,
            workers: w,
            nodes,
            steps,
            resume: job.checkpoint.take(),
            restart_from_disk,
            store: self.store.clone(),
            config: tcfg,
        };
        job.transition(JobState::Running { workers: w })?;
        job.segment = Some(SegmentMeta {
            end,
            start: now,
            restart_pay,
            step_secs,
            planned_steps: steps,
            epochs_per_step,
            launch_epochs: job.epochs_done,
            launch_steps: job.steps_done,
            stop,
            preempted_steps: None,
            budget_deadline,
            fail_injected,
        });
        job.inflight = Some(spawn_segment(plan));
        job.last_segment_restarted = pay_restart;
        job.segments += 1;
        job.max_w_granted = job.max_w_granted.max(w);
        job.max_nodes_spanned = job.max_nodes_spanned.max(nodes);
        if nodes > 1 {
            job.cross_node_segments += 1;
            self.cross_node_segments += 1;
        }
        if job.first_start.is_none() {
            job.first_start = Some(now);
        }
        if pay_restart {
            job.restarts += 1;
            job.virtual_restart_secs += restart_pay;
            self.total_restarts += 1;
        }

        self.committed += w;
        self.peak_allocated = self.peak_allocated.max(self.committed);
        self.busy_gpu_secs += w as f64 * duration;
        self.queue.push(Event { time: end, kind: EventKind::SegmentEnd, job: id });
        if let Some(deadline) = budget_deadline {
            self.queue.push(Event { time: deadline, kind: EventKind::BudgetCheck, job: id });
        }
        if sink.enabled() {
            sink.count("launches", 1);
            if pay_restart {
                sink.count("restarts", 1);
            }
            let tenancy = if self.cfg.link_contention.enabled()
                && !self.cfg.topology.is_flat()
            {
                self.cluster.tenancy_of(id)
            } else {
                1
            };
            sink.emit(event(
                "seg_launch",
                now,
                vec![
                    ("job", Json::num(id as f64)),
                    ("w", Json::num(w as f64)),
                    ("nodes", Json::num(nodes as f64)),
                    ("steps", Json::num(steps as f64)),
                    ("restart", Json::Bool(pay_restart)),
                    ("restart_pay", Json::num(restart_pay)),
                    ("step_secs", Json::num(step_secs)),
                    ("end", Json::num(end)),
                    ("tenancy", Json::num(tenancy as f64)),
                ],
            ));
        }
        Ok(())
    }

    fn idx(&self, id: u64) -> Result<usize> {
        self.index
            .get(&id)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown job id {id}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_names_resolve() {
        for (name, want) in [
            ("doubling", "doubling"),
            ("precompute", "doubling"),
            ("optimus", "optimus-greedy"),
            ("exact", "exact-dp"),
            ("fixed-4", "fixed-4"),
            ("fixed-1", "fixed-1"),
        ] {
            assert_eq!(scheduler_by_name(name).unwrap().name(), want, "{name}");
        }
        assert!(scheduler_by_name("fixed-0").is_err());
        assert!(scheduler_by_name("fixed-x").is_err());
        assert!(scheduler_by_name("annealing").is_err());
    }

    #[test]
    fn config_validation_catches_nonsense() {
        let train = TrainConfig::new("artifacts", "tiny", 1);
        let specs = generate_trace(&TraceGen::default(), 1);
        let mut cfg = OrchestratorConfig::new(train.clone(), 0);
        assert!(Orchestrator::new(&cfg, &specs).is_err());
        cfg.capacity = 4;
        cfg.segment_steps = 0;
        assert!(Orchestrator::new(&cfg, &specs).is_err());
        cfg.segment_steps = 8;
        assert!(Orchestrator::new(&cfg, &[]).is_err());
        cfg.segment_budget_secs = 0.0;
        assert!(Orchestrator::new(&cfg, &specs).is_err());
        cfg.segment_budget_secs = f64::NAN;
        assert!(Orchestrator::new(&cfg, &specs).is_err());
        cfg.segment_budget_secs = f64::INFINITY;
        assert!(Orchestrator::new(&cfg, &specs).is_ok());
    }
}
