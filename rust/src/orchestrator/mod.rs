//! Live multi-job orchestrator: the doubling scheduler as an *online
//! service* over a stream of arriving jobs, executed against real
//! concurrent trainers.
//!
//! This is the piece that closes the gap between the two halves of the
//! repo: the DES ([`crate::sim`]) reaches the paper's Table-3 result but
//! never trains anything, while the coordinator
//! ([`crate::coordinator`]) drives the real trainer but only one job at
//! a time. The orchestrator owns a shared worker pool, admits jobs from
//! a JSONL trace ([`trace`]) or the paper-calibrated generators, and
//! runs every admitted job as a real in-process trainer
//! ([`crate::trainer`]) — many jobs training concurrently on real
//! worker threads, gradients moving through the real all-reduce.
//!
//! **Two clocks.** Real training wall time on a shared CPU says nothing
//! about a 64-GPU cluster, so the orchestrator separates execution from
//! accounting: segments *execute* for real (real parameters, real
//! checkpoints, real eq-7 LR rescaling), while scheduling and metrics
//! advance on a *virtual* clock where a segment of `e` epochs at `w`
//! workers costs `e · secs_per_epoch(w)` from the job's profile, plus
//! the §6 restart charge whenever the worker count changes. Every
//! decision is a pure function of trace + seed, so an orchestrated run
//! is deterministic end to end (asserted in tests) even though runner
//! threads race underneath — the event loop orders segment completions
//! by virtual time and joins each real thread only when its virtual end
//! event fires.
//!
//! **Decision points.** The configured [`Scheduler`] (doubling, optimus,
//! exact, fixed-k) runs after every event batch — arrival, finish, or
//! segment boundary — over the jobs that are actually stoppable: queued
//! jobs and jobs parked at a boundary. Workers committed to in-flight
//! segments are not available (a real cluster cannot preempt a Horovod
//! job mid-step; it stops it at the next boundary), which is the honest
//! live version of the DES's instant global reallocation — the measured
//! gap between the two is the boundary-granularity cost, and the
//! sim-vs-real experiment in EXPERIMENTS.md quantifies it.
//!
//! Reallocation executes the paper's mechanism for real: stop, atomic
//! checkpoint to disk, reload, restart the trainer at the new width with
//! eq 7's LR rescaling applied structurally by the `base·w` schedule.

pub mod event;
pub mod executor;
pub mod job;
pub mod report;
pub mod trace;

pub use job::{Job, JobSpec, JobState};
pub use report::{JobReport, OrchestratorReport};
pub use trace::{generate as generate_trace, load_trace, save_trace, TraceGen};

use std::collections::BTreeMap;
use std::time::Instant;

use event::{Event, EventKind, EventQueue};
use executor::{spawn_segment, SegmentPlan};

use crate::cluster::{ClusterSpec, ClusterState};
use crate::runtime::Artifacts;
use crate::scheduler::{total_allocated, JobInfo, Scheduler, Speed};
use crate::trainer::TrainConfig;
use crate::Result;

/// Progress below this epoch remainder counts as converged.
const EPOCH_EPS: f64 = 1e-9;

/// Configuration of one orchestrated run.
#[derive(Clone, Debug)]
pub struct OrchestratorConfig {
    /// Cluster worker capacity shared by all jobs.
    pub capacity: usize,
    /// Virtual seconds charged whenever a job (re)starts with a new
    /// worker count (§6: stop/checkpoint/restart ≈ 10 s).
    pub restart_cost: f64,
    /// Real trainer steps per segment between scheduling decisions.
    pub segment_steps: u64,
    /// Trainer template; per-segment copies get `workers` set and the
    /// seed mixed with the job id (distinct corpora per job).
    pub train: TrainConfig,
}

impl OrchestratorConfig {
    pub fn new(train: TrainConfig, capacity: usize) -> OrchestratorConfig {
        OrchestratorConfig { capacity, restart_cost: 10.0, segment_steps: 16, train }
    }
}

/// Resolve a strategy name to a scheduler:
/// `doubling | optimus | exact | fixed-K`.
pub fn scheduler_by_name(name: &str) -> Result<Box<dyn Scheduler>> {
    use crate::scheduler::{doubling::Doubling, exact::ExactDp, fixed::Fixed, optimus::OptimusGreedy};
    Ok(match name {
        "doubling" | "precompute" => Box::new(Doubling),
        "optimus" | "greedy" => Box::new(OptimusGreedy),
        "exact" => Box::new(ExactDp),
        other => match other.strip_prefix("fixed-") {
            Some(k) => {
                let k: usize =
                    k.parse().map_err(|e| anyhow::anyhow!("strategy {other:?}: {e}"))?;
                anyhow::ensure!(k >= 1, "strategy {other:?}: k must be >= 1");
                Box::new(Fixed(k))
            }
            None => anyhow::bail!(
                "unknown strategy {other:?}: want doubling|optimus|exact|fixed-K"
            ),
        },
    })
}

/// Run the full workload to completion under `scheduler`; returns the
/// per-job and cluster metrics. Errors if any job can never be placed.
pub fn orchestrate(
    cfg: &OrchestratorConfig,
    scheduler: &dyn Scheduler,
    specs: &[JobSpec],
) -> Result<OrchestratorReport> {
    Orchestrator::new(cfg, specs)?.run(scheduler)
}

struct Orchestrator {
    cfg: OrchestratorConfig,
    /// Preset batch size (the epochs-per-step arithmetic shared with the
    /// trainer: one step advances `batch·w / dataset_examples` epochs).
    batch: usize,
    jobs: Vec<Job>,
    /// Spec id -> index into `jobs`.
    index: BTreeMap<u64, usize>,
    queue: EventQueue,
    /// Placement ledger (second line of defense against double-booking).
    cluster: ClusterState,
    /// Workers committed to in-flight segments.
    committed: usize,
    now: f64,
    busy_gpu_secs: f64,
    peak_allocated: usize,
    total_restarts: u64,
    events: u64,
}

impl Orchestrator {
    fn new(cfg: &OrchestratorConfig, specs: &[JobSpec]) -> Result<Orchestrator> {
        anyhow::ensure!(cfg.capacity >= 1, "capacity must be >= 1");
        anyhow::ensure!(cfg.segment_steps >= 1, "segment_steps must be >= 1");
        anyhow::ensure!(cfg.restart_cost >= 0.0, "restart_cost must be >= 0");
        anyhow::ensure!(cfg.train.dataset_examples >= 1, "dataset_examples must be >= 1");
        anyhow::ensure!(!specs.is_empty(), "no jobs to orchestrate");

        let batch = Artifacts::resolve(&cfg.train.artifacts_dir)?
            .preset(&cfg.train.preset)?
            .batch;

        let mut jobs = Vec::with_capacity(specs.len());
        let mut index = BTreeMap::new();
        let mut queue = EventQueue::new();
        for spec in specs {
            anyhow::ensure!(spec.max_w >= 1, "job {}: max_w must be >= 1", spec.id);
            anyhow::ensure!(
                spec.profile.arrival.is_finite() && spec.profile.arrival >= 0.0,
                "job {}: bad arrival",
                spec.id
            );
            anyhow::ensure!(
                index.insert(spec.id, jobs.len()).is_none(),
                "duplicate job id {}",
                spec.id
            );
            queue.push(Event {
                time: spec.profile.arrival,
                kind: EventKind::Arrival,
                job: spec.id,
            });
            jobs.push(Job::new(spec.clone()));
        }

        Ok(Orchestrator {
            cfg: cfg.clone(),
            batch,
            jobs,
            index,
            queue,
            cluster: ClusterState::new(ClusterSpec::new(1, cfg.capacity)),
            committed: 0,
            now: 0.0,
            busy_gpu_secs: 0.0,
            peak_allocated: 0,
            total_restarts: 0,
            events: 0,
        })
    }

    fn run(mut self, scheduler: &dyn Scheduler) -> Result<OrchestratorReport> {
        let wall = Instant::now();
        while let Some((t, batch)) = self.queue.pop_batch() {
            self.now = t;
            for ev in batch {
                self.events += 1;
                match ev.kind {
                    EventKind::Arrival => self.on_arrival(ev.job)?,
                    EventKind::SegmentEnd => self.on_segment_end(ev.job)?,
                }
            }
            self.reallocate(scheduler)?;
        }

        let stuck: Vec<u64> = self
            .jobs
            .iter()
            .filter(|j| !matches!(j.state, JobState::Done { .. }))
            .map(|j| j.spec.id)
            .collect();
        anyhow::ensure!(
            stuck.is_empty(),
            "orchestration stalled with jobs {stuck:?} unfinished (strategy {:?} can never \
             place them within capacity {})",
            scheduler.name(),
            self.cfg.capacity
        );

        let mut job_reports = Vec::with_capacity(self.jobs.len());
        for j in &self.jobs {
            let finish = match j.state {
                JobState::Done { finish } => finish,
                _ => unreachable!("checked above"),
            };
            let first_start = j.first_start.expect("done job must have started");
            job_reports.push(JobReport {
                id: j.spec.id,
                arrival: j.spec.profile.arrival,
                first_start,
                finish,
                queue_secs: first_start - j.spec.profile.arrival,
                jct_secs: finish - j.spec.profile.arrival,
                segments: j.segments,
                restarts: j.restarts,
                virtual_restart_secs: j.virtual_restart_secs,
                measured_restart_secs: j.measured_restart_secs,
                measured_train_secs: j.measured_train_secs,
                steps: j.steps_done,
                epochs: j.epochs_done,
                max_w: j.max_w_granted,
                final_loss: j.final_loss,
            });
        }

        let makespan = self.now;
        Ok(OrchestratorReport {
            strategy: scheduler.name().to_string(),
            capacity: self.cfg.capacity,
            jobs: job_reports,
            makespan_secs: makespan,
            utilization: self.busy_gpu_secs / (self.cfg.capacity as f64 * makespan).max(1e-9),
            peak_allocated: self.peak_allocated,
            total_restarts: self.total_restarts,
            events: self.events,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }

    fn on_arrival(&mut self, id: u64) -> Result<()> {
        let idx = self.idx(id)?;
        self.jobs[idx].transition(JobState::Queued)
    }

    /// Join the real runner thread for this job's segment (it finished at
    /// this virtual instant), fold its outcome into the registry, and
    /// park the job at the boundary (or complete it).
    fn on_segment_end(&mut self, id: u64) -> Result<()> {
        let idx = self.idx(id)?;
        let now = self.now;
        let job = &mut self.jobs[idx];
        let workers = match job.state {
            JobState::Running { workers } => workers,
            ref other => {
                anyhow::bail!("job {id}: segment end while {}", other.name())
            }
        };
        let rx = job
            .inflight
            .take()
            .ok_or_else(|| anyhow::anyhow!("job {id}: no in-flight segment"))?;
        let outcome = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("job {id}: segment runner thread vanished"))??;

        job.epochs_done = outcome.checkpoint.epochs;
        job.steps_done = outcome.checkpoint.step;
        job.checkpoint = Some(outcome.checkpoint);
        job.last_w = workers;
        job.boundary_time = Some(now);
        job.measured_train_secs += outcome.train_secs;
        // Startup is paid on every segment (each is a fresh `train` call)
        // but only counts as *restart* overhead when the job was actually
        // stopped — continuations' startup is an execution artifact.
        if job.last_segment_restarted {
            job.measured_restart_secs += outcome.ckpt_io_secs + outcome.startup_secs;
        }
        if let Some(l) = outcome.final_loss {
            job.final_loss = Some(l);
        }

        if job.remaining_epochs() <= EPOCH_EPS {
            job.transition(JobState::Done { finish: now })?;
        } else {
            job.transition(JobState::Preempted)?;
        }
        self.committed -= workers;
        self.cluster.release(id)?;
        Ok(())
    }

    /// Invoke the strategy over every stoppable job, then launch the
    /// granted segments. Workers held by in-flight segments are off the
    /// table; the hard capacity invariant is re-checked on every launch.
    fn reallocate(&mut self, scheduler: &dyn Scheduler) -> Result<()> {
        let mut schedulable: Vec<usize> = (0..self.jobs.len())
            .filter(|&i| self.jobs[i].is_schedulable())
            .collect();
        if schedulable.is_empty() {
            return Ok(());
        }
        // FIFO by (arrival, id) — the order every strategy sees.
        schedulable.sort_by(|&a, &b| {
            let ja = &self.jobs[a].spec;
            let jb = &self.jobs[b].spec;
            ja.profile
                .arrival
                .total_cmp(&jb.profile.arrival)
                .then_with(|| ja.id.cmp(&jb.id))
        });

        let free = self.cfg.capacity - self.committed;
        let infos: Vec<JobInfo> = schedulable
            .iter()
            .map(|&i| {
                let j = &self.jobs[i];
                JobInfo {
                    id: j.spec.id,
                    q: j.remaining_epochs().max(1e-6),
                    speed: Speed::Table(j.spec.profile.speed_table()),
                    max_w: j.spec.max_w.min(self.cfg.capacity),
                }
            })
            .collect();
        let alloc = scheduler.allocate(&infos, free);
        anyhow::ensure!(
            total_allocated(&alloc) <= free,
            "scheduler {:?} over-allocated: {} granted, {free} free",
            scheduler.name(),
            total_allocated(&alloc)
        );

        for info in &infos {
            let w = alloc.get(&info.id).copied().unwrap_or(0);
            if w > 0 {
                self.launch(info.id, w)?;
            }
        }
        Ok(())
    }

    /// Start one training segment for `id` at `w` workers: charge the §6
    /// restart cost if the width changed (or cold start), size the
    /// segment, spawn the real runner thread, and enqueue the segment's
    /// virtual end event.
    fn launch(&mut self, id: u64, w: usize) -> Result<()> {
        anyhow::ensure!(
            self.committed + w <= self.cfg.capacity,
            "capacity invariant violated launching job {id}: {} committed + {w} > {}",
            self.committed,
            self.cfg.capacity
        );
        let idx = self.idx(id)?;
        self.cluster.place(id, w)?;

        let now = self.now;
        let restart_cost = self.cfg.restart_cost;
        let segment_steps = self.cfg.segment_steps;
        let dataset = self.cfg.train.dataset_examples;
        let batch = self.batch;

        let mut tcfg = self.cfg.train.clone();
        tcfg.workers = w;
        tcfg.seed = self.cfg.train.seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);

        let job = &mut self.jobs[idx];
        // A segment is a *continuation* (the job was never stopped) only
        // when it resumes at the same width at the very instant its last
        // segment ended. Everything else — cold start, width change, or
        // sitting parked while its workers ran other jobs — is a real
        // stop→restart and pays the §6 cost, exactly like the DES
        // (sim/des.rs charges on every `w` transition, including 0→w).
        let continued = job.last_w == w
            && job
                .boundary_time
                .map(|t| t.to_bits() == now.to_bits())
                .unwrap_or(false);
        let pay_restart = !continued;

        // One step advances batch·w/M epochs — identical to the trainer's
        // own accounting, so virtual progress and real checkpoints agree.
        let epochs_per_step = (batch * w) as f64 / dataset as f64;
        let needed = (job.remaining_epochs() / epochs_per_step).ceil().max(1.0) as u64;
        let steps = needed.min(segment_steps);
        let seg_epochs = steps as f64 * epochs_per_step;
        let restart_pay = if pay_restart { restart_cost } else { 0.0 };
        let duration = restart_pay + seg_epochs * job.spec.profile.secs_per_epoch(w);

        let restart_from_disk = pay_restart && job.checkpoint.is_some();
        let plan = SegmentPlan {
            job: id,
            workers: w,
            steps,
            resume: job.checkpoint.take(),
            restart_from_disk,
            config: tcfg,
        };
        job.transition(JobState::Running { workers: w })?;
        job.inflight = Some(spawn_segment(plan));
        job.last_segment_restarted = pay_restart;
        job.segments += 1;
        job.max_w_granted = job.max_w_granted.max(w);
        if job.first_start.is_none() {
            job.first_start = Some(now);
        }
        if pay_restart {
            job.restarts += 1;
            job.virtual_restart_secs += restart_pay;
            self.total_restarts += 1;
        }

        self.committed += w;
        self.peak_allocated = self.peak_allocated.max(self.committed);
        self.busy_gpu_secs += w as f64 * duration;
        self.queue.push(Event { time: now + duration, kind: EventKind::SegmentEnd, job: id });
        Ok(())
    }

    fn idx(&self, id: u64) -> Result<usize> {
        self.index
            .get(&id)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown job id {id}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_names_resolve() {
        for (name, want) in [
            ("doubling", "doubling"),
            ("precompute", "doubling"),
            ("optimus", "optimus-greedy"),
            ("exact", "exact-dp"),
            ("fixed-4", "fixed-4"),
            ("fixed-1", "fixed-1"),
        ] {
            assert_eq!(scheduler_by_name(name).unwrap().name(), want, "{name}");
        }
        assert!(scheduler_by_name("fixed-0").is_err());
        assert!(scheduler_by_name("fixed-x").is_err());
        assert!(scheduler_by_name("annealing").is_err());
    }

    #[test]
    fn config_validation_catches_nonsense() {
        let train = TrainConfig::new("artifacts", "tiny", 1);
        let specs = generate_trace(&TraceGen::default(), 1);
        let mut cfg = OrchestratorConfig::new(train.clone(), 0);
        assert!(Orchestrator::new(&cfg, &specs).is_err());
        cfg.capacity = 4;
        cfg.segment_steps = 0;
        assert!(Orchestrator::new(&cfg, &specs).is_err());
        cfg.segment_steps = 8;
        assert!(Orchestrator::new(&cfg, &[]).is_err());
    }
}
