//! Per-job and cluster-level metrics of an orchestrated run.
//!
//! The per-job table carries the Table-3 statistic (job completion time)
//! plus the orchestrator-only quantities the DES cannot measure: real
//! seconds spent in `trainer::train`, measured restart overhead
//! (checkpoint I/O + engine startup), and the final training loss. The
//! cluster summary reports average/median JCT, queueing delay,
//! utilization (busy GPU-seconds over capacity × makespan), and restart
//! counts — everything the sim-vs-real experiment compares.

use crate::cluster::Topology;
use crate::metrics::{quantile, CsvTable};

/// Completed-job metrics (all times in virtual seconds unless noted).
#[derive(Clone, Debug)]
pub struct JobReport {
    pub id: u64,
    pub arrival: f64,
    pub first_start: f64,
    pub finish: f64,
    /// Arrival → first workers granted.
    pub queue_secs: f64,
    /// Arrival → finish (the Table-3 statistic).
    pub jct_secs: f64,
    pub segments: u64,
    /// Cold start + every worker-count change.
    pub restarts: u64,
    pub virtual_restart_secs: f64,
    /// Real measured checkpoint I/O + engine startup seconds.
    pub measured_restart_secs: f64,
    /// Real measured seconds inside `trainer::train`.
    pub measured_train_secs: f64,
    /// Real measured checkpoint I/O seconds: restart round trips plus,
    /// under `--ckpt-store`, boundary park-saves and the completion free.
    pub ckpt_io_secs: f64,
    /// Real checkpoint bytes written over the job's lifetime (round
    /// trips + store park-saves).
    pub ckpt_bytes_written: u64,
    /// Bytes written by restart round trips only — the whole-file vs
    /// store dedup comparison (`--ckpt-store` makes this the deduped
    /// delta; the default path pays the full file image per restart).
    pub restart_ckpt_bytes: u64,
    pub steps: u64,
    pub epochs: f64,
    /// Largest worker count the job ever held.
    pub max_w: usize,
    /// Widest node span any segment's ring ever had (1 on flat pools).
    pub max_nodes: usize,
    /// Segments whose ring crossed a node boundary.
    pub cross_node_segments: u64,
    pub final_loss: Option<f32>,
    /// `--online-model` only: learned-model-vs-trace-truth RMSE
    /// (secs/epoch over the trace table's widths) at the first refit the
    /// confidence gate accepted, and at the last — the learned-vs-oracle
    /// gap and its trajectory as segments accumulated.
    pub model_rmse_first: Option<f64>,
    pub model_rmse: Option<f64>,
    /// Completed segments when the confidence gate first opened; `None`
    /// when the scheduler only ever consulted the trace-table prior.
    pub learned_after_segments: Option<u64>,
    /// `--faults` give-up flag: the job exhausted its retry budget and
    /// never finished. `finish` is then the give-up instant and the job
    /// is excluded from every JCT/queueing aggregate.
    pub failed: bool,
    /// Failed segments over the job's lifetime (0 without `--faults`
    /// unless a real trainer died).
    pub failures: u64,
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct OrchestratorReport {
    pub strategy: String,
    pub capacity: usize,
    /// Pool shape the run was placed on.
    pub topology: Topology,
    pub jobs: Vec<JobReport>,
    /// Virtual time of the last completion.
    pub makespan_secs: f64,
    /// Busy GPU-seconds / (capacity × makespan), in [0, 1].
    pub utilization: f64,
    /// Largest number of workers ever simultaneously allocated.
    pub peak_allocated: usize,
    pub total_restarts: u64,
    /// Mid-segment preemptions (0 unless `preempt_on_arrival`).
    pub total_preemptions: u64,
    /// Segments across the whole run whose ring spanned >1 node.
    pub cross_node_segments: u64,
    /// Events processed by the loop (arrivals + segment ends).
    pub events: u64,
    /// Real wall seconds of the whole orchestration.
    pub wall_secs: f64,
}

impl OrchestratorReport {
    /// Jobs that actually completed — JCT statistics are over these
    /// only; a failed job's "JCT" would be the give-up instant, which
    /// is a policy artifact, not a completion time.
    fn finished(&self) -> impl Iterator<Item = &JobReport> {
        self.jobs.iter().filter(|j| !j.failed)
    }

    fn jcts_sorted(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.finished().map(|j| j.jct_secs).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        v
    }

    /// Average job completion time in virtual seconds (Table 3's
    /// metric), over finished jobs.
    pub fn avg_jct_secs(&self) -> f64 {
        let n = self.finished().count();
        if n == 0 {
            return 0.0;
        }
        self.finished().map(|j| j.jct_secs).sum::<f64>() / n as f64
    }

    pub fn p50_jct_secs(&self) -> f64 {
        let v = self.jcts_sorted();
        if v.is_empty() {
            0.0
        } else {
            quantile(&v, 0.5)
        }
    }

    pub fn avg_queue_secs(&self) -> f64 {
        let n = self.finished().count();
        if n == 0 {
            return 0.0;
        }
        self.finished().map(|j| j.queue_secs).sum::<f64>() / n as f64
    }

    /// Jobs that exhausted their retry budget (`--faults` give-ups).
    pub fn failed_jobs(&self) -> usize {
        self.jobs.iter().filter(|j| j.failed).count()
    }

    /// Failed segments across the whole run.
    pub fn total_failures(&self) -> u64 {
        self.jobs.iter().map(|j| j.failures).sum()
    }

    /// Jobs whose confidence gate opened (ran on a learned model).
    pub fn learned_jobs(&self) -> usize {
        self.jobs.iter().filter(|j| j.learned_after_segments.is_some()).count()
    }

    /// Total measured checkpoint bytes written across the run.
    pub fn ckpt_bytes_written(&self) -> u64 {
        self.jobs.iter().map(|j| j.ckpt_bytes_written).sum()
    }

    /// Total measured checkpoint I/O seconds across the run.
    pub fn ckpt_io_secs(&self) -> f64 {
        self.jobs.iter().map(|j| j.ckpt_io_secs).sum()
    }

    /// Restart-round-trip bytes only (the dedup comparison metric).
    pub fn restart_ckpt_bytes(&self) -> u64 {
        self.jobs.iter().map(|j| j.restart_ckpt_bytes).sum()
    }

    /// Aligned per-job table (rendered by `ringmaster orchestrate`).
    pub fn per_job_table(&self) -> CsvTable {
        let mut t = CsvTable::new(&[
            "job", "arrival_s", "queue_s", "jct_s", "segs", "restarts", "fails", "max_w",
            "nodes", "xnode_segs", "steps", "epochs", "train_s(real)", "restart_s(real)",
            "ckpt_kb", "rmse", "final_loss",
        ]);
        for j in &self.jobs {
            t.row(&[
                j.id.to_string(),
                format!("{:.1}", j.arrival),
                format!("{:.1}", j.queue_secs),
                // a failed job has no completion time — mark the give-up
                if j.failed { "FAILED".into() } else { format!("{:.1}", j.jct_secs) },
                j.segments.to_string(),
                j.restarts.to_string(),
                j.failures.to_string(),
                j.max_w.to_string(),
                j.max_nodes.to_string(),
                j.cross_node_segments.to_string(),
                j.steps.to_string(),
                format!("{:.2}", j.epochs),
                format!("{:.2}", j.measured_train_secs),
                format!("{:.2}", j.measured_restart_secs),
                format!("{:.1}", j.ckpt_bytes_written as f64 / 1024.0),
                j.model_rmse.map(|r| format!("{r:.2}")).unwrap_or_else(|| "-".into()),
                j.final_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// Multi-line cluster summary.
    pub fn summary(&self) -> String {
        let learned = if self.learned_jobs() > 0 {
            format!("  learned models {}/{}", self.learned_jobs(), self.jobs.len())
        } else {
            String::new()
        };
        let failed = if self.failed_jobs() > 0 || self.total_failures() > 0 {
            format!(
                "  failures {} (jobs failed {}/{})",
                self.total_failures(),
                self.failed_jobs(),
                self.jobs.len()
            )
        } else {
            String::new()
        };
        format!(
            "strategy={} capacity={} topology={} jobs={} events={}\n\
             avg JCT {:.1}s  p50 JCT {:.1}s  avg queue {:.1}s  makespan {:.1}s (virtual)\n\
             utilization {:.1}%  peak workers {}  restarts {}  preemptions {}  \
             cross-node segs {}{learned}{failed}  ckpt io {:.2}s / {:.1} KiB written (real)  \
             orchestration wall {:.2}s (real)",
            self.strategy,
            self.capacity,
            self.topology.label(),
            self.jobs.len(),
            self.events,
            self.avg_jct_secs(),
            self.p50_jct_secs(),
            self.avg_queue_secs(),
            self.makespan_secs,
            100.0 * self.utilization,
            self.peak_allocated,
            self.total_restarts,
            self.total_preemptions,
            self.cross_node_segments,
            self.ckpt_io_secs(),
            self.ckpt_bytes_written() as f64 / 1024.0,
            self.wall_secs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, arrival: f64, start: f64, finish: f64) -> JobReport {
        JobReport {
            id,
            arrival,
            first_start: start,
            finish,
            queue_secs: start - arrival,
            jct_secs: finish - arrival,
            segments: 2,
            restarts: 1,
            virtual_restart_secs: 10.0,
            measured_restart_secs: 0.01,
            measured_train_secs: 0.5,
            ckpt_io_secs: 0.005,
            ckpt_bytes_written: 2048,
            restart_ckpt_bytes: 2048,
            steps: 32,
            epochs: 1.0,
            max_w: 4,
            max_nodes: 1,
            cross_node_segments: 0,
            final_loss: Some(1.25),
            model_rmse_first: None,
            model_rmse: None,
            learned_after_segments: None,
            failed: false,
            failures: 0,
        }
    }

    fn report() -> OrchestratorReport {
        OrchestratorReport {
            strategy: "doubling".into(),
            capacity: 8,
            topology: Topology::flat(8),
            jobs: vec![job(0, 0.0, 0.0, 100.0), job(1, 0.0, 50.0, 200.0), job(2, 10.0, 60.0, 310.0)],
            makespan_secs: 310.0,
            utilization: 0.8,
            peak_allocated: 8,
            total_restarts: 3,
            total_preemptions: 0,
            cross_node_segments: 0,
            events: 9,
            wall_secs: 1.5,
        }
    }

    #[test]
    fn aggregates_are_right() {
        let r = report();
        assert!((r.avg_jct_secs() - 200.0).abs() < 1e-9);
        assert!((r.p50_jct_secs() - 200.0).abs() < 1e-9);
        assert!((r.avg_queue_secs() - (0.0 + 50.0 + 50.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn tables_render_every_job_and_summary_names_the_metrics() {
        let r = report();
        let rendered = r.per_job_table().render();
        for id in ["0", "1", "2"] {
            assert!(rendered.contains(id));
        }
        let s = r.summary();
        assert!(s.contains("avg JCT") && s.contains("utilization") && s.contains("doubling"));
        // 3 jobs x 2048 bytes = 6 KiB of measured checkpoint writes
        assert!(s.contains("ckpt io") && s.contains("6.0 KiB"), "{s}");
        assert!(rendered.contains("ckpt_kb") && rendered.contains("2.0"), "{rendered}");
    }

    #[test]
    fn learned_model_metrics_render_when_present() {
        let mut r = report();
        assert_eq!(r.learned_jobs(), 0);
        assert!(!r.summary().contains("learned models"));
        let rendered = r.per_job_table().render();
        assert!(rendered.contains("rmse"));
        r.jobs[0].model_rmse_first = Some(4.5);
        r.jobs[0].model_rmse = Some(1.25);
        r.jobs[0].learned_after_segments = Some(3);
        assert_eq!(r.learned_jobs(), 1);
        assert!(r.summary().contains("learned models 1/3"), "{}", r.summary());
        assert!(r.per_job_table().render().contains("1.25"));
    }

    #[test]
    fn failed_jobs_are_excluded_from_jct_aggregates() {
        let mut r = report();
        // job 2's "finish" becomes a give-up instant, not a completion
        r.jobs[2].failed = true;
        r.jobs[2].failures = 4;
        assert_eq!(r.failed_jobs(), 1);
        assert_eq!(r.total_failures(), 4);
        // aggregates over jobs 0 and 1 only
        assert!((r.avg_jct_secs() - 150.0).abs() < 1e-9);
        assert!((r.avg_queue_secs() - 25.0).abs() < 1e-9);
        let s = r.summary();
        assert!(s.contains("jobs failed 1/3"), "{s}");
        assert!(r.per_job_table().render().contains("FAILED"));
        // an all-failed fleet must not divide by zero
        for j in r.jobs.iter_mut() {
            j.failed = true;
        }
        assert_eq!(r.avg_jct_secs(), 0.0);
    }

    #[test]
    fn empty_report_does_not_divide_by_zero() {
        let mut r = report();
        r.jobs.clear();
        assert_eq!(r.avg_jct_secs(), 0.0);
        assert_eq!(r.p50_jct_secs(), 0.0);
        assert_eq!(r.avg_queue_secs(), 0.0);
    }
}
