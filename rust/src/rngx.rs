//! Deterministic pseudo-random numbers for the simulator and workloads.
//!
//! Self-contained (no `rand` dependency) so simulation runs are exactly
//! reproducible across platforms: xoshiro256++ seeded through SplitMix64,
//! plus the distributions the paper's simulation needs — uniform,
//! exponential inter-arrival times (Poisson process, §7), and normal noise
//! for job-profile jitter.

/// SplitMix64: seeds the main generator from a single u64.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministic generator from a single seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for simulation purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Exponential with the given mean (inter-arrival times of a Poisson
    /// process; the paper uses means of 250/500/1000 s — §7).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal-ish multiplicative jitter: exp(std * N(0,1)).
    pub fn jitter(&mut self, std: f64) -> f64 {
        (std * self.normal()).exp()
    }

    /// Random f32 vector with entries in [-1, 1) (collective tests).
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform_range(-1.0, 1.0) as f32).collect()
    }

    /// Fork a child generator (stream-split for parallel components).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean_matches() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(250.0)).sum::<f64>() / n as f64;
        assert!((mean - 250.0).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn exponential_positive() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.exponential(1.0) > 0.0);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(19);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork();
        let mut b = root.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
