//! Lightweight metrics: counters, wall-clock timers, streaming stats, and
//! CSV emission for the bench harnesses. No external deps — results must
//! be exactly reproducible and the vendor snapshot has no metrics crates.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::jsonx::Json;

/// Streaming mean/min/max/count (Welford for variance).
#[derive(Clone, Debug, Default)]
pub struct Stat {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stat {
    pub fn new() -> Self {
        Stat { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Linear-interpolated quantile of an ascending-sorted slice (q=0.5 is
/// the median). Total on degenerate input instead of panicking: `q` is
/// clamped to `[0, 1]` (a NaN `q` reads as the median), NaN samples are
/// skipped, and an empty or all-NaN slice yields 0.0 — callers render
/// "no data" as a zero cell rather than poisoning a whole stats table.
/// Used by the orchestrator's cluster-level JCT statistics.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    let q = if q.is_nan() { 0.5 } else { q.clamp(0.0, 1.0) };
    // NaN sorts nowhere; dropping it keeps the remaining slice ascending
    let clean: Vec<f64> = sorted.iter().copied().filter(|x| !x.is_nan()).collect();
    if clean.is_empty() {
        return 0.0;
    }
    let pos = q * (clean.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    clean[lo] + (clean[hi] - clean[lo]) * frac
}

/// Named scope timer collection.
#[derive(Debug, Default)]
pub struct Timers {
    stats: BTreeMap<String, Stat>,
}

impl Timers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under `name` (seconds).
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.record(name, start.elapsed().as_secs_f64());
        out
    }

    pub fn record(&mut self, name: &str, secs: f64) {
        self.stats.entry(name.to_string()).or_insert_with(Stat::new).push(secs);
    }

    pub fn get(&self, name: &str) -> Option<&Stat> {
        self.stats.get(name)
    }

    /// Human-readable summary table.
    pub fn summary(&self) -> String {
        let mut out = String::from("timer                          n      mean       min       max\n");
        for (name, s) in &self.stats {
            out.push_str(&format!(
                "{:<28} {:>5} {:>9.4} {:>9.4} {:>9.4}\n",
                name,
                s.count(),
                s.mean(),
                s.min(),
                s.max()
            ));
        }
        out
    }
}

/// Minimal CSV table writer (used by benches to dump paper tables).
#[derive(Debug)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",") + "\n";
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Aligned plain-text rendering (what the benches print).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> crate::Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Shared `BENCH_*.json` emitter for the bench harnesses: top-level
/// metadata (bench name, run parameters) plus an array of uniform row
/// objects — the machine-readable perf trajectory later PRs race.
///
/// Cargo runs bench binaries with the *package* root as cwd, so
/// [`BenchJson::save`] anchors the file at the repo root above the
/// caller's `env!("CARGO_MANIFEST_DIR")` (the macro must expand in the
/// bench crate, hence the argument).
#[derive(Debug)]
pub struct BenchJson {
    bench: String,
    meta: Vec<(String, Json)>,
    rows: Vec<Json>,
}

impl BenchJson {
    pub fn new(bench: &str) -> Self {
        BenchJson { bench: bench.to_string(), meta: vec![], rows: vec![] }
    }

    /// Add one top-level metadata field (capacity, seed, ...).
    pub fn meta(&mut self, key: &str, value: Json) -> &mut Self {
        self.meta.push((key.to_string(), value));
        self
    }

    /// Add one result row.
    pub fn row(&mut self, fields: Vec<(&str, Json)>) {
        self.rows.push(Json::obj(fields));
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// One meta field per line, one row per line — diffable in the repo
    /// root while staying trivially machine-parseable.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": {},\n", Json::str(self.bench.as_str()).dump()));
        for (k, v) in &self.meta {
            out.push_str(&format!("  {}: {},\n", Json::str(k.as_str()).dump(), v.dump()));
        }
        out.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str("    ");
            out.push_str(&r.dump());
            out.push_str(if i + 1 == self.rows.len() { "\n" } else { ",\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write `BENCH_<tag>.json` at the repo root above `manifest_dir`
    /// and return the path written.
    pub fn save(&self, manifest_dir: &str, tag: &str) -> crate::Result<std::path::PathBuf> {
        let path = std::path::Path::new(manifest_dir)
            .parent()
            .ok_or_else(|| anyhow::anyhow!("manifest dir {manifest_dir:?} has no parent"))?
            .join(format!("BENCH_{tag}.json"));
        std::fs::write(&path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_moments() {
        let mut s = Stat::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&v, 0.25) - 1.75).abs() < 1e-12);
        assert_eq!(quantile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn quantile_is_total_on_degenerate_input() {
        // empty and all-NaN slices yield the documented 0.0
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(quantile(&[f64::NAN, f64::NAN], 0.5), 0.0);
        // single element is itself at every q
        assert_eq!(quantile(&[7.0], 0.0), 7.0);
        assert_eq!(quantile(&[7.0], 0.5), 7.0);
        assert_eq!(quantile(&[7.0], 1.0), 7.0);
        // NaN samples are skipped, not propagated
        let v = [1.0, 2.0, 3.0, f64::NAN];
        assert_eq!(quantile(&v, 1.0), 3.0);
        assert!((quantile(&v, 0.5) - 2.0).abs() < 1e-12);
        // q is clamped to [0, 1]; NaN q reads as the median
        assert_eq!(quantile(&[1.0, 3.0], 2.0), 3.0);
        assert_eq!(quantile(&[1.0, 3.0], -1.0), 1.0);
        assert!((quantile(&[1.0, 3.0], f64::NAN) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn timers_record_and_summarize() {
        let mut t = Timers::new();
        let out = t.time("op", || 42);
        assert_eq!(out, 42);
        t.record("op", 0.5);
        assert_eq!(t.get("op").unwrap().count(), 2);
        assert!(t.summary().contains("op"));
    }

    #[test]
    fn csv_round_trip() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(&["1".into(), "x".into()]);
        t.row(&["2".into(), "y".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,x\n2,y\n");
        let rendered = t.render();
        assert!(rendered.contains('x') && rendered.contains('y'));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn csv_rejects_ragged_rows() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn bench_json_emits_meta_and_rows() {
        let mut b = BenchJson::new("demo");
        b.meta("capacity", Json::num(128.0)).meta("seed", Json::num(42.0));
        b.row(vec![("jobs", Json::num(100.0)), ("wall_secs", Json::num(0.25))]);
        assert_eq!(b.len(), 1);
        assert!(!b.is_empty());
        let text = b.to_json();
        assert!(text.starts_with("{\n  \"bench\": \"demo\",\n"), "{text}");
        assert!(text.contains("\"capacity\": 128,"), "{text}");
        assert!(text.contains("{\"jobs\":100,\"wall_secs\":0.25}"), "{text}");
        // the whole document is valid JSON and round-trips
        let parsed = crate::jsonx::parse(&text).expect("valid JSON");
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "demo");
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
