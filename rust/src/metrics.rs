//! Lightweight metrics: counters, wall-clock timers, streaming stats, and
//! CSV emission for the bench harnesses. No external deps — results must
//! be exactly reproducible and the vendor snapshot has no metrics crates.

use std::collections::BTreeMap;
use std::time::Instant;

/// Streaming mean/min/max/count (Welford for variance).
#[derive(Clone, Debug, Default)]
pub struct Stat {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stat {
    pub fn new() -> Self {
        Stat { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Linear-interpolated quantile of an ascending-sorted slice
/// (`q` in `[0, 1]`; q=0.5 is the median). Used by the orchestrator's
/// cluster-level JCT statistics.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q={q} outside [0, 1]");
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Named scope timer collection.
#[derive(Debug, Default)]
pub struct Timers {
    stats: BTreeMap<String, Stat>,
}

impl Timers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under `name` (seconds).
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.record(name, start.elapsed().as_secs_f64());
        out
    }

    pub fn record(&mut self, name: &str, secs: f64) {
        self.stats.entry(name.to_string()).or_insert_with(Stat::new).push(secs);
    }

    pub fn get(&self, name: &str) -> Option<&Stat> {
        self.stats.get(name)
    }

    /// Human-readable summary table.
    pub fn summary(&self) -> String {
        let mut out = String::from("timer                          n      mean       min       max\n");
        for (name, s) in &self.stats {
            out.push_str(&format!(
                "{:<28} {:>5} {:>9.4} {:>9.4} {:>9.4}\n",
                name,
                s.count(),
                s.mean(),
                s.min(),
                s.max()
            ));
        }
        out
    }
}

/// Minimal CSV table writer (used by benches to dump paper tables).
#[derive(Debug)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",") + "\n";
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Aligned plain-text rendering (what the benches print).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> crate::Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_moments() {
        let mut s = Stat::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&v, 0.25) - 1.75).abs() < 1e-12);
        assert_eq!(quantile(&[7.0], 0.5), 7.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn quantile_rejects_empty() {
        let _ = quantile(&[], 0.5);
    }

    #[test]
    fn timers_record_and_summarize() {
        let mut t = Timers::new();
        let out = t.time("op", || 42);
        assert_eq!(out, 42);
        t.record("op", 0.5);
        assert_eq!(t.get("op").unwrap().count(), 2);
        assert!(t.summary().contains("op"));
    }

    #[test]
    fn csv_round_trip() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(&["1".into(), "x".into()]);
        t.row(&["2".into(), "y".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,x\n2,y\n");
        let rendered = t.render();
        assert!(rendered.contains('x') && rendered.contains('y'));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn csv_rejects_ragged_rows() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
