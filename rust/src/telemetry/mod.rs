//! Telemetry: structured run events, counters, and samples — the audit
//! substrate behind `ringmaster report`.
//!
//! Every engine (the event-heap DES, the live orchestrator) takes a
//! [`Sink`] and narrates itself through it: scheduler decision
//! provenance (every marginal-gain heap pop, the winning width, the
//! contention tenancy assumed at scoring vs observed at execution),
//! placement-ledger snapshots, segment lifecycle, and self-profiling
//! counters/samples. The stream is JSONL, schema v3 of the versioned
//! trace lineage (`orchestrator::trace` is v1/v2 — job *inputs*; this is
//! run *outputs*; the preamble's `"stream":"telemetry"` key tells the
//! two apart so neither loader misreads the other).
//!
//! **Zero cost when off.** The engines' public entry points
//! (`sim::simulate`, `orchestrator::orchestrate`) pass [`NullSink`],
//! every hook is guarded by [`Sink::enabled`], and hooks only *read*
//! engine state — so the telemetry-off engine is the pre-telemetry
//! engine, bit for bit (asserted in `tests/golden_parity.rs`).
//!
//! **Deterministic when on.** Everything serialized into the stream is
//! derived from the virtual clock and the seeded workload: two runs of
//! the same config and seed produce byte-identical files (also asserted
//! in golden_parity). Wall-clock self-profiling (per-phase timings)
//! therefore stays OUT of the stream: it lives in the recorder's
//! side-channel, rendered by [`Recorder::phase_summary`] for humans. The
//! one exception is the orchestrator's measured trainer timings, which
//! are emitted as events flagged `"measured":true` — the audit tool
//! reports them but never feeds them into an invariant.

pub mod audit;

use std::collections::BTreeMap;

use crate::jsonx::Json;
use crate::metrics::Stat;
use crate::Result;

/// Telemetry stream schema version. Versions 1 and 2 of the trace
/// lineage are job-submission traces (`orchestrator::trace`); v3 is the
/// first telemetry stream. The preamble line is
/// `{"ringmaster_trace":3,"stream":"telemetry"}`.
pub const TELEMETRY_VERSION: u64 = 3;

/// Event sink the engines narrate through. All methods must be cheap
/// no-ops when [`Sink::enabled`] is false; engine hooks additionally
/// guard any work needed to *build* an event behind `enabled()`, so the
/// disabled path never allocates, formats, or reads a clock.
pub trait Sink {
    /// Gate: engines skip event construction entirely when false.
    fn enabled(&self) -> bool;
    /// Gate for wall-clock self-profiling only. Defaults to
    /// [`Sink::enabled`] so existing sinks are unchanged; a sink may
    /// override it to collect [`Sink::phase_secs`] *without* paying
    /// for the event stream (see [`PhaseProfiler`]) — at 100k-job
    /// scale the stream is gigabytes, the phase table is a dozen
    /// floats.
    fn profiling(&self) -> bool {
        self.enabled()
    }
    /// Record one structured event (built with [`event`]).
    fn emit(&mut self, ev: Json);
    /// Bump a named counter.
    fn count(&mut self, name: &'static str, delta: u64);
    /// Record one sample of a named distribution (heap sizes, resync
    /// touch counts, queue depths, ...).
    fn sample(&mut self, name: &'static str, value: f64);
    /// Record wall seconds spent in a named engine phase. Side-channel:
    /// never serialized into the stream (wall clocks are not
    /// deterministic), only summarized for humans.
    fn phase_secs(&mut self, name: &'static str, secs: f64);
}

/// The disabled sink: every engine entry point without an explicit
/// telemetry argument uses this, and every method is a no-op, so
/// telemetry-off is structurally the pre-telemetry engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl Sink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn emit(&mut self, _ev: Json) {}
    fn count(&mut self, _name: &'static str, _delta: u64) {}
    fn sample(&mut self, _name: &'static str, _value: f64) {}
    fn phase_secs(&mut self, _name: &'static str, _secs: f64) {}
}

/// Phase-timings-only sink: `enabled()` is false (the engine builds no
/// events, touches no counters — the hot loop stays the telemetry-off
/// loop except for four `Instant::now()` reads per event), but
/// `profiling()` is true, so `phase_secs` accumulates. This is what the
/// scale benches run through to attribute wall time to engine phases
/// (fire / reallocate / scan / advance) at job counts where a full
/// [`Recorder`] would distort the measurement it is taking.
#[derive(Debug, Default)]
pub struct PhaseProfiler {
    phases: BTreeMap<&'static str, Stat>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        PhaseProfiler::default()
    }

    /// `(phase, calls, total_secs)` rows in phase-name order.
    pub fn totals(&self) -> Vec<(&'static str, u64, f64)> {
        self.phases.iter().map(|(&k, s)| (k, s.count(), s.mean() * s.count() as f64)).collect()
    }

    /// Total wall seconds attributed across all phases.
    pub fn total_secs(&self) -> f64 {
        self.totals().iter().map(|&(_, _, t)| t).sum()
    }
}

impl Sink for PhaseProfiler {
    fn enabled(&self) -> bool {
        false
    }
    fn profiling(&self) -> bool {
        true
    }
    fn emit(&mut self, _ev: Json) {}
    fn count(&mut self, _name: &'static str, _delta: u64) {}
    fn sample(&mut self, _name: &'static str, _value: f64) {}
    fn phase_secs(&mut self, name: &'static str, secs: f64) {
        self.phases.entry(name).or_insert_with(Stat::new).push(secs);
    }
}

/// Build one telemetry event: `{"ev":kind,"t":t, ...fields}`. Keys are
/// sorted by the `Json::Obj` BTreeMap, so serialization is
/// deterministic regardless of field order here.
pub fn event(kind: &str, t: f64, fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ev", Json::str(kind)), ("t", Json::num(t))];
    pairs.extend(fields);
    Json::obj(pairs)
}

/// In-memory recorder: serializes each event to one JSONL line as it
/// arrives (bounded memory per event, deterministic output), accumulates
/// counters/samples for the trailing summary line, and keeps wall-clock
/// phase timings in a non-serialized side channel.
#[derive(Debug, Default)]
pub struct Recorder {
    lines: Vec<String>,
    counters: BTreeMap<&'static str, u64>,
    samples: BTreeMap<&'static str, Stat>,
    phases: BTreeMap<&'static str, Stat>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    /// Events recorded so far.
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// The full stream: preamble, events in arrival order, then one
    /// `{"ev":"summary",...}` line with final counters and sample
    /// statistics. Byte-identical across runs of the same seeded config.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &Json::obj(vec![
                ("ringmaster_trace", Json::num(TELEMETRY_VERSION as f64)),
                ("stream", Json::str("telemetry")),
            ])
            .dump(),
        );
        out.push('\n');
        for line in &self.lines {
            out.push_str(line);
            out.push('\n');
        }
        let counters: Vec<(&str, Json)> =
            self.counters.iter().map(|(&k, &v)| (k, Json::num(v as f64))).collect();
        let samples: Vec<(&str, Json)> = self
            .samples
            .iter()
            .map(|(&k, s)| {
                (
                    k,
                    Json::obj(vec![
                        ("n", Json::num(s.count() as f64)),
                        ("mean", Json::num(s.mean())),
                        ("min", Json::num(s.min())),
                        ("max", Json::num(s.max())),
                    ]),
                )
            })
            .collect();
        out.push_str(
            &Json::obj(vec![
                ("ev", Json::str("summary")),
                ("counters", Json::Obj(counters.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
                ("samples", Json::Obj(samples.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
            ])
            .dump(),
        );
        out.push('\n');
        out
    }

    /// Write the stream to a file, atomically (tmp + fsync + rename +
    /// dir fsync) — a crash mid-write must not leave a torn stream that
    /// `report` then chokes on.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        crate::fsx::atomic_write(path, self.to_jsonl().as_bytes())
            .map_err(|e| anyhow::anyhow!("writing telemetry {}: {e}", path.display()))?;
        Ok(())
    }

    /// Human-readable table of the wall-clock phase side channel (the
    /// part of self-profiling that must stay out of the stream).
    pub fn phase_summary(&self) -> String {
        if self.phases.is_empty() {
            return String::new();
        }
        let mut out =
            String::from("phase                            n     total_s      mean_us\n");
        for (name, s) in &self.phases {
            let total = s.mean() * s.count() as f64;
            out.push_str(&format!(
                "{:<28} {:>6} {:>11.4} {:>12.2}\n",
                name,
                s.count(),
                total,
                s.mean() * 1e6
            ));
        }
        out
    }
}

impl Sink for Recorder {
    fn enabled(&self) -> bool {
        true
    }

    fn emit(&mut self, ev: Json) {
        self.lines.push(ev.dump());
    }

    fn count(&mut self, name: &'static str, delta: u64) {
        *self.counters.entry(name).or_insert(0) += delta;
    }

    fn sample(&mut self, name: &'static str, value: f64) {
        self.samples.entry(name).or_insert_with(Stat::new).push(value);
    }

    fn phase_secs(&mut self, name: &'static str, secs: f64) {
        self.phases.entry(name).or_insert_with(Stat::new).push(secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_sink_is_disabled_and_inert() {
        let mut s = NullSink;
        assert!(!s.enabled());
        assert!(!s.profiling(), "profiling() must follow enabled() by default");
        s.emit(event("x", 0.0, vec![]));
        s.count("c", 1);
        s.sample("s", 1.0);
        s.phase_secs("p", 0.1);
    }

    #[test]
    fn recorder_profiles_by_default() {
        // the default-method contract: an enabled sink profiles unless
        // it opts out
        assert!(Recorder::new().profiling());
    }

    #[test]
    fn phase_profiler_collects_timings_without_events() {
        let mut p = PhaseProfiler::new();
        assert!(!p.enabled());
        assert!(p.profiling());
        p.emit(event("x", 0.0, vec![])); // must be inert
        p.phase_secs("scan", 0.25);
        p.phase_secs("scan", 0.75);
        p.phase_secs("fire", 0.5);
        let rows = p.totals();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "fire");
        assert_eq!(rows[1], ("scan", 2, 1.0));
        assert!((p.total_secs() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn recorder_stream_has_preamble_events_and_summary() {
        let mut r = Recorder::new();
        r.emit(event("run_start", 0.0, vec![("capacity", Json::num(8.0))]));
        r.emit(event("arrival", 1.5, vec![("job", Json::num(0.0))]));
        r.count("arrivals", 1);
        r.sample("ready", 3.0);
        let text = r.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0], "{\"ringmaster_trace\":3,\"stream\":\"telemetry\"}");
        assert!(lines[1].contains("\"ev\":\"run_start\"") && lines[1].contains("\"capacity\":8"));
        assert!(lines[2].contains("\"ev\":\"arrival\""));
        assert!(lines[3].contains("\"ev\":\"summary\"") && lines[3].contains("\"arrivals\":1"));
    }

    #[test]
    fn recorder_serialization_is_deterministic() {
        let build = || {
            let mut r = Recorder::new();
            r.emit(event("e", 0.5, vec![("b", Json::num(2.0)), ("a", Json::num(1.0))]));
            r.count("z", 2);
            r.count("a", 1);
            r.sample("x", 0.25);
            r.sample("x", 0.75);
            r.to_jsonl()
        };
        assert_eq!(build(), build());
        // keys inside an event are sorted regardless of insertion order
        assert!(build().contains("{\"a\":1,\"b\":2,\"ev\":\"e\",\"t\":0.5}"));
    }

    #[test]
    fn phase_side_channel_stays_out_of_the_stream() {
        let mut r = Recorder::new();
        r.phase_secs("fire", 0.001);
        let text = r.to_jsonl();
        assert!(!text.contains("fire"), "wall-clock phases must not be serialized:\n{text}");
        assert!(r.phase_summary().contains("fire"));
    }

    #[test]
    fn save_is_atomic_and_cleans_tmp_on_failure() {
        let p = std::env::temp_dir()
            .join(format!("rm-telemetry-atomic-{}.jsonl", std::process::id()));
        let mut r = Recorder::new();
        r.emit(event("run_start", 0.0, vec![]));
        r.save(&p).unwrap();
        let tmp = p.with_file_name(format!("{}.tmp", p.file_name().unwrap().to_string_lossy()));
        assert!(!tmp.exists(), "tmp sibling left behind");
        // a stale tmp from a torn earlier writer must not break a resave
        std::fs::write(&tmp, b"torn partial stream").unwrap();
        r.save(&p).unwrap();
        assert!(!tmp.exists());
        assert_eq!(std::fs::read_to_string(&p).unwrap(), r.to_jsonl());
        let _ = std::fs::remove_file(&p);
        // rename failure (directory at the target): tmp removed, target intact
        let d = std::env::temp_dir()
            .join(format!("rm-telemetry-atomic-dir-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        assert!(r.save(&d).is_err());
        let dtmp = d.with_file_name(format!("{}.tmp", d.file_name().unwrap().to_string_lossy()));
        assert!(!dtmp.exists(), "failed save leaked the tmp sibling");
        assert!(d.is_dir());
        let _ = std::fs::remove_dir(&d);
    }
}
