//! Offline run audit: `ringmaster report` over a telemetry stream.
//!
//! The audit does two jobs at once. It *renders* a human-readable
//! account of the run — per-job timeline, utilization/queue-depth
//! curves, the restart-cost ledger, and a decision table with the "why
//! width w" provenance the scheduler recorded — and it *re-verifies*
//! the run event by event: every decision's `from` width must match the
//! replayed state, every grant-step chain must land on the granted
//! width, every placement snapshot must conserve capacity and per-node
//! occupancy, and the incremental crossing-ring ledger the engine
//! emitted must equal the rings recomputed from the placements alone.
//! A violation is a hard error (non-zero exit from the CLI), so a
//! checked-in golden stream doubles as a CI tripwire for both the
//! schema and the engine's conservation laws.
//!
//! Events flagged `"measured":true` carry wall-clock observations from
//! real trainer threads; they are summarized but never fed into an
//! invariant (they are not deterministic — DESIGN.md §14).

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::jsonx::{self, Json};
use crate::Result;

use super::TELEMETRY_VERSION;

/// Tolerance for replayed f64 identities (JCT vs arrival arithmetic).
const TIME_EPS: f64 = 1e-6;

/// Outcome of a successful audit.
pub struct Audit {
    /// Engine that produced the stream (`des` or `orchestrator`).
    pub engine: String,
    /// Events audited (excluding preamble and summary lines).
    pub events: usize,
    /// Individual invariant checks that passed.
    pub checks: u64,
    /// Rendered report.
    pub rendered: String,
}

#[derive(Default)]
struct JobTrack {
    arrival: Option<f64>,
    /// Granted/running width per the replay.
    width: usize,
    /// Exploration reservation per the replay (DES only).
    hold: usize,
    first_grant: Option<f64>,
    finish: Option<f64>,
    restarts: u64,
    restart_secs: f64,
    segments: u64,
    /// Last pessimistic tenancy a decision scored this job at.
    scored_tenancy: Option<usize>,
    /// Last tenancy observed at execution (place snapshot / launch).
    observed_tenancy: Option<usize>,
    /// Epochs at the last durable checkpoint an orchestrator
    /// `seg_failed` rolled back to — the `recovered` invariant bound.
    last_ckpt_epochs: Option<f64>,
    /// The job exhausted its retry budget; no further recovery allowed.
    gave_up: bool,
}

/// Fault/recovery event tallies for the rendered ledger.
#[derive(Default)]
struct FaultTally {
    node_downs: u64,
    evictions: u64,
    failures: u64,
    recoveries: u64,
    gave_ups: u64,
}

struct Run {
    engine: String,
    capacity: usize,
    nodes: usize,
    gpus_per_node: usize,
    contended: bool,
    restart_cost: f64,
}

/// One rendered decision-table row.
struct DecisionRow {
    t: f64,
    text: String,
}

/// Audit the telemetry stream at `path`.
pub fn audit_file(path: &Path) -> Result<Audit> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    audit_str(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

/// Audit a telemetry stream from memory. Errors on schema violations,
/// unknown versions, and any broken replay invariant.
pub fn audit_str(text: &str) -> Result<Audit> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, first) = lines.next().ok_or_else(|| anyhow::anyhow!("empty stream"))?;
    let preamble = jsonx::parse(first)?;
    let version = preamble
        .opt("ringmaster_trace")
        .ok_or_else(|| anyhow::anyhow!("not a ringmaster stream: no preamble line"))?
        .as_usize()? as u64;
    match preamble.opt("stream").map(|s| s.as_str()).transpose()? {
        Some("telemetry") => {}
        Some(other) => anyhow::bail!("unknown stream kind {other:?} (want \"telemetry\")"),
        None => anyhow::bail!(
            "this is a v{version} job-submission trace, not a telemetry stream; \
             feed it to `ringmaster orchestrate --trace`, then audit the \
             `--telemetry` output"
        ),
    }
    anyhow::ensure!(
        version == TELEMETRY_VERSION,
        "telemetry stream is schema v{version}; this build reads v{TELEMETRY_VERSION}"
    );

    let mut run: Option<Run> = None;
    let mut jobs: BTreeMap<u64, JobTrack> = BTreeMap::new();
    let mut events = 0usize;
    let mut checks = 0u64;
    let mut completions = 0u64;
    let mut total_restart_secs = 0.0f64;
    let mut total_restarts = 0u64;
    let mut preemptions = 0u64;
    let mut util_curve: Vec<(f64, f64, f64)> = Vec::new(); // t, used, queued
    let mut decision_rows: Vec<DecisionRow> = Vec::new();
    let mut measured: Vec<(f64, f64)> = Vec::new(); // (mean_step, mean_allreduce)
    let mut run_end: Option<Json> = None;
    let mut summary: Option<Json> = None;
    let mut makespan = 0.0f64;
    let mut down_nodes: BTreeSet<usize> = BTreeSet::new();
    let mut faults = FaultTally::default();

    macro_rules! check {
        ($line:expr, $cond:expr, $($msg:tt)*) => {
            anyhow::ensure!($cond, "line {}: {}", $line + 1, format!($($msg)*));
            checks += 1;
        };
    }

    for (ln, raw) in lines {
        let ev = jsonx::parse(raw).map_err(|e| anyhow::anyhow!("line {}: {e}", ln + 1))?;
        let kind = ev.get("ev")?.as_str()?.to_string();
        if kind == "summary" {
            summary = Some(ev);
            continue;
        }
        let t = ev.get("t")?.as_f64()?;
        check!(ln, t.is_finite() && t >= 0.0, "non-finite or negative event time {t}");
        check!(ln, t + TIME_EPS >= makespan, "time went backwards: {t} after {makespan}");
        makespan = makespan.max(t);
        events += 1;

        if kind != "run_start" {
            anyhow::ensure!(run.is_some(), "line {}: event before run_start", ln + 1);
        }
        match kind.as_str() {
            "run_start" => {
                check!(ln, run.is_none(), "duplicate run_start");
                run = Some(Run {
                    engine: ev.get("engine")?.as_str()?.to_string(),
                    capacity: ev.get("capacity")?.as_usize()?,
                    nodes: ev.get("nodes")?.as_usize()?,
                    gpus_per_node: ev.get("gpus_per_node")?.as_usize()?,
                    contended: ev.get("contended")?.as_bool()?,
                    restart_cost: ev.get("restart_cost")?.as_f64()?,
                });
            }
            "arrival" => {
                let id = ev.get("job")?.as_usize()? as u64;
                let at = ev.opt("at").map(|v| v.as_f64()).transpose()?.unwrap_or(t);
                let job = jobs.entry(id).or_default();
                check!(ln, job.arrival.is_none(), "job {id} arrived twice");
                job.arrival = Some(at);
            }
            "explore_start" => {
                let id = ev.get("job")?.as_usize()? as u64;
                let hold = ev.get("hold")?.as_usize()?;
                let job = track(&mut jobs, id, ln)?;
                check!(ln, job.hold == 0, "job {id} started exploring while already holding");
                job.hold = hold;
            }
            "explore_end" => {
                let id = ev.get("job")?.as_usize()? as u64;
                let job = track(&mut jobs, id, ln)?;
                check!(ln, job.hold > 0, "job {id} ended exploration it never started");
                job.hold = 0;
            }
            "alloc" => {
                let r = run.as_ref().expect("checked above");
                audit_alloc(&ev, &mut jobs, ln, &mut checks, &mut decision_rows)?;
                // restart charges (DES decisions carry a restart flag)
                for d in ev.get("decisions")?.as_arr()? {
                    if d.opt("restart").map(|v| v.as_bool()).transpose()?.unwrap_or(false) {
                        let id = d.get("job")?.as_usize()? as u64;
                        let job = track(&mut jobs, id, ln)?;
                        job.restarts += 1;
                        job.restart_secs += r.restart_cost;
                        if job.first_grant.is_none() {
                            job.first_grant = Some(t);
                        }
                        total_restarts += 1;
                        total_restart_secs += r.restart_cost;
                    }
                }
            }
            "seg_launch" => {
                let r = run.as_ref().expect("checked above");
                let capacity = r.capacity;
                let id = ev.get("job")?.as_usize()? as u64;
                let w = ev.get("w")?.as_usize()?;
                let restart = ev.get("restart")?.as_bool()?;
                let pay = ev.get("restart_pay")?.as_f64()?;
                let tenancy = ev.get("tenancy")?.as_usize()?;
                let job = track(&mut jobs, id, ln)?;
                check!(ln, job.width == 0, "job {id} launched while already running");
                check!(ln, w > 0, "job {id} launched at width 0");
                job.width = w;
                job.segments += 1;
                job.observed_tenancy = Some(tenancy);
                if job.first_grant.is_none() {
                    job.first_grant = Some(t);
                }
                if restart {
                    job.restarts += 1;
                    job.restart_secs += pay;
                    total_restarts += 1;
                    total_restart_secs += pay;
                }
                let committed: usize = jobs.values().map(|j| j.width).sum();
                check!(
                    ln,
                    committed <= capacity,
                    "double-booking: {committed} workers committed > capacity {capacity}"
                );
            }
            "seg_end" => {
                let id = ev.get("job")?.as_usize()? as u64;
                let w = ev.get("w")?.as_usize()?;
                let job = track(&mut jobs, id, ln)?;
                check!(
                    ln,
                    job.width == w,
                    "job {id} segment ended at width {w} but replay says {}",
                    job.width
                );
                job.width = 0;
            }
            "preempt" => {
                preemptions += 1;
            }
            "complete" => {
                let id = ev.get("job")?.as_usize()? as u64;
                let jct = ev.get("jct")?.as_f64()?;
                let job = track(&mut jobs, id, ln)?;
                check!(ln, job.finish.is_none(), "job {id} completed twice");
                let arrival = job.arrival.expect("tracked jobs have arrivals");
                let expect = t - arrival;
                check!(
                    ln,
                    (jct - expect).abs() <= TIME_EPS * expect.abs().max(1.0),
                    "job {id} jct {jct} disagrees with t - arrival = {expect}"
                );
                job.finish = Some(t);
                job.width = 0;
                completions += 1;
            }
            "place" => {
                let r = run.as_ref().expect("checked above");
                audit_place(&ev, r, &jobs, &down_nodes, ln, &mut checks)?;
            }
            "node_down" => {
                let r = run.as_ref().expect("checked above");
                let node = ev.get("node")?.as_usize()?;
                check!(ln, node < r.nodes, "node_down for node {node} of {}", r.nodes);
                check!(ln, down_nodes.insert(node), "node {node} went down twice");
                faults.node_downs += 1;
            }
            "node_up" => {
                let node = ev.get("node")?.as_usize()?;
                check!(ln, down_nodes.remove(&node), "node {node} repaired while up");
            }
            "seg_failed" => {
                // Two emitters share this kind: the DES eviction record
                // carries `node`, the orchestrator recovery record
                // carries `attempt`/`ckpt_epochs`.
                let id = ev.get("job")?.as_usize()? as u64;
                if let Some(node) = ev.opt("node") {
                    let r = run.as_ref().expect("checked above");
                    let node = node.as_usize()?;
                    check!(ln, node < r.nodes, "eviction on node {node} of {}", r.nodes);
                    let probe = ev.get("probe")?.as_bool()?;
                    let rework = ev.get("rework_epochs")?.as_f64()?;
                    check!(
                        ln,
                        rework.is_finite() && rework >= 0.0,
                        "job {id} evicted with negative rework {rework}"
                    );
                    let job = track(&mut jobs, id, ln)?;
                    if probe {
                        check!(ln, job.hold > 0, "job {id} probe evicted while not probing");
                        job.hold = 0;
                    } else {
                        check!(ln, job.width > 0, "job {id} evicted while not running");
                        job.width = 0;
                    }
                    faults.evictions += 1;
                } else {
                    let w = ev.get("w")?.as_usize()?;
                    let ckpt = ev.get("ckpt_epochs")?.as_f64()?;
                    let gave_up = ev.get("gave_up")?.as_bool()?;
                    let job = track(&mut jobs, id, ln)?;
                    check!(
                        ln,
                        job.width == w,
                        "job {id} failed at width {w} but replay says {}",
                        job.width
                    );
                    check!(ln, !job.gave_up, "job {id} failed again after giving up");
                    job.width = 0;
                    job.last_ckpt_epochs = Some(ckpt);
                    if gave_up {
                        job.gave_up = true;
                        faults.gave_ups += 1;
                    }
                    faults.failures += 1;
                }
            }
            "recovered" => {
                let id = ev.get("job")?.as_usize()? as u64;
                let resume = ev.get("resume_epochs")?.as_f64()?;
                let job = track(&mut jobs, id, ln)?;
                check!(ln, !job.gave_up, "job {id} recovered after giving up");
                // The central recovery invariant: a retry may only
                // resume from (at most) the last durable checkpoint —
                // progress past it did not survive the failure.
                let ckpt = job.last_ckpt_epochs;
                check!(
                    ln,
                    matches!(ckpt, Some(c) if resume <= c + TIME_EPS),
                    "job {id} resumed at {resume} epochs, past its checkpoint {ckpt:?}"
                );
                faults.recoveries += 1;
            }
            "job_failed" => {
                let id = ev.get("job")?.as_usize()? as u64;
                let attempts = ev.get("attempts")?.as_usize()?;
                let job = track(&mut jobs, id, ln)?;
                check!(ln, job.gave_up, "job {id} marked failed without a gave_up seg_failed");
                check!(ln, attempts >= 1, "job {id} gave up after {attempts} attempts");
            }
            "util" => {
                let r = run.as_ref().expect("checked above");
                let used = ev.get("used")?.as_usize()?;
                let queued =
                    ev.opt("queued").map(|v| v.as_usize()).transpose()?.unwrap_or(0);
                check!(
                    ln,
                    used <= r.capacity,
                    "utilization over capacity: {used} > {}",
                    r.capacity
                );
                let tracked: usize = jobs.values().map(|j| j.width + j.hold).sum();
                check!(
                    ln,
                    used == tracked,
                    "tenancy conservation: util says {used} workers busy, replay says {tracked}"
                );
                util_curve.push((t, used as f64, queued as f64));
            }
            "seg_measured" => {
                // wall-clock truth: summarized, never replayed
                measured.push((
                    ev.get("mean_step_secs")?.as_f64()?,
                    ev.get("mean_allreduce_secs")?.as_f64()?,
                ));
            }
            "run_end" => {
                check!(ln, run_end.is_none(), "duplicate run_end");
                let completed = ev.get("completed")?.as_usize()? as u64;
                check!(
                    ln,
                    completed == completions,
                    "run_end says {completed} completions, replay counted {completions}"
                );
                run_end = Some(ev);
            }
            other => anyhow::bail!("line {}: unknown event kind {other:?}", ln + 1),
        }
    }

    let run = run.ok_or_else(|| anyhow::anyhow!("stream has no run_start event"))?;
    anyhow::ensure!(run_end.is_some(), "stream has no run_end event");
    for (id, job) in &jobs {
        if job.finish.is_none() {
            anyhow::ensure!(
                job.width == 0 && job.hold == 0,
                "job {id} still holds workers at end of stream"
            );
        }
    }
    if let Some(s) = &summary {
        if let Some(c) = s.get("counters")?.opt("completions") {
            let c = c.as_usize()? as u64;
            anyhow::ensure!(
                c == completions,
                "summary counter says {c} completions, replay counted {completions}"
            );
            checks += 1;
        }
    }

    let rendered = render(
        &run,
        &jobs,
        &util_curve,
        &decision_rows,
        &measured,
        run_end.as_ref(),
        summary.as_ref(),
        makespan,
        events,
        checks,
        total_restarts,
        total_restart_secs,
        preemptions,
        &faults,
    );
    Ok(Audit { engine: run.engine, events, checks, rendered })
}

fn track<'a>(
    jobs: &'a mut BTreeMap<u64, JobTrack>,
    id: u64,
    ln: usize,
) -> Result<&'a mut JobTrack> {
    let job = jobs
        .get_mut(&id)
        .ok_or_else(|| anyhow::anyhow!("line {}: job {id} referenced before arrival", ln + 1))?;
    anyhow::ensure!(
        job.arrival.is_some(),
        "line {}: job {id} referenced before arrival",
        ln + 1
    );
    anyhow::ensure!(job.finish.is_none(), "line {}: job {id} referenced after completion", ln + 1);
    Ok(job)
}

/// Replay one `alloc` event: decision `from` widths must match the
/// replayed state, the grant-step chains must land exactly on the
/// decided widths, and the total grant must fit in `free`.
fn audit_alloc(
    ev: &Json,
    jobs: &mut BTreeMap<u64, JobTrack>,
    ln: usize,
    checks: &mut u64,
    rows: &mut Vec<DecisionRow>,
) -> Result<()> {
    let t = ev.get("t")?.as_f64()?;
    let free = ev.get("free")?.as_usize()?;
    let decisions = ev.get("decisions")?.as_arr()?;
    let steps = ev.get("steps")?.as_arr()?;

    // Replay the recorded heap pops: seeds establish 0 -> w, grants must
    // extend the exact current width, stale/nofit must change nothing.
    let mut replay: BTreeMap<u64, usize> = BTreeMap::new();
    let mut provenance: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    for s in steps {
        let id = s.get("job")?.as_usize()? as u64;
        let from = s.get("from")?.as_usize()?;
        let to = s.get("to")?.as_usize()?;
        let gain = s.get("gain")?.as_f64()?;
        let outcome = s.get("outcome")?.as_str()?;
        let cur = replay.get(&id).copied();
        match outcome {
            "seed" => {
                anyhow::ensure!(
                    cur.is_none() && from == 0,
                    "line {}: job {id} re-seeded (steps replay)",
                    ln + 1
                );
                replay.insert(id, to);
                provenance.entry(id).or_default().push(format!("seed {to}"));
            }
            "grant" => {
                anyhow::ensure!(
                    cur == Some(from),
                    "line {}: job {id} granted {from}->{to} but replay holds {cur:?}",
                    ln + 1
                );
                replay.insert(id, to);
                provenance
                    .entry(id)
                    .or_default()
                    .push(format!("{from}->{to} g={gain:.3}"));
            }
            // lazily-invalidated heap entries and refused grants must
            // leave the replayed width untouched
            "stale" | "nofit" => {
                if outcome == "nofit" && cur.is_none() {
                    replay.insert(id, 0); // fixed-k queues at 0
                }
            }
            other => anyhow::bail!("line {}: unknown step outcome {other:?}", ln + 1),
        }
        *checks += 1;
    }
    let granted: usize = replay.values().sum();
    anyhow::ensure!(
        granted <= free,
        "line {}: steps replay grants {granted} workers with only {free} free",
        ln + 1
    );
    *checks += 1;

    let mut summary_bits: Vec<String> = Vec::new();
    for d in decisions {
        let id = d.get("job")?.as_usize()? as u64;
        let to = d.get("to")?.as_usize()?;
        let scored = d.opt("scoring_tenancy").map(|v| v.as_usize()).transpose()?;
        // DES decisions carry the pre-decision width; the steps replay
        // must land every decided job exactly on its decided width.
        if let Some(from) = d.opt("from").map(|v| v.as_usize()).transpose()? {
            let job = jobs
                .get(&id)
                .ok_or_else(|| anyhow::anyhow!("line {}: decision for unknown job {id}", ln + 1))?;
            anyhow::ensure!(
                job.width == from,
                "line {}: decision says job {id} was at {from}, replay says {}",
                ln + 1,
                job.width
            );
            *checks += 1;
        }
        if let Some(&w) = replay.get(&id) {
            anyhow::ensure!(
                w == to,
                "line {}: job {id} decided to {to} but its grant chain lands on {w}",
                ln + 1
            );
            *checks += 1;
        }
        if let Some(job) = jobs.get_mut(&id) {
            if d.opt("from").is_some() {
                job.width = to; // DES: decisions are the width transitions
                if to > 0 && job.first_grant.is_none() {
                    job.first_grant = Some(t);
                }
            }
            job.scored_tenancy = scored;
            if summary_bits.len() < 6 {
                let chain = provenance
                    .get(&id)
                    .map(|c| c.join(", "))
                    .unwrap_or_else(|| "held".to_string());
                let tenancy = match (scored, job.observed_tenancy) {
                    (Some(s), Some(o)) => format!(" tenancy {s}~{o}"),
                    (Some(s), None) => format!(" tenancy {s}"),
                    _ => String::new(),
                };
                summary_bits.push(format!("job {id}: {to} [{chain}]{tenancy}"));
            }
        }
    }
    if decisions.len() > summary_bits.len() {
        summary_bits.push(format!("... {} more", decisions.len() - summary_bits.len()));
    }
    if !summary_bits.is_empty() {
        rows.push(DecisionRow {
            t,
            text: format!("n={} free={free} | {}", decisions.len(), summary_bits.join("; ")),
        });
    }
    Ok(())
}

/// Replay one placement snapshot: widths must match the replayed grants
/// (or exploration holds), per-node occupancy must fit, and the emitted
/// crossing-ring ledger and tenancies must equal the values recomputed
/// from the placements alone — the audit-side proof that the engine's
/// incremental ledger never drifted.
fn audit_place(
    ev: &Json,
    run: &Run,
    jobs: &BTreeMap<u64, JobTrack>,
    down_nodes: &BTreeSet<usize>,
    ln: usize,
    checks: &mut u64,
) -> Result<()> {
    let placements = ev.get("placements")?.as_arr()?;
    let mut node_used: BTreeMap<usize, usize> = BTreeMap::new();
    let mut node_rings: BTreeMap<usize, usize> = BTreeMap::new();
    let mut spans: Vec<(u64, Vec<usize>, usize)> = Vec::new();

    for p in placements {
        let id = p.get("job")?.as_usize()? as u64;
        let w = p.get("w")?.as_usize()?;
        let probe = p.get("probe")?.as_bool()?;
        let tenancy = p.get("tenancy")?.as_usize()?;
        let job = jobs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("line {}: placed job {id} never arrived", ln + 1))?;
        let expect = if probe { job.hold } else { job.width };
        anyhow::ensure!(
            w == expect,
            "line {}: job {id} placed at {w} GPUs but replay grants {expect}",
            ln + 1
        );
        let mut total = 0usize;
        let mut nodes: Vec<usize> = Vec::new();
        for pair in p.get("gpus")?.as_arr()? {
            let pair = pair.as_arr()?;
            anyhow::ensure!(pair.len() == 2, "line {}: bad gpus pair", ln + 1);
            let node = pair[0].as_usize()?;
            let count = pair[1].as_usize()?;
            anyhow::ensure!(
                node < run.nodes,
                "line {}: job {id} on node {node} of {}",
                ln + 1,
                run.nodes
            );
            // recovery invariant: nothing runs on a downed node
            anyhow::ensure!(
                !down_nodes.contains(&node),
                "line {}: job {id} placed on downed node {node}",
                ln + 1
            );
            *node_used.entry(node).or_insert(0) += count;
            total += count;
            nodes.push(node);
        }
        anyhow::ensure!(
            total == w,
            "line {}: job {id} gpus sum to {total}, width says {w}",
            ln + 1
        );
        if nodes.len() > 1 {
            for &n in &nodes {
                *node_rings.entry(n).or_insert(0) += 1;
            }
        }
        spans.push((id, nodes, tenancy));
        *checks += 3;
    }
    for (&node, &used) in &node_used {
        anyhow::ensure!(
            used <= run.gpus_per_node,
            "line {}: node {node} holds {used} GPUs of {}",
            ln + 1,
            run.gpus_per_node
        );
        *checks += 1;
    }
    // emitted crossing-ring ledger == rings recomputed from placements
    let mut emitted: BTreeMap<usize, usize> = BTreeMap::new();
    for pair in ev.get("links")?.as_arr()? {
        let pair = pair.as_arr()?;
        anyhow::ensure!(pair.len() == 2, "line {}: bad links pair", ln + 1);
        emitted.insert(pair[0].as_usize()?, pair[1].as_usize()?);
    }
    anyhow::ensure!(
        emitted == node_rings,
        "line {}: links ledger {:?} != rings recomputed from placements {:?}",
        ln + 1,
        emitted,
        node_rings
    );
    *checks += 1;
    // emitted tenancy == tenancy recomputed from the recomputed rings
    for (id, nodes, tenancy) in &spans {
        let expect = if nodes.len() <= 1 {
            1
        } else {
            nodes.iter().map(|n| node_rings.get(n).copied().unwrap_or(0)).max().unwrap_or(1)
        };
        anyhow::ensure!(
            *tenancy == expect.max(1),
            "line {}: job {id} tenancy {tenancy} != recomputed {}",
            ln + 1,
            expect.max(1)
        );
        *checks += 1;
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn render(
    run: &Run,
    jobs: &BTreeMap<u64, JobTrack>,
    util: &[(f64, f64, f64)],
    decisions: &[DecisionRow],
    measured: &[(f64, f64)],
    run_end: Option<&Json>,
    summary: Option<&Json>,
    makespan: f64,
    events: usize,
    checks: u64,
    total_restarts: u64,
    total_restart_secs: f64,
    preemptions: u64,
    faults: &FaultTally,
) -> String {
    let mut out = String::new();
    let topo = if run.nodes == 0 {
        format!("flat x{}", run.capacity)
    } else {
        format!("{}x{} grid", run.nodes, run.gpus_per_node)
    };
    out.push_str(&format!(
        "run audit: engine={} capacity={} topology={} contended={}\n\
         events={} jobs={} makespan={:.1}s invariant checks passed={}\n",
        run.engine,
        run.capacity,
        topo,
        run.contended,
        events,
        jobs.len(),
        makespan,
        checks
    ));

    out.push_str("\nper-job timeline (arrival -> first grant -> finish):\n");
    out.push_str("  job     arrival  first_grant       finish          jct  restarts  restart_s\n");
    for (id, j) in jobs.iter().take(20) {
        let fmt = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.1}"));
        let jct = match (j.arrival, j.finish) {
            (Some(a), Some(f)) => format!("{:.1}", f - a),
            _ => "-".to_string(),
        };
        out.push_str(&format!(
            "  {id:>3} {:>11} {:>12} {:>12} {:>12} {:>9} {:>10.1}\n",
            fmt(j.arrival),
            fmt(j.first_grant),
            fmt(j.finish),
            jct,
            j.restarts,
            j.restart_secs,
        ));
    }
    if jobs.len() > 20 {
        out.push_str(&format!("  ... {} more jobs\n", jobs.len() - 20));
    }

    if !util.is_empty() {
        out.push_str("\ncluster utilization / queue depth:\n");
        let stride = (util.len() / 16).max(1);
        for (t, used, queued) in util.iter().step_by(stride) {
            let frac = used / run.capacity.max(1) as f64;
            let bar = "#".repeat((frac * 32.0).round() as usize);
            out.push_str(&format!(
                "  t={t:>10.1}  {used:>5.0}/{:<5} |{bar:<32}| queued={queued:.0}\n",
                run.capacity
            ));
        }
    }

    out.push_str(&format!(
        "\nrestart-cost ledger: {total_restarts} restarts, {total_restart_secs:.1} virtual \
         seconds charged ({preemptions} preemptions)\n"
    ));
    let mut by_cost: Vec<(&u64, &JobTrack)> = jobs.iter().collect();
    by_cost.sort_by(|a, b| b.1.restart_secs.total_cmp(&a.1.restart_secs));
    for (id, j) in by_cost.iter().take(5).filter(|(_, j)| j.restarts > 0) {
        out.push_str(&format!(
            "  job {id}: {} restarts, {:.1}s ({} segments)\n",
            j.restarts, j.restart_secs, j.segments
        ));
    }

    if faults.node_downs + faults.evictions + faults.failures + faults.recoveries > 0 {
        out.push_str(&format!(
            "\nfault ledger: {} node-down events, {} gang evictions, {} failed segments, \
             {} recoveries, {} jobs gave up\n",
            faults.node_downs,
            faults.evictions,
            faults.failures,
            faults.recoveries,
            faults.gave_ups
        ));
    }

    if !decisions.is_empty() {
        out.push_str("\ndecision table (why width w; tenancy scored~observed):\n");
        let stride = (decisions.len() / 12).max(1);
        for row in decisions.iter().step_by(stride) {
            out.push_str(&format!("  t={:>10.1}  {}\n", row.t, row.text));
        }
    }

    if !measured.is_empty() {
        let n = measured.len() as f64;
        let step: f64 = measured.iter().map(|m| m.0).sum::<f64>() / n;
        let ar: f64 = measured.iter().map(|m| m.1).sum::<f64>() / n;
        out.push_str(&format!(
            "\nmeasured trainer wall clock (non-deterministic, excluded from invariants):\n  \
             {} segments, mean step {:.2}ms, mean all-reduce {:.2}ms\n",
            measured.len(),
            step * 1e3,
            ar * 1e3
        ));
    }

    if let Some(e) = run_end {
        out.push_str(&format!("\nrun_end: {}\n", e.dump()));
    }
    if let Some(s) = summary {
        out.push_str(&format!("summary: {}\n", s.dump()));
    }
    out.push_str(&format!(
        "\naudit OK: {events} events replayed, {checks} invariant checks, 0 violations\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny hand-built grid stream exercising every invariant path.
    fn golden() -> String {
        [
            r#"{"ringmaster_trace":3,"stream":"telemetry"}"#,
            r#"{"capacity":8,"contended":true,"engine":"des","ev":"run_start","explore_reserve":8,"gpus_per_node":4,"n_jobs":2,"nodes":2,"restart_cost":10,"seed":7,"strategy":"precompute","t":0}"#,
            r#"{"at":0,"ev":"arrival","job":0,"t":0}"#,
            r#"{"at":0,"ev":"arrival","job":1,"t":0}"#,
            r#"{"decisions":[{"from":0,"job":0,"restart":true,"scoring_tenancy":1,"to":4},{"from":0,"job":1,"restart":true,"scoring_tenancy":1,"to":4}],"ev":"alloc","free":8,"n":2,"steps":[{"from":0,"gain":0,"job":0,"outcome":"seed","to":1},{"from":0,"gain":0,"job":1,"outcome":"seed","to":1},{"from":1,"gain":9,"job":0,"outcome":"grant","to":2},{"from":1,"gain":9,"job":1,"outcome":"grant","to":2},{"from":2,"gain":4,"job":0,"outcome":"grant","to":4},{"from":2,"gain":4,"job":1,"outcome":"grant","to":4}],"t":0}"#,
            r#"{"ev":"place","links":[],"placements":[{"gpus":[[0,4]],"job":0,"probe":false,"tenancy":1,"w":4},{"gpus":[[1,4]],"job":1,"probe":false,"tenancy":1,"w":4}],"t":0}"#,
            r#"{"capacity":8,"ev":"util","exploring":0,"queued":0,"running":2,"t":0,"used":8,"waiting":0}"#,
            r#"{"ev":"complete","jct":500,"job":1,"t":500}"#,
            r#"{"decisions":[{"from":4,"job":0,"restart":true,"scoring_tenancy":1,"to":8}],"ev":"alloc","free":8,"n":1,"steps":[{"from":0,"gain":0,"job":0,"outcome":"seed","to":1},{"from":1,"gain":9,"job":0,"outcome":"grant","to":2},{"from":2,"gain":4,"job":0,"outcome":"grant","to":4},{"from":4,"gain":2,"job":0,"outcome":"grant","to":8}],"t":500}"#,
            r#"{"ev":"place","links":[[0,1],[1,1]],"placements":[{"gpus":[[0,4],[1,4]],"job":0,"probe":false,"tenancy":1,"w":8}],"t":500}"#,
            r#"{"capacity":8,"ev":"util","exploring":0,"queued":0,"running":1,"t":500,"used":8,"waiting":0}"#,
            r#"{"ev":"complete","jct":900,"job":0,"t":900}"#,
            r#"{"completed":2,"ev":"run_end","events":5,"peak_concurrent":2,"rescales":3,"t":900}"#,
            r#"{"counters":{"allocs":2,"arrivals":2,"completions":2},"ev":"summary","samples":{"ready_len":{"max":2,"mean":1.5,"min":1,"n":2}}}"#,
        ]
        .join("\n")
    }

    #[test]
    fn golden_stream_audits_clean() {
        let audit = audit_str(&golden()).expect("clean stream must audit");
        assert_eq!(audit.engine, "des");
        assert!(audit.checks > 20, "expected many checks, got {}", audit.checks);
        assert!(audit.rendered.contains("audit OK"));
        assert!(audit.rendered.contains("decision table"));
        assert!(audit.rendered.contains("restart-cost ledger"));
    }

    #[test]
    fn double_booking_is_caught() {
        // node 0 suddenly hosts both 4-GPU gangs: 8 GPUs on a 4-GPU node
        let bad = golden().replace(
            r#"{"gpus":[[1,4]],"job":1,"probe":false,"tenancy":1,"w":4}"#,
            r#"{"gpus":[[0,4]],"job":1,"probe":false,"tenancy":1,"w":4}"#,
        );
        let err = audit_str(&bad).unwrap_err().to_string();
        assert!(err.contains("node 0 holds 8"), "{err}");
    }

    #[test]
    fn link_ledger_drift_is_caught() {
        let bad = golden().replace(
            r#""links":[[0,1],[1,1]]"#,
            r#""links":[[0,1]]"#,
        );
        let err = audit_str(&bad).unwrap_err().to_string();
        assert!(err.contains("links ledger"), "{err}");
    }

    #[test]
    fn grant_chain_mismatch_is_caught() {
        // second alloc decides 8 but the chain is edited to stop at 4
        let bad = golden().replace(
            r#"{"from":4,"gain":2,"job":0,"outcome":"grant","to":8}"#,
            r#"{"from":4,"gain":2,"job":0,"outcome":"stale","to":8}"#,
        );
        let err = audit_str(&bad).unwrap_err().to_string();
        assert!(err.contains("grant chain"), "{err}");
    }

    #[test]
    fn stale_width_provenance_is_caught() {
        // decision claims job 0 was at width 2 when replay says 4
        let bad = golden().replace(
            r#"{"from":4,"job":0,"restart":true,"scoring_tenancy":1,"to":8}"#,
            r#"{"from":2,"job":0,"restart":true,"scoring_tenancy":1,"to":8}"#,
        );
        let err = audit_str(&bad).unwrap_err().to_string();
        assert!(err.contains("was at 2"), "{err}");
    }

    #[test]
    fn job_traces_and_unknown_versions_are_redirected() {
        let v2 = "{\"ringmaster_trace\":2}\n{}";
        let err = audit_str(v2).unwrap_err().to_string();
        assert!(err.contains("job-submission trace"), "{err}");
        let v99 = "{\"ringmaster_trace\":99,\"stream\":\"telemetry\"}\n";
        let err = audit_str(v99).unwrap_err().to_string();
        assert!(err.contains("v99"), "{err}");
        assert!(audit_str("").is_err());
        assert!(audit_str("{\"x\":1}").is_err());
    }

    /// DES-style fault lines spliced between golden()'s two epochs:
    /// node 1 dies at t=100 evicting job 1's gang, repairs at t=200
    /// (before the t=500 placement that spans nodes 0 and 1 again).
    fn golden_with_faults() -> String {
        golden().replace(
            "{\"ev\":\"complete\",\"jct\":500,\"job\":1,\"t\":500}",
            "{\"ev\":\"node_down\",\"node\":1,\"t\":100}\n\
             {\"ev\":\"seg_failed\",\"job\":1,\"kind\":\"down\",\"node\":1,\"probe\":false,\"rework_epochs\":12.5,\"t\":100}\n\
             {\"ev\":\"node_up\",\"node\":1,\"t\":200}\n\
             {\"ev\":\"complete\",\"jct\":500,\"job\":1,\"t\":500}",
        )
    }

    #[test]
    fn fault_events_audit_clean_and_render_a_ledger() {
        let audit = audit_str(&golden_with_faults()).expect("fault stream must audit");
        assert!(audit.rendered.contains("fault ledger"), "{}", audit.rendered);
        assert!(audit.rendered.contains("1 gang evictions"), "{}", audit.rendered);
    }

    #[test]
    fn placement_on_a_downed_node_is_caught() {
        // drop the repair: the t=500 placement spans node 1 while down
        let bad = golden_with_faults()
            .replace("{\"ev\":\"node_up\",\"node\":1,\"t\":200}\n", "");
        let err = audit_str(&bad).unwrap_err().to_string();
        assert!(err.contains("downed node 1"), "{err}");
    }

    #[test]
    fn repairing_an_up_node_is_caught() {
        let bad = golden_with_faults().replace(
            "{\"ev\":\"node_up\",\"node\":1,\"t\":200}",
            "{\"ev\":\"node_up\",\"node\":0,\"t\":200}",
        );
        let err = audit_str(&bad).unwrap_err().to_string();
        assert!(err.contains("repaired while up"), "{err}");
    }

    /// A minimal orchestrator-style recovery stream: one job fails its
    /// first segment, backs off, recovers from the (empty) checkpoint,
    /// then finishes.
    fn recovery_stream(resume_epochs: &str) -> String {
        [
            r#"{"ringmaster_trace":3,"stream":"telemetry"}"#,
            r#"{"capacity":8,"contended":false,"engine":"orchestrator","ev":"run_start","gpus_per_node":8,"n_jobs":1,"nodes":1,"restart_cost":10,"seed":1,"strategy":"doubling","t":0}"#,
            r#"{"at":0,"ev":"arrival","job":0,"t":0}"#,
            r#"{"ev":"seg_launch","job":0,"restart":true,"restart_pay":10,"t":0,"tenancy":1,"w":4}"#,
            r#"{"attempt":1,"ckpt_epochs":0,"ev":"seg_failed","gave_up":false,"job":0,"reason":"injected fault","t":50,"w":4}"#,
        ]
        .join("\n")
            + &format!(
                "\n{{\"attempt\":1,\"ev\":\"recovered\",\"job\":0,\"resume_epochs\":{resume_epochs},\"t\":80}}\n"
            )
            + &[
                r#"{"ev":"seg_launch","job":0,"restart":true,"restart_pay":10,"t":80,"tenancy":1,"w":4}"#,
                r#"{"done":true,"ev":"seg_end","epochs":1,"job":0,"preempted":false,"steps":32,"t":200,"w":4}"#,
                r#"{"ev":"complete","jct":200,"job":0,"t":200}"#,
                r#"{"completed":1,"ev":"run_end","events":4,"t":200}"#,
            ]
            .join("\n")
    }

    #[test]
    fn recovery_resumes_at_most_from_its_checkpoint() {
        let audit = audit_str(&recovery_stream("0")).expect("recovery stream must audit");
        assert!(audit.rendered.contains("1 recoveries"), "{}", audit.rendered);
        // claiming to resume *past* the rolled-back checkpoint is the
        // lost-progress lie the audit exists to catch
        let err = audit_str(&recovery_stream("5.0")).unwrap_err().to_string();
        assert!(err.contains("past its checkpoint"), "{err}");
    }

    #[test]
    fn traced_faulted_des_run_audits_clean() {
        use crate::sim::workload::{FaultPlan, WorkloadGen};
        use crate::sim::{simulate_traced, Contention, SimConfig, StrategyKind};
        use crate::telemetry::Recorder;
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 61)
            .with_topology(8, 8);
        cfg.faults = FaultPlan::steady(20_000.0, 600.0, 400_000.0, 61);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 61);
        let mut rec = Recorder::new();
        let r = simulate_traced(&cfg, &jobs, &mut rec);
        assert!(r.evictions > 0, "plan never fired — the audit path went untested");
        let audit = audit_str(&rec.to_jsonl()).expect("faulted DES stream must audit clean");
        assert!(audit.rendered.contains("fault ledger"), "{}", audit.rendered);
    }

    #[test]
    fn traced_des_run_on_a_contended_grid_audits_clean() {
        use crate::sim::workload::WorkloadGen;
        use crate::sim::{simulate_traced, Contention, SimConfig, StrategyKind};
        use crate::telemetry::Recorder;
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::None, 11)
            .with_topology(4, 4);
        cfg.n_jobs = 12;
        cfg.link_contention = crate::perfmodel::LinkContention::fair_share();
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 11);
        let mut rec = Recorder::new();
        simulate_traced(&cfg, &jobs, &mut rec);
        let audit = audit_str(&rec.to_jsonl()).expect("live DES stream must audit clean");
        assert_eq!(audit.engine, "des");
        assert!(audit.checks > 50);
    }

    #[test]
    fn traced_exploratory_des_run_audits_clean() {
        use crate::sim::workload::WorkloadGen;
        use crate::sim::{simulate_traced, Contention, SimConfig, StrategyKind};
        use crate::telemetry::Recorder;
        let mut cfg = SimConfig::paper(StrategyKind::Exploratory, Contention::None, 5)
            .with_topology(4, 4);
        cfg.n_jobs = 8;
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 5);
        let mut rec = Recorder::new();
        simulate_traced(&cfg, &jobs, &mut rec);
        audit_str(&rec.to_jsonl()).expect("exploratory stream (probes+holds) must audit clean");
    }
}
