//! Crash-safe filesystem primitives shared by every durable artifact in
//! the tree — checkpoints, job traces, telemetry streams, and store
//! snapshots: write-to-sibling-tmp + fsync + atomic rename + parent
//! directory fsync.
//!
//! The discipline exists because tmp+rename alone is not durable: POSIX
//! only promises the rename is atomic *in the namespace*. After a crash
//! the new directory entry itself can be lost unless the parent
//! directory is fsynced after the rename — the old `Checkpoint::save`
//! carried the tmp+fsync+rename half of this since PR 2 but never synced
//! the directory, so a crash shortly after a "successful" save could
//! still come back with the previous checkpoint (or none), and a failed
//! rename leaked the `.tmp` sibling. Centralizing the full sequence here
//! fixes both once, for every caller.

use std::io::Write;
use std::path::Path;

use crate::Result;

/// Fsync a directory so a just-renamed entry inside it survives a crash.
/// No-op on platforms where directories cannot be opened for syncing.
pub fn fsync_dir(dir: &Path) -> Result<()> {
    #[cfg(unix)]
    {
        let d = std::fs::File::open(dir)
            .map_err(|e| anyhow::anyhow!("opening dir {} to fsync: {e}", dir.display()))?;
        d.sync_all()
            .map_err(|e| anyhow::anyhow!("fsync dir {}: {e}", dir.display()))?;
    }
    #[cfg(not(unix))]
    let _ = dir;
    Ok(())
}

/// Atomically and durably replace `path` with `bytes`: write
/// `<path>.tmp`, flush + fsync, rename over `path`, then fsync the
/// parent directory. On any failure the tmp sibling is removed and
/// `path` still holds its previous complete contents (or is still
/// absent) — a reader can never observe a torn file at `path`. Returns
/// the number of bytes written.
pub fn atomic_write(path: impl AsRef<Path>, bytes: &[u8]) -> Result<u64> {
    let path = path.as_ref();
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("path {} has no file name", path.display()))?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);

    let write = || -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", tmp.display()))?;
        f.write_all(bytes)?;
        f.flush()?;
        f.sync_all()?;
        Ok(())
    };
    let renamed = write().and_then(|()| {
        std::fs::rename(&tmp, path).map_err(|e| {
            anyhow::anyhow!("renaming {} -> {}: {e}", tmp.display(), path.display())
        })
    });
    if let Err(e) = renamed {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fsync_dir(parent)?;
        }
    }
    Ok(bytes.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmppath(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rm-fsx-{tag}-{}", std::process::id()))
    }

    #[test]
    fn writes_and_replaces_without_tmp_residue() {
        let p = tmppath("basic");
        assert_eq!(atomic_write(&p, b"first").unwrap(), 5);
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second, longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer");
        let tmp = tmppath("basic.tmp");
        assert!(!tmp.exists(), "tmp sibling left behind");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn failed_rename_cleans_tmp_and_keeps_destination_absent_or_intact() {
        // a directory at the destination makes the rename fail
        let p = tmppath("dir-target");
        std::fs::create_dir_all(&p).unwrap();
        let err = atomic_write(&p, b"payload").unwrap_err().to_string();
        assert!(err.contains("renaming"), "{err}");
        let tmp = tmppath("dir-target.tmp");
        assert!(!tmp.exists(), "tmp sibling must be removed on rename failure");
        assert!(p.is_dir(), "destination must be untouched");
        let _ = std::fs::remove_dir(&p);
    }

    #[test]
    fn missing_parent_errors_without_residue() {
        let p = tmppath("no-such-dir").join("leaf.bin");
        assert!(atomic_write(&p, b"x").is_err());
        assert!(!p.exists());
    }

    #[test]
    fn pathless_target_is_rejected() {
        assert!(atomic_write("/", b"x").is_err());
    }

    #[test]
    fn stale_tmp_from_a_torn_writer_is_clobbered() {
        let p = tmppath("stale");
        let tmp = tmppath("stale.tmp");
        std::fs::write(&tmp, b"torn partial write").unwrap();
        atomic_write(&p, b"good").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"good");
        assert!(!tmp.exists());
        let _ = std::fs::remove_file(&p);
    }
}
