//! Performance modelling of ring-reduce DL jobs — §3 of the paper.
//!
//! Two-step process, exactly as Optimus and this paper do it:
//!
//! 1. [`convergence`] — online fit of the loss curve `l = 1/(b0·e + b1) + b2`
//!    (eq 1, NNLS with `b0 > 0`), giving the remaining epochs `Q_j` to a
//!    target loss.
//! 2. [`speed`] — the resource-to-speed model `f(w)` (eq 5), an NNLS fit
//!    of per-epoch time over the features `[m/w, w-1, (w-1)·n/w, 1]`,
//!    giving epochs/second at any candidate worker count.
//!
//! [`JobModel`] combines both into the quantity the scheduler minimizes:
//! predicted remaining runtime `t_j = Q_j / f(w_j)` (§4.1).
//!
//! [`placement`] extends step 2 beyond the paper: `f(w)` becomes
//! `f(w, placement)` by pricing the eq 2–4 α/β terms differently intra-
//! vs inter-node, so a ring scattered across nodes is slower than the
//! same `w` packed into one.
//!
//! [`online`] closes §7's precompute-vs-explore loop: a per-job
//! [`OnlineModel`] learns both fits from finished live segments
//! (placement-stripped via [`PlacementModel`]) behind a confidence gate,
//! so schedulers can run on *measured* behavior instead of trace tables.

pub mod convergence;
pub mod online;
pub mod placement;
pub mod speed;

pub use convergence::ConvergenceModel;
pub use online::{OnlineConfig, OnlineModel};
pub use placement::{LinkContention, PlacementModel, TopoCostParams};
pub use speed::SpeedModel;

/// Full performance model of one training job.
#[derive(Clone, Debug)]
pub struct JobModel {
    /// Loss-curve fit (eq 1); `None` until enough samples arrive.
    pub convergence: Option<ConvergenceModel>,
    /// Resource-to-speed fit (eq 5); `None` until >= 2 distinct w samples.
    pub speed: Option<SpeedModel>,
    /// Loss the job is declared converged at.
    pub target_loss: f64,
}

impl JobModel {
    pub fn new(target_loss: f64) -> Self {
        JobModel { convergence: None, speed: None, target_loss }
    }

    /// Remaining epochs `Q_j` from the current epoch (§4.1); `None` while
    /// the loss curve is unfit or the target is unreachable under the fit.
    pub fn remaining_epochs(&self, current_epoch: f64) -> Option<f64> {
        let conv = self.convergence.as_ref()?;
        let target_epoch = conv.epochs_to_loss(self.target_loss)?;
        Some((target_epoch - current_epoch).max(0.0))
    }

    /// Predicted remaining runtime at `w` workers: `t = Q / f(w)`.
    pub fn remaining_time(&self, current_epoch: f64, w: usize) -> Option<f64> {
        let q = self.remaining_epochs(current_epoch)?;
        let f = self.speed.as_ref()?.epochs_per_sec(w);
        if f <= 0.0 {
            return None;
        }
        Some(q / f)
    }

    /// True once both sub-models are fitted.
    pub fn ready(&self) -> bool {
        self.convergence.is_some() && self.speed.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fitted_model() -> JobModel {
        let mut m = JobModel::new(0.2);
        // synthetic loss curve: l = 1/(0.5 e + 1) + 0.1
        let samples: Vec<(f64, f64)> = (0..40)
            .map(|e| {
                let e = e as f64;
                (e, 1.0 / (0.5 * e + 1.0) + 0.1)
            })
            .collect();
        m.convergence = ConvergenceModel::fit(&samples).ok();
        // speed: 100 s/epoch at w=1, scaling ~1/w with small overhead
        let speed_samples: Vec<(usize, f64)> = [1usize, 2, 4, 8]
            .iter()
            .map(|&w| (w, 100.0 / w as f64 + 2.0 * (w - 1) as f64))
            .map(|(w, t)| (w, 1.0 / t))
            .collect();
        m.speed = SpeedModel::fit(&speed_samples, 128.0, 4.0e6).ok();
        m
    }

    #[test]
    fn unfitted_model_returns_none() {
        let m = JobModel::new(0.1);
        assert!(!m.ready());
        assert!(m.remaining_epochs(0.0).is_none());
        assert!(m.remaining_time(0.0, 4).is_none());
    }

    #[test]
    fn remaining_epochs_decreases_with_progress() {
        let m = fitted_model();
        let q0 = m.remaining_epochs(0.0).unwrap();
        let q5 = m.remaining_epochs(5.0).unwrap();
        assert!(q0 > q5);
        assert!(q5 > 0.0);
    }

    #[test]
    fn remaining_time_decreases_with_more_workers() {
        let m = fitted_model();
        let t1 = m.remaining_time(0.0, 1).unwrap();
        let t4 = m.remaining_time(0.0, 4).unwrap();
        assert!(t4 < t1);
    }

    #[test]
    fn remaining_epochs_clamps_at_zero_past_target() {
        let m = fitted_model();
        assert_eq!(m.remaining_epochs(1e6).unwrap(), 0.0);
    }
}
