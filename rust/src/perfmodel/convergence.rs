//! Online convergence model — eq 1 of the paper (§3.1).
//!
//! SGD converges at O(1/k), so the loss curve is fit as
//!
//!   `l(e) = 1 / (b0·e + b1) + b2`,  `b0 > 0`
//!
//! We fit epochs rather than raw batch steps: Table 2 shows
//! epochs-to-converge is nearly invariant to the worker count (160–170
//! across 1–8 GPUs with eq 7's LR rescaling), which is exactly what lets
//! `Q_j` (remaining epochs) be the scheduler's unit of work.
//!
//! The model is nonlinear in `b2`, so the solve is a 1-D grid over `b2`
//! with an inner NNLS on the linearization `1/(l - b2) = b0·e + b1`
//! (the standard trick for eq 1; NNLS keeps `b0, b1 >= 0`).

use crate::linalg::Matrix;
use crate::nnls::nnls;
use crate::Result;

/// Fitted eq-1 loss curve.
#[derive(Clone, Debug)]
pub struct ConvergenceModel {
    pub b0: f64,
    pub b1: f64,
    pub b2: f64,
    /// RMS error of the fit in loss space.
    pub rms: f64,
}

/// Grid resolution over the asymptote `b2`.
const B2_GRID: usize = 64;
/// Minimum samples before a fit is attempted.
pub const MIN_SAMPLES: usize = 5;

impl ConvergenceModel {
    /// Fit from `(epoch, loss)` samples.
    pub fn fit(samples: &[(f64, f64)]) -> Result<ConvergenceModel> {
        anyhow::ensure!(
            samples.len() >= MIN_SAMPLES,
            "need >= {MIN_SAMPLES} samples, got {}",
            samples.len()
        );
        let min_loss = samples.iter().map(|&(_, l)| l).fold(f64::INFINITY, f64::min);
        anyhow::ensure!(min_loss.is_finite(), "non-finite losses");

        // Coarse grid over b2, then one refinement pass around the winner
        // (two-level grid: b2 resolution ~ min_loss / B2_GRID^2).
        let mut best: Option<ConvergenceModel> = None;
        let coarse = min_loss / B2_GRID as f64;
        let mut centers: Vec<f64> = (0..B2_GRID).map(|gi| coarse * gi as f64).collect();
        let mut refine_round = false;
        loop {
            for &b2 in &centers {
                if let Some(m) = Self::fit_at_b2(samples, b2) {
                    if best.as_ref().map_or(true, |b| m.rms < b.rms) {
                        best = Some(m);
                    }
                }
            }
            if refine_round {
                break;
            }
            refine_round = true;
            let Some(b) = best.as_ref() else { break };
            let center = b.b2;
            let fine = 2.0 * coarse / B2_GRID as f64;
            centers = (0..B2_GRID)
                .map(|gi| (center - coarse + fine * gi as f64).max(0.0))
                .filter(|&b2| b2 < min_loss)
                .collect();
        }
        best.ok_or_else(|| anyhow::anyhow!("no feasible eq-1 fit (is the loss increasing?)"))
    }

    /// Inner NNLS fit at a fixed asymptote `b2`; `None` if infeasible.
    fn fit_at_b2(samples: &[(f64, f64)], b2: f64) -> Option<ConvergenceModel> {
        let design = Matrix::from_fn(samples.len(), 2, |r, c| {
            if c == 0 {
                samples[r].0
            } else {
                1.0
            }
        });
        let rhs: Vec<f64> = samples.iter().map(|&(_, l)| 1.0 / (l - b2)).collect();
        if rhs.iter().any(|v| !v.is_finite() || *v <= 0.0) {
            return None;
        }
        let sol = nnls(&design, &rhs).ok()?;
        let (b0, b1) = (sol.x[0], sol.x[1]);
        if b0 <= 0.0 {
            return None; // paper requires b0 > 0 (loss must decrease)
        }
        // Score in loss space, not linearized space.
        let mut sse = 0.0;
        for &(e, l) in samples {
            let pred = 1.0 / (b0 * e + b1) + b2;
            sse += (pred - l).powi(2);
        }
        let rms = (sse / samples.len() as f64).sqrt();
        Some(ConvergenceModel { b0, b1, b2, rms })
    }

    /// Predicted loss at `epoch`.
    pub fn predict(&self, epoch: f64) -> f64 {
        1.0 / (self.b0 * epoch + self.b1) + self.b2
    }

    /// Epochs needed to reach `target` loss; `None` if the asymptote `b2`
    /// makes the target unreachable.
    pub fn epochs_to_loss(&self, target: f64) -> Option<f64> {
        if target <= self.b2 {
            return None;
        }
        let e = (1.0 / (target - self.b2) - self.b1) / self.b0;
        Some(e.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn curve(b0: f64, b1: f64, b2: f64, epochs: usize) -> Vec<(f64, f64)> {
        (0..epochs)
            .map(|e| {
                let e = e as f64;
                (e, 1.0 / (b0 * e + b1) + b2)
            })
            .collect()
    }

    #[test]
    fn recovers_exact_curve() {
        let m = ConvergenceModel::fit(&curve(0.3, 1.2, 0.25, 50)).unwrap();
        assert!(m.rms < 1e-3, "rms={}", m.rms);
        // predictions must track the curve closely even if params trade off
        for &(e, l) in &curve(0.3, 1.2, 0.25, 50) {
            assert!((m.predict(e) - l).abs() < 5e-3, "e={e}");
        }
    }

    #[test]
    fn epochs_to_loss_inverts_predict() {
        let m = ConvergenceModel::fit(&curve(0.5, 1.0, 0.1, 60)).unwrap();
        let target = m.predict(25.0);
        let e = m.epochs_to_loss(target).unwrap();
        assert!((e - 25.0).abs() < 0.5, "e={e}");
    }

    #[test]
    fn unreachable_target_returns_none() {
        let m = ConvergenceModel::fit(&curve(0.5, 1.0, 0.3, 60)).unwrap();
        assert!(m.epochs_to_loss(0.05).is_none());
    }

    #[test]
    fn tolerates_noise() {
        let mut rng = Rng::new(5);
        let samples: Vec<(f64, f64)> = curve(0.4, 1.5, 0.2, 80)
            .into_iter()
            .map(|(e, l)| (e, l * (1.0 + 0.02 * rng.normal())))
            .collect();
        let m = ConvergenceModel::fit(&samples).unwrap();
        // mid-curve prediction should still be accurate to a few percent
        let truth = 1.0 / (0.4 * 40.0 + 1.5) + 0.2;
        assert!((m.predict(40.0) - truth).abs() / truth < 0.05);
    }

    #[test]
    fn too_few_samples_errors() {
        assert!(ConvergenceModel::fit(&curve(0.3, 1.0, 0.1, 3)).is_err());
    }

    #[test]
    fn rejects_increasing_loss() {
        let samples: Vec<(f64, f64)> = (0..20).map(|e| (e as f64, 1.0 + 0.1 * e as f64)).collect();
        // b0 would need to be negative; fit either errors or produces a
        // large-rms model — it must not produce a confident good fit.
        match ConvergenceModel::fit(&samples) {
            Err(_) => {}
            Ok(m) => assert!(m.rms > 0.05, "rms={}", m.rms),
        }
    }

    #[test]
    fn predict_monotone_decreasing() {
        let m = ConvergenceModel::fit(&curve(0.2, 2.0, 0.15, 40)).unwrap();
        let mut prev = f64::INFINITY;
        for e in 0..100 {
            let p = m.predict(e as f64);
            assert!(p <= prev + 1e-12);
            prev = p;
        }
    }
}
