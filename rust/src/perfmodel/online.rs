//! Online performance modelling — learning the eq 1 / eq 5 fits from
//! live segments instead of assuming them (§7's precompute-vs-explore
//! tradeoff, resolved the way Optimus deploys it: fit as you go).
//!
//! The precompute strategy of §4 assumes every job arrives with its
//! resource-to-speed curve `f(w)` and loss curve known. Real clusters
//! have neither: they *observe* finished training segments. Each live
//! job therefore owns one [`OnlineModel`] that accumulates
//!
//! - **speed observations** `(w, nodes_spanned, measured secs/epoch)` —
//!   one per finished segment, priced at whatever placement the segment
//!   actually ran on, and
//! - **loss observations** `(epoch, loss)` — the trainer's reported
//!   losses over cumulative epochs,
//!
//! and refits [`SpeedModel`] (eq 5) and [`ConvergenceModel`] (eq 1)
//! after every segment.
//!
//! **Placement split.** A segment whose ring spanned `k > 1` nodes
//! measured `base + extra(w, k)` seconds/epoch, where `extra` is the
//! eq 2–4 inter-node delta of [`PlacementModel`]. The interconnect model
//! is cluster configuration, not job knowledge, so the learner strips
//! the delta and fits eq 5 on single-node-equivalent samples — the same
//! convention the trace tables use, which is what lets a learned model
//! be wrapped in the scheduler's placement-aware
//! [`Speed::Placed`](crate::scheduler::Speed) exactly like a table.
//!
//! **Confidence gate.** A fit is handed to the scheduler only once it is
//! trustworthy: at least [`OnlineConfig::min_speed_samples`] segments
//! observed, at least [`OnlineConfig::min_distinct_widths`] distinct
//! worker counts among them (eq 5 is unconstrained along `w` with one),
//! and relative fit residual at most [`OnlineConfig::max_rel_residual`].
//! Until the gate opens, consumers fall back to their prior — under
//! `--online-model` the submission-time trace table (see
//! `scheduler::LearnedSpeed`).
//!
//! **Dedup by width.** Segments repeat widths; on the virtual clock
//! repeated measurements at one `(w, nodes)` are identical, so the fit
//! uses the *latest* observation per width. This keeps the fit — and
//! the model-vs-truth RMSE trajectory the orchestrator reports — a pure
//! function of which widths have been visited: new information moves
//! the model, repetition never jitters it.

use std::collections::BTreeMap;

use crate::perfmodel::convergence::{ConvergenceModel, MIN_SAMPLES};
use crate::perfmodel::placement::PlacementModel;
use crate::perfmodel::speed::SpeedModel;

/// CIFAR-10 examples per epoch — the paper's `m`, scaling feature 0 of
/// eq 5. Only conditioning depends on it (eq-5 coefficients absorb any
/// positive scale), so it doubles as the default for learned fits over
/// trace profiles, which are calibrated to the paper's workload.
pub const PAPER_EXAMPLES_PER_EPOCH: f64 = 50_000.0;

/// Confidence-gate thresholds for [`OnlineModel`].
#[derive(Clone, Copy, Debug)]
pub struct OnlineConfig {
    /// Segments observed before the speed fit may be trusted.
    pub min_speed_samples: usize,
    /// Distinct worker counts observed before the speed fit may be
    /// trusted (eq 5 needs >= 2 to constrain the `w` direction at all).
    pub min_distinct_widths: usize,
    /// Largest trustworthy relative residual: RMS fit error over the
    /// RMS of the measured seconds/epoch.
    pub max_rel_residual: f64,
    /// Loss observations before an eq-1 fit is attempted.
    pub min_loss_samples: usize,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            min_speed_samples: 3,
            min_distinct_widths: 2,
            max_rel_residual: 0.15,
            min_loss_samples: MIN_SAMPLES,
        }
    }
}

/// One finished segment's measured speed at the placement it ran on.
#[derive(Clone, Copy, Debug)]
pub struct SpeedObs {
    pub w: usize,
    /// Nodes the segment's ring spanned (1 on flat pools).
    pub nodes: usize,
    /// Measured seconds/epoch *including* the span's eq-2 delta.
    pub secs_per_epoch: f64,
}

/// Per-job online model: accumulated observations plus the current
/// eq-5 / eq-1 refits and the confidence-gate verdict.
#[derive(Clone, Debug)]
pub struct OnlineModel {
    cfg: OnlineConfig,
    /// Interconnect model used to strip the inter-node delta from
    /// observations (sized to this job's gradient payload).
    placement: PlacementModel,
    /// Eq-5 job constants (`m` examples/epoch, `n` payload bytes).
    m: f64,
    n_bytes: f64,
    speed_obs: Vec<SpeedObs>,
    loss_obs: Vec<(f64, f64)>,
    speed: Option<SpeedModel>,
    confident: bool,
    convergence: Option<ConvergenceModel>,
    refits: u64,
}

impl OnlineModel {
    pub fn new(placement: PlacementModel, m: f64, n_bytes: f64) -> OnlineModel {
        OnlineModel::with_config(placement, m, n_bytes, OnlineConfig::default())
    }

    pub fn with_config(
        placement: PlacementModel,
        m: f64,
        n_bytes: f64,
        cfg: OnlineConfig,
    ) -> OnlineModel {
        OnlineModel {
            cfg,
            placement,
            m,
            n_bytes,
            speed_obs: Vec::new(),
            loss_obs: Vec::new(),
            speed: None,
            confident: false,
            convergence: None,
            refits: 0,
        }
    }

    /// Record one finished segment's measured speed and refit eq 5.
    /// Non-finite or non-positive measurements are dropped, never fitted.
    pub fn observe_speed(&mut self, w: usize, nodes: usize, secs_per_epoch: f64) {
        if w == 0 || !secs_per_epoch.is_finite() || secs_per_epoch <= 0.0 {
            return;
        }
        self.speed_obs.push(SpeedObs { w, nodes: nodes.max(1), secs_per_epoch });
        self.refit_speed();
    }

    /// Record one loss sample at cumulative `epoch` and refit eq 1 once
    /// enough samples exist. A failed refit keeps the previous fit.
    pub fn observe_loss(&mut self, epoch: f64, loss: f64) {
        if !epoch.is_finite() || !loss.is_finite() || loss <= 0.0 {
            return;
        }
        self.loss_obs.push((epoch, loss));
        if self.loss_obs.len() >= self.cfg.min_loss_samples {
            if let Ok(m) = ConvergenceModel::fit(&self.loss_obs) {
                self.convergence = Some(m);
                self.refits += 1;
            }
        }
    }

    /// Single-node-equivalent seconds/epoch of one observation: the
    /// eq-2 delta its span paid is stripped. Clamped positive so a
    /// mis-specified interconnect model can degrade the fit but never
    /// poison it with a non-positive speed.
    fn base_secs(&self, o: &SpeedObs) -> f64 {
        let stripped = o.secs_per_epoch - self.placement.extra_epoch_secs(o.w, o.nodes);
        stripped.max(0.01 * o.secs_per_epoch)
    }

    /// Fit samples: latest observation per width, placement-stripped,
    /// as `(w, epochs/sec)` the way [`SpeedModel::fit`] wants them.
    fn fit_samples(&self) -> Vec<(usize, f64)> {
        let mut latest: BTreeMap<usize, f64> = BTreeMap::new();
        for o in &self.speed_obs {
            latest.insert(o.w, self.base_secs(o));
        }
        latest.into_iter().map(|(w, secs)| (w, 1.0 / secs)).collect()
    }

    fn refit_speed(&mut self) {
        let samples = self.fit_samples();
        self.confident = false;
        if samples.len() < 2 {
            self.speed = None;
            return;
        }
        match SpeedModel::fit(&samples, self.m, self.n_bytes) {
            Ok(m) => {
                // Relative residual: RMS fit error over RMS target, both
                // in seconds/epoch space.
                let rms_target = (samples.iter().map(|&(_, f)| (1.0 / f).powi(2)).sum::<f64>()
                    / samples.len() as f64)
                    .sqrt();
                let rms_err = m.residual / (samples.len() as f64).sqrt();
                let rel = rms_err / rms_target.max(1e-12);
                self.confident = self.speed_obs.len() >= self.cfg.min_speed_samples
                    && samples.len() >= self.cfg.min_distinct_widths
                    && rel <= self.cfg.max_rel_residual;
                self.speed = Some(m);
                self.refits += 1;
            }
            Err(_) => self.speed = None,
        }
    }

    /// The gate-opened eq-5 fit — what schedulers may consume. `None`
    /// until the confidence gate opens.
    pub fn speed(&self) -> Option<&SpeedModel> {
        if self.confident {
            self.speed.as_ref()
        } else {
            None
        }
    }

    /// Current eq-5 fit regardless of confidence (diagnostics only).
    pub fn speed_ungated(&self) -> Option<&SpeedModel> {
        self.speed.as_ref()
    }

    /// Latest eq-1 loss-curve fit, if enough samples have arrived.
    pub fn convergence(&self) -> Option<&ConvergenceModel> {
        self.convergence.as_ref()
    }

    /// True once the speed fit passed the confidence gate.
    pub fn gate_open(&self) -> bool {
        self.confident
    }

    pub fn speed_samples(&self) -> usize {
        self.speed_obs.len()
    }

    pub fn distinct_widths(&self) -> usize {
        let mut ws: Vec<usize> = self.speed_obs.iter().map(|o| o.w).collect();
        ws.sort_unstable();
        ws.dedup();
        ws.len()
    }

    /// Total successful refits (speed + convergence).
    pub fn refits(&self) -> u64 {
        self.refits
    }

    /// RMSE of the *gated* fit against a truth table of
    /// `(w, secs/epoch)` — the learned-vs-oracle gap the orchestrator
    /// reports per job. `None` while the gate is closed.
    pub fn speed_rmse_vs(&self, truth: &[(usize, f64)]) -> Option<f64> {
        let m = self.speed()?;
        if truth.is_empty() {
            return None;
        }
        let sse: f64 = truth
            .iter()
            .map(|&(w, secs)| (m.secs_per_epoch(w) - secs).powi(2))
            .sum();
        Some((sse / truth.len() as f64).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Eq-5-realizable truth: `t(w) = a/w + b·(w-1) + c`, all >= 0 —
    /// exactly the function family eq 5 spans, so a fit over >= 3
    /// distinct widths must reproduce it at *every* width (the eq-5
    /// features are rank 3 and their null direction is prediction-free).
    fn truth(a: f64, b: f64, c: f64) -> impl Fn(usize) -> f64 {
        move |w: usize| a / w as f64 + b * (w as f64 - 1.0) + c
    }

    fn model() -> OnlineModel {
        OnlineModel::new(PlacementModel::paper(), PAPER_EXAMPLES_PER_EPOCH, 6.9e6)
    }

    #[test]
    fn gate_stays_closed_without_distinct_widths() {
        let t = truth(120.0, 1.2, 16.0);
        let mut m = model();
        for _ in 0..5 {
            m.observe_speed(4, 1, t(4));
        }
        assert!(m.speed().is_none(), "one width can never open the gate");
        assert!(!m.gate_open());
        assert_eq!(m.distinct_widths(), 1);
        m.observe_speed(8, 1, t(8));
        assert!(m.gate_open(), "exact samples at 2 widths and 6 obs must pass");
        assert!(m.speed().is_some());
    }

    #[test]
    fn gate_needs_min_samples_even_with_two_widths() {
        let t = truth(120.0, 1.2, 16.0);
        let mut m = model();
        m.observe_speed(1, 1, t(1));
        m.observe_speed(2, 1, t(2));
        assert!(m.speed().is_none(), "2 obs < min_speed_samples");
        assert!(m.speed_ungated().is_some(), "a fit exists, just untrusted");
        m.observe_speed(2, 1, t(2));
        assert!(m.gate_open());
    }

    #[test]
    fn full_width_coverage_recovers_truth_everywhere() {
        let t = truth(140.0, 0.9, 11.0);
        let mut m = model();
        for &w in &[1usize, 2, 4, 8] {
            m.observe_speed(w, 1, t(w));
        }
        let fit = m.speed().expect("gate open");
        for w in [1usize, 3, 5, 8, 16, 32] {
            let got = fit.secs_per_epoch(w);
            let want = t(w);
            assert!((got - want).abs() / want < 1e-3, "w={w}: {got} vs {want}");
        }
    }

    #[test]
    fn placement_split_strips_the_internode_delta() {
        // Observations taken on rings spanning 2 nodes include the eq-2
        // delta; the learner must recover the single-node base curve.
        let t = truth(130.0, 1.0, 14.0);
        let placement = PlacementModel::paper().with_model_bytes(1.0e8);
        let mut m =
            OnlineModel::new(placement, PAPER_EXAMPLES_PER_EPOCH, 1.0e8);
        for &(w, nodes) in &[(1usize, 1usize), (2, 2), (4, 2), (8, 2)] {
            let measured = placement.placed_epoch_secs(t(w), w, nodes);
            m.observe_speed(w, nodes, measured);
        }
        let fit = m.speed().expect("gate open");
        for &w in &[1usize, 2, 4, 8] {
            let got = fit.secs_per_epoch(w);
            let want = t(w);
            assert!((got - want).abs() / want < 1e-3, "w={w}: {got} vs {want}");
        }
    }

    #[test]
    fn rmse_drops_to_zero_at_full_coverage_and_repeats_do_not_jitter() {
        let t = truth(125.0, 1.4, 13.0);
        let table: Vec<(usize, f64)> = [1usize, 2, 4, 8].iter().map(|&w| (w, t(w))).collect();
        let mut m = model();
        m.observe_speed(8, 1, t(8));
        m.observe_speed(4, 1, t(4));
        m.observe_speed(4, 1, t(4));
        let first = m.speed_rmse_vs(&table).expect("gate open at 2 widths / 3 obs");
        m.observe_speed(4, 1, t(4));
        let repeat = m.speed_rmse_vs(&table).unwrap();
        assert_eq!(first.to_bits(), repeat.to_bits(), "duplicate widths moved the fit");
        m.observe_speed(2, 1, t(2));
        m.observe_speed(1, 1, t(1));
        let last = m.speed_rmse_vs(&table).unwrap();
        // slack above NNLS numerical noise, far below any real signal
        assert!(last <= first + 1e-6 * t(1), "rmse rose with coverage: {first} -> {last}");
        assert!(last < 1e-3 * t(1), "full coverage should recover truth: rmse={last}");
    }

    #[test]
    fn garbage_observations_are_dropped() {
        let t = truth(120.0, 1.2, 16.0);
        let mut m = model();
        m.observe_speed(0, 1, 10.0);
        m.observe_speed(2, 1, f64::NAN);
        m.observe_speed(2, 1, -3.0);
        m.observe_speed(2, 1, 0.0);
        assert_eq!(m.speed_samples(), 0);
        m.observe_loss(f64::NAN, 1.0);
        m.observe_loss(0.0, -1.0);
        // valid data still works afterwards
        for &w in &[1usize, 2, 4] {
            m.observe_speed(w, 1, t(w));
        }
        assert!(m.gate_open());
    }

    #[test]
    fn convergence_fit_arrives_with_enough_losses() {
        let mut m = model();
        for e in 0..4 {
            m.observe_loss(e as f64, 1.0 / (0.4 * e as f64 + 1.2) + 0.2);
        }
        assert!(m.convergence().is_none(), "below min_loss_samples");
        for e in 4..30 {
            m.observe_loss(e as f64, 1.0 / (0.4 * e as f64 + 1.2) + 0.2);
        }
        let conv = m.convergence().expect("fit after enough samples");
        let want = 1.0 / (0.4 * 15.0 + 1.2) + 0.2;
        assert!((conv.predict(15.0) - want).abs() / want < 0.05);
    }
}
