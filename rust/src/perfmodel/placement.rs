//! Placement-dependent speed — the eq 2–4 intra/inter-node split.
//!
//! The paper's cost models (eqs 2–4) price one all-reduce with a single
//! (α, β, γ); its testbed nodes hold 8 GPUs, so a ring wider than 8 —
//! or any ring a fragmented cluster scatters across nodes — mixes two
//! very different links. A synchronous ring pipeline advances at the
//! pace of its *slowest* edge, so the split is sharp:
//!
//! - ring fits one node → every edge is NVLink/PCIe: eq 2 with
//!   `(α_intra, β_intra)`;
//! - ring spans `k ≥ 2` nodes → every pipelined chunk round is gated by
//!   an inter-node edge: eq 2 with `(α_inter, β_inter)`, plus a per-hop
//!   latency term growing in `k` (switch traversals).
//!
//! Rings are always ordered node-contiguously (GPUs sorted by node), so
//! a ring spanning `k` nodes crosses the network exactly `k` times —
//! the *span* is the whole story, which is why [`crate::cluster::Span`]
//! is all a speed lookup needs. [`PlacementModel`] turns the comm-time
//! delta into extra seconds per epoch so the profile-table speeds
//! (measured on a single node) extend to any placement:
//!
//! `secs/epoch(w, k) = secs/epoch(w) + steps(w) · (ring(w,k) − ring(w,1))`
//!
//! with `steps(w) = steps_per_epoch_1w / w` (global batch grows with
//! `w`, exactly the trainer's accounting). For `k = 1` — and for
//! [`Topology::Flat`] — the delta is identically zero: the flat path is
//! preserved bit-for-bit.

use crate::collectives::cost::{comm_time, Algorithm, CostParams};
use crate::Result;

/// ResNet-110/CIFAR-10, the paper's workload: ~1.7M f32 params.
pub const PAPER_MODEL_BYTES: f64 = 6.9e6;

/// 50k examples / minibatch 128 → all-reduce rounds per epoch at w = 1.
pub const PAPER_STEPS_PER_EPOCH_1W: f64 = 390.0;

/// Multi-tenant shared-bandwidth law for the inter-node links (GADGET's
/// contention regime, arXiv 2202.01158 / 2207.07817).
///
/// Eqs 2–4 price an all-reduce as if the ring owned its links; on a
/// shared cluster every ring crossing a node's uplink competes with the
/// other rings crossing it. With `r` rings on the busiest link a job
/// traverses, the effective link constants degrade linearly:
///
/// - `β_eff = β · (1 + beta_share · (r − 1))` — bandwidth is divided:
///   `beta_share = 1.0` is perfect fair-share (each of `r` rings sees
///   `1/r` of the pipe);
/// - `α_eff = α · (1 + alpha_share · (r − 1))` — per-message latency
///   grows with switch/NIC queueing, a weaker second-order term.
///
/// `r = 1` (sole tenant) leaves both constants untouched — by
/// construction every `r <= 1` call delegates to the uncontended code
/// path, so a single-tenant world is **bit-identical** to the PR-3
/// placement model, and disabling the law (`enabled = false`) is
/// bit-identical everywhere. Intra-node rings never touch a link and
/// are never degraded.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkContention {
    /// Master switch; `false` (the default) is provably the PR-3 model.
    pub enabled: bool,
    /// Fractional β growth per extra tenant (1.0 = fair-share).
    pub beta_share: f64,
    /// Fractional α growth per extra tenant (switch queueing).
    pub alpha_share: f64,
}

impl Default for LinkContention {
    fn default() -> Self {
        LinkContention::OFF
    }
}

impl LinkContention {
    /// Contention modelling off — the uncontended eq 2–4 world.
    pub const OFF: LinkContention =
        LinkContention { enabled: false, beta_share: 1.0, alpha_share: 0.25 };

    /// Fair-share bandwidth division with mild latency queueing — the
    /// `--contention` default.
    pub fn fair_share() -> LinkContention {
        LinkContention { enabled: true, beta_share: 1.0, alpha_share: 0.25 }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Checked constructor for config plumbing: negative shares would
    /// make extra tenants *speed a ring up*, violating monotonicity.
    pub fn checked(self) -> Result<LinkContention> {
        anyhow::ensure!(
            self.beta_share >= 0.0 && self.beta_share.is_finite(),
            "link contention beta_share must be finite and >= 0"
        );
        anyhow::ensure!(
            self.alpha_share >= 0.0 && self.alpha_share.is_finite(),
            "link contention alpha_share must be finite and >= 0"
        );
        Ok(self)
    }
}

/// Link constants for the two tiers of the interconnect.
#[derive(Clone, Copy, Debug)]
pub struct TopoCostParams {
    pub intra: CostParams,
    pub inter: CostParams,
    /// Extra per-message latency per node boundary beyond the first
    /// split (additional switch hops), seconds.
    pub hop_alpha: f64,
}

impl Default for TopoCostParams {
    fn default() -> Self {
        TopoCostParams {
            intra: CostParams::intra_node(),
            inter: CostParams::inter_node(),
            hop_alpha: 5e-6,
        }
    }
}

/// Turns a `(w, nodes_spanned)` placement into an epoch-time penalty.
#[derive(Clone, Copy, Debug)]
pub struct PlacementModel {
    pub params: TopoCostParams,
    /// Gradient payload per all-reduce (model size in bytes).
    pub n_bytes: f64,
    /// All-reduce rounds per epoch for a 1-worker run (`M / batch`);
    /// rounds at `w` workers = this / `w`.
    pub steps_per_epoch_1w: f64,
}

impl Default for PlacementModel {
    fn default() -> Self {
        PlacementModel::paper()
    }
}

impl PlacementModel {
    /// The paper's workload on a two-tier commodity cluster.
    pub fn paper() -> PlacementModel {
        PlacementModel {
            params: TopoCostParams::default(),
            n_bytes: PAPER_MODEL_BYTES,
            steps_per_epoch_1w: PAPER_STEPS_PER_EPOCH_1W,
        }
    }

    /// Same interconnect, a communication-bound model (`n_bytes`
    /// override) — the regime where locality is first-order.
    pub fn with_model_bytes(mut self, n_bytes: f64) -> PlacementModel {
        self.n_bytes = n_bytes;
        self
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.n_bytes > 0.0 && self.steps_per_epoch_1w > 0.0,
            "placement model needs positive n_bytes/steps_per_epoch"
        );
        Ok(())
    }

    /// Eq-2 ring all-reduce seconds for a ring of `w` spanning `nodes`
    /// nodes (node-contiguous ring order; zero for `w <= 1`). Delegates
    /// to the canonical eq-2 model in `collectives::cost` with the
    /// link tier — and, past one node, the per-hop latency — folded
    /// into the constants, so the two can never drift apart.
    pub fn ring_comm_secs(&self, w: usize, nodes: usize, n_bytes: f64) -> f64 {
        let tier = if nodes <= 1 { self.params.intra } else { self.params.inter };
        // slowest-edge gating: one inter-node edge paces every chunk round
        let alpha = if nodes <= 1 {
            tier.alpha
        } else {
            tier.alpha + self.params.hop_alpha * (nodes as f64 - 2.0).max(0.0)
        };
        comm_time(Algorithm::Ring, w, n_bytes, &CostParams { alpha, ..tier })
    }

    /// Extra seconds per epoch a ring of `w` pays for spanning `nodes`
    /// nodes instead of one, for a job moving `n_bytes` per all-reduce.
    /// Exactly 0.0 for `nodes <= 1`.
    pub fn extra_epoch_secs_for(&self, w: usize, nodes: usize, n_bytes: f64) -> f64 {
        if nodes <= 1 || w <= 1 {
            return 0.0;
        }
        let steps = self.steps_per_epoch_1w / w as f64;
        steps * (self.ring_comm_secs(w, nodes, n_bytes) - self.ring_comm_secs(w, 1, n_bytes))
    }

    /// [`Self::extra_epoch_secs_for`] with the model's own payload size.
    pub fn extra_epoch_secs(&self, w: usize, nodes: usize) -> f64 {
        self.extra_epoch_secs_for(w, nodes, self.n_bytes)
    }

    /// Memo table of [`Self::extra_epoch_secs`] at the contiguous
    /// best-case span, for widths `1..=max_w` (indexed by `w - 1`) —
    /// what `Speed::placed_memo` consults so scheduler inner loops stop
    /// re-pricing eq 2–4 per probe. Values are produced by the exact
    /// same call the unmemoized path makes, so they agree bit for bit.
    pub fn contiguous_extra_table(&self, gpus_per_node: usize, max_w: usize) -> Vec<f64> {
        (1..=max_w)
            .map(|w| self.extra_epoch_secs(w, crate::cluster::contiguous_span(w, gpus_per_node)))
            .collect()
    }

    /// Profile seconds/epoch adjusted for placement. Identity (the exact
    /// same float) when the ring fits one node.
    pub fn placed_epoch_secs(&self, base_secs: f64, w: usize, nodes: usize) -> f64 {
        if nodes <= 1 {
            return base_secs;
        }
        base_secs + self.extra_epoch_secs(w, nodes)
    }

    /// Checked constructor for config plumbing.
    pub fn checked(self) -> Result<PlacementModel> {
        self.validate()?;
        Ok(self)
    }

    /// [`Self::ring_comm_secs`] under link contention: `tenants` rings
    /// share the busiest link this ring traverses, degrading the
    /// inter-node constants per `law`. Delegates to the uncontended
    /// method — same floats, same order — whenever the law is off, the
    /// ring is sole tenant, or the ring never leaves its node, so those
    /// cases are bit-identical to the PR-3 model by construction.
    pub fn contended_ring_comm_secs(
        &self,
        w: usize,
        nodes: usize,
        n_bytes: f64,
        law: LinkContention,
        tenants: usize,
    ) -> f64 {
        if !law.enabled() || tenants <= 1 || nodes <= 1 {
            return self.ring_comm_secs(w, nodes, n_bytes);
        }
        let tier = self.params.inter;
        let extra_tenants = (tenants - 1) as f64;
        // same slowest-edge α as the uncontended path, then queueing
        let alpha = (tier.alpha + self.params.hop_alpha * (nodes as f64 - 2.0).max(0.0))
            * (1.0 + law.alpha_share * extra_tenants);
        // fair-share bandwidth division on the shared uplink
        let beta = tier.beta * (1.0 + law.beta_share * extra_tenants);
        comm_time(Algorithm::Ring, w, n_bytes, &CostParams { alpha, beta, ..tier })
    }

    /// [`Self::extra_epoch_secs_for`] under link contention. The
    /// single-node baseline inside the delta stays uncontended (an
    /// intra-node ring has no link to share), so the penalty is
    /// monotone in `tenants` and exactly the PR-3 delta at one tenant.
    pub fn contended_extra_epoch_secs_for(
        &self,
        w: usize,
        nodes: usize,
        n_bytes: f64,
        law: LinkContention,
        tenants: usize,
    ) -> f64 {
        if nodes <= 1 || w <= 1 {
            return 0.0;
        }
        let steps = self.steps_per_epoch_1w / w as f64;
        steps
            * (self.contended_ring_comm_secs(w, nodes, n_bytes, law, tenants)
                - self.ring_comm_secs(w, 1, n_bytes))
    }

    /// [`Self::contended_extra_epoch_secs_for`] with the model's own
    /// payload size.
    pub fn contended_extra_epoch_secs(
        &self,
        w: usize,
        nodes: usize,
        law: LinkContention,
        tenants: usize,
    ) -> f64 {
        self.contended_extra_epoch_secs_for(w, nodes, self.n_bytes, law, tenants)
    }

    /// [`Self::placed_epoch_secs`] under link contention. Structurally
    /// delegates to `placed_epoch_secs` when the law is off or the job
    /// is sole tenant — the contention-off execution path *is* the PR-3
    /// path, not a re-derivation of it.
    pub fn contended_epoch_secs(
        &self,
        base_secs: f64,
        w: usize,
        nodes: usize,
        law: LinkContention,
        tenants: usize,
    ) -> f64 {
        if !law.enabled() || tenants <= 1 {
            return self.placed_epoch_secs(base_secs, w, nodes);
        }
        if nodes <= 1 {
            return base_secs;
        }
        base_secs + self.contended_extra_epoch_secs(w, nodes, law, tenants)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Communication-bound payload (VGG-class, 25M params).
    const BIG: f64 = 1.0e8;

    #[test]
    fn single_node_span_is_exact_identity() {
        let m = PlacementModel::paper();
        for w in [1usize, 2, 4, 8, 64] {
            assert_eq!(m.extra_epoch_secs(w, 1), 0.0);
            assert_eq!(m.extra_epoch_secs(w, 0), 0.0);
            let base = 29.6;
            assert_eq!(m.placed_epoch_secs(base, w, 1).to_bits(), base.to_bits());
        }
    }

    #[test]
    fn crossing_a_node_boundary_costs_time() {
        let m = PlacementModel::paper();
        for w in [2usize, 4, 8, 16] {
            assert!(m.extra_epoch_secs(w, 2) > 0.0, "w={w}");
        }
    }

    #[test]
    fn penalty_monotone_in_nodes_spanned() {
        let m = PlacementModel::paper();
        let mut prev = 0.0;
        for nodes in 1..=8 {
            let extra = m.extra_epoch_secs(16, nodes);
            assert!(extra >= prev, "nodes={nodes}: {extra} < {prev}");
            prev = extra;
        }
        // and strictly so once the hop term engages
        assert!(m.extra_epoch_secs(16, 4) > m.extra_epoch_secs(16, 2));
    }

    #[test]
    fn penalty_scales_with_payload() {
        let m = PlacementModel::paper();
        let small = m.extra_epoch_secs_for(8, 2, PAPER_MODEL_BYTES);
        let big = m.extra_epoch_secs_for(8, 2, BIG);
        assert!(big > 10.0 * small, "{big} vs {small}");
    }

    #[test]
    fn comm_bound_model_pays_measurably() {
        // VGG-class payload on 10 GbE: spanning 2 nodes at w=8 must cost
        // a double-digit percentage of the paper's 29.6 s/epoch — the
        // regime where gang placement is first-order.
        let m = PlacementModel::paper().with_model_bytes(BIG);
        let extra = m.extra_epoch_secs(8, 2);
        assert!(extra > 0.1 * 29.6, "extra {extra:.2}s not measurable");
    }

    #[test]
    fn ring_comm_matches_eq2_shape() {
        // intra ring at w=2 vs w=4: latency term linear in (w-1)
        let m = PlacementModel::paper();
        let c2 = m.ring_comm_secs(2, 1, 4e6);
        let c4 = m.ring_comm_secs(4, 1, 4e6);
        assert!(c4 > c2);
        assert_eq!(m.ring_comm_secs(1, 1, 4e6), 0.0);
        assert_eq!(m.ring_comm_secs(1, 4, 4e6), 0.0);
    }

    #[test]
    fn checked_rejects_nonsense() {
        let mut m = PlacementModel::paper();
        m.n_bytes = 0.0;
        assert!(m.checked().is_err());
        assert!(PlacementModel::paper().checked().is_ok());
    }

    #[test]
    fn contention_single_tenant_is_bit_identical() {
        // tenants = 1 and law-off must be the PR-3 floats exactly
        let m = PlacementModel::paper().with_model_bytes(BIG);
        let law = LinkContention::fair_share();
        for w in [2usize, 4, 8, 16] {
            for nodes in [1usize, 2, 4] {
                let base = 29.6;
                let plain = m.placed_epoch_secs(base, w, nodes);
                assert_eq!(
                    m.contended_epoch_secs(base, w, nodes, law, 1).to_bits(),
                    plain.to_bits(),
                    "tenants=1 w={w} nodes={nodes}"
                );
                assert_eq!(
                    m.contended_epoch_secs(base, w, nodes, LinkContention::OFF, 5).to_bits(),
                    plain.to_bits(),
                    "law off w={w} nodes={nodes}"
                );
            }
        }
    }

    #[test]
    fn contention_monotone_in_tenants() {
        let m = PlacementModel::paper().with_model_bytes(BIG);
        let law = LinkContention::fair_share();
        let mut prev = 0.0;
        for tenants in 1..=6 {
            let extra = m.contended_extra_epoch_secs(8, 2, law, tenants);
            assert!(extra >= prev, "tenants={tenants}: {extra} < {prev}");
            prev = extra;
        }
        // strictly worse once a second ring shares the link
        assert!(
            m.contended_extra_epoch_secs(8, 2, law, 2)
                > m.contended_extra_epoch_secs(8, 2, law, 1)
        );
    }

    #[test]
    fn contention_never_touches_intra_node_rings() {
        let m = PlacementModel::paper().with_model_bytes(BIG);
        let law = LinkContention::fair_share();
        for tenants in 1..=8 {
            assert_eq!(m.contended_extra_epoch_secs(8, 1, law, tenants), 0.0);
            let base = 47.3;
            assert_eq!(
                m.contended_epoch_secs(base, 8, 1, law, tenants).to_bits(),
                base.to_bits()
            );
        }
    }

    #[test]
    fn fair_share_halves_effective_bandwidth_at_two_tenants() {
        // with β dominating (huge payload), two fair-share tenants pay
        // roughly twice the β term of the sole-tenant inter-node ring
        let m = PlacementModel::paper();
        let alone = m.contended_ring_comm_secs(8, 2, 1.0e9, LinkContention::fair_share(), 1);
        let shared = m.contended_ring_comm_secs(8, 2, 1.0e9, LinkContention::fair_share(), 2);
        assert!(shared > 1.8 * alone, "shared {shared} vs alone {alone}");
        assert!(shared < 2.5 * alone, "shared {shared} vs alone {alone}");
    }

    #[test]
    fn link_contention_checked_rejects_nonsense() {
        assert!(LinkContention::fair_share().checked().is_ok());
        assert!(LinkContention::OFF.checked().is_ok());
        let mut bad = LinkContention::fair_share();
        bad.beta_share = -0.1;
        assert!(bad.checked().is_err());
        bad = LinkContention::fair_share();
        bad.alpha_share = f64::NAN;
        assert!(bad.checked().is_err());
    }
}
