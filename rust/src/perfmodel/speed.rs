//! Resource-to-speed model — eq 5 of the paper (§3.2).
//!
//!   `f(w) = (t0·(m/w) + t1·(w-1) + t2·(w-1)·(n/w) + t3)^-1`
//!
//! `f` is epochs/second; the bracket is seconds/epoch, a linear model in
//! the features `[m/w, w-1, (w-1)·n/w, 1]` whose structure mirrors the
//! all-reduce cost models (eqs 2–4): per-worker compute, per-step
//! latency, per-step bandwidth, and a constant. All `t`'s are fitted
//! with NNLS from observed `(w, f(w))` samples — the data the
//! *exploratory* strategy spends its first ten minutes collecting and the
//! *precompute* strategy is assumed to already have (§4).

use crate::linalg::Matrix;
use crate::nnls::nnls;
use crate::Result;

/// Fitted eq-5 resource model.
#[derive(Clone, Debug)]
pub struct SpeedModel {
    /// Coefficients `[t0, t1, t2, t3]`, all >= 0.
    pub theta: [f64; 4],
    /// Per-epoch examples `m` (job constant baked into feature 0).
    pub m: f64,
    /// Model size in bytes `n` (job constant baked into feature 2).
    pub n_bytes: f64,
    /// Residual of the NNLS fit in seconds-per-epoch space.
    pub residual: f64,
}

impl SpeedModel {
    /// Feature vector of eq 5 for `w` workers.
    fn features(m: f64, n_bytes: f64, w: usize) -> [f64; 4] {
        let wf = w as f64;
        [m / wf, wf - 1.0, (wf - 1.0) * (n_bytes / wf), 1.0]
    }

    /// Fit from `(w, epochs_per_sec)` samples. Needs >= 2 distinct worker
    /// counts; more are better (the exploratory strategy collects 4).
    pub fn fit(samples: &[(usize, f64)], m: f64, n_bytes: f64) -> Result<SpeedModel> {
        anyhow::ensure!(samples.len() >= 2, "need >= 2 samples, got {}", samples.len());
        let mut ws: Vec<usize> = samples.iter().map(|&(w, _)| w).collect();
        ws.sort_unstable();
        ws.dedup();
        anyhow::ensure!(ws.len() >= 2, "need >= 2 distinct worker counts");
        for &(w, f) in samples {
            anyhow::ensure!(w >= 1 && f > 0.0, "bad sample (w={w}, f={f})");
        }

        let design = Matrix::from_fn(samples.len(), 4, |r, c| {
            Self::features(m, n_bytes, samples[r].0)[c]
        });
        // target: seconds per epoch
        let rhs: Vec<f64> = samples.iter().map(|&(_, f)| 1.0 / f).collect();
        let sol = nnls(&design, &rhs)?;
        anyhow::ensure!(
            sol.x.iter().any(|&t| t > 0.0),
            "degenerate fit: all coefficients zero"
        );
        Ok(SpeedModel {
            theta: [sol.x[0], sol.x[1], sol.x[2], sol.x[3]],
            m,
            n_bytes,
            residual: sol.residual,
        })
    }

    /// Seconds per epoch at `w` workers.
    pub fn secs_per_epoch(&self, w: usize) -> f64 {
        let x = Self::features(self.m, self.n_bytes, w);
        self.theta.iter().zip(&x).map(|(t, f)| t * f).sum()
    }

    /// Training speed `f(w)` in epochs/second.
    pub fn epochs_per_sec(&self, w: usize) -> f64 {
        let t = self.secs_per_epoch(w);
        if t <= 0.0 {
            0.0
        } else {
            1.0 / t
        }
    }

    /// Marginal *per-GPU* gain of doubling from `w` to `2w` for a job with
    /// `q` remaining epochs — eq 6, the doubling heuristic's score.
    pub fn doubling_gain(&self, q: f64, w: usize) -> f64 {
        let t_now = q / self.epochs_per_sec(w);
        let t_double = q / self.epochs_per_sec(2 * w);
        (t_now - t_double) / w as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ground-truth epoch time with compute that parallelizes plus a
    /// per-step overhead growing in w (the eq 2 ring shape).
    fn epoch_secs(w: usize) -> f64 {
        200.0 / w as f64 + 3.0 * (w as f64 - 1.0) + 5.0
    }

    fn fitted() -> SpeedModel {
        let samples: Vec<(usize, f64)> =
            [1usize, 2, 4, 8].iter().map(|&w| (w, 1.0 / epoch_secs(w))).collect();
        SpeedModel::fit(&samples, 200.0, 1.0e6).unwrap()
    }

    #[test]
    fn interpolates_observed_points() {
        let m = fitted();
        for &w in &[1usize, 2, 4, 8] {
            let got = m.secs_per_epoch(w);
            let want = epoch_secs(w);
            assert!((got - want).abs() / want < 0.05, "w={w}: {got} vs {want}");
        }
    }

    #[test]
    fn extrapolates_sanely_to_16() {
        let m = fitted();
        let got = m.secs_per_epoch(16);
        let want = epoch_secs(16);
        assert!((got - want).abs() / want < 0.4, "{got} vs {want}");
    }

    #[test]
    fn speed_increases_then_saturates() {
        // With a strong serial overhead term the model must show
        // diminishing returns: f(2)/f(1) > f(16)/f(8).
        let m = fitted();
        let r_low = m.epochs_per_sec(2) / m.epochs_per_sec(1);
        let r_high = m.epochs_per_sec(16) / m.epochs_per_sec(8);
        assert!(r_low > r_high);
    }

    #[test]
    fn coefficients_nonnegative() {
        let m = fitted();
        assert!(m.theta.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn doubling_gain_positive_when_scaling_helps() {
        let m = fitted();
        assert!(m.doubling_gain(100.0, 1) > 0.0);
    }

    #[test]
    fn doubling_gain_shrinks_per_gpu() {
        // per-GPU gain of 1->2 exceeds per-GPU gain of 8->16
        let m = fitted();
        assert!(m.doubling_gain(100.0, 1) > m.doubling_gain(100.0, 8));
    }

    #[test]
    fn needs_two_distinct_worker_counts() {
        assert!(SpeedModel::fit(&[(4, 0.1), (4, 0.11)], 100.0, 1e6).is_err());
        assert!(SpeedModel::fit(&[(4, 0.1)], 100.0, 1e6).is_err());
    }

    #[test]
    fn rejects_nonpositive_speed() {
        assert!(SpeedModel::fit(&[(1, 0.0), (2, 0.1)], 100.0, 1e6).is_err());
    }
}
