//! Execution-backend abstraction (DESIGN.md §6.1).
//!
//! The trainer only ever talks to an [`Engine`](super::Engine); the engine
//! dispatches through this trait. Two implementations exist:
//!
//! - [`ReferenceBackend`](super::reference::ReferenceBackend) — pure rust,
//!   zero native dependencies, the default everywhere;
//! - `PjrtBackend` (`pjrt` cargo feature) — compiles and executes the AOT
//!   HLO artifacts through the PJRT C API.
//!
//! Selection: `RINGMASTER_BACKEND=reference|pjrt` forces a backend;
//! otherwise PJRT is chosen only when it was compiled in *and* every
//! artifact of the preset is on disk, so a bare checkout always runs.

use crate::runtime::manifest::{Artifacts, PresetSpec};
use crate::Result;

/// One execution substrate for a compiled model preset.
///
/// Inputs are pre-validated by [`Engine`](super::Engine) (theta length,
/// token-buffer shapes), so implementations own only the math. All methods
/// take `&self`: a backend is used by exactly one worker thread, and any
/// lazy state (e.g. PJRT executable compilation) is interior.
pub trait Backend {
    /// Short platform label (e.g. `"reference-cpu"`), for reports.
    fn name(&self) -> &'static str;

    /// Pay ahead-of-time costs (compilation) for the training path. The
    /// wall time of `load + warmup` is the paper's stop/restart cost (§6).
    fn warmup(&self, fresh_start: bool) -> Result<()>;

    /// Deterministic parameter init from a 64-bit seed.
    fn init(&self, seed: u64) -> Result<Vec<f32>>;

    /// One local fwd+bwd step: `(loss, grad)` for this worker's shard.
    fn train_step(
        &self,
        theta: &[f32],
        inputs: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<f32>)>;

    /// Forward-only loss (eval / Table 1 `T_forward` profiling).
    fn fwd_loss(&self, theta: &[f32], inputs: &[i32], targets: &[i32]) -> Result<f32>;

    /// Fused momentum-SGD update: `(theta', mu')`.
    fn sgd_update(
        &self,
        theta: &[f32],
        grad: &[f32],
        mu: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)>;
}

/// Which backend an [`Engine`](super::Engine) should construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust reference implementation (always available).
    Reference,
    /// PJRT execution of the AOT artifacts (`pjrt` feature).
    Pjrt,
}

impl BackendKind {
    /// Default policy: PJRT when compiled in and every artifact of the
    /// preset exists on disk; the reference backend otherwise. The env
    /// override and the fall-back-on-construction-failure logic live in
    /// [`Engine::load`](super::Engine::load).
    #[cfg(feature = "pjrt")]
    pub fn auto(artifacts: &Artifacts, preset: &PresetSpec) -> BackendKind {
        let entries = crate::runtime::manifest::ENTRY_POINTS;
        if entries.iter().all(|e| artifacts.entry_path(preset, e).is_ok()) {
            BackendKind::Pjrt
        } else {
            BackendKind::Reference
        }
    }

    /// Default policy without the `pjrt` feature: always the reference
    /// backend.
    #[cfg(not(feature = "pjrt"))]
    pub fn auto(_artifacts: &Artifacts, _preset: &PresetSpec) -> BackendKind {
        BackendKind::Reference
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_without_artifacts_is_reference() {
        // a known-empty dir, so the test is independent of the process
        // env ($RINGMASTER_ARTIFACTS) and of cwd-relative artifacts/
        let d = std::env::temp_dir()
            .join(format!("ringmaster-backend-auto-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        let a = Artifacts::resolve(&d).unwrap();
        let p = a.preset("tiny").unwrap();
        assert_eq!(BackendKind::auto(&a, &p), BackendKind::Reference);
        let _ = std::fs::remove_dir_all(&d);
    }
}
