//! Execution runtime: pluggable backends behind one [`Engine`] facade
//! (DESIGN.md §6).
//!
//! The trainer asks an `Engine` for exactly four operations — `init`,
//! `train_step`, `fwd_loss`, `sgd_update` — and the engine dispatches to
//! an execution [`Backend`]:
//!
//! - [`reference`] — pure-rust forward/backward of the Layer-2 model,
//!   zero native dependencies; the default, and what CI runs;
//! - [`pjrt`] (`pjrt` cargo feature) — compiles the AOT HLO-text
//!   artifacts through the PJRT C API (see DESIGN.md §6.2 / aot.py — the
//!   64-bit-proto-id gotcha).
//!
//! Every trainer worker thread builds its *own* `Engine` (the PJRT client
//! is `Rc`-backed and `!Send`); per-(re)start construction cost is exactly
//! the stop/restart overhead the paper measures (~10 s on their testbed;
//! Table 2 experiment — ours reports the same quantity for our stack).
//! Backend choice: `RINGMASTER_BACKEND=reference|pjrt`, else automatic
//! (PJRT only when compiled in and its artifacts exist on disk).

pub mod backend;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;

pub use backend::{Backend, BackendKind};
pub use manifest::{Artifacts, ParamEntry, PresetSpec};
pub use reference::ReferenceBackend;

use crate::Result;

/// A loaded model preset bound to one execution backend.
pub struct Engine {
    backend: Box<dyn Backend>,
    preset: PresetSpec,
}

impl Engine {
    /// Load a preset on the backend selected for this process:
    /// `RINGMASTER_BACKEND=reference|pjrt` forces one (and failures are
    /// fatal); otherwise [`BackendKind::auto`] proposes PJRT only when it
    /// was compiled in and the artifacts exist, and if that construction
    /// fails (e.g. the offline `xla` API stub is linked, or the native
    /// libs are absent) the engine falls back to the reference backend
    /// with a single warning. The auto decision is memoized process-wide:
    /// every worker thread of a data-parallel job gets the *same* backend
    /// (mixed backends would break the bit-identical-parameters
    /// invariant), and a transient PJRT failure after another rank
    /// succeeded is a hard error, not a silent divergence.
    pub fn load(artifacts: &Artifacts, preset_name: &str) -> Result<Engine> {
        static AUTO_KIND: std::sync::OnceLock<BackendKind> = std::sync::OnceLock::new();
        let preset = artifacts.preset(preset_name)?;
        match std::env::var("RINGMASTER_BACKEND") {
            Ok(v) if v == "reference" => {
                Engine::from_preset(artifacts, preset, BackendKind::Reference)
            }
            Ok(v) if v == "pjrt" => Engine::from_preset(artifacts, preset, BackendKind::Pjrt),
            Ok(v) => anyhow::bail!("RINGMASTER_BACKEND={v:?}: want `reference` or `pjrt`"),
            Err(_) => {
                if let Some(&kind) = AUTO_KIND.get() {
                    return Engine::from_preset(artifacts, preset, kind);
                }
                match BackendKind::auto(artifacts, &preset) {
                    BackendKind::Reference => {
                        let _ = AUTO_KIND.set(BackendKind::Reference);
                        Engine::from_preset(artifacts, preset, BackendKind::Reference)
                    }
                    BackendKind::Pjrt => {
                        match Engine::from_preset(artifacts, preset.clone(), BackendKind::Pjrt) {
                            Ok(engine) => match *AUTO_KIND.get_or_init(|| BackendKind::Pjrt) {
                                BackendKind::Pjrt => Ok(engine),
                                // another thread already settled on the
                                // reference backend — stay consistent
                                BackendKind::Reference => {
                                    Engine::from_preset(artifacts, preset, BackendKind::Reference)
                                }
                            },
                            Err(e) => {
                                let decided = *AUTO_KIND.get_or_init(|| {
                                    eprintln!(
                                        "warning: PJRT backend unavailable ({e:#}); \
                                         falling back to the reference backend"
                                    );
                                    BackendKind::Reference
                                });
                                match decided {
                                    BackendKind::Reference => Engine::from_preset(
                                        artifacts,
                                        preset,
                                        BackendKind::Reference,
                                    ),
                                    // a sibling rank already proved PJRT
                                    // works — failing here must be fatal
                                    BackendKind::Pjrt => Err(e),
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Load a preset on an explicit backend (failures are fatal).
    pub fn load_with(
        artifacts: &Artifacts,
        preset_name: &str,
        kind: BackendKind,
    ) -> Result<Engine> {
        let preset = artifacts.preset(preset_name)?;
        Engine::from_preset(artifacts, preset, kind)
    }

    #[cfg_attr(not(feature = "pjrt"), allow(unused_variables))]
    fn from_preset(artifacts: &Artifacts, preset: PresetSpec, kind: BackendKind) -> Result<Engine> {
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Reference => Box::new(ReferenceBackend::new(preset.clone())?),
            BackendKind::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    Box::new(pjrt::PjrtBackend::load(artifacts, &preset)?)
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    anyhow::bail!(
                        "backend `pjrt` requested but this binary was built without the \
                         `pjrt` cargo feature — rebuild with `--features pjrt`"
                    )
                }
            }
        };
        Ok(Engine { backend, preset })
    }

    /// Pay ahead-of-time costs for the training path (compilation on the
    /// PJRT backend; a no-op on the reference backend).
    pub fn warmup(&self, fresh_start: bool) -> Result<()> {
        self.backend.warmup(fresh_start)
    }

    pub fn preset(&self) -> &PresetSpec {
        &self.preset
    }

    /// Platform label of the active backend (e.g. `"reference-cpu"`).
    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// Deterministic parameter init from a 64-bit seed.
    pub fn init(&self, seed: u64) -> Result<Vec<f32>> {
        let theta = self.backend.init(seed)?;
        anyhow::ensure!(
            theta.len() == self.preset.n_params,
            "backend {} returned {} params, preset wants {}",
            self.backend.name(),
            theta.len(),
            self.preset.n_params
        );
        Ok(theta)
    }

    /// One local fwd+bwd step: `(loss, grad)` for this worker's shard.
    pub fn train_step(
        &self,
        theta: &[f32],
        inputs: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        self.check_theta(theta)?;
        self.check_tokens(inputs)?;
        self.check_tokens(targets)?;
        self.backend.train_step(theta, inputs, targets)
    }

    /// Forward-only loss (eval / Table 1 T_forward profiling).
    pub fn fwd_loss(&self, theta: &[f32], inputs: &[i32], targets: &[i32]) -> Result<f32> {
        self.check_theta(theta)?;
        self.check_tokens(inputs)?;
        self.check_tokens(targets)?;
        self.backend.fwd_loss(theta, inputs, targets)
    }

    /// Fused SGD+momentum update.
    pub fn sgd_update(
        &self,
        theta: &[f32],
        grad: &[f32],
        mu: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_theta(theta)?;
        anyhow::ensure!(
            grad.len() == theta.len() && mu.len() == theta.len(),
            "sgd_update shape mismatch: theta {}, grad {}, mu {}",
            theta.len(),
            grad.len(),
            mu.len()
        );
        self.backend.sgd_update(theta, grad, mu, lr, momentum)
    }

    fn check_theta(&self, theta: &[f32]) -> Result<()> {
        anyhow::ensure!(
            theta.len() == self.preset.n_params,
            "theta: want {} params, got {}",
            self.preset.n_params,
            theta.len()
        );
        Ok(())
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        let want = self.preset.batch * self.preset.seq_len;
        anyhow::ensure!(
            tokens.len() == want,
            "token buffer: want {}x{} = {want}, got {}",
            self.preset.batch,
            self.preset.seq_len,
            tokens.len()
        );
        // range-check here so every backend rejects bad ids identically
        // (XLA gather would otherwise silently clamp out-of-range tokens)
        let vocab = self.preset.vocab as i32;
        for &tok in tokens {
            anyhow::ensure!(
                (0..vocab).contains(&tok),
                "token {tok} outside vocab [0, {vocab})"
            );
        }
        Ok(())
    }
}
