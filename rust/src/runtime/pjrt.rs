//! PJRT execution backend (`pjrt` cargo feature): load AOT artifacts and
//! execute them from rust (DESIGN.md §6.2).
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! The interchange format is HLO **text** (see DESIGN.md §6.2 / aot.py —
//! the 64-bit-proto-id gotcha). In offline builds the `xla` dependency is
//! the API stub under `vendor/xla`, which compiles this whole path but
//! errors at runtime; swap in the registry crate to execute for real.
//!
//! Thread model: `PjRtClient` is `Rc`-backed (`!Send`), so every trainer
//! worker thread builds its *own* backend — own client, own compiled
//! executables. Compilation cost is paid per (re)start, which is exactly
//! the stop/restart overhead the paper measures (~10 s on their testbed;
//! Table 2 experiment — ours reports the same quantity for our stack).

use std::cell::OnceCell;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::backend::Backend;
use crate::runtime::manifest::{Artifacts, PresetSpec};
use crate::Result;

/// A compiled model: the AOT entry points of one preset, on one client.
///
/// Entry points compile lazily on first use — a training worker only ever
/// pays for `train_step` + `sgd_update` (plus `init_params` on a cold
/// start), which roughly halves the restart cost the paper's rescale math
/// cares about. `warmup()` forces what a worker will need.
pub struct PjrtBackend {
    client: PjRtClient,
    preset: PresetSpec,
    paths: std::collections::BTreeMap<String, std::path::PathBuf>,
    train_step: OnceCell<PjRtLoadedExecutable>,
    fwd_loss: OnceCell<PjRtLoadedExecutable>,
    sgd_update: OnceCell<PjRtLoadedExecutable>,
    init_params: OnceCell<PjRtLoadedExecutable>,
}

impl PjrtBackend {
    /// Create a CPU PJRT client; entries compile on first use.
    pub fn load(artifacts: &Artifacts, preset: &PresetSpec) -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let mut paths = std::collections::BTreeMap::new();
        for entry in crate::runtime::manifest::ENTRY_POINTS {
            paths.insert(entry.to_string(), artifacts.entry_path(preset, entry)?);
        }
        Ok(PjrtBackend {
            client,
            preset: preset.clone(),
            paths,
            train_step: OnceCell::new(),
            fwd_loss: OnceCell::new(),
            sgd_update: OnceCell::new(),
            init_params: OnceCell::new(),
        })
    }

    fn compile(&self, entry: &str) -> Result<PjRtLoadedExecutable> {
        let path = &self.paths[entry];
        let proto = HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {entry}: {e}"))
    }

    fn entry<'c>(
        &self,
        cell: &'c OnceCell<PjRtLoadedExecutable>,
        name: &str,
    ) -> Result<&'c PjRtLoadedExecutable> {
        if cell.get().is_none() {
            let exe = self.compile(name)?;
            let _ = cell.set(exe);
        }
        Ok(cell.get().unwrap())
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Vec<Literal>> {
        let result = exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e}"))
    }

    /// Shape a pre-validated token buffer (the [`Engine`](super::Engine)
    /// facade owns input validation — see the [`Backend`] contract).
    fn tokens_literal(&self, data: &[i32]) -> Result<Literal> {
        let (b, t) = (self.preset.batch as i64, self.preset.seq_len as i64);
        debug_assert_eq!(data.len(), (b * t) as usize);
        Literal::vec1(data)
            .reshape(&[b, t])
            .map_err(|e| anyhow::anyhow!("reshape tokens: {e}"))
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Compile the training-path entries up front (so the first step's
    /// latency is not polluted by compilation).
    fn warmup(&self, fresh_start: bool) -> Result<()> {
        self.entry(&self.train_step, "train_step")?;
        self.entry(&self.sgd_update, "sgd_update")?;
        if fresh_start {
            self.entry(&self.init_params, "init_params")?;
        }
        Ok(())
    }

    /// Deterministic parameter init from a 64-bit seed (threefry inside).
    fn init(&self, seed: u64) -> Result<Vec<f32>> {
        let seed2 = [(seed >> 32) as u32, seed as u32];
        let out = self.run(
            self.entry(&self.init_params, "init_params")?,
            &[Literal::vec1(&seed2[..])],
        )?;
        let theta = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("init returned empty tuple"))?;
        theta.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))
    }

    fn train_step(
        &self,
        theta: &[f32],
        inputs: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let out = self.run(
            self.entry(&self.train_step, "train_step")?,
            &[
                Literal::vec1(theta),
                self.tokens_literal(inputs)?,
                self.tokens_literal(targets)?,
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "train_step: want (loss, grad), got {}", out.len());
        let mut it = out.into_iter();
        let loss = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let grad = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok((loss[0], grad))
    }

    fn fwd_loss(&self, theta: &[f32], inputs: &[i32], targets: &[i32]) -> Result<f32> {
        let out = self.run(
            self.entry(&self.fwd_loss, "fwd_loss")?,
            &[
                Literal::vec1(theta),
                self.tokens_literal(inputs)?,
                self.tokens_literal(targets)?,
            ],
        )?;
        let loss = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(loss[0])
    }

    /// Fused SGD+momentum update (Layer-1 Pallas kernel inside).
    fn sgd_update(
        &self,
        theta: &[f32],
        grad: &[f32],
        mu: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.run(
            self.entry(&self.sgd_update, "sgd_update")?,
            &[
                Literal::vec1(theta),
                Literal::vec1(grad),
                Literal::vec1(mu),
                Literal::scalar(lr),
                Literal::scalar(momentum),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "sgd_update: want (theta, mu)");
        let mut it = out.into_iter();
        let theta2 = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let mu2 = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok((theta2, mu2))
    }
}
