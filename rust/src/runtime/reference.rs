//! Pure-rust reference execution backend (DESIGN.md §6.1).
//!
//! Implements the Layer-2 model semantics — the decoder-only transformer
//! of `python/compile/model.py` with the `ref.py` kernel oracles
//! (layernorm eps 1e-5, tanh-approximate GELU, tied LM head) — directly
//! over `f32` slices, forward *and* backward, with zero native
//! dependencies. This is the default [`Backend`]: it makes `train`,
//! `rescale`, `profile`, every example, and the whole test suite run on a
//! bare toolchain, while the PJRT backend (`pjrt` feature) executes the
//! AOT artifacts when its native libs are present.
//!
//! Numerics are pinned by `rust/tests/backend_parity.rs` against golden
//! values produced from `jax.value_and_grad` of the Layer-2 model
//! (generator: `python/tools/gen_backend_goldens.py`), plus a
//! finite-difference probe that is independent of any transcription.
//!
//! The backward pass is hand-derived (no tape): each op caches exactly
//! what its gradient needs — layernorm keeps `(x̂, 1/σ)`, attention keeps
//! the post-softmax weights, the MLP keeps its pre-activation. Shapes
//! follow the flat-theta layout of `PresetSpec::layout`, so the same
//! parameter vector moves between this backend, PJRT, checkpoints, and
//! the all-reduce ring without translation.
#![allow(clippy::needless_range_loop)]

use crate::runtime::backend::Backend;
use crate::runtime::manifest::PresetSpec;
use crate::rngx::Rng;
use crate::Result;

/// Layernorm epsilon — matches `python/compile/kernels/ref.py::EPS`.
const EPS: f32 = 1e-5;

/// Offsets of one transformer block's parameters in flat theta.
struct LayerOffsets {
    ln1_g: usize,
    ln1_b: usize,
    w_qkv: usize,
    w_proj: usize,
    ln2_g: usize,
    ln2_b: usize,
    w_mlp1: usize,
    w_mlp2: usize,
}

/// Offsets of every parameter in flat theta, resolved once at load.
struct Offsets {
    tok_embed: usize,
    pos_embed: usize,
    layers: Vec<LayerOffsets>,
    lnf_g: usize,
    lnf_b: usize,
}

/// The default, dependency-free execution backend.
pub struct ReferenceBackend {
    spec: PresetSpec,
    off: Offsets,
}

impl ReferenceBackend {
    pub fn new(spec: PresetSpec) -> Result<ReferenceBackend> {
        let d = spec.d_model;
        anyhow::ensure!(
            spec.n_heads > 0 && d % spec.n_heads == 0,
            "preset {}: d_model {} not divisible by n_heads {}",
            spec.name,
            d,
            spec.n_heads
        );
        let need = |name: &str, size: usize| -> Result<usize> {
            match spec.param_range(name) {
                Some((s, e)) if e - s == size => Ok(s),
                Some((s, e)) => anyhow::bail!(
                    "preset {}: param {name:?} has {} elements in the manifest layout, expected {size}",
                    spec.name,
                    e - s
                ),
                None => anyhow::bail!(
                    "preset {}: param {name:?} missing from the manifest layout",
                    spec.name
                ),
            }
        };
        let mut layers = Vec::with_capacity(spec.n_layers);
        for i in 0..spec.n_layers {
            layers.push(LayerOffsets {
                ln1_g: need(&format!("l{i}.ln1_g"), d)?,
                ln1_b: need(&format!("l{i}.ln1_b"), d)?,
                w_qkv: need(&format!("l{i}.w_qkv"), d * 3 * d)?,
                w_proj: need(&format!("l{i}.w_proj"), d * d)?,
                ln2_g: need(&format!("l{i}.ln2_g"), d)?,
                ln2_b: need(&format!("l{i}.ln2_b"), d)?,
                w_mlp1: need(&format!("l{i}.w_mlp1"), d * 4 * d)?,
                w_mlp2: need(&format!("l{i}.w_mlp2"), 4 * d * d)?,
            });
        }
        let off = Offsets {
            tok_embed: need("tok_embed", spec.vocab * d)?,
            pos_embed: need("pos_embed", spec.seq_len * d)?,
            layers,
            lnf_g: need("lnf_g", d)?,
            lnf_b: need("lnf_b", d)?,
        };
        Ok(ReferenceBackend { spec, off })
    }

    /// Forward pass over the whole minibatch; caches everything the
    /// backward pass reads. Tokens are pre-validated (shape and vocab
    /// range) by the [`Engine`](super::Engine) facade.
    fn forward(&self, theta: &[f32], inputs: &[i32]) -> Fwd {
        let (b, t, d, v, heads) = self.dims();
        let n = b * t;
        let dh = d / heads;
        let tok = &theta[self.off.tok_embed..self.off.tok_embed + v * d];
        let pos = &theta[self.off.pos_embed..self.off.pos_embed + t * d];

        // h = tok_embed[ids] + pos_embed
        let mut h = vec![0f32; n * d];
        for r in 0..n {
            let id = inputs[r] as usize;
            let ti = r % t;
            let row = &mut h[r * d..(r + 1) * d];
            for (c, hv) in row.iter_mut().enumerate() {
                *hv = tok[id * d + c] + pos[ti * d + c];
            }
        }

        let sqrt_dh = (dh as f64).sqrt() as f32;
        let mut layers = Vec::with_capacity(self.spec.n_layers);
        for lo in &self.off.layers {
            let h_in = h;
            let (a1, xhat1, rstd1) =
                layernorm_fwd(&h_in, self.p(theta, lo.ln1_g, d), self.p(theta, lo.ln1_b, d), n, d);
            let qkv = matmul(&a1, self.p(theta, lo.w_qkv, d * 3 * d), n, d, 3 * d);

            // causal multi-head self-attention
            let mut att = vec![0f32; b * heads * t * t];
            let mut o = vec![0f32; n * d];
            for bi in 0..b {
                for hi in 0..heads {
                    let q_off = hi * dh;
                    let k_off = d + hi * dh;
                    let v_off = 2 * d + hi * dh;
                    let att_base = ((bi * heads) + hi) * t * t;
                    for ti in 0..t {
                        let qrow = &qkv[(bi * t + ti) * 3 * d + q_off..][..dh];
                        let arow = &mut att[att_base + ti * t..att_base + (ti + 1) * t];
                        // scores over allowed keys j <= ti
                        let mut max = f32::NEG_INFINITY;
                        for (j, av) in arow.iter_mut().enumerate().take(ti + 1) {
                            let krow = &qkv[(bi * t + j) * 3 * d + k_off..][..dh];
                            let mut s = 0f32;
                            for (qv, kv) in qrow.iter().zip(krow) {
                                s += qv * kv;
                            }
                            let s = s / sqrt_dh;
                            *av = s;
                            if s > max {
                                max = s;
                            }
                        }
                        let mut sum = 0f32;
                        for av in arow.iter_mut().take(ti + 1) {
                            *av = (*av - max).exp();
                            sum += *av;
                        }
                        let inv = 1.0 / sum;
                        for av in arow.iter_mut().take(ti + 1) {
                            *av *= inv;
                        }
                        // o[ti] = sum_j att[ti, j] * v[j]
                        let orow = &mut o[(bi * t + ti) * d + hi * dh..][..dh];
                        for j in 0..=ti {
                            let w = arow[j];
                            let vrow = &qkv[(bi * t + j) * 3 * d + v_off..][..dh];
                            for (ov, vv) in orow.iter_mut().zip(vrow) {
                                *ov += w * vv;
                            }
                        }
                    }
                }
            }

            let proj = matmul(&o, self.p(theta, lo.w_proj, d * d), n, d, d);
            let mut h_mid = h_in.clone();
            add_assign(&mut h_mid, &proj);

            let (a2, xhat2, rstd2) =
                layernorm_fwd(&h_mid, self.p(theta, lo.ln2_g, d), self.p(theta, lo.ln2_b, d), n, d);
            let pre = matmul(&a2, self.p(theta, lo.w_mlp1, d * 4 * d), n, d, 4 * d);
            let ff: Vec<f32> = pre.iter().map(|&x| gelu(x)).collect();
            let mlp = matmul(&ff, self.p(theta, lo.w_mlp2, 4 * d * d), n, 4 * d, d);
            let mut h_out = h_mid.clone();
            add_assign(&mut h_out, &mlp);

            layers.push(LayerCache {
                xhat1,
                rstd1,
                a1,
                qkv,
                att,
                o,
                xhat2,
                rstd2,
                a2,
                pre,
                ff,
            });
            h = h_out;
        }

        let lnf_g = self.p(theta, self.off.lnf_g, d);
        let lnf_b = self.p(theta, self.off.lnf_b, d);
        let (hf, xhat_f, rstd_f) = layernorm_fwd(&h, lnf_g, lnf_b, n, d);
        // tied LM head: logits = hf @ tok_embed^T
        let logits = matmul_nt(&hf, tok, n, d, v);
        Fwd { layers, xhat_f, rstd_f, hf, logits }
    }

    /// Mean cross-entropy + d(loss)/d(logits).
    fn loss_and_dlogits(&self, logits: &[f32], targets: &[i32]) -> (f32, Vec<f32>) {
        let (b, t, _, v, _) = self.dims();
        let n = b * t;
        let inv_n = 1.0 / n as f32;
        let mut loss_acc = 0f64;
        let mut dlogits = vec![0f32; n * v];
        for r in 0..n {
            let row = &logits[r * v..(r + 1) * v];
            let mut max = f32::NEG_INFINITY;
            for &x in row {
                if x > max {
                    max = x;
                }
            }
            let mut sum = 0f32;
            for &x in row {
                sum += (x - max).exp();
            }
            let lse = sum.ln();
            let tgt = targets[r] as usize;
            loss_acc += -f64::from(row[tgt] - max - lse);
            let drow = &mut dlogits[r * v..(r + 1) * v];
            let inv_sum = 1.0 / sum;
            for (dv, &x) in drow.iter_mut().zip(row) {
                *dv = (x - max).exp() * inv_sum * inv_n;
            }
            drow[tgt] -= inv_n;
        }
        ((loss_acc / n as f64) as f32, dlogits)
    }

    /// Backward pass: full gradient of the mean loss w.r.t. flat theta.
    fn backward(&self, theta: &[f32], inputs: &[i32], fwd: &Fwd, dlogits: &[f32]) -> Vec<f32> {
        let (b, t, d, v, heads) = self.dims();
        let n = b * t;
        let dh = d / heads;
        let sqrt_dh = (dh as f64).sqrt() as f32;
        let tok = &theta[self.off.tok_embed..self.off.tok_embed + v * d];
        let mut grad = vec![0f32; self.spec.n_params];

        // tied head: logits = hf @ tok^T
        // d tok += dlogits^T @ hf ; d hf = dlogits @ tok
        {
            let dtok = matmul_tn(dlogits, &fwd.hf, n, v, d);
            add_assign(&mut grad[self.off.tok_embed..self.off.tok_embed + v * d], &dtok);
        }
        let dhf = matmul(dlogits, tok, n, v, d);

        // final layernorm
        let (mut dhead, dg, db) = layernorm_bwd(
            &dhf,
            &fwd.xhat_f,
            &fwd.rstd_f,
            self.p(theta, self.off.lnf_g, d),
            n,
            d,
        );
        add_assign(&mut grad[self.off.lnf_g..self.off.lnf_g + d], &dg);
        add_assign(&mut grad[self.off.lnf_b..self.off.lnf_b + d], &db);

        for (lo, c) in self.off.layers.iter().zip(&fwd.layers).rev() {
            // ---- MLP: h_out = h_mid + gelu(a2 @ w1) @ w2 ----------------
            {
                let dw2 = matmul_tn(&c.ff, &dhead, n, 4 * d, d);
                add_assign(&mut grad[lo.w_mlp2..lo.w_mlp2 + 4 * d * d], &dw2);
            }
            let dff = matmul_nt(&dhead, self.p(theta, lo.w_mlp2, 4 * d * d), n, d, 4 * d);
            let dpre: Vec<f32> = dff
                .iter()
                .zip(&c.pre)
                .map(|(&dy, &x)| dy * gelu_grad(x))
                .collect();
            {
                let dw1 = matmul_tn(&c.a2, &dpre, n, d, 4 * d);
                add_assign(&mut grad[lo.w_mlp1..lo.w_mlp1 + d * 4 * d], &dw1);
            }
            let da2 = matmul_nt(&dpre, self.p(theta, lo.w_mlp1, d * 4 * d), n, 4 * d, d);
            let (dx, dg, db) =
                layernorm_bwd(&da2, &c.xhat2, &c.rstd2, self.p(theta, lo.ln2_g, d), n, d);
            add_assign(&mut grad[lo.ln2_g..lo.ln2_g + d], &dg);
            add_assign(&mut grad[lo.ln2_b..lo.ln2_b + d], &db);
            add_assign(&mut dhead, &dx);

            // ---- attention: h_mid = h_in + (att · v | heads) @ w_proj ---
            {
                let dwp = matmul_tn(&c.o, &dhead, n, d, d);
                add_assign(&mut grad[lo.w_proj..lo.w_proj + d * d], &dwp);
            }
            let do_ = matmul_nt(&dhead, self.p(theta, lo.w_proj, d * d), n, d, d);

            let mut dqkv = vec![0f32; n * 3 * d];
            let mut ds = vec![0f32; t * t]; // per (batch, head) scratch
            for bi in 0..b {
                for hi in 0..heads {
                    let q_off = hi * dh;
                    let k_off = d + hi * dh;
                    let v_off = 2 * d + hi * dh;
                    let att_base = ((bi * heads) + hi) * t * t;
                    // ds = att * (datt - rowdot) / sqrt(dh); masked entries
                    // have att == 0 and stay zero.
                    for ti in 0..t {
                        let dorow = &do_[(bi * t + ti) * d + hi * dh..][..dh];
                        let arow = &c.att[att_base + ti * t..att_base + (ti + 1) * t];
                        let dsrow = &mut ds[ti * t..(ti + 1) * t];
                        let mut rowdot = 0f32;
                        for j in 0..=ti {
                            let vrow = &c.qkv[(bi * t + j) * 3 * d + v_off..][..dh];
                            let mut datt = 0f32;
                            for (ov, vv) in dorow.iter().zip(vrow) {
                                datt += ov * vv;
                            }
                            dsrow[j] = datt;
                            rowdot += arow[j] * datt;
                        }
                        for j in 0..=ti {
                            dsrow[j] = arow[j] * (dsrow[j] - rowdot) / sqrt_dh;
                        }
                    }
                    for ti in 0..t {
                        let arow = &c.att[att_base + ti * t..att_base + (ti + 1) * t];
                        let dorow = &do_[(bi * t + ti) * d + hi * dh..][..dh];
                        let dsrow = &ds[ti * t..(ti + 1) * t];
                        // dq[ti] = sum_j ds[ti, j] * k[j]
                        {
                            let dqrow_start = (bi * t + ti) * 3 * d + q_off;
                            for j in 0..=ti {
                                let w = dsrow[j];
                                let krow = &c.qkv[(bi * t + j) * 3 * d + k_off..][..dh];
                                let dqrow = &mut dqkv[dqrow_start..dqrow_start + dh];
                                for (dv, kv) in dqrow.iter_mut().zip(krow) {
                                    *dv += w * kv;
                                }
                            }
                        }
                        // dk[j] += ds[ti, j] * q[ti]; dv[j] += att[ti, j] * do[ti]
                        let qrow = &c.qkv[(bi * t + ti) * 3 * d + q_off..][..dh];
                        for j in 0..=ti {
                            let dsw = dsrow[j];
                            let aw = arow[j];
                            let base = (bi * t + j) * 3 * d;
                            {
                                let dkrow = &mut dqkv[base + k_off..base + k_off + dh];
                                for (dv, qv) in dkrow.iter_mut().zip(qrow) {
                                    *dv += dsw * qv;
                                }
                            }
                            let dvrow = &mut dqkv[base + v_off..base + v_off + dh];
                            for (dv, ov) in dvrow.iter_mut().zip(dorow) {
                                *dv += aw * ov;
                            }
                        }
                    }
                }
            }

            {
                let dwq = matmul_tn(&c.a1, &dqkv, n, d, 3 * d);
                add_assign(&mut grad[lo.w_qkv..lo.w_qkv + d * 3 * d], &dwq);
            }
            let da1 = matmul_nt(&dqkv, self.p(theta, lo.w_qkv, d * 3 * d), n, 3 * d, d);
            let (dx, dg, db) =
                layernorm_bwd(&da1, &c.xhat1, &c.rstd1, self.p(theta, lo.ln1_g, d), n, d);
            add_assign(&mut grad[lo.ln1_g..lo.ln1_g + d], &dg);
            add_assign(&mut grad[lo.ln1_b..lo.ln1_b + d], &db);
            add_assign(&mut dhead, &dx);
        }

        // embeddings: h0 = tok_embed[ids] + pos_embed
        for r in 0..n {
            let id = inputs[r] as usize;
            let ti = r % t;
            let drow = &dhead[r * d..(r + 1) * d];
            {
                let start = self.off.tok_embed + id * d;
                let gtok = &mut grad[start..start + d];
                for (g, dv) in gtok.iter_mut().zip(drow) {
                    *g += dv;
                }
            }
            let gpos = &mut grad[self.off.pos_embed + ti * d..self.off.pos_embed + (ti + 1) * d];
            for (g, dv) in gpos.iter_mut().zip(drow) {
                *g += dv;
            }
        }
        grad
    }

    #[inline]
    fn p<'t>(&self, theta: &'t [f32], off: usize, len: usize) -> &'t [f32] {
        &theta[off..off + len]
    }

    fn dims(&self) -> (usize, usize, usize, usize, usize) {
        let s = &self.spec;
        (s.batch, s.seq_len, s.d_model, s.vocab, s.n_heads)
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference-cpu"
    }

    fn warmup(&self, _fresh_start: bool) -> Result<()> {
        // nothing to compile — that absence *is* this backend's startup
        // story (the PJRT backend pays per-entry compilation here)
        Ok(())
    }

    /// Deterministic scaled-normal init: one forked `rngx` stream per
    /// layout entry; gains 1, biases 0, `pos_embed` scale 0.01, matrices
    /// scale 1/sqrt(fan_in) — the shape of `model.py::init_params` under
    /// the crate's own RNG.
    fn init(&self, seed: u64) -> Result<Vec<f32>> {
        let mut theta = vec![0f32; self.spec.n_params];
        let mut root = Rng::new(seed);
        for e in &self.spec.layout {
            let mut r = root.fork();
            let slice = &mut theta[e.offset..e.offset + e.size()];
            if e.name.ends_with("_g") {
                slice.fill(1.0);
            } else if e.name.ends_with("_b") {
                slice.fill(0.0);
            } else {
                let scale = if e.name == "pos_embed" {
                    0.01
                } else {
                    1.0 / (e.shape[0] as f64).sqrt()
                };
                for v in slice.iter_mut() {
                    *v = (scale * r.normal()) as f32;
                }
            }
        }
        Ok(theta)
    }

    fn train_step(
        &self,
        theta: &[f32],
        inputs: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let fwd = self.forward(theta, inputs);
        let (loss, dlogits) = self.loss_and_dlogits(&fwd.logits, targets);
        let grad = self.backward(theta, inputs, &fwd, &dlogits);
        Ok((loss, grad))
    }

    fn fwd_loss(&self, theta: &[f32], inputs: &[i32], targets: &[i32]) -> Result<f32> {
        let fwd = self.forward(theta, inputs);
        let (loss, _) = self.loss_and_dlogits(&fwd.logits, targets);
        Ok(loss)
    }

    /// Momentum SGD, the `ref.py::sgd_update_ref` formula exactly:
    /// `mu' = momentum * mu + grad; theta' = theta - lr * mu'`.
    fn sgd_update(
        &self,
        theta: &[f32],
        grad: &[f32],
        mu: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut theta2 = Vec::with_capacity(theta.len());
        let mut mu2 = Vec::with_capacity(theta.len());
        for i in 0..theta.len() {
            let m = momentum * mu[i] + grad[i];
            mu2.push(m);
            theta2.push(theta[i] - lr * m);
        }
        Ok((theta2, mu2))
    }
}

/// Per-layer forward cache (everything the backward pass reads; the
/// residual-stream values themselves are not needed — their gradient is
/// the pass-through term of each `h + f(h)` block).
struct LayerCache {
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    a1: Vec<f32>,
    qkv: Vec<f32>,
    att: Vec<f32>,
    o: Vec<f32>,
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    a2: Vec<f32>,
    pre: Vec<f32>,
    ff: Vec<f32>,
}

struct Fwd {
    layers: Vec<LayerCache>,
    xhat_f: Vec<f32>,
    rstd_f: Vec<f32>,
    hf: Vec<f32>,
    logits: Vec<f32>,
}

// ---------------------------------------------------------------------
// f32 tensor helpers (row-major flat slices)
// ---------------------------------------------------------------------

/// out(m,n) = a(m,k) @ b(k,n)
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (ov, &bv) in orow.iter_mut().zip(brow) {
                *ov += av * bv;
            }
        }
    }
    out
}

/// out(k,n) = a(m,k)^T @ b(m,n) — weight gradients.
fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut out = vec![0f32; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let orow = &mut out[p * n..(p + 1) * n];
            for (ov, &bv) in orow.iter_mut().zip(brow) {
                *ov += av * bv;
            }
        }
    }
    out
}

/// out(m,k) = c(m,n) @ b(k,n)^T — activation gradients / tied head.
fn matmul_nt(c: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * k];
    for i in 0..m {
        let crow = &c[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (p, ov) in orow.iter_mut().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            let mut s = 0f32;
            for (&cv, &bv) in crow.iter().zip(brow) {
                s += cv * bv;
            }
            *ov = s;
        }
    }
    out
}

fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Row-wise layernorm; returns `(y, xhat, rstd)`.
fn layernorm_fwd(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut y = vec![0f32; rows * d];
    let mut xhat = vec![0f32; rows * d];
    let mut rstd = vec![0f32; rows];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let xrow = &x[r * d..(r + 1) * d];
        let mut mean = 0f32;
        for &v in xrow {
            mean += v;
        }
        mean *= inv_d;
        let mut var = 0f32;
        for &v in xrow {
            let dv = v - mean;
            var += dv * dv;
        }
        var *= inv_d;
        let rs = 1.0 / (var + EPS).sqrt();
        rstd[r] = rs;
        let hrow = &mut xhat[r * d..(r + 1) * d];
        let yrow = &mut y[r * d..(r + 1) * d];
        for c in 0..d {
            let xh = (xrow[c] - mean) * rs;
            hrow[c] = xh;
            yrow[c] = xh * g[c] + b[c];
        }
    }
    (y, xhat, rstd)
}

/// Layernorm backward; returns `(dx, dgain, dbias)`.
fn layernorm_bwd(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0f32; rows * d];
    let mut dg = vec![0f32; d];
    let mut db = vec![0f32; d];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let dyrow = &dy[r * d..(r + 1) * d];
        let hrow = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0f32;
        let mut m2 = 0f32;
        for c in 0..d {
            let dyg = dyrow[c] * g[c];
            m1 += dyg;
            m2 += dyg * hrow[c];
        }
        m1 *= inv_d;
        m2 *= inv_d;
        let rs = rstd[r];
        let dxrow = &mut dx[r * d..(r + 1) * d];
        for c in 0..d {
            let dyg = dyrow[c] * g[c];
            dxrow[c] = rs * (dyg - m1 - hrow[c] * m2);
            dg[c] += dyrow[c] * hrow[c];
            db[c] += dyrow[c];
        }
    }
    (dx, dg, db)
}

/// Tanh-approximate GELU (the `jax.nn.gelu` default the model lowers).
fn gelu(x: f32) -> f32 {
    let u = GELU_C * (x + 0.044715 * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + 0.044715 * x * x * x);
    let th = u.tanh();
    let du = GELU_C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * du
}

/// sqrt(2/pi), rounded from the f64 value (matches the numpy mirror).
const GELU_C: f32 = 0.797_884_560_802_865_4_f64 as f32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul(&[1., 2., 3., 4.], &[5., 6., 7., 8.], 2, 2, 2);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_transposes_agree() {
        // A^T @ B via matmul_tn == manual transpose + matmul
        let a = [1., 2., 3., 4., 5., 6.]; // 3x2
        let b = [1., 0., 2., 1., 0., 3.]; // 3x2
        let tn = matmul_tn(&a, &b, 3, 2, 2);
        let at = [1., 3., 5., 2., 4., 6.]; // 2x3
        assert_eq!(tn, matmul(&at, &b, 2, 3, 2));
        // C @ B^T via matmul_nt == matmul against transposed b
        let c = [1., 2., 3., 4.]; // 2x2
        let bt = [1., 2., 0., 1.]; // b2 = [[1,0],[2,1]] (2x2), transposed
        let nt = matmul_nt(&c, &[1., 0., 2., 1.], 2, 2, 2);
        assert_eq!(nt, matmul(&c, &bt, 2, 2, 2));
    }

    #[test]
    fn layernorm_normalizes_rows() {
        let x = [1., 2., 3., 4., -2., 0., 2., 4.];
        let g = [1., 1., 1., 1.];
        let b = [0., 0., 0., 0.];
        let (y, _, _) = layernorm_fwd(&x, &g, &b, 2, 4);
        for r in 0..2 {
            let row = &y[r * 4..(r + 1) * 4];
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-6, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn gelu_matches_reference_points() {
        // gelu(0) = 0; gelu is ~identity for large x, ~0 for very negative
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
        // tanh approximation at x = 1: 0.8411919906082768
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.5, 2.0] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }
}
