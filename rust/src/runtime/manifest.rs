//! AOT artifact manifest (`artifacts/manifest.json`) — the contract
//! between the build-time python pipeline and the rust runtime.
//!
//! The manifest (preset shapes, flat-theta layout, entry-point files) is
//! also compiled into the binary ([`Artifacts::builtin`]), so the
//! reference backend runs on a bare checkout; `make artifacts` only adds
//! the `.hlo.txt` files the PJRT backend executes. [`Artifacts::resolve`]
//! picks whichever is available.

use std::path::{Path, PathBuf};

use crate::jsonx::{self, Json};
use crate::Result;

/// The repo's checked-in manifest, embedded at compile time. Kept in sync
/// with `python/compile/model.py::PRESETS` by `aot.py` (which rewrites the
/// same file) and asserted by `runtime_integration` tests.
const BUILTIN_MANIFEST: &str = include_str!("../../../artifacts/manifest.json");

/// The AOT entry points every preset provides (the names `aot.py` emits);
/// shared by backend auto-selection and the PJRT loader so the list can't
/// drift between them.
pub const ENTRY_POINTS: [&str; 4] = ["train_step", "fwd_loss", "sgd_update", "init_params"];

/// One named parameter slice of the flat theta vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamEntry {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Shapes + file names of one model preset.
#[derive(Clone, Debug)]
pub struct PresetSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_params: usize,
    pub tokens_per_step: usize,
    /// entry name -> artifact file name.
    pub entries: std::collections::BTreeMap<String, String>,
    pub layout: Vec<ParamEntry>,
}

impl PresetSpec {
    /// Model size in bytes (the `n` of eqs 2–5).
    pub fn n_bytes(&self) -> f64 {
        (self.n_params * 4) as f64
    }

    /// Look up a named parameter's slice bounds in theta.
    pub fn param_range(&self, name: &str) -> Option<(usize, usize)> {
        self.layout
            .iter()
            .find(|e| e.name == name)
            .map(|e| (e.offset, e.offset + e.size()))
    }
}

/// The artifacts directory + parsed manifest.
#[derive(Clone, Debug)]
pub struct Artifacts {
    dir: PathBuf,
    manifest: Json,
}

impl Artifacts {
    /// Load `dir/manifest.json`. Errors tell the user to run
    /// `make artifacts` when the directory is missing.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        anyhow::ensure!(
            manifest_path.exists(),
            "no manifest at {} — run `make artifacts` first",
            manifest_path.display()
        );
        let manifest = jsonx::parse_file(&manifest_path)?;
        Ok(Artifacts { dir, manifest })
    }

    /// Load `dir/manifest.json` when present, otherwise fall back to the
    /// compiled-in manifest (keeping `dir` for artifact-file lookups).
    /// This is what the trainer uses: presets always resolve; only the
    /// PJRT backend additionally needs the `.hlo.txt` files on disk.
    pub fn resolve(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").exists() {
            Artifacts::load(dir)
        } else {
            // Note the fallback once per process: a typo'd --artifacts
            // should not silently measure the wrong engine.
            static FALLBACK_NOTED: std::sync::Once = std::sync::Once::new();
            let dir_buf = dir.to_path_buf();
            FALLBACK_NOTED.call_once(|| {
                eprintln!(
                    "note: {} has no manifest.json; using the builtin manifest \
                     (reference backend only — run `make artifacts` for PJRT)",
                    dir_buf.display()
                );
            });
            Ok(Artifacts { dir: dir_buf, manifest: builtin_manifest() })
        }
    }

    /// The compiled-in manifest rooted at [`default_dir`].
    pub fn builtin() -> Artifacts {
        Artifacts { dir: default_dir(), manifest: builtin_manifest() }
    }

    /// Names of all presets in the manifest.
    pub fn preset_names(&self) -> Result<Vec<String>> {
        Ok(self.manifest.get("presets")?.as_obj()?.keys().cloned().collect())
    }

    /// Parse one preset's spec.
    pub fn preset(&self, name: &str) -> Result<PresetSpec> {
        let p = self.manifest.get("presets")?.get(name).map_err(|_| {
            anyhow::anyhow!(
                "preset {name:?} not in manifest (have: {:?}) — re-run `make artifacts`",
                self.preset_names().unwrap_or_default()
            )
        })?;
        let mut entries = std::collections::BTreeMap::new();
        for (entry, spec) in p.get("entries")?.as_obj()? {
            entries.insert(entry.clone(), spec.get("file")?.as_str()?.to_string());
        }
        let mut layout = Vec::new();
        for e in p.get("param_layout")?.as_arr()? {
            layout.push(ParamEntry {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                offset: e.get("offset")?.as_usize()?,
            });
        }
        Ok(PresetSpec {
            name: name.to_string(),
            vocab: p.get("vocab")?.as_usize()?,
            d_model: p.get("d_model")?.as_usize()?,
            n_layers: p.get("n_layers")?.as_usize()?,
            n_heads: p.get("n_heads")?.as_usize()?,
            seq_len: p.get("seq_len")?.as_usize()?,
            batch: p.get("batch")?.as_usize()?,
            n_params: p.get("n_params")?.as_usize()?,
            tokens_per_step: p.get("tokens_per_step")?.as_usize()?,
            entries,
            layout,
        })
    }

    /// Absolute path of one entry's HLO text file.
    pub fn entry_path(&self, preset: &PresetSpec, entry: &str) -> Result<PathBuf> {
        let file = preset
            .entries
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("preset {} has no entry {entry:?}", preset.name))?;
        let path = self.dir.join(file);
        anyhow::ensure!(path.exists(), "missing artifact {} — run `make artifacts`", path.display());
        Ok(path)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// Default artifacts directory: `$RINGMASTER_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("RINGMASTER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn builtin_manifest() -> Json {
    jsonx::parse(BUILTIN_MANIFEST).expect("embedded artifacts/manifest.json is valid JSON")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let doc = r#"{
          "presets": {
            "tiny": {
              "vocab": 256, "d_model": 64, "n_layers": 2, "n_heads": 4,
              "seq_len": 32, "batch": 8, "n_params": 117376,
              "tokens_per_step": 256,
              "entries": {
                "train_step": {"file": "train_step_tiny.hlo.txt", "outputs": ["loss","grad"]}
              },
              "param_layout": [
                {"name": "tok_embed", "shape": [256, 64], "offset": 0},
                {"name": "pos_embed", "shape": [32, 64], "offset": 16384}
              ]
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        std::fs::write(dir.join("train_step_tiny.hlo.txt"), "HloModule fake").unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ringmaster-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_preset_spec() {
        let d = tmpdir("parse");
        fake_manifest(&d);
        let a = Artifacts::load(&d).unwrap();
        let p = a.preset("tiny").unwrap();
        assert_eq!(p.vocab, 256);
        assert_eq!(p.n_params, 117376);
        assert_eq!(p.layout.len(), 2);
        assert_eq!(p.param_range("pos_embed"), Some((16384, 16384 + 32 * 64)));
        assert_eq!(p.param_range("nope"), None);
        assert!((p.n_bytes() - 117376.0 * 4.0).abs() < 1.0);
    }

    #[test]
    fn entry_path_resolves_and_validates() {
        let d = tmpdir("entry");
        fake_manifest(&d);
        let a = Artifacts::load(&d).unwrap();
        let p = a.preset("tiny").unwrap();
        assert!(a.entry_path(&p, "train_step").is_ok());
        assert!(a.entry_path(&p, "missing_entry").is_err());
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let d = tmpdir("missing");
        let err = Artifacts::load(&d).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn unknown_preset_lists_available() {
        let d = tmpdir("unknown");
        fake_manifest(&d);
        let a = Artifacts::load(&d).unwrap();
        let err = a.preset("huge").unwrap_err().to_string();
        assert!(err.contains("tiny"), "{err}");
    }

    #[test]
    fn builtin_manifest_has_all_presets() {
        let a = Artifacts::builtin();
        let mut names = a.preset_names().unwrap();
        names.sort();
        assert_eq!(names, vec!["base", "small", "tiny"]);
        for name in ["tiny", "small", "base"] {
            let p = a.preset(name).unwrap();
            assert_eq!(p.tokens_per_step, p.batch * p.seq_len, "{name}");
            let last = p.layout.last().unwrap();
            assert_eq!(last.offset + last.size(), p.n_params, "{name} layout");
        }
    }

    #[test]
    fn resolve_prefers_on_disk_manifest() {
        let d = tmpdir("resolve-disk");
        fake_manifest(&d);
        let a = Artifacts::resolve(&d).unwrap();
        // the fake on-disk manifest has a single truncated tiny preset
        assert_eq!(a.preset("tiny").unwrap().layout.len(), 2);
        assert!(a.preset("small").is_err());
    }

    #[test]
    fn resolve_falls_back_to_builtin() {
        let d = tmpdir("resolve-builtin");
        let a = Artifacts::resolve(&d).unwrap();
        assert_eq!(a.preset("tiny").unwrap().n_params, 117_376);
        // entry files still resolve against the requested dir (and are
        // absent, which is what steers backend auto-selection)
        let p = a.preset("tiny").unwrap();
        assert!(a.entry_path(&p, "train_step").is_err());
        assert_eq!(a.dir(), d.as_path());
    }
}
