//! Dense linear algebra substrate for the NNLS solver and model fitting.
//!
//! Small, self-contained f64 matrices (the fitting problems in this paper
//! are tiny — a handful of coefficients over at most a few thousand
//! samples), with Householder-QR least squares as the numerical core.

use std::fmt;

/// Row-major dense f64 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(r, c)])?;
            }
            writeln!(f)?;
        }
        write!(f, "]")
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row-major data.
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |r, c| self[(c, r)])
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| self.row(r).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Matrix-matrix product.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(r, k)];
                if a == 0.0 {
                    continue;
                }
                for c in 0..other.cols {
                    out[(r, c)] += a * other[(k, c)];
                }
            }
        }
        out
    }

    /// Select a subset of columns.
    pub fn select_cols(&self, cols: &[usize]) -> Matrix {
        Matrix::from_fn(self.rows, cols.len(), |r, j| self[(r, cols[j])])
    }

    /// Least-squares solve min ||self * x - b||_2 via Householder QR.
    ///
    /// Requires rows >= cols and full column rank (returns None when the
    /// triangular solve hits a (near-)zero pivot).
    pub fn lstsq(&self, b: &[f64]) -> Option<Vec<f64>> {
        assert_eq!(b.len(), self.rows);
        let (m, n) = (self.rows, self.cols);
        if m < n {
            return None;
        }
        let mut a = self.clone();
        let mut rhs = b.to_vec();

        // Householder QR, applying reflections to rhs as we go.
        for k in 0..n {
            // norm of column k below the diagonal
            let mut norm = 0.0;
            for i in k..m {
                norm += a[(i, k)] * a[(i, k)];
            }
            let norm = norm.sqrt();
            if norm < 1e-300 {
                return None;
            }
            let alpha = if a[(k, k)] > 0.0 { -norm } else { norm };
            let mut v = vec![0.0; m - k];
            v[0] = a[(k, k)] - alpha;
            for i in k + 1..m {
                v[i - k] = a[(i, k)];
            }
            let vtv: f64 = v.iter().map(|x| x * x).sum();
            if vtv < 1e-300 {
                continue;
            }
            // apply H = I - 2 v v^T / (v^T v) to remaining columns + rhs
            for c in k..n {
                let dot: f64 = (k..m).map(|i| v[i - k] * a[(i, c)]).sum();
                let s = 2.0 * dot / vtv;
                for i in k..m {
                    a[(i, c)] -= s * v[i - k];
                }
            }
            let dot: f64 = (k..m).map(|i| v[i - k] * rhs[i]).sum();
            let s = 2.0 * dot / vtv;
            for i in k..m {
                rhs[i] -= s * v[i - k];
            }
            a[(k, k)] = alpha;
        }

        // Back-substitute R x = Q^T b.
        let mut x = vec![0.0; n];
        for k in (0..n).rev() {
            let mut sum = rhs[k];
            for c in k + 1..n {
                sum -= a[(k, c)] * x[c];
            }
            let pivot = a[(k, k)];
            if pivot.abs() < 1e-12 {
                return None;
            }
            x[k] = sum / pivot;
        }
        Some(x)
    }
}

/// Euclidean norm of a vector.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// a - b elementwise.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matvec() {
        let i = Matrix::identity(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(i.matvec(&x), x);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), &[19.0, 22.0]);
        assert_eq!(c.row(1), &[43.0, 50.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 5 + c) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn lstsq_exact_square() {
        // x + y = 3 ; x - y = 1 -> x=2, y=1
        let a = Matrix::from_rows(2, 2, vec![1.0, 1.0, 1.0, -1.0]);
        let x = a.lstsq(&[3.0, 1.0]).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-10);
        assert!((x[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn lstsq_overdetermined_recovers_line() {
        // y = 2x + 1 with exact data
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let a = Matrix::from_fn(20, 2, |r, c| if c == 0 { xs[r] } else { 1.0 });
        let b: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let sol = a.lstsq(&b).unwrap();
        assert!((sol[0] - 2.0).abs() < 1e-9);
        assert!((sol[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lstsq_residual_orthogonal_to_columns() {
        let a = Matrix::from_fn(10, 3, |r, c| ((r + 1) * (c + 2)) as f64 % 7.0 + 0.1 * r as f64);
        let b: Vec<f64> = (0..10).map(|i| (i as f64).sin()).collect();
        let x = a.lstsq(&b).unwrap();
        let resid = sub(&b, &a.matvec(&x));
        let at = a.transpose();
        for c in 0..3 {
            assert!(dot(at.row(c), &resid).abs() < 1e-8);
        }
    }

    #[test]
    fn lstsq_rank_deficient_returns_none() {
        // duplicate columns
        let a = Matrix::from_fn(5, 2, |r, _| r as f64 + 1.0);
        assert!(a.lstsq(&[1.0, 2.0, 3.0, 4.0, 5.0]).is_none());
    }

    #[test]
    fn select_cols_picks() {
        let a = Matrix::from_fn(2, 3, |r, c| (r * 3 + c) as f64);
        let s = a.select_cols(&[2, 0]);
        assert_eq!(s.row(0), &[2.0, 0.0]);
        assert_eq!(s.row(1), &[5.0, 3.0]);
    }
}
