//! `ringmaster` — leader entrypoint.
//!
//! Subcommands (each maps to a paper experiment; see DESIGN.md §5):
//!
//! ```text
//! ringmaster train     --preset tiny --workers 2 --steps 100     # E2E training
//! ringmaster rescale   --preset tiny --plan 4:60,8:60            # Table 2
//! ringmaster profile   --preset tiny --workers 1,2,4 --steps 10  # Table 1
//! ringmaster simulate  --contention moderate [--all]             # Table 3
//! ringmaster orchestrate --strategy doubling --capacity 8        # live multi-job
//! ringmaster collectives --workers 8 --elems 1000000             # eqs 2-4
//! ringmaster fit       --demo                                    # eq 1 / eq 5
//! ringmaster report    --stream telemetry.jsonl                  # run audit
//! ```

use ringmaster::cli::Args;
use ringmaster::cluster::PlacePolicy;
use ringmaster::collectives::{self, cost, Algorithm};
use ringmaster::coordinator;
use ringmaster::metrics::CsvTable;
use ringmaster::orchestrator::{self, OrchestratorConfig, TraceGen};
use ringmaster::perfmodel::{ConvergenceModel, LinkContention, PlacementModel, SpeedModel};
use ringmaster::runtime::manifest::default_dir;
use ringmaster::sim::{
    prune_from_env, simulate_traced, sweep, Contention, FaultPlan, SimConfig, StrategyKind,
    SweepCell, WorkloadGen,
};
use ringmaster::telemetry::{audit, Recorder};
use ringmaster::trainer::{train, Checkpoint, TrainConfig};
use ringmaster::Result;

fn main() {
    let sub = std::env::args().nth(1).unwrap_or_else(|| "help".to_string());
    let wants_help = std::env::args().skip(2).any(|a| a == "--help" || a == "-h");
    let result = match sub.as_str() {
        "train" | "rescale" | "profile" | "simulate" | "orchestrate" | "collectives" | "fit"
        | "report"
            if wants_help =>
        {
            print!("{}", subcommand_help(&sub));
            Ok(())
        }
        "train" => cmd_train(),
        "rescale" => cmd_rescale(),
        "profile" => cmd_profile(),
        "simulate" => cmd_simulate(),
        "orchestrate" => cmd_orchestrate(),
        "collectives" => cmd_collectives(),
        "fit" => cmd_fit(),
        "report" => cmd_report(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown subcommand {other:?}\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Per-subcommand flag documentation (`ringmaster <sub> --help`); the same
/// tables appear in README.md.
fn subcommand_help(sub: &str) -> &'static str {
    match sub {
        "train" => {
            "ringmaster train — run data-parallel training (E2E driver)\n\n\
             flags:\n\
             \x20 --preset NAME      model preset: tiny|small|base (default tiny)\n\
             \x20 --workers W        data-parallel worker count (default 2)\n\
             \x20 --steps N          steps to run (default 50)\n\
             \x20 --save PATH        write the final checkpoint here\n\
             \x20 --resume PATH      resume from a checkpoint file\n\
             \x20 --artifacts DIR    artifacts dir (default $RINGMASTER_ARTIFACTS or ./artifacts)\n\
             \x20 --seed S           corpus/init seed (default 42)\n\
             \x20 --log-every K      record a loss sample every K steps (default 5)\n"
        }
        "rescale" => {
            "ringmaster rescale — run an explicit stop/restart plan (Table 2)\n\n\
             flags:\n\
             \x20 --preset NAME      model preset (default tiny)\n\
             \x20 --plan W:S,W:S     segments as workers:steps (default 4:60,8:60)\n\
             \x20 --artifacts DIR    artifacts dir\n\
             \x20 --seed S           corpus/init seed (default 42)\n"
        }
        "profile" => {
            "ringmaster profile — per-worker-count step timing (Table 1)\n\n\
             flags:\n\
             \x20 --preset NAME      model preset (default tiny)\n\
             \x20 --workers LIST     comma-separated worker counts (default 1,2,4)\n\
             \x20 --steps N          steps per configuration (default 10)\n\
             \x20 --artifacts DIR    artifacts dir\n"
        }
        "simulate" => {
            "ringmaster simulate — 64-GPU scheduler simulation (Table 3)\n\n\
             flags:\n\
             \x20 --contention C     extreme|moderate|none (default moderate)\n\
             \x20 --strategy S       precompute|exploratory|optimus|fixed-1|fixed-2|fixed-4|fixed-8\n\
             \x20 --all              run all strategies x all contentions\n\
             \x20 --n-jobs N         override the trace length (default: contention preset)\n\
             \x20 --trace-scale      heavy-tailed workload, arrival rate targeting ~65%\n\
             \x20                    pool load (scale sweeps; pairs with --n-jobs)\n\
             \x20 --nodes N          grid topology: node count (default 0 = flat pool)\n\
             \x20 --gpus-per-node G  grid topology: GPUs per node (default 8)\n\
             \x20 --placement P      pack|scatter|spread gang layout (default pack;\n\
             \x20                    spread = contention-aware pack)\n\
             \x20 --model-bytes B    per-job all-reduce payload for the topology\n\
             \x20                    penalty (default 6.9e6, the paper's ResNet-110)\n\
             \x20 --link-contention  model shared uplink bandwidth: concurrent rings\n\
             \x20                    crossing the same inter-node link degrade each\n\
             \x20                    other's eq-2 constants (off by default; named\n\
             \x20                    --link-contention because --contention is this\n\
             \x20                    subcommand's arrival-rate preset)\n\
             \x20 --faults F         off|steady|burst seeded fault injection (default\n\
             \x20                    off; needs --nodes — faults down whole nodes).\n\
             \x20                    steady = per-node MTBF/MTTR clocks; burst = fixed\n\
             \x20                    failure-storm preset (3600s MTBF, 300s repairs,\n\
             \x20                    transient gang killers). Evicted gangs lose\n\
             \x20                    progress back to their last segment boundary\n\
             \x20 --mtbf S           steady preset: per-node mean secs between\n\
             \x20                    failures (default 20000)\n\
             \x20 --mttr S           steady preset: mean repair secs (default 600)\n\
             \x20 --telemetry FILE   record a v3 telemetry stream of the run (events,\n\
             \x20                    decision provenance, placement snapshots) for\n\
             \x20                    `ringmaster report`; incompatible with --all\n\
             \x20 --threads N        worker threads for the strategy x contention sweep\n\
             \x20                    (default 0 = $RINGMASTER_THREADS, else all cores);\n\
             \x20                    output is byte-identical for any N\n\
             \x20 --seed S           workload seed (default 42)\n\n\
             env: RINGMASTER_PRUNE=0|1 forces the completion-scan pruner off/on\n\
             (diagnostics only — results are bit-identical either way)\n"
        }
        "orchestrate" => {
            "ringmaster orchestrate — live multi-job scheduling over real trainers\n\n\
             flags:\n\
             \x20 --strategy S       doubling|optimus|exact|fixed-K (default doubling)\n\
             \x20 --capacity C       cluster worker capacity (default 8)\n\
             \x20 --trace FILE       JSONL job trace; omit to generate a workload\n\
             \x20 --jobs N           generated workload size (default 6)\n\
             \x20 --mean-interarrival S  generated arrival mean secs (default 30; small = burst)\n\
             \x20 --epochs E         generated per-job epochs (default 1.0)\n\
             \x20 --max-w W          generated per-job worker cap (default 8)\n\
             \x20 --emit-trace FILE  write the trace that was run as JSONL\n\
             \x20 --nodes N          grid topology: node count (default 0 = flat pool)\n\
             \x20 --gpus-per-node G  grid topology: GPUs per node (default 8); with\n\
             \x20                    --nodes, capacity becomes N*G and rings spanning\n\
             \x20                    nodes pay the eq 2-4 inter-node cost\n\
             \x20 --placement P      pack|scatter|spread gang layout (default pack;\n\
             \x20                    spread = contention-aware pack)\n\
             \x20 --contention       model shared uplink bandwidth: concurrent rings\n\
             \x20                    crossing the same inter-node link degrade each\n\
             \x20                    other's eq-2 constants; segments are priced at\n\
             \x20                    their launch-time tenancy (off by default)\n\
             \x20 --model-bytes B    override every job's all-reduce payload bytes\n\
             \x20 --preempt          stop running segments at the next *step* on every\n\
             \x20                    arrival (mid-segment preemption; model bits become\n\
             \x20                    execution-dependent, the schedule stays deterministic)\n\
             \x20 --segment-budget S cut any running segment at its next step boundary\n\
             \x20                    once its training time exceeds S virtual seconds\n\
             \x20                    (default inf = off; same determinism contract as\n\
             \x20                    --preempt)\n\
             \x20 --online-model     learn eq-1/eq-5 fits from live segments instead of\n\
             \x20                    trusting the trace tables; schedulers use the learned\n\
             \x20                    fit once its confidence gate opens, and the per-job\n\
             \x20                    table reports model-vs-truth RMSE\n\
             \x20 --preset NAME      trainer preset (default tiny)\n\
             \x20 --segment-steps N  real steps between scheduling decisions (default 16)\n\
             \x20 --dataset-examples M  windows per epoch (default 256)\n\
             \x20 --restart-cost S   virtual stop/restart charge (default 10)\n\
             \x20 --ckpt-store DIR   content-addressed deduplicated checkpoint store:\n\
             \x20                    restarts round-trip through chunked, refcounted\n\
             \x20                    snapshots (only changed chunks hit disk) instead of\n\
             \x20                    whole-file temp copies; jobs free their snapshots on\n\
             \x20                    completion so a finished run leaves the store empty.\n\
             \x20                    Off by default; the schedule is bit-identical either\n\
             \x20                    way, only measured ckpt io/bytes change\n\
             \x20 --faults F         off|steady|burst seeded fault injection (default\n\
             \x20                    off). Segments die with the plan's per-duration\n\
             \x20                    hazard; victims roll back to their last durable\n\
             \x20                    checkpoint and retry with exponential backoff,\n\
             \x20                    giving up after --max-retries (job marked FAILED\n\
             \x20                    in the report, run still exits 0)\n\
             \x20 --mtbf S           steady preset: per-node mean secs between\n\
             \x20                    failures (default 20000)\n\
             \x20 --mttr S           steady preset: mean repair secs (default 600)\n\
             \x20 --max-retries K    consecutive failed attempts of one segment\n\
             \x20                    before the job is abandoned (default 3)\n\
             \x20 --telemetry FILE   record a v3 telemetry stream of the run (segment\n\
             \x20                    lifecycle, decision provenance, placement\n\
             \x20                    snapshots) for `ringmaster report`\n\
             \x20 --artifacts DIR    artifacts dir\n\
             \x20 --seed S           workload + trainer seed (default 42)\n"
        }
        "collectives" => {
            "ringmaster collectives — all-reduce algorithms vs cost models (eqs 2-4)\n\n\
             flags:\n\
             \x20 --workers W        world size (default 8)\n\
             \x20 --elems N          elements per rank (default 1000000)\n"
        }
        "fit" => {
            "ringmaster fit — demo of the eq 1 / eq 5 NNLS fits\n\n\
             flags:\n\
             \x20 --demo             accepted (demo is the only mode)\n"
        }
        "report" => {
            "ringmaster report — audit a telemetry stream offline\n\n\
             Replays a `--telemetry` stream event by event: renders the\n\
             per-job timeline, utilization/queue curves, restart-cost\n\
             ledger, and the scheduler decision table (why width w), and\n\
             re-verifies the ledger invariants (no double-booking, link\n\
             ring conservation, grant-chain consistency). Exits non-zero\n\
             on any schema or invariant violation.\n\n\
             flags:\n\
             \x20 --stream FILE      telemetry JSONL to audit (required)\n"
        }
        _ => HELP,
    }
}

const HELP: &str = "\
ringmaster — dynamic scheduling of MPI-based distributed DL training jobs

USAGE: ringmaster <subcommand> [flags]

  train        run data-parallel training (E2E driver)
  rescale      run an explicit stop/restart plan (Table 2)
  profile      per-worker-count step timing (Table 1)
  simulate     64-GPU scheduler simulation (Table 3)
  orchestrate  live multi-job scheduling over real concurrent trainers
  collectives  all-reduce algorithms vs analytic cost models (eqs 2-4)
  fit          demo of the eq 1 / eq 5 NNLS fits
  report       audit a recorded telemetry stream (timelines, decisions,
               ledger invariants); see simulate/orchestrate --telemetry

Run `ringmaster <subcommand> --help` for that subcommand's flags (also
documented in README.md); unknown flags are rejected with an error.
";

fn cmd_train() -> Result<()> {
    let a = Args::from_env(2)?;
    let preset = a.str_or("preset", "tiny");
    let workers = a.get_or("workers", 2usize)?;
    let steps = a.get_or("steps", 50u64)?;
    let save = a.str_or("save", "");
    let resume = a.str_or("resume", "");
    let artifacts = a.str_or("artifacts", &default_dir().to_string_lossy());
    let mut cfg = TrainConfig::new(artifacts, &preset, workers);
    cfg.seed = a.get_or("seed", 42u64)?;
    cfg.log_every = a.get_or("log-every", 5u64)?;
    a.reject_unknown()?;

    let resume_ck = if resume.is_empty() { None } else { Some(Checkpoint::load(&resume)?) };
    let (ck, report) = train(&cfg, resume_ck, steps)?;
    println!(
        "preset={preset} workers={workers} backend={} alg={} steps={} wall={:.2}s \
         startup={:.2}s steps/s={:.2} tokens/s={:.0}",
        report.backend, report.algorithm, report.steps, report.wall_secs,
        report.startup_secs, report.steps_per_sec, report.tokens_per_sec
    );
    for l in &report.logs {
        println!("step {:>6}  epoch {:>8.3}  loss {:.4}", l.step, l.epoch, l.loss);
    }
    if !save.is_empty() {
        ck.save(&save)?;
        println!("checkpoint -> {save}");
    }
    Ok(())
}

fn cmd_rescale() -> Result<()> {
    let a = Args::from_env(2)?;
    let preset = a.str_or("preset", "tiny");
    let plan_s = a.str_or("plan", "4:60,8:60");
    let artifacts = a.str_or("artifacts", &default_dir().to_string_lossy());
    let seed = a.get_or("seed", 42u64)?;
    a.reject_unknown()?;

    let plan: Vec<(usize, u64)> = plan_s
        .split(',')
        .map(|seg| {
            let (w, s) = seg
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("plan segment {seg:?}: want W:STEPS"))?;
            Ok((w.trim().parse()?, s.trim().parse()?))
        })
        .collect::<Result<_>>()?;

    let mut cfg = TrainConfig::new(artifacts, &preset, plan[0].0);
    cfg.seed = seed;
    let out = coordinator::run_with_rescales(&cfg, &plan)?;
    let mut table = CsvTable::new(&["segment", "workers", "steps", "wall_s", "restart_s", "final_loss"]);
    for (i, seg) in out.segments.iter().enumerate() {
        table.row(&[
            i.to_string(),
            seg.workers.to_string(),
            seg.steps.to_string(),
            format!("{:.2}", seg.report.wall_secs),
            format!("{:.2}", seg.restart_secs),
            seg.report.logs.last().map(|l| format!("{:.4}", l.loss)).unwrap_or_default(),
        ]);
    }
    print!("{}", table.render());
    println!("total wall: {:.2}s  final loss: {:?}", out.total_secs, out.final_loss());
    Ok(())
}

fn cmd_profile() -> Result<()> {
    let a = Args::from_env(2)?;
    let preset = a.str_or("preset", "tiny");
    let worker_counts = a.list_or("workers", &[1usize, 2, 4])?;
    let steps = a.get_or("steps", 10u64)?;
    let artifacts = a.str_or("artifacts", &default_dir().to_string_lossy());
    a.reject_unknown()?;

    let mut table = CsvTable::new(&[
        "workers", "alg", "step_ms", "allreduce_ms", "tokens_per_s", "scaling_eff_%",
    ]);
    let mut base_tps = None;
    let mut backend = String::new();
    for &w in &worker_counts {
        let mut cfg = TrainConfig::new(artifacts.clone(), &preset, w);
        cfg.log_every = u64::MAX; // quiet
        let (_, report) = train(&cfg, None, steps)?;
        backend = report.backend.clone();
        let tps = report.tokens_per_sec;
        let base = *base_tps.get_or_insert(tps / w as f64);
        table.row(&[
            w.to_string(),
            report.algorithm.to_string(),
            format!("{:.1}", report.mean_step_secs * 1e3),
            format!("{:.1}", report.mean_allreduce_secs * 1e3),
            format!("{:.0}", tps),
            format!("{:.1}", 100.0 * tps / (base * w as f64)),
        ]);
    }
    print!("{}", table.render());
    println!("backend: {backend}");
    Ok(())
}

fn cmd_simulate() -> Result<()> {
    let a = Args::from_env(2)?;
    let seed = a.get_or("seed", 42u64)?;
    let all = a.flag("all");
    let threads = a.get_or("threads", 0usize)?;
    let contention_opt = a.str_opt("contention");
    let contention_s = contention_opt.clone().unwrap_or_else(|| "moderate".into());
    let strategy_s = a.str_or("strategy", "precompute");
    let n_jobs = a.get_or("n-jobs", 0usize)?;
    let trace_scale = a.flag("trace-scale");
    let nodes = a.get_or("nodes", 0usize)?;
    let gpn_s = a.str_opt("gpus-per-node");
    let placement_s = a.str_opt("placement");
    let model_bytes_s = a.str_opt("model-bytes");
    let link_contention = a.flag("link-contention");
    let faults_s = a.str_opt("faults");
    let mtbf_s = a.str_opt("mtbf");
    let mttr_s = a.str_opt("mttr");
    let telemetry = a.str_opt("telemetry");
    a.reject_unknown()?;
    // One stream records one run; the --all sweep would overwrite it
    // 21 times and keep only the last cell of Table 3.
    anyhow::ensure!(
        telemetry.is_none() || !all,
        "--telemetry records a single run; drop --all and pick one \
         --strategy/--contention cell"
    );
    // Topology knobs are inert on a flat pool — reject rather than let a
    // forgotten --nodes silently produce penalty-free results.
    anyhow::ensure!(
        nodes > 0
            || (gpn_s.is_none()
                && placement_s.is_none()
                && model_bytes_s.is_none()
                && !link_contention),
        "--gpus-per-node/--placement/--model-bytes/--link-contention require --nodes \
         (a flat pool has no topology penalty)"
    );
    // Faults down whole nodes; a flat pool has no nodes to down.
    anyhow::ensure!(
        nodes > 0 || faults_s.is_none(),
        "--faults requires --nodes (faults evict whole nodes from the grid)"
    );
    let faults =
        parse_faults(faults_s.as_deref(), mtbf_s.as_deref(), mttr_s.as_deref(), None, seed)?;
    // --trace-scale replaces the contention presets' arrival process, so
    // an explicit --contention (or the --all sweep) would be silently
    // ignored — reject, same convention as the topology knobs above.
    anyhow::ensure!(
        !trace_scale || (contention_opt.is_none() && !all),
        "--trace-scale supplies its own load-targeted arrival process; \
         drop --contention/--all and size the trace with --n-jobs"
    );
    let gpus_per_node: usize = match &gpn_s {
        Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--gpus-per-node {s:?}: {e}"))?,
        None => 8,
    };
    let place_policy = parse_placement(placement_s.as_deref().unwrap_or("pack"))?;
    let model_bytes: f64 = match &model_bytes_s {
        Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--model-bytes {s:?}: {e}"))?,
        None => PlacementModel::paper().n_bytes,
    };

    let contentions: Vec<Contention> = if all {
        Contention::all().to_vec()
    } else {
        vec![parse_contention(&contention_s)?]
    };
    let strategies: Vec<StrategyKind> = if all {
        StrategyKind::table3_rows()
    } else {
        vec![parse_strategy(&strategy_s)?]
    };

    // Build every (contention, strategy) cell up front, then fan the
    // batch across the sweep runner. Cell construction order == output
    // row order regardless of --threads: `sweep::run_cells` returns
    // results in submission order, so the printed table is a pure
    // function of the flags (asserted byte-for-byte in cli_smoke).
    let mut cells: Vec<SweepCell> = Vec::new();
    let mut cell_contention: Vec<Contention> = Vec::new();
    for &c in &contentions {
        for &s in &strategies {
            let mut cfg = SimConfig::paper(s, c, seed);
            if nodes > 0 {
                cfg = cfg.with_topology(nodes, gpus_per_node);
                cfg.placement = PlacementModel::paper().with_model_bytes(model_bytes);
                cfg.place_policy = place_policy;
                if link_contention {
                    cfg.link_contention = LinkContention::fair_share();
                }
                cfg.faults = faults;
            }
            if n_jobs > 0 {
                cfg.n_jobs = n_jobs;
            }
            if let Some(p) = prune_from_env() {
                cfg.completion_prune = p;
            }
            let jobs = if trace_scale {
                // heavy-tailed trace sized to the pool: --contention's
                // arrival mean is replaced by a load-targeted one
                WorkloadGen::trace_scale(cfg.n_jobs, cfg.capacity, seed)
            } else {
                WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed)
            };
            cells.push(SweepCell::new(cfg, std::sync::Arc::new(jobs)));
            cell_contention.push(c);
        }
    }

    let results = match &telemetry {
        Some(path) => {
            // --telemetry records a single run (ensured above), so the
            // traced path stays serial and identical to before.
            let cell = &cells[0];
            let mut rec = Recorder::new();
            let r = simulate_traced(&cell.cfg, &cell.jobs, &mut rec);
            rec.save(path)?;
            println!("telemetry ({} events) -> {path}", rec.len());
            print!("{}", rec.phase_summary());
            vec![r]
        }
        None => sweep::run_cells(&cells, sweep::resolve_threads(Some(threads))),
    };

    let mut table = CsvTable::new(&["strategy", "contention", "avg_hours", "jobs", "peak", "rescales"]);
    for (r, c) in results.iter().zip(&cell_contention) {
        table.row(&[
            r.strategy.clone(),
            c.name().to_string(),
            format!("{:.2}", r.avg_completion_hours),
            r.completed.to_string(),
            r.peak_concurrent.to_string(),
            r.total_rescales.to_string(),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_orchestrate() -> Result<()> {
    let a = Args::from_env(2)?;
    let strategy = a.str_or("strategy", "doubling");
    let capacity = a.get_or("capacity", 8usize)?;
    let trace_path = a.str_opt("trace");
    let n_jobs = a.get_or("jobs", 6usize)?;
    let mean_interarrival = a.get_or("mean-interarrival", 30.0f64)?;
    let epochs = a.get_or("epochs", 1.0f64)?;
    let max_w = a.get_or("max-w", 8usize)?;
    let emit = a.str_opt("emit-trace");
    let nodes = a.get_or("nodes", 0usize)?;
    let gpn_s = a.str_opt("gpus-per-node");
    let placement_s = a.str_opt("placement");
    // (--model-bytes stays legal without --nodes: it rewrites the specs
    // and is recorded in emitted traces either way)
    let model_bytes = a.str_opt("model-bytes");
    let preempt = a.flag("preempt");
    let contention = a.flag("contention");
    let segment_budget = a.get_or("segment-budget", f64::INFINITY)?;
    let online_model = a.flag("online-model");
    let preset = a.str_or("preset", "tiny");
    let segment_steps = a.get_or("segment-steps", 16u64)?;
    let dataset_examples = a.get_or("dataset-examples", 256usize)?;
    let restart_cost = a.get_or("restart-cost", 10.0f64)?;
    let ckpt_store = a.str_opt("ckpt-store");
    let faults_s = a.str_opt("faults");
    let mtbf_s = a.str_opt("mtbf");
    let mttr_s = a.str_opt("mttr");
    let max_retries_s = a.str_opt("max-retries");
    let telemetry = a.str_opt("telemetry");
    let artifacts = a.str_or("artifacts", &default_dir().to_string_lossy());
    let seed = a.get_or("seed", 42u64)?;
    a.reject_unknown()?;
    let faults = parse_faults(
        faults_s.as_deref(),
        mtbf_s.as_deref(),
        mttr_s.as_deref(),
        max_retries_s.as_deref(),
        seed,
    )?;
    anyhow::ensure!(
        nodes > 0 || (gpn_s.is_none() && placement_s.is_none() && !contention),
        "--gpus-per-node/--placement/--contention require --nodes \
         (a flat pool has no topology penalty)"
    );
    let gpus_per_node: usize = match &gpn_s {
        Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--gpus-per-node {s:?}: {e}"))?,
        None => 8,
    };
    let place_policy = parse_placement(placement_s.as_deref().unwrap_or("pack"))?;

    let mut specs = match &trace_path {
        Some(path) => orchestrator::load_trace(path)?,
        None => orchestrator::generate_trace(
            &TraceGen { n_jobs, mean_interarrival, total_epochs: epochs, max_w },
            seed,
        ),
    };
    if let Some(b) = &model_bytes {
        let b: f64 = b.parse().map_err(|e| anyhow::anyhow!("--model-bytes {b:?}: {e}"))?;
        for s in &mut specs {
            s.model_bytes = b;
        }
    }
    if let Some(emit) = &emit {
        orchestrator::save_trace(emit, &specs)?;
        println!("trace ({} jobs) -> {emit}", specs.len());
    }

    let mut tcfg = TrainConfig::new(artifacts, &preset, 1);
    tcfg.seed = seed;
    tcfg.dataset_examples = dataset_examples;
    tcfg.log_every = u64::MAX; // quiet workers; final losses still recorded
    let mut cfg = OrchestratorConfig::new(tcfg, capacity);
    cfg.restart_cost = restart_cost;
    cfg.segment_steps = segment_steps;
    cfg.place_policy = place_policy;
    cfg.preempt_on_arrival = preempt;
    cfg.segment_budget_secs = segment_budget;
    cfg.online_model = online_model;
    cfg.ckpt_store = ckpt_store.as_ref().map(std::path::PathBuf::from);
    cfg.faults = faults;
    if nodes > 0 {
        cfg = cfg.with_topology(nodes, gpus_per_node);
        if contention {
            cfg.link_contention = LinkContention::fair_share();
        }
    }

    let scheduler = orchestrator::scheduler_by_name(&strategy)?;
    println!(
        "orchestrating {} jobs on {} workers ({}) under {} (preset {preset}, seed {seed})...",
        specs.len(),
        cfg.capacity,
        cfg.topology.label(),
        scheduler.name()
    );
    let report = match &telemetry {
        Some(path) => {
            let mut rec = Recorder::new();
            let report =
                orchestrator::orchestrate_traced(&cfg, scheduler.as_ref(), &specs, &mut rec)?;
            rec.save(path)?;
            println!("telemetry ({} events) -> {path}", rec.len());
            print!("{}", rec.phase_summary());
            report
        }
        None => orchestrator::orchestrate(&cfg, scheduler.as_ref(), &specs)?,
    };
    print!("{}", report.per_job_table().render());
    println!("{}", report.summary());
    Ok(())
}

fn cmd_report() -> Result<()> {
    let a = Args::from_env(2)?;
    let stream = a.str_opt("stream");
    a.reject_unknown()?;
    let stream = stream
        .ok_or_else(|| anyhow::anyhow!("--stream FILE is required (a --telemetry output)"))?;
    let audit = audit::audit_file(std::path::Path::new(&stream))?;
    print!("{}", audit.rendered);
    Ok(())
}

fn cmd_collectives() -> Result<()> {
    let a = Args::from_env(2)?;
    let w = a.get_or("workers", 8usize)?;
    let elems = a.get_or("elems", 1_000_000usize)?;
    a.reject_unknown()?;

    let params = cost::CostParams::default();
    let mut table = CsvTable::new(&["alg", "wall_ms", "msgs", "bytes", "model_ms"]);
    for alg in [Algorithm::Ring, Algorithm::DoublingHalving, Algorithm::BinaryBlocks] {
        if alg == Algorithm::DoublingHalving && !w.is_power_of_two() {
            continue;
        }
        let payloads: Vec<Vec<f32>> = (0..w).map(|r| vec![r as f32; elems]).collect();
        let t0 = std::time::Instant::now();
        let (_, traffic) = collectives::comm::run_world(w, payloads, move |rank, data| {
            collectives::all_reduce(alg, rank, data).unwrap();
        });
        table.row(&[
            alg.name().to_string(),
            format!("{:.2}", t0.elapsed().as_secs_f64() * 1e3),
            traffic.messages().to_string(),
            traffic.bytes().to_string(),
            format!("{:.3}", cost::comm_time(alg, w, (elems * 4) as f64, &params) * 1e3),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_fit() -> Result<()> {
    let a = Args::from_env(2)?;
    // `--demo` is accepted for compatibility with the usage string; the
    // subcommand is demo-only either way.
    let _ = a.flag("demo");
    a.reject_unknown()?;
    // eq 1 demo on a synthetic 1/k curve
    let samples: Vec<(f64, f64)> =
        (0..60).map(|e| (e as f64, 1.0 / (0.35 * e as f64 + 1.4) + 0.22)).collect();
    let conv = ConvergenceModel::fit(&samples)?;
    println!(
        "eq 1 fit: b0={:.4} b1={:.4} b2={:.4} rms={:.2e}; epochs to loss 0.3: {:.1}",
        conv.b0,
        conv.b1,
        conv.b2,
        conv.rms,
        conv.epochs_to_loss(0.3).unwrap_or(f64::NAN)
    );
    // eq 5 demo on the paper's Table 2 epoch times
    let speeds: Vec<(usize, f64)> = ringmaster::sim::workload::PAPER_EPOCH_SECS
        .iter()
        .map(|&(w, s)| (w, 1.0 / s))
        .collect();
    let model = SpeedModel::fit(&speeds, 50_000.0, 6.9e6)?;
    println!("eq 5 fit on paper Table 2 data: theta={:?}", model.theta);
    for w in [1usize, 2, 4, 8, 16] {
        println!("  f({w:>2}) -> {:>7.1} s/epoch", model.secs_per_epoch(w));
    }
    Ok(())
}

fn parse_contention(s: &str) -> Result<Contention> {
    Ok(match s {
        "extreme" => Contention::Extreme,
        "moderate" => Contention::Moderate,
        "none" => Contention::None,
        other => anyhow::bail!("contention {other:?}: want extreme|moderate|none"),
    })
}

/// Build a [`FaultPlan`] from the CLI knobs. The default (`--faults`
/// absent or `off`) is `FaultPlan::OFF` itself, so the no-faults CLI
/// path is structurally the pre-fault binary — no clocks, no draws.
fn parse_faults(
    preset: Option<&str>,
    mtbf: Option<&str>,
    mttr: Option<&str>,
    max_retries: Option<&str>,
    seed: u64,
) -> Result<FaultPlan> {
    // Fault clocks stop here (pending repairs still complete). Chosen
    // generously past any run this CLI produces, so `steady` behaves
    // like an unbounded failure process without an extra flag.
    const FAULT_HORIZON_SECS: f64 = 4.0e6;
    let knobs_given = mtbf.is_some() || mttr.is_some() || max_retries.is_some();
    let parse_f64 = |name: &str, s: Option<&str>, default: f64| -> Result<f64> {
        match s {
            None => Ok(default),
            Some(s) => {
                let v: f64 = s.parse().map_err(|e| anyhow::anyhow!("--{name} {s:?}: {e}"))?;
                anyhow::ensure!(v > 0.0, "--{name} must be > 0 (got {s})");
                Ok(v)
            }
        }
    };
    let mut plan = match preset.unwrap_or("off") {
        "off" => {
            // Inert knobs are bugs waiting to happen — reject, same
            // convention as the topology flags.
            anyhow::ensure!(
                !knobs_given,
                "--mtbf/--mttr/--max-retries require --faults steady|burst"
            );
            return Ok(FaultPlan::OFF);
        }
        "steady" => FaultPlan::steady(
            parse_f64("mtbf", mtbf, 20_000.0)?,
            parse_f64("mttr", mttr, 600.0)?,
            FAULT_HORIZON_SECS,
            seed,
        ),
        "burst" => {
            anyhow::ensure!(
                mtbf.is_none() && mttr.is_none(),
                "--faults burst is a fixed storm preset; use --faults steady \
                 to tune --mtbf/--mttr"
            );
            FaultPlan::burst(FAULT_HORIZON_SECS, seed)
        }
        other => anyhow::bail!("faults {other:?}: want off|steady|burst"),
    };
    if let Some(k) = max_retries {
        plan.max_retries = k.parse().map_err(|e| anyhow::anyhow!("--max-retries {k:?}: {e}"))?;
    }
    Ok(plan)
}

fn parse_placement(s: &str) -> Result<PlacePolicy> {
    Ok(match s {
        "pack" => PlacePolicy::Pack,
        "scatter" => PlacePolicy::Scatter,
        "spread" => PlacePolicy::Spread,
        other => anyhow::bail!("placement {other:?}: want pack|scatter|spread"),
    })
}

fn parse_strategy(s: &str) -> Result<StrategyKind> {
    Ok(match s {
        "precompute" => StrategyKind::Precompute,
        "exploratory" => StrategyKind::Exploratory,
        "optimus" => StrategyKind::Optimus,
        "fixed-1" | "one" => StrategyKind::Fixed(1),
        "fixed-2" | "two" => StrategyKind::Fixed(2),
        "fixed-4" | "four" => StrategyKind::Fixed(4),
        "fixed-8" | "eight" => StrategyKind::Fixed(8),
        other => anyhow::bail!("strategy {other:?}"),
    })
}
