//! Minimal CLI argument parsing (substrate — the vendor snapshot has no
//! clap). Supports `--flag value`, `--flag=value`, bare `--flag`
//! booleans, and positional arguments, with typed accessors and a
//! "did you consume everything" check for typo safety.

use std::collections::BTreeMap;

use crate::Result;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                anyhow::ensure!(!stripped.is_empty(), "bare `--` not supported");
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    flags.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { flags, positional, consumed: Default::default() })
    }

    /// Parse from the process environment, skipping program + subcommand.
    pub fn from_env(skip: usize) -> Result<Args> {
        Args::parse(std::env::args().skip(skip))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn raw(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    /// Optional string flag (`None` when absent) — for flags where the
    /// empty string is not a usable sentinel, e.g. file paths.
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.raw(key).map(|s| s.to_string())
    }

    /// Required string flag.
    pub fn str_req(&self, key: &str) -> Result<String> {
        self.raw(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.raw(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
        }
    }

    /// Boolean flag (present, `=true`, or `=1`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.raw(key), Some("true") | Some("1"))
    }

    /// Comma-separated typed list.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.raw(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|tok| {
                    tok.trim()
                        .parse::<T>()
                        .map_err(|e| anyhow::anyhow!("--{key} item {tok:?}: {e}"))
                })
                .collect(),
        }
    }

    /// Error if any provided flag was never read (typo protection).
    pub fn reject_unknown(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !consumed.contains(*k)).collect();
        anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn parses_flag_styles() {
        // positionals go before flags: a bare token after `--verbose`
        // would be consumed as its value (documented ambiguity).
        let a = args("pos1 --preset tiny --steps=100 --verbose");
        assert_eq!(a.str_or("preset", "x"), "tiny");
        assert_eq!(a.get_or("steps", 0u64).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.str_or("preset", "small"), "small");
        assert_eq!(a.get_or("workers", 4usize).unwrap(), 4);
        assert!(!a.flag("all"));
    }

    #[test]
    fn str_opt_distinguishes_absent_from_present() {
        let a = args("--trace jobs.jsonl");
        assert_eq!(a.str_opt("trace").as_deref(), Some("jobs.jsonl"));
        assert_eq!(a.str_opt("emit-trace"), None);
        assert!(a.reject_unknown().is_ok()); // both lookups count as consumed
    }

    #[test]
    fn required_flag_errors_with_name() {
        let a = args("");
        let err = a.str_req("plan").unwrap_err().to_string();
        assert!(err.contains("--plan"));
    }

    #[test]
    fn typed_parse_errors_are_descriptive() {
        let a = args("--steps banana");
        let err = a.get_or("steps", 0u64).unwrap_err().to_string();
        assert!(err.contains("steps") && err.contains("banana"));
    }

    #[test]
    fn lists_parse() {
        let a = args("--workers 1,2,4,8");
        assert_eq!(a.list_or("workers", &[0usize]).unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(a.list_or("missing", &[3usize]).unwrap(), vec![3]);
    }

    #[test]
    fn reject_unknown_catches_typos() {
        let a = args("--stpes 100");
        let _ = a.get_or("steps", 0u64);
        assert!(a.reject_unknown().is_err());
        let b = args("--steps 100");
        let _ = b.get_or("steps", 0u64);
        assert!(b.reject_unknown().is_ok());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args("--offset -5");
        assert_eq!(a.get_or("offset", 0i64).unwrap(), -5);
    }
}
