//! Table 3 reproduction invariants across seeds — the claims of §7 must
//! hold on *shape* (who wins, where the crossovers are), not just on one
//! lucky workload.

use ringmaster::sim::{simulate, Contention, SimConfig, SimResult, StrategyKind, WorkloadGen};

fn run(strategy: StrategyKind, contention: Contention, seed: u64) -> SimResult {
    let cfg = SimConfig::paper(strategy, contention, seed);
    let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
    simulate(&cfg, &jobs)
}

const SEEDS: [u64; 3] = [42, 1337, 7];

#[test]
fn everyone_finishes_every_workload() {
    for &seed in &SEEDS {
        for c in Contention::all() {
            for s in StrategyKind::table3_rows() {
                let r = run(s, c, seed);
                let want = SimConfig::paper(s, c, seed).n_jobs;
                assert_eq!(r.completed, want, "{} {} seed {seed}", r.strategy, c.name());
                assert!(r.avg_completion_hours.is_finite() && r.avg_completion_hours > 0.0);
            }
        }
    }
}

#[test]
fn precompute_wins_or_ties_at_every_contention() {
    // §7: "the precompute algorithm always outperforms or ties"
    for &seed in &SEEDS {
        for c in Contention::all() {
            let pre = run(StrategyKind::Precompute, c, seed);
            for s in StrategyKind::table3_rows() {
                let r = run(s, c, seed);
                assert!(
                    pre.avg_completion_hours <= r.avg_completion_hours * 1.05,
                    "seed {seed} {}: precompute {:.2} vs {} {:.2}",
                    c.name(),
                    pre.avg_completion_hours,
                    r.strategy,
                    r.avg_completion_hours
                );
            }
        }
    }
}

#[test]
fn moderate_contention_precompute_halves_fixed8() {
    // the paper's headline: >2x at moderate contention vs Eight (6.20 vs
    // 2.63). Our simulator reproduces the direction with factor >= 1.25.
    for &seed in &SEEDS {
        let pre = run(StrategyKind::Precompute, Contention::Moderate, seed);
        let eight = run(StrategyKind::Fixed(8), Contention::Moderate, seed);
        assert!(
            eight.avg_completion_hours > pre.avg_completion_hours * 1.25,
            "seed {seed}: {:.2} vs {:.2}",
            eight.avg_completion_hours,
            pre.avg_completion_hours
        );
    }
}

#[test]
fn fixed1_worst_at_no_contention() {
    // Table 3 column None: One = 6.37 vs Eight/precompute = 1.40
    for &seed in &SEEDS {
        let one = run(StrategyKind::Fixed(1), Contention::None, seed);
        let eight = run(StrategyKind::Fixed(8), Contention::None, seed);
        assert!(one.avg_completion_hours > 3.0 * eight.avg_completion_hours);
    }
}

#[test]
fn fixed8_degrades_fastest_with_contention() {
    // Eight: 1.40 -> 22.76 across columns (16x); One: 6.37 -> 10.10 (1.6x)
    for &seed in &SEEDS {
        let e_none = run(StrategyKind::Fixed(8), Contention::None, seed);
        let e_ext = run(StrategyKind::Fixed(8), Contention::Extreme, seed);
        let o_none = run(StrategyKind::Fixed(1), Contention::None, seed);
        let o_ext = run(StrategyKind::Fixed(1), Contention::Extreme, seed);
        let eight_blowup = e_ext.avg_completion_hours / e_none.avg_completion_hours;
        let one_blowup = o_ext.avg_completion_hours / o_none.avg_completion_hours;
        assert!(
            eight_blowup > 2.0 * one_blowup,
            "seed {seed}: eight {eight_blowup:.1}x vs one {one_blowup:.1}x"
        );
    }
}

#[test]
fn exploration_overhead_visible_without_contention() {
    // §7: at zero contention exploration underperforms fixed-8 because of
    // the 7.5 min spent below 8 GPUs per job
    for &seed in &SEEDS {
        let exp = run(StrategyKind::Exploratory, Contention::None, seed);
        let eight = run(StrategyKind::Fixed(8), Contention::None, seed);
        assert!(exp.avg_completion_hours >= eight.avg_completion_hours * 0.99);
    }
}

#[test]
fn peak_concurrency_scales_with_contention() {
    // paper: peaks 125 / 59 / 20 across the three workloads
    for &seed in &SEEDS {
        let ext = run(StrategyKind::Precompute, Contention::Extreme, seed);
        let mode = run(StrategyKind::Precompute, Contention::Moderate, seed);
        let none = run(StrategyKind::Precompute, Contention::None, seed);
        assert!(ext.peak_concurrent > mode.peak_concurrent);
        assert!(mode.peak_concurrent > none.peak_concurrent);
        assert!(
            (60..=160).contains(&ext.peak_concurrent),
            "extreme peak {}",
            ext.peak_concurrent
        );
    }
}

#[test]
fn seed42_regression_snapshot() {
    // loose regression pin so accidental simulator changes are caught;
    // values from the initial calibrated run (cf. EXPERIMENTS.md)
    let pre = run(StrategyKind::Precompute, Contention::Moderate, 42);
    assert!((2.0..3.6).contains(&pre.avg_completion_hours), "{}", pre.avg_completion_hours);
    let none_pre = run(StrategyKind::Precompute, Contention::None, 42);
    assert!((1.1..1.8).contains(&none_pre.avg_completion_hours), "{}", none_pre.avg_completion_hours);
}
