//! Orchestrator × checkpoint store integration: turning `--ckpt-store`
//! on must not move a single bit of the schedule (the store lives on
//! the measured side of the two-clock split), restarts through the
//! store must write far fewer bytes than the whole-file path, and a
//! completed fleet run must leave no `.ckpt` residue in the temp dir
//! and a fully drained (removed) store root.

use std::path::PathBuf;

use ringmaster::orchestrator::{
    orchestrate, scheduler_by_name, JobSpec, OrchestratorConfig, OrchestratorReport,
};
use ringmaster::sim::workload::JobProfile;
use ringmaster::trainer::TrainConfig;

fn train_cfg() -> TrainConfig {
    let mut c = TrainConfig::new(
        env!("CARGO_MANIFEST_DIR").to_string() + "/../artifacts",
        "tiny",
        1,
    );
    c.dataset_examples = 256;
    c.log_every = u64::MAX;
    c
}

fn paper_job(id: u64, arrival: f64, total_epochs: f64, size: f64) -> JobSpec {
    let epoch_secs = vec![
        (1, 138.0 * size),
        (2, 81.9 * size),
        (4, 47.3 * size),
        (8, 29.6 * size),
    ];
    JobSpec::from_profile(id, JobProfile { arrival, epoch_secs, total_epochs }, 8)
}

/// Two staggered jobs on short segments: job 0 seizes the cluster, is
/// stopped at a boundary when job 1 arrives, and restarts narrower — the
/// stop→checkpoint→restart traffic the store exists to absorb.
fn rescale_trace() -> Vec<JobSpec> {
    vec![paper_job(0, 0.0, 2.0, 1.0), paper_job(1, 30.0, 2.0, 1.0)]
}

fn cfg_with_store(store: Option<PathBuf>) -> OrchestratorConfig {
    let mut cfg = OrchestratorConfig::new(train_cfg(), 8);
    cfg.segment_steps = 4;
    cfg.restart_cost = 10.0;
    cfg.ckpt_store = store;
    cfg
}

fn run(cfg: &OrchestratorConfig, specs: &[JobSpec]) -> OrchestratorReport {
    let sched = scheduler_by_name("doubling").unwrap();
    orchestrate(cfg, sched.as_ref(), specs).unwrap()
}

/// Orchestrator checkpoint temp files carry this process-scoped prefix
/// (see executor.rs); counting them before/after detects leaks without
/// racing other tests' files.
fn orch_temp_residue() -> usize {
    let prefix = format!("ringmaster-orch-{}-", std::process::id());
    std::fs::read_dir(std::env::temp_dir())
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with(&prefix))
                .count()
        })
        .unwrap_or(0)
}

fn assert_same_schedule(a: &OrchestratorReport, b: &OrchestratorReport) {
    assert_eq!(a.total_restarts, b.total_restarts);
    assert_eq!(a.events, b.events);
    assert_eq!(a.makespan_secs.to_bits(), b.makespan_secs.to_bits(), "virtual clock diverged");
    for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(ja.id, jb.id);
        assert_eq!(ja.jct_secs.to_bits(), jb.jct_secs.to_bits(), "job {} JCT diverged", ja.id);
        assert_eq!(ja.segments, jb.segments);
        assert_eq!(ja.steps, jb.steps);
        assert_eq!(ja.max_w, jb.max_w);
        assert_eq!(
            ja.final_loss.map(f32::to_bits),
            jb.final_loss.map(f32::to_bits),
            "job {} trained different models",
            ja.id
        );
    }
}

#[test]
fn store_mode_is_bit_identical_and_writes_fewer_restart_bytes() {
    let specs = rescale_trace();
    let root = std::env::temp_dir().join(format!("rm-ckptstore-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    let whole_file = run(&cfg_with_store(None), &specs);
    let through_store = run(&cfg_with_store(Some(root.clone())), &specs);

    // the acceptance bar: the flag may not move the schedule at all
    assert_same_schedule(&whole_file, &through_store);

    // both modes measured real restart checkpoint traffic...
    assert!(whole_file.restart_ckpt_bytes() > 0, "no measured restarts in baseline");
    assert!(through_store.restart_ckpt_bytes() > 0, "no measured restarts through store");
    // ...but a store restart re-saves unchanged parked content, so it
    // commits a manifest instead of the full theta‖mu image
    assert!(
        through_store.restart_ckpt_bytes() * 4 < whole_file.restart_ckpt_bytes(),
        "store restarts wrote {} bytes vs whole-file {} — dedup not engaged",
        through_store.restart_ckpt_bytes(),
        whole_file.restart_ckpt_bytes()
    );
    // park-saves + frees are accounted as checkpoint I/O on the measured side
    assert!(through_store.ckpt_io_secs() > 0.0);
    for j in &through_store.jobs {
        assert!(j.ckpt_bytes_written > 0, "job {}: no store traffic recorded", j.id);
    }

    // a completed run frees every job: the store must be drained and gone
    assert!(!root.exists(), "store root survived a fully drained run");
}

#[test]
fn completed_runs_leak_no_temp_checkpoints() {
    let specs = rescale_trace();
    let before = orch_temp_residue();
    let r = run(&cfg_with_store(None), &specs);
    assert!(r.total_restarts >= 3, "trace must exercise the roundtrip path");
    assert_eq!(
        orch_temp_residue(),
        before,
        "whole-file restart path leaked .ckpt/.tmp files in {}",
        std::env::temp_dir().display()
    );
}
