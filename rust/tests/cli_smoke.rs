//! CLI smoke tests: every subcommand's help path exits 0 and the fast
//! subcommands actually run on a bare checkout (builtin manifest, no
//! artifacts, reference backend).

use std::process::Command;

const SUBCOMMANDS: [&str; 8] =
    ["train", "rescale", "profile", "simulate", "orchestrate", "collectives", "fit", "report"];

fn bin() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_ringmaster"));
    // pin the backend-selection and sweep-tuning env so the smoke tests
    // exercise the bare-checkout defaults regardless of the invoking
    // shell's exports
    c.env_remove("RINGMASTER_BACKEND");
    c.env_remove("RINGMASTER_ARTIFACTS");
    c.env_remove("RINGMASTER_THREADS");
    c.env_remove("RINGMASTER_PRUNE");
    c
}

#[test]
fn global_help_exits_zero_and_lists_subcommands() {
    for flag in ["help", "--help", "-h"] {
        let out = bin().arg(flag).output().expect("run binary");
        assert!(out.status.success(), "`ringmaster {flag}` failed: {out:?}");
        let text = String::from_utf8_lossy(&out.stdout);
        for sub in SUBCOMMANDS {
            assert!(text.contains(sub), "help is missing {sub:?}:\n{text}");
        }
    }
}

#[test]
fn no_args_prints_help_and_exits_zero() {
    let out = bin().output().expect("run binary");
    assert!(out.status.success(), "bare `ringmaster` failed: {out:?}");
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn every_subcommand_help_exits_zero() {
    for sub in SUBCOMMANDS {
        for flag in ["--help", "-h"] {
            let out = bin().args([sub, flag]).output().expect("run binary");
            assert!(out.status.success(), "`ringmaster {sub} {flag}` failed: {out:?}");
            let text = String::from_utf8_lossy(&out.stdout);
            assert!(text.contains(sub), "{sub} help doesn't name itself:\n{text}");
            assert!(text.contains("flags:"), "{sub} help lists no flags:\n{text}");
        }
    }
}

#[test]
fn unknown_subcommand_exits_nonzero() {
    let out = bin().arg("frobnicate").output().expect("run binary");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("frobnicate"));
}

#[test]
fn unknown_flag_is_rejected() {
    let out = bin().args(["fit", "--bogus-flag", "1"]).output().expect("run binary");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bogus-flag"));
}

#[test]
fn fit_runs_on_bare_checkout() {
    let out = bin().args(["fit", "--demo"]).output().expect("run binary");
    assert!(out.status.success(), "fit failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("eq 1 fit") && text.contains("eq 5 fit"), "{text}");
}

#[test]
fn collectives_runs_on_bare_checkout() {
    let out = bin()
        .args(["collectives", "--workers", "4", "--elems", "1000"])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "collectives failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ring"), "{text}");
}

#[test]
fn simulate_trace_scale_runs_a_heavy_tailed_workload() {
    // scale-sweep plumbing end to end: --n-jobs overrides the preset
    // trace length, --trace-scale swaps in the load-targeted heavy-tail
    // generator, and the optimus baseline rides the same DES
    let out = bin()
        .args([
            "simulate",
            "--strategy",
            "optimus",
            "--n-jobs",
            "60",
            "--trace-scale",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "simulate --trace-scale failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    // anchor on the data row's jobs column (strategy, contention,
    // avg_hours, jobs, ...) — a bare substring/token match could hit an
    // unrelated cell like a "3.60" average or a rescale count
    let row = text
        .lines()
        .find(|l| l.trim_start().starts_with("optimus"))
        .unwrap_or_else(|| panic!("no optimus row in output:\n{text}"));
    let jobs_cell = row.split_whitespace().nth(3).unwrap_or("");
    assert_eq!(jobs_cell, "60", "completed-jobs column should read exactly 60:\n{text}");
}

#[test]
fn simulate_all_is_byte_identical_across_thread_counts() {
    // the sweep runner's determinism contract, end to end: the printed
    // Table 3 must be a pure function of the flags, so fanning the
    // 18-cell --all sweep across 1 worker and 8 workers has to produce
    // byte-identical stdout (--n-jobs keeps the cells tier-1 cheap)
    let run = |threads: &str| {
        let out = bin()
            .args(["simulate", "--all", "--n-jobs", "24", "--seed", "7", "--threads", threads])
            .output()
            .expect("run binary");
        assert!(
            out.status.success(),
            "simulate --all --threads {threads} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        out.stdout
    };
    let serial = run("1");
    let fanned = run("8");
    assert!(
        serial == fanned,
        "--threads 1 vs --threads 8 stdout diverged:\n--- 1 ---\n{}\n--- 8 ---\n{}",
        String::from_utf8_lossy(&serial),
        String::from_utf8_lossy(&fanned)
    );
    assert!(
        String::from_utf8_lossy(&serial).lines().any(|l| l.trim_start().starts_with("fixed-1")),
        "sweep output is missing Table 3 rows"
    );
}

#[test]
fn simulate_link_contention_runs_with_spread_placement() {
    // the contended DES end to end: fixed-8 gangs on 6-wide nodes must
    // split 6+2, --link-contention prices the shared uplinks, and the
    // spread policy is accepted by --placement
    let out = bin()
        .args([
            "simulate",
            "--strategy",
            "fixed-8",
            "--n-jobs",
            "40",
            "--nodes",
            "8",
            "--gpus-per-node",
            "6",
            "--link-contention",
            "--placement",
            "spread",
            "--model-bytes",
            "1e8",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "simulate --link-contention failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    let row = text
        .lines()
        .find(|l| l.trim_start().starts_with("fixed-8"))
        .unwrap_or_else(|| panic!("no fixed-8 row in output:\n{text}"));
    let jobs_cell = row.split_whitespace().nth(3).unwrap_or("");
    assert_eq!(jobs_cell, "40", "completed-jobs column should read exactly 40:\n{text}");
}

#[test]
fn link_contention_flags_require_a_grid() {
    // a flat pool has no links to share: both binaries' flags must be
    // rejected rather than silently ignored
    let out = bin().args(["simulate", "--link-contention"]).output().expect("run binary");
    assert!(!out.status.success(), "simulate --link-contention without --nodes passed");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--nodes"));

    let out = bin().args(["orchestrate", "--contention"]).output().expect("run binary");
    assert!(!out.status.success(), "orchestrate --contention without --nodes passed");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--nodes"));
}

#[test]
fn fault_flags_are_validated() {
    // faults down whole nodes: simulate must reject them on a flat pool
    let out = bin().args(["simulate", "--faults", "burst"]).output().expect("run binary");
    assert!(!out.status.success(), "simulate --faults without --nodes passed");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--nodes"));

    // fault knobs without a fault preset are inert — reject, same
    // convention as the topology flags
    let out = bin()
        .args(["orchestrate", "--mtbf", "100", "--jobs", "1"])
        .output()
        .expect("run binary");
    assert!(!out.status.success(), "orchestrate --mtbf without --faults passed");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--faults"));

    // unknown preset names the valid set
    let out = bin()
        .args(["simulate", "--nodes", "8", "--faults", "meteor"])
        .output()
        .expect("run binary");
    assert!(!out.status.success(), "simulate --faults meteor passed");
    assert!(String::from_utf8_lossy(&out.stderr).contains("off|steady|burst"));
}

#[test]
fn simulate_runs_a_faulted_grid_end_to_end() {
    // the fault-injected DES through the real CLI: burst preset on the
    // paper grid, every job must still complete (victims roll back and
    // re-queue; downed nodes return after repair)
    let out = bin()
        .args([
            "simulate",
            "--strategy",
            "fixed-8",
            "--n-jobs",
            "40",
            "--nodes",
            "8",
            "--gpus-per-node",
            "8",
            "--faults",
            "burst",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "faulted simulate failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    let row = text
        .lines()
        .find(|l| l.trim_start().starts_with("fixed-8"))
        .unwrap_or_else(|| panic!("no fixed-8 row in output:\n{text}"));
    let jobs_cell = row.split_whitespace().nth(3).unwrap_or("");
    assert_eq!(jobs_cell, "40", "completed-jobs column should read exactly 40:\n{text}");
}

#[test]
fn orchestrate_runs_under_injected_faults() {
    // miniature faulted live run: segments die with ~50% hazard, the
    // deep retry budget means the run still drains and exits 0
    let out = bin()
        .args([
            "orchestrate",
            "--strategy",
            "doubling",
            "--capacity",
            "2",
            "--jobs",
            "2",
            "--epochs",
            "0.25",
            "--segment-steps",
            "8",
            "--dataset-examples",
            "128",
            "--mean-interarrival",
            "5",
            "--faults",
            "steady",
            "--mtbf",
            "60",
            "--mttr",
            "60",
            "--max-retries",
            "30",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "faulted orchestrate failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("avg JCT"), "summary missing avg JCT:\n{text}");
}

#[test]
fn orchestrate_runs_under_link_contention() {
    // miniature contended live run: 2x2 grid, two jobs, spread placement
    let out = bin()
        .args([
            "orchestrate",
            "--strategy",
            "doubling",
            "--nodes",
            "2",
            "--gpus-per-node",
            "2",
            "--contention",
            "--placement",
            "spread",
            "--jobs",
            "2",
            "--epochs",
            "0.25",
            "--segment-steps",
            "8",
            "--dataset-examples",
            "128",
            "--mean-interarrival",
            "5",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "contended orchestrate failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("topology=2x2"), "summary missing topology:\n{text}");
    assert!(text.contains("avg JCT"), "summary missing avg JCT:\n{text}");
}

#[test]
fn orchestrate_runs_a_generated_workload_on_bare_checkout() {
    // miniature live run: 2 jobs, tiny epochs, reference backend
    let out = bin()
        .args([
            "orchestrate",
            "--strategy",
            "doubling",
            "--capacity",
            "2",
            "--jobs",
            "2",
            "--epochs",
            "0.25",
            "--segment-steps",
            "8",
            "--dataset-examples",
            "128",
            "--mean-interarrival",
            "5",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "orchestrate failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("jct_s"), "per-job JCT column missing:\n{text}");
    assert!(text.contains("avg JCT"), "summary missing avg JCT:\n{text}");
    assert!(text.contains("utilization"), "summary missing utilization:\n{text}");
}

#[test]
fn orchestrate_runs_with_a_checkpoint_store() {
    // same miniature run routed through `--ckpt-store`: the binary must
    // parse the flag, report ckpt io in the summary, and leave the store
    // directory fully drained (removed) once every job completes
    let root = std::env::temp_dir().join(format!("rm-cli-ckpt-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let out = bin()
        .args([
            "orchestrate",
            "--strategy",
            "doubling",
            "--capacity",
            "2",
            "--jobs",
            "2",
            "--epochs",
            "0.25",
            "--segment-steps",
            "8",
            "--dataset-examples",
            "128",
            "--mean-interarrival",
            "5",
            "--seed",
            "7",
            "--ckpt-store",
            root.to_str().expect("utf-8 temp path"),
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "orchestrate --ckpt-store failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ckpt_kb"), "per-job ckpt column missing:\n{text}");
    assert!(text.contains("ckpt io"), "summary missing ckpt io line:\n{text}");
    assert!(!root.exists(), "store not drained+removed after the run: {}", root.display());
}

#[test]
fn orchestrate_runs_on_a_grid_topology() {
    // 2x2 grid: capacity follows the grid (4), summary names the shape,
    // and the per-job table reports node spans
    let out = bin()
        .args([
            "orchestrate",
            "--strategy",
            "doubling",
            "--nodes",
            "2",
            "--gpus-per-node",
            "2",
            "--jobs",
            "2",
            "--epochs",
            "0.25",
            "--segment-steps",
            "8",
            "--dataset-examples",
            "128",
            "--mean-interarrival",
            "5",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "grid orchestrate failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("topology=2x2"), "summary missing topology:\n{text}");
    assert!(text.contains("nodes"), "per-job table missing node spans:\n{text}");
    assert!(text.contains("cross-node segs"), "summary missing cross-node count:\n{text}");
}

#[test]
fn orchestrate_online_model_runs_on_bare_checkout() {
    // --online-model + --segment-budget: the learner path end-to-end on
    // a miniature workload; the per-job table must carry the rmse column
    let out = bin()
        .args([
            "orchestrate",
            "--strategy",
            "doubling",
            "--capacity",
            "2",
            "--jobs",
            "2",
            "--epochs",
            "0.25",
            "--segment-steps",
            "8",
            "--dataset-examples",
            "128",
            "--mean-interarrival",
            "5",
            "--online-model",
            "--segment-budget",
            "30",
            "--seed",
            "7",
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "online-model orchestrate failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rmse"), "per-job table missing rmse column:\n{text}");
    assert!(text.contains("avg JCT"), "summary missing avg JCT:\n{text}");
}

#[test]
fn orchestrate_round_trips_a_trace_file() {
    let dir = std::env::temp_dir();
    let trace = dir.join(format!("rm-cli-trace-{}.jsonl", std::process::id()));
    // emit a generated trace, then re-run it from the file
    let out = bin()
        .args([
            "orchestrate",
            "--jobs",
            "2",
            "--epochs",
            "0.25",
            "--dataset-examples",
            "128",
            "--capacity",
            "2",
            "--emit-trace",
            trace.to_str().unwrap(),
        ])
        .output()
        .expect("run binary");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin()
        .args([
            "orchestrate",
            "--strategy",
            "fixed-2",
            "--capacity",
            "2",
            "--dataset-examples",
            "128",
            "--trace",
            trace.to_str().unwrap(),
        ])
        .output()
        .expect("run binary");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("fixed-2"));
    let _ = std::fs::remove_file(&trace);
}

#[test]
fn report_audits_the_checked_in_golden_fixture() {
    // same fixture CI replays: schema v3 + every ledger invariant
    let fixture = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package root has a parent")
        .join("artifacts/telemetry_golden.jsonl");
    let out = bin()
        .args(["report", "--stream", fixture.to_str().unwrap()])
        .output()
        .expect("run binary");
    assert!(out.status.success(), "report failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("audit OK"), "{text}");
    assert!(text.contains("decision table"), "{text}");
    assert!(text.contains("per-job timeline"), "{text}");
}

#[test]
fn report_rejects_a_job_trace_and_requires_stream() {
    // v2 job-submission traces must be redirected, not misparsed
    let dir = std::env::temp_dir();
    let trace = dir.join(format!("rm-cli-v2-{}.jsonl", std::process::id()));
    std::fs::write(&trace, "{\"ringmaster_trace\":2}\n").expect("write trace");
    let out = bin()
        .args(["report", "--stream", trace.to_str().unwrap()])
        .output()
        .expect("run binary");
    assert!(!out.status.success(), "report accepted a v2 job trace");
    assert!(String::from_utf8_lossy(&out.stderr).contains("job-submission trace"));
    let _ = std::fs::remove_file(&trace);

    let out = bin().arg("report").output().expect("run binary");
    assert!(!out.status.success(), "report without --stream passed");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--stream"));
}

#[test]
fn simulate_telemetry_round_trips_through_report() {
    // record a real DES run, then audit it: the end-to-end proof that
    // the engine's stream satisfies its own invariants
    let dir = std::env::temp_dir();
    let stream = dir.join(format!("rm-cli-telemetry-{}.jsonl", std::process::id()));
    let out = bin()
        .args([
            "simulate",
            "--strategy",
            "precompute",
            "--n-jobs",
            "20",
            "--nodes",
            "4",
            "--gpus-per-node",
            "4",
            "--link-contention",
            "--seed",
            "7",
            "--telemetry",
            stream.to_str().unwrap(),
        ])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "simulate --telemetry failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("telemetry ("),
        "simulate didn't report the stream path"
    );
    let out = bin()
        .args(["report", "--stream", stream.to_str().unwrap()])
        .output()
        .expect("run binary");
    assert!(
        out.status.success(),
        "report on live stream failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("audit OK"), "{text}");
    assert!(text.contains("engine=des"), "{text}");
    let _ = std::fs::remove_file(&stream);
}

#[test]
fn simulate_telemetry_rejects_the_all_sweep() {
    let out = bin()
        .args(["simulate", "--all", "--telemetry", "/tmp/never-written.jsonl"])
        .output()
        .expect("run binary");
    assert!(!out.status.success(), "--telemetry with --all passed");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--all"));
}

#[test]
fn train_runs_on_bare_checkout_with_reference_backend() {
    // the full E2E path through the builtin manifest + reference backend:
    // tiny preset, 1 worker, a handful of steps
    let out = bin()
        .args(["train", "--preset", "tiny", "--workers", "1", "--steps", "6", "--log-every", "2"])
        .output()
        .expect("run binary");
    assert!(out.status.success(), "train failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("steps/s"), "{text}");
    assert!(
        text.contains("backend=reference-cpu"),
        "expected the reference backend on a bare checkout:\n{text}"
    );
}
