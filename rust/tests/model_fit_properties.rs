//! Generative property harness for every performance estimator
//! (ISSUE 4): synthetic jobs are drawn from *known* eq-1 / eq-5
//! parameters across many seeds, and the fits must recover them.
//!
//! What "recover the parameters" means per estimator:
//!
//! - **eq 5 ([`SpeedModel`])** — the four features
//!   `[m/w, w-1, (w-1)·n/w, 1]` are rank 3 (`(w-1)·n/w = n·1 −
//!   (n/m)·(m/w)`), so raw `theta` is never identifiable. What *is*
//!   identified — uniquely, once ≥ 3 distinct widths are observed and
//!   the truth lies in the model family — are the function-space
//!   coordinates of `t(w) = A/w + B·w + C`, and therefore every
//!   prediction. The harness asserts exactly that: the identified
//!   `(A, B, C)` combos and held-out-width predictions are recovered,
//!   monotonicity holds where the math forces it, and noise never
//!   produces NaN or negative speeds.
//! - **eq 1 ([`ConvergenceModel`])** — `(b0, b1, b2)` are identifiable;
//!   the harness asserts prediction recovery, `epochs_to_loss`
//!   inversion, forced monotone decrease, and noise robustness.
//! - **[`OnlineModel`]** — the live learner must reach the same
//!   recovery through its segment-observation interface: the confidence
//!   gate opens only with enough distinct widths, placement-spanned
//!   observations are stripped back to the single-node base curve, and
//!   the model-vs-truth RMSE trajectory never rises as width coverage
//!   grows.
//!
//! No proptest crate in the vendor set, so the same discipline by hand:
//! a deterministic RNG drives >= 20 parameter sets per property and
//! every assertion message carries the case number.

use ringmaster::perfmodel::online::PAPER_EXAMPLES_PER_EPOCH;
use ringmaster::perfmodel::{ConvergenceModel, OnlineModel, PlacementModel, SpeedModel};
use ringmaster::rngx::Rng;

/// Parameter sets per property (issue floor: 20).
const CASES: usize = 24;

const M: f64 = PAPER_EXAMPLES_PER_EPOCH;
const N_BYTES: f64 = 6.9e6;

// ----------------------------------------------------------------------
// eq 5 — resource-to-speed
// ----------------------------------------------------------------------

/// Eq-5-realizable ground truth `t(w) = a/w + b·(w-1) + c` (equivalently
/// `A/w + B·w + C` with `A = a`, `B = b`, `C = c − b`), reachable with
/// `theta = (a/m, b, 0, c) >= 0`.
#[derive(Clone, Copy, Debug)]
struct SpeedTruth {
    a: f64,
    b: f64,
    c: f64,
}

impl SpeedTruth {
    fn random(rng: &mut Rng) -> SpeedTruth {
        SpeedTruth {
            a: rng.uniform_range(40.0, 400.0),
            b: rng.uniform_range(0.2, 4.0),
            c: rng.uniform_range(1.0, 12.0),
        }
    }

    fn secs(&self, w: usize) -> f64 {
        self.a / w as f64 + self.b * (w as f64 - 1.0) + self.c
    }

    fn samples(&self, widths: &[usize]) -> Vec<(usize, f64)> {
        widths.iter().map(|&w| (w, 1.0 / self.secs(w))).collect()
    }

    /// Identified function-space coordinates of a fitted model:
    /// `t(w) = A/w + B·w + C` with `A = t0·m − t2·n`, `B = t1`,
    /// `C = t2·n + t3 − t1`.
    fn identified(m: &SpeedModel) -> (f64, f64, f64) {
        let [t0, t1, t2, t3] = m.theta;
        (t0 * m.m - t2 * m.n_bytes, t1, t2 * m.n_bytes + t3 - t1)
    }
}

#[test]
fn prop_speed_fit_recovers_identified_parameters() {
    let mut rng = Rng::new(0xE951);
    for case in 0..CASES {
        let t = SpeedTruth::random(&mut rng);
        let m = SpeedModel::fit(&t.samples(&[1, 2, 4, 8, 16]), M, N_BYTES)
            .unwrap_or_else(|e| panic!("case {case} ({t:?}): {e}"));
        let (ga, gb, gc) = SpeedTruth::identified(&m);
        let (wa, wb, wc) = (t.a, t.b, t.c - t.b);
        let scale = t.secs(1);
        assert!((ga - wa).abs() < 1e-3 * scale, "case {case}: A {ga} vs {wa}");
        assert!((gb - wb).abs() < 1e-3 * scale, "case {case}: B {gb} vs {wb}");
        assert!((gc - wc).abs() < 1e-3 * scale, "case {case}: C {gc} vs {wc}");
    }
}

#[test]
fn prop_speed_fit_predictions_exact_at_held_out_widths() {
    // With >= 3 distinct widths of realizable truth the zero-residual
    // prediction function is unique, so held-out widths are as exact as
    // sampled ones — including extrapolation.
    let mut rng = Rng::new(0xE952);
    for case in 0..CASES {
        let t = SpeedTruth::random(&mut rng);
        let m = SpeedModel::fit(&t.samples(&[1, 2, 4, 8]), M, N_BYTES)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        for w in [3usize, 5, 6, 7, 12, 16, 24, 32] {
            let got = m.secs_per_epoch(w);
            let want = t.secs(w);
            assert!(
                (got - want).abs() / want < 1e-3,
                "case {case} w={w}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn prop_speed_fit_monotone_where_math_says() {
    // With b = 0 the truth t(w) = a/w + c is strictly decreasing, so
    // the recovered curve must be non-increasing (equivalently f(w)
    // non-decreasing) across the whole width range.
    let mut rng = Rng::new(0xE953);
    for case in 0..CASES {
        let t = SpeedTruth {
            a: rng.uniform_range(40.0, 400.0),
            b: 0.0,
            c: rng.uniform_range(1.0, 12.0),
        };
        let m = SpeedModel::fit(&t.samples(&[1, 2, 4, 8]), M, N_BYTES).unwrap();
        let mut prev = f64::INFINITY;
        for w in 1..=64usize {
            let secs = m.secs_per_epoch(w);
            assert!(
                secs <= prev + 1e-9 * t.secs(1),
                "case {case}: secs/epoch rose at w={w}"
            );
            prev = secs;
        }
    }
}

#[test]
fn prop_speed_fit_noise_never_nan_or_negative() {
    let mut rng = Rng::new(0xE954);
    for case in 0..CASES {
        let t = SpeedTruth::random(&mut rng);
        let noisy: Vec<(usize, f64)> = t
            .samples(&[1, 2, 4, 8, 16])
            .into_iter()
            .map(|(w, f)| (w, f * (1.0 + 0.05 * rng.normal()).max(0.05)))
            .collect();
        let m = SpeedModel::fit(&noisy, M, N_BYTES)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert!(m.theta.iter().all(|&v| v >= 0.0 && v.is_finite()), "case {case}: {:?}", m.theta);
        for w in 1..=64usize {
            let f = m.epochs_per_sec(w);
            assert!(!f.is_nan(), "case {case}: NaN speed at w={w}");
            assert!(f >= 0.0, "case {case}: negative speed at w={w}");
            assert!(f.is_finite(), "case {case}: infinite speed at w={w}");
            let secs = m.secs_per_epoch(w);
            assert!(!secs.is_nan() && secs >= 0.0, "case {case}: bad secs at w={w}");
        }
    }
}

// ----------------------------------------------------------------------
// eq 1 — convergence
// ----------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct ConvTruth {
    b0: f64,
    b1: f64,
    b2: f64,
}

impl ConvTruth {
    fn random(rng: &mut Rng) -> ConvTruth {
        ConvTruth {
            b0: rng.uniform_range(0.05, 0.9),
            b1: rng.uniform_range(0.4, 3.0),
            b2: rng.uniform_range(0.0, 0.5),
        }
    }

    fn loss(&self, e: f64) -> f64 {
        1.0 / (self.b0 * e + self.b1) + self.b2
    }

    fn curve(&self, epochs: usize) -> Vec<(f64, f64)> {
        (0..epochs).map(|e| (e as f64, self.loss(e as f64))).collect()
    }
}

#[test]
fn prop_convergence_fit_recovers_curves_and_inverts() {
    let mut rng = Rng::new(0xC0E1);
    for case in 0..CASES {
        let t = ConvTruth::random(&mut rng);
        let m = ConvergenceModel::fit(&t.curve(60))
            .unwrap_or_else(|e| panic!("case {case} ({t:?}): {e}"));
        assert!(m.b0 > 0.0, "case {case}: b0 must be positive");
        for e in [0.0, 5.0, 17.0, 30.0, 45.0, 59.0] {
            let got = m.predict(e);
            let want = t.loss(e);
            assert!(
                (got - want).abs() / want < 0.03,
                "case {case} e={e}: {got} vs {want}"
            );
        }
        // epochs_to_loss inverts predict at a mid-curve target
        let target = m.predict(25.0);
        let e = m.epochs_to_loss(target).unwrap_or_else(|| panic!("case {case}: unreachable"));
        assert!((e - 25.0).abs() < 1.0, "case {case}: inverted to {e}");
        // and a target below the fitted asymptote is unreachable
        assert!(m.epochs_to_loss(m.b2 * 0.5).is_none() || m.b2 == 0.0, "case {case}");
    }
}

#[test]
fn prop_convergence_predictions_monotone_decreasing() {
    let mut rng = Rng::new(0xC0E2);
    for case in 0..CASES {
        let t = ConvTruth::random(&mut rng);
        let m = ConvergenceModel::fit(&t.curve(50)).unwrap();
        let mut prev = f64::INFINITY;
        for e in 0..200 {
            let p = m.predict(e as f64);
            assert!(p <= prev + 1e-12, "case {case}: loss rose at epoch {e}");
            prev = p;
        }
    }
}

#[test]
fn prop_convergence_noise_never_nan() {
    let mut rng = Rng::new(0xC0E3);
    for case in 0..CASES {
        let t = ConvTruth::random(&mut rng);
        let noisy: Vec<(f64, f64)> = t
            .curve(80)
            .into_iter()
            .map(|(e, l)| (e, l * (1.0 + 0.02 * rng.normal()).max(0.05)))
            .collect();
        let m = ConvergenceModel::fit(&noisy)
            .unwrap_or_else(|e| panic!("case {case}: noisy fit failed: {e}"));
        assert!(m.b0 > 0.0 && m.b0.is_finite(), "case {case}");
        assert!(m.rms.is_finite(), "case {case}");
        for e in 0..300 {
            let p = m.predict(e as f64);
            assert!(p.is_finite() && !p.is_nan(), "case {case}: bad prediction at {e}");
        }
        if let Some(e) = m.epochs_to_loss(t.loss(40.0)) {
            assert!(e.is_finite() && e >= 0.0, "case {case}: bad inversion {e}");
        }
    }
}

// ----------------------------------------------------------------------
// OnlineModel — the live learner over both estimators
// ----------------------------------------------------------------------

#[test]
fn prop_online_gate_requires_distinct_widths_then_recovers() {
    let mut rng = Rng::new(0x0A11);
    for case in 0..CASES {
        let t = SpeedTruth::random(&mut rng);
        let mut online = OnlineModel::new(PlacementModel::paper(), M, N_BYTES);
        let w0 = 1usize << rng.below(4);
        for _ in 0..5 {
            online.observe_speed(w0, 1, t.secs(w0));
            assert!(online.speed().is_none(), "case {case}: gate open on one width");
        }
        for &w in &[1usize, 2, 4, 8] {
            online.observe_speed(w, 1, t.secs(w));
        }
        let fit = online
            .speed()
            .unwrap_or_else(|| panic!("case {case}: gate closed after full coverage"));
        for w in [1usize, 2, 4, 8, 16] {
            let got = fit.secs_per_epoch(w);
            let want = t.secs(w);
            assert!(
                (got - want).abs() / want < 1e-3,
                "case {case} w={w}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn prop_online_placement_split_recovers_single_node_base() {
    // Observations taken on rings spanning several nodes include the
    // eq-2 delta; the learner knows the interconnect and must strip it,
    // recovering the same single-node curve a flat run would learn.
    let mut rng = Rng::new(0x0A12);
    for case in 0..CASES {
        let t = SpeedTruth::random(&mut rng);
        let placement = PlacementModel::paper().with_model_bytes(1.0e8);
        let mut online = OnlineModel::new(placement, M, 1.0e8);
        for &(w, nodes) in &[(1usize, 1usize), (2, 2), (4, 2), (8, 3), (16, 2)] {
            online.observe_speed(w, nodes, placement.placed_epoch_secs(t.secs(w), w, nodes));
        }
        let fit = online.speed().unwrap_or_else(|| panic!("case {case}: gate closed"));
        for &w in &[1usize, 2, 4, 8, 16] {
            let got = fit.secs_per_epoch(w);
            let want = t.secs(w);
            assert!(
                (got - want).abs() / want < 1e-3,
                "case {case} w={w}: {got} vs {want} (delta not stripped?)"
            );
        }
    }
}

#[test]
fn prop_online_rmse_never_rises_as_coverage_grows() {
    // Width coverage only grows and repeated widths are deduped, so the
    // model-vs-truth RMSE trajectory must be non-increasing — and hit
    // ~zero at full coverage (the truth is realizable).
    let mut rng = Rng::new(0x0A13);
    for case in 0..CASES {
        let t = SpeedTruth::random(&mut rng);
        let table: Vec<(usize, f64)> = [1usize, 2, 4, 8].iter().map(|&w| (w, t.secs(w))).collect();
        let mut online = OnlineModel::new(PlacementModel::paper(), M, N_BYTES);
        // the width sequence a live job sees: repeats, then growth
        let schedule = [8usize, 8, 4, 4, 8, 2, 2, 1, 1];
        let mut trace: Vec<f64> = Vec::new();
        for &w in &schedule {
            online.observe_speed(w, 1, t.secs(w));
            if let Some(rmse) = online.speed_rmse_vs(&table) {
                trace.push(rmse);
            }
        }
        assert!(!trace.is_empty(), "case {case}: gate never opened");
        // slack sits above NNLS numerical noise (~1e-8 s on zero-residual
        // refits) and far below any real learning signal
        let slack = 1e-6 * t.secs(1);
        for pair in trace.windows(2) {
            assert!(
                pair[1] <= pair[0] + slack,
                "case {case}: rmse rose {} -> {} in {trace:?}",
                pair[0],
                pair[1]
            );
        }
        let last = *trace.last().unwrap();
        assert!(last < 1e-3 * t.secs(1), "case {case}: full coverage rmse {last}");
    }
}

#[test]
fn prop_online_noisy_segments_never_poison_the_model() {
    let mut rng = Rng::new(0x0A14);
    for case in 0..CASES {
        let t = SpeedTruth::random(&mut rng);
        let conv = ConvTruth::random(&mut rng);
        let mut online = OnlineModel::new(PlacementModel::paper(), M, N_BYTES);
        for seg in 0..30 {
            let w = 1usize << rng.below(4);
            let measured = t.secs(w) * (1.0 + 0.05 * rng.normal()).max(0.05);
            online.observe_speed(w, 1, measured);
            let e = seg as f64;
            online.observe_loss(e, conv.loss(e) * (1.0 + 0.02 * rng.normal()).max(0.05));
            if let Some(fit) = online.speed() {
                for w in 1..=32usize {
                    let f = fit.epochs_per_sec(w);
                    assert!(!f.is_nan() && f >= 0.0, "case {case} seg {seg} w={w}: {f}");
                }
            }
        }
        if let Some(c) = online.convergence() {
            for e in 0..100 {
                assert!(c.predict(e as f64).is_finite(), "case {case} epoch {e}");
            }
        }
    }
}
