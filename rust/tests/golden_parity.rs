//! Golden parity: the event-heap DES (PR 5) must reproduce the frozen
//! pre-refactor scan engine (`sim::reference`) **bit for bit**.
//!
//! The rewrite changed how the engine *finds* the next event and who it
//! reconciles against the placement ledger — never when the scheduler
//! runs, what it sees, or the order anything is placed. These tests pin
//! that claim on the paper config across every strategy (the six Table 3
//! rows plus the optimus baseline), three topologies (flat, the
//! degenerate 1×64 grid, the paper's 8×8 grid), and three seeds:
//! `avg_completion_hours`, `total_rescales`, `makespan_hours`, and every
//! per-job `completion_secs` must agree to the last bit, and the event
//! counts must match exactly (same instants fired).
//!
//! The scheduler inner-loop rewrites are covered separately by the
//! randomized equivalence property tests in `scheduler::doubling` /
//! `scheduler::optimus`, and the binary-search table lookup by the
//! lookup property test in `scheduler` — together the chain reaches the
//! true pre-PR-5 engine even though both engines here link the new
//! scheduler code.

use ringmaster::cluster::PlacePolicy;
use ringmaster::perfmodel::{LinkContention, PlacementModel};
use ringmaster::sim::{
    simulate, simulate_reference, simulate_traced, Contention, SimConfig, SimResult,
    StrategyKind, WorkloadGen,
};
use ringmaster::telemetry::Recorder;

fn assert_bit_identical(heap: &SimResult, scan: &SimResult, label: &str) {
    assert_eq!(
        heap.avg_completion_hours.to_bits(),
        scan.avg_completion_hours.to_bits(),
        "{label}: avg_completion_hours {} vs {}",
        heap.avg_completion_hours,
        scan.avg_completion_hours
    );
    assert_eq!(heap.total_rescales, scan.total_rescales, "{label}: total_rescales");
    assert_eq!(
        heap.makespan_hours.to_bits(),
        scan.makespan_hours.to_bits(),
        "{label}: makespan_hours"
    );
    assert_eq!(heap.completed, scan.completed, "{label}: completed");
    assert_eq!(heap.peak_concurrent, scan.peak_concurrent, "{label}: peak_concurrent");
    assert_eq!(heap.events, scan.events, "{label}: event count");
    assert_eq!(heap.completion_secs.len(), scan.completion_secs.len(), "{label}: job count");
    for (i, (a, b)) in heap.completion_secs.iter().zip(&scan.completion_secs).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: job {i} completion {a} vs {b}");
    }
}

fn strategies() -> Vec<StrategyKind> {
    let mut v = StrategyKind::table3_rows();
    v.push(StrategyKind::Optimus);
    v
}

fn parity_case(strategy: StrategyKind, topo: Option<(usize, usize)>, seed: u64) {
    let mut cfg = SimConfig::paper(strategy, Contention::Moderate, seed);
    let label = match topo {
        Some((n, g)) => {
            cfg = cfg.with_topology(n, g);
            format!("{} {}x{} seed {seed}", strategy.name(), n, g)
        }
        None => format!("{} flat seed {seed}", strategy.name()),
    };
    let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
    let heap = simulate(&cfg, &jobs);
    let scan = simulate_reference(&cfg, &jobs);
    assert_bit_identical(&heap, &scan, &label);
}

#[test]
fn flat_pool_parity_all_strategies_three_seeds() {
    for seed in [11u64, 23, 42] {
        for s in strategies() {
            parity_case(s, None, seed);
        }
    }
}

#[test]
fn degenerate_grid_parity_all_strategies_three_seeds() {
    // 1×64: every ring spans one node — the ledger runs but every
    // penalty is zero, so this catches dirty-tracking bugs that flat
    // (which skips the ledger entirely) cannot.
    for seed in [11u64, 23, 42] {
        for s in strategies() {
            parity_case(s, Some((1, 64)), seed);
        }
    }
}

#[test]
fn paper_grid_parity_all_strategies_three_seeds() {
    // 8×8: real spans, real penalties, real re-packs.
    for seed in [11u64, 23, 42] {
        for s in strategies() {
            parity_case(s, Some((8, 8)), seed);
        }
    }
}

#[test]
fn contention_off_stays_reference_identical_even_set_explicitly() {
    // `LinkContention::OFF` is the default everywhere above; this pins
    // the *explicit* off switch (and the new spread policy, whose picks
    // both engines share through `ClusterState`) to the same bit-exact
    // parity claim. The scan oracle predates contention entirely, so
    // passing here proves the off path never touches the new code.
    for seed in [11u64, 23, 42] {
        for policy in [PlacePolicy::Pack, PlacePolicy::Spread] {
            let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, seed)
                .with_topology(8, 8);
            cfg.link_contention = LinkContention::OFF;
            cfg.place_policy = policy;
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
            let heap = simulate(&cfg, &jobs);
            let scan = simulate_reference(&cfg, &jobs);
            assert_bit_identical(&heap, &scan, &format!("off {policy:?} seed {seed}"));
        }
    }
}

#[test]
fn contention_on_runs_are_bit_deterministic() {
    // The scan oracle has no contention path, so contention-on cannot
    // parity-check against it; the golden claim is instead full-run
    // determinism: same config, same trace, run twice — every summary
    // statistic and every per-job completion identical to the last bit.
    // Fixed-6 on 4-wide nodes forces every gang to split 4+2, so the
    // ledger, the tenancy resync, and (for spread) the uplink-aware
    // picks are all genuinely exercised.
    for policy in [PlacePolicy::Pack, PlacePolicy::Spread] {
        for seed in [11u64, 23, 42] {
            let mut cfg = SimConfig::paper(StrategyKind::Fixed(6), Contention::Moderate, seed)
                .with_topology(16, 4);
            cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
            cfg.link_contention = LinkContention::fair_share();
            cfg.place_policy = policy;
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
            let a = simulate(&cfg, &jobs);
            let b = simulate(&cfg, &jobs);
            assert_bit_identical(&a, &b, &format!("contended {policy:?} seed {seed}"));
            assert_eq!(a.completed, cfg.n_jobs, "contended {policy:?} seed {seed}: unfinished");
        }
    }
}

#[test]
fn telemetry_off_and_on_stay_reference_identical() {
    // The telemetry PR's standing parity claim: the public `simulate`
    // (NullSink inside) must still match the frozen scan oracle bit for
    // bit, and — because every hook only *reads* engine state — so must
    // a fully-recorded run. One contended-free grid case per strategy
    // family keeps the oracle cheap while covering the instrumented
    // paths (alloc/place/util events all fire on an 8×8 grid).
    for s in [StrategyKind::Precompute, StrategyKind::Exploratory, StrategyKind::Fixed(4)] {
        let cfg = SimConfig::paper(s, Contention::Moderate, 42).with_topology(8, 8);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 42);
        let scan = simulate_reference(&cfg, &jobs);
        let off = simulate(&cfg, &jobs);
        assert_bit_identical(&off, &scan, &format!("{} telemetry-off", s.name()));
        let mut rec = Recorder::new();
        let on = simulate_traced(&cfg, &jobs, &mut rec);
        assert_bit_identical(&on, &scan, &format!("{} telemetry-on", s.name()));
        assert!(!rec.is_empty(), "{}: recorder saw no events", s.name());
    }
}

#[test]
fn telemetry_streams_are_byte_identical_per_seed() {
    // Determinism of the stream itself: same seeded config run twice
    // must serialize to the same bytes (wall-clock self-profiling lives
    // in the recorder's side channel, never the stream), and different
    // seeds must not collide.
    let stream = |seed: u64| {
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, seed)
            .with_topology(8, 8);
        cfg.link_contention = LinkContention::fair_share();
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
        let mut rec = Recorder::new();
        simulate_traced(&cfg, &jobs, &mut rec);
        rec.to_jsonl()
    };
    for seed in [11u64, 23, 42] {
        assert_eq!(stream(seed), stream(seed), "seed {seed}: stream bytes diverged");
    }
    assert_ne!(stream(11), stream(23), "different seeds produced identical streams");
}

#[test]
fn heavy_tailed_trace_parity() {
    // the scale-sweep workload itself (elephants, load-targeted
    // arrivals) on both engines, flat and grid — modest n so the scan
    // oracle stays cheap
    for &(nodes, gpn) in &[(0usize, 0usize), (16, 8)] {
        let mut cfg =
            SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 7);
        if nodes > 0 {
            cfg = cfg.with_topology(nodes, gpn);
        } else {
            cfg.capacity = 128;
            cfg.topology = ringmaster::cluster::Topology::flat(128);
        }
        cfg.n_jobs = 500;
        let jobs = WorkloadGen::trace_scale(500, 128, 7);
        let heap = simulate(&cfg, &jobs);
        let scan = simulate_reference(&cfg, &jobs);
        assert_bit_identical(&heap, &scan, &format!("trace_scale {nodes}x{gpn}"));
    }
}
