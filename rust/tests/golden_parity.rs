//! Golden parity: the event-heap DES (PR 5) must reproduce the frozen
//! pre-refactor scan engine (`sim::reference`) **bit for bit**.
//!
//! The rewrite changed how the engine *finds* the next event and who it
//! reconciles against the placement ledger — never when the scheduler
//! runs, what it sees, or the order anything is placed. These tests pin
//! that claim on the paper config across every strategy (the six Table 3
//! rows plus the optimus baseline), four topologies (flat, the
//! degenerate 1×64 grid, the paper's 8×8 grid, the scale sweep's 16×8
//! grid), three seeds, the PR-8 completion-scan pruner both on and off,
//! and the PR-8 sweep runner at 1 and 4 workers:
//! `avg_completion_hours`, `total_rescales`, `makespan_hours`, and every
//! per-job `completion_secs` must agree to the last bit, and the event
//! counts must match exactly (same instants fired).
//!
//! The scheduler inner-loop rewrites are covered separately by the
//! randomized equivalence property tests in `scheduler::doubling` /
//! `scheduler::optimus`, and the binary-search table lookup by the
//! lookup property test in `scheduler` — together the chain reaches the
//! true pre-PR-5 engine even though both engines here link the new
//! scheduler code.

use std::sync::Arc;

use ringmaster::cluster::PlacePolicy;
use ringmaster::perfmodel::{LinkContention, PlacementModel};
use ringmaster::sim::{
    simulate, simulate_reference, simulate_traced, sweep, Contention, FaultPlan, SimConfig,
    SimResult, StrategyKind, SweepCell, WorkloadGen,
};
use ringmaster::telemetry::Recorder;

fn assert_bit_identical(heap: &SimResult, scan: &SimResult, label: &str) {
    assert_eq!(
        heap.avg_completion_hours.to_bits(),
        scan.avg_completion_hours.to_bits(),
        "{label}: avg_completion_hours {} vs {}",
        heap.avg_completion_hours,
        scan.avg_completion_hours
    );
    assert_eq!(heap.total_rescales, scan.total_rescales, "{label}: total_rescales");
    assert_eq!(
        heap.makespan_hours.to_bits(),
        scan.makespan_hours.to_bits(),
        "{label}: makespan_hours"
    );
    assert_eq!(heap.completed, scan.completed, "{label}: completed");
    assert_eq!(heap.peak_concurrent, scan.peak_concurrent, "{label}: peak_concurrent");
    assert_eq!(heap.events, scan.events, "{label}: event count");
    assert_eq!(heap.completion_secs.len(), scan.completion_secs.len(), "{label}: job count");
    for (i, (a, b)) in heap.completion_secs.iter().zip(&scan.completion_secs).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: job {i} completion {a} vs {b}");
    }
}

fn strategies() -> Vec<StrategyKind> {
    let mut v = StrategyKind::table3_rows();
    v.push(StrategyKind::Optimus);
    v
}

/// The PR-8 parity matrix for one topology: every strategy × three
/// seeds, the scan oracle run once per case, then the event-heap engine
/// re-run through the [`sweep`] runner with the completion-scan pruner
/// on AND off, at 1 and 4 workers — four heap runs per case, each
/// bit-identical to the oracle. One call covers the full
/// `{threads} × {strategy} × {seed} × {prune}` cube for its topology.
fn sweep_matrix_parity(topo: Option<(usize, usize)>) {
    let mut cells: Vec<SweepCell> = Vec::new();
    let mut oracle: Vec<SimResult> = Vec::new();
    let mut labels: Vec<String> = Vec::new();
    for seed in [11u64, 23, 42] {
        // n_jobs / mean_interarrival are the paper defaults for every
        // strategy, so the trace depends on the seed alone — generate it
        // once and Arc-share it across the whole strategy column.
        let base = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, seed);
        let jobs =
            Arc::new(WorkloadGen::default().generate(base.n_jobs, base.mean_interarrival, seed));
        for s in strategies() {
            let mut cfg = SimConfig::paper(s, Contention::Moderate, seed);
            let label = match topo {
                Some((n, g)) => {
                    cfg = cfg.with_topology(n, g);
                    format!("{} {n}x{g} seed {seed}", s.name())
                }
                None => format!("{} flat seed {seed}", s.name()),
            };
            oracle.push(simulate_reference(&cfg, &jobs));
            for prune in [true, false] {
                let mut c = cfg.clone();
                c.completion_prune = prune;
                cells.push(SweepCell::new(c, jobs.clone()));
                labels.push(format!("{label} prune={prune}"));
            }
        }
    }
    for threads in [1usize, 4] {
        let results = sweep::run_cells(&cells, threads);
        for (i, r) in results.iter().enumerate() {
            // cells come two per oracle case (prune on, prune off)
            assert_bit_identical(r, &oracle[i / 2], &format!("{} @{threads}t", labels[i]));
        }
    }
}

#[test]
fn flat_pool_sweep_parity_all_strategies_threads_and_prune() {
    sweep_matrix_parity(None);
}

#[test]
fn degenerate_grid_sweep_parity_all_strategies_threads_and_prune() {
    // 1×64: every ring spans one node — the ledger runs but every
    // penalty is zero, so this catches dirty-tracking bugs that flat
    // (which skips the ledger entirely) cannot.
    sweep_matrix_parity(Some((1, 64)));
}

#[test]
fn paper_grid_sweep_parity_all_strategies_threads_and_prune() {
    // 8×8: real spans, real penalties, real re-packs.
    sweep_matrix_parity(Some((8, 8)));
}

#[test]
fn tall_grid_sweep_parity_all_strategies_threads_and_prune() {
    // 16×8: the scale sweep's grid — more nodes than any gang needs,
    // so best-fit has real choices and the pruner sees reallocation
    // churn from re-packs it must invalidate against.
    sweep_matrix_parity(Some((16, 8)));
}

#[test]
fn contention_off_stays_reference_identical_even_set_explicitly() {
    // `LinkContention::OFF` is the default everywhere above; this pins
    // the *explicit* off switch (and the new spread policy, whose picks
    // both engines share through `ClusterState`) to the same bit-exact
    // parity claim. The scan oracle predates contention entirely, so
    // passing here proves the off path never touches the new code.
    for seed in [11u64, 23, 42] {
        for policy in [PlacePolicy::Pack, PlacePolicy::Spread] {
            let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, seed)
                .with_topology(8, 8);
            cfg.link_contention = LinkContention::OFF;
            cfg.place_policy = policy;
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
            let heap = simulate(&cfg, &jobs);
            let scan = simulate_reference(&cfg, &jobs);
            assert_bit_identical(&heap, &scan, &format!("off {policy:?} seed {seed}"));
        }
    }
}

#[test]
fn fault_off_stays_reference_identical_even_set_explicitly() {
    // `FaultPlan::OFF` is the default in every sweep above; this pins
    // the *explicit* off switch — and the zero-rate steady plan, which
    // `is_off()` must fold into it — to the same bit-exact parity
    // claim. The scan oracle predates faults entirely, so passing here
    // proves the fault-off engine draws no clock, builds no timeline,
    // and fires no event: off by construction, not by coincidence.
    for seed in [11u64, 23, 42] {
        for (plan, name) in
            [(FaultPlan::OFF, "OFF"), (FaultPlan::steady(0.0, 600.0, 1.0e9, seed), "zero-rate")]
        {
            let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, seed)
                .with_topology(8, 8);
            cfg.faults = plan;
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
            let heap = simulate(&cfg, &jobs);
            let scan = simulate_reference(&cfg, &jobs);
            assert_bit_identical(&heap, &scan, &format!("faults-{name} seed {seed}"));
            assert_eq!(heap.evictions, 0, "faults-{name} seed {seed}: off plan evicted a gang");
        }
    }
}

#[test]
fn fault_on_telemetry_streams_are_byte_identical_per_seed() {
    // Fault-on has no scan oracle to parity against (the reference
    // engine predates faults), so its golden claim is stream-level
    // determinism: the full recorded run — every node_down/node_up/
    // seg_failed event included — serializes to the same bytes on a
    // re-run, and different fault seeds genuinely diverge.
    let stream = |seed: u64| {
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 42)
            .with_topology(8, 8);
        cfg.faults = FaultPlan::steady(20_000.0, 600.0, 400_000.0, seed);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 42);
        let mut rec = Recorder::new();
        let r = simulate_traced(&cfg, &jobs, &mut rec);
        (r.evictions, rec.to_jsonl())
    };
    for seed in [11u64, 23] {
        let (ev_a, a) = stream(seed);
        let (ev_b, b) = stream(seed);
        assert_eq!(a, b, "seed {seed}: faulted stream bytes diverged");
        assert_eq!(ev_a, ev_b, "seed {seed}: eviction counts diverged");
        assert!(ev_a > 0, "seed {seed}: plan injected no faults — test is vacuous");
    }
    assert_ne!(stream(11).1, stream(23).1, "different fault seeds produced identical streams");
}

#[test]
fn contention_on_runs_are_bit_deterministic() {
    // The scan oracle has no contention path, so contention-on cannot
    // parity-check against it; the golden claim is instead full-run
    // determinism: same config, same trace, run twice — every summary
    // statistic and every per-job completion identical to the last bit.
    // Fixed-6 on 4-wide nodes forces every gang to split 4+2, so the
    // ledger, the tenancy resync, and (for spread) the uplink-aware
    // picks are all genuinely exercised.
    for policy in [PlacePolicy::Pack, PlacePolicy::Spread] {
        for seed in [11u64, 23, 42] {
            let mut cfg = SimConfig::paper(StrategyKind::Fixed(6), Contention::Moderate, seed)
                .with_topology(16, 4);
            cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
            cfg.link_contention = LinkContention::fair_share();
            cfg.place_policy = policy;
            let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
            let a = simulate(&cfg, &jobs);
            let b = simulate(&cfg, &jobs);
            assert_bit_identical(&a, &b, &format!("contended {policy:?} seed {seed}"));
            assert_eq!(a.completed, cfg.n_jobs, "contended {policy:?} seed {seed}: unfinished");
        }
    }
}

#[test]
fn telemetry_off_and_on_stay_reference_identical() {
    // The telemetry PR's standing parity claim: the public `simulate`
    // (NullSink inside) must still match the frozen scan oracle bit for
    // bit, and — because every hook only *reads* engine state — so must
    // a fully-recorded run. One contended-free grid case per strategy
    // family keeps the oracle cheap while covering the instrumented
    // paths (alloc/place/util events all fire on an 8×8 grid).
    for s in [StrategyKind::Precompute, StrategyKind::Exploratory, StrategyKind::Fixed(4)] {
        let cfg = SimConfig::paper(s, Contention::Moderate, 42).with_topology(8, 8);
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, 42);
        let scan = simulate_reference(&cfg, &jobs);
        let off = simulate(&cfg, &jobs);
        assert_bit_identical(&off, &scan, &format!("{} telemetry-off", s.name()));
        let mut rec = Recorder::new();
        let on = simulate_traced(&cfg, &jobs, &mut rec);
        assert_bit_identical(&on, &scan, &format!("{} telemetry-on", s.name()));
        assert!(!rec.is_empty(), "{}: recorder saw no events", s.name());
    }
}

#[test]
fn telemetry_streams_are_byte_identical_per_seed() {
    // Determinism of the stream itself: same seeded config run twice
    // must serialize to the same bytes (wall-clock self-profiling lives
    // in the recorder's side channel, never the stream), and different
    // seeds must not collide.
    let stream = |seed: u64| {
        let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, seed)
            .with_topology(8, 8);
        cfg.link_contention = LinkContention::fair_share();
        let jobs = WorkloadGen::default().generate(cfg.n_jobs, cfg.mean_interarrival, seed);
        let mut rec = Recorder::new();
        simulate_traced(&cfg, &jobs, &mut rec);
        rec.to_jsonl()
    };
    for seed in [11u64, 23, 42] {
        assert_eq!(stream(seed), stream(seed), "seed {seed}: stream bytes diverged");
    }
    assert_ne!(stream(11), stream(23), "different seeds produced identical streams");
}

#[test]
fn nan_arrival_never_arrives_identically_under_both_engines() {
    // A malformed NaN arrival must degrade the same way everywhere: the
    // job never arrives (NaN completion), every well-formed job still
    // completes, and the two engines stay bit-identical. The heap engine
    // excludes NaN arrivals from its cursor up front; the scan oracle
    // relies on `f64::min` ignoring NaN and `arrival <= now` being false
    // — different mechanisms, same semantics, pinned here so neither the
    // pruner nor any future fast path can fork them. Flat and grid, with
    // the pruner on and off (NaN never poisons the bound: NaN >= next is
    // false, so the skip test always falls through to the live compute).
    for &(nodes, gpn) in &[(0usize, 0usize), (8usize, 8usize)] {
        for prune in [true, false] {
            let mut cfg = SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 5);
            if nodes > 0 {
                cfg = cfg.with_topology(nodes, gpn);
            }
            cfg.n_jobs = 12;
            cfg.completion_prune = prune;
            let mut jobs = WorkloadGen::default().generate(12, cfg.mean_interarrival, 5);
            jobs[3].arrival = f64::NAN;
            let heap = simulate(&cfg, &jobs);
            let scan = simulate_reference(&cfg, &jobs);
            let label = format!("nan-arrival {nodes}x{gpn} prune={prune}");
            assert_eq!(heap.completed, 11, "{label}: well-formed jobs must all finish");
            assert!(heap.completion_secs[3].is_nan(), "{label}: NaN job must never complete");
            assert_bit_identical(&heap, &scan, &label);
        }
    }
}

#[test]
fn heavy_tailed_trace_parity() {
    // the scale-sweep workload itself (elephants, load-targeted
    // arrivals) on both engines, flat and grid — modest n so the scan
    // oracle stays cheap
    for &(nodes, gpn) in &[(0usize, 0usize), (16, 8)] {
        let mut cfg =
            SimConfig::paper(StrategyKind::Precompute, Contention::Moderate, 7);
        if nodes > 0 {
            cfg = cfg.with_topology(nodes, gpn);
        } else {
            cfg.capacity = 128;
            cfg.topology = ringmaster::cluster::Topology::flat(128);
        }
        cfg.n_jobs = 500;
        let jobs = WorkloadGen::trace_scale(500, 128, 7);
        let heap = simulate(&cfg, &jobs);
        let scan = simulate_reference(&cfg, &jobs);
        assert_bit_identical(&heap, &scan, &format!("trace_scale {nodes}x{gpn}"));
    }
}
