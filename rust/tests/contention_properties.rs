//! Generative property harness for the shared-bandwidth link model
//! (PR 6): randomized payloads, grids, and churn sequences pin the four
//! invariants the contention design rests on:
//!
//! - **ring-count monotonicity** — more rings on a shared uplink never
//!   make anyone faster, and with a positive bandwidth share every
//!   extra tenant is strictly slower (cross-node, w > 1);
//! - **single-tenant equivalence** — a sole tenant (or a disabled law)
//!   is *bit-identical* to the PR-3 placement model, at the model level
//!   and through every `Speed` wrapper (plain, memo, contended);
//! - **ledger conservation** — under arbitrary place/release/rescale
//!   churn on any policy, the per-link ring ledger always equals the
//!   count recomputed from scratch out of the live allocations, and its
//!   sum equals the summed span of crossing jobs;
//! - **intra-node immunity** — a gang on one node has no uplink to
//!   share: tenancy 1 regardless of neighbours, and the contended price
//!   is the base price for any tenant count.
//!
//! No proptest crate in the vendor set, so the same discipline by hand
//! as `model_fit_properties`: a deterministic RNG drives >= 20 cases
//! per property and every assertion message carries the case number.

use std::sync::Arc;

use ringmaster::cluster::{ClusterSpec, ClusterState, PlacePolicy};
use ringmaster::perfmodel::{LinkContention, PlacementModel};
use ringmaster::rngx::Rng;
use ringmaster::scheduler::Speed;

/// Parameter sets per property (issue floor: 20).
const CASES: usize = 24;

/// Random comm payload, log-uniform across compute-bound (paper's
/// 6.9 MB) to severely comm-bound (200 MB) regimes.
fn random_model(rng: &mut Rng) -> PlacementModel {
    let n_bytes = 10f64.powf(rng.uniform_range(6.5, 8.3));
    PlacementModel::paper().with_model_bytes(n_bytes)
}

fn random_law(rng: &mut Rng) -> LinkContention {
    LinkContention {
        enabled: true,
        beta_share: rng.uniform_range(0.1, 2.0),
        alpha_share: rng.uniform_range(0.0, 1.0),
    }
}

// ----------------------------------------------------------------------
// ring-count monotonicity
// ----------------------------------------------------------------------

#[test]
fn prop_contended_price_monotone_in_ring_count() {
    let mut rng = Rng::new(0xC0DE01);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        let law = random_law(&mut rng);
        let w = 2 + rng.below(31);
        let nodes = 2 + rng.below(5);
        let base = rng.uniform_range(5.0, 200.0);
        let mut prev = 0.0;
        for tenants in 1..=8 {
            let extra = m.contended_extra_epoch_secs(w, nodes, law, tenants);
            assert!(
                extra >= prev - 1e-12,
                "case {case} w={w} nodes={nodes} tenants={tenants}: extra fell {prev} -> {extra}"
            );
            if tenants > 1 && law.beta_share > 0.0 {
                assert!(
                    extra > prev,
                    "case {case} w={w} nodes={nodes} tenants={tenants}: not strictly slower"
                );
            }
            prev = extra;
            // the full epoch price inherits the ordering
            let secs = m.contended_epoch_secs(base, w, nodes, law, tenants);
            assert!(secs.is_finite() && secs >= base, "case {case}: bad price {secs}");
        }
    }
}

// ----------------------------------------------------------------------
// single-tenant equivalence (model level and Speed level)
// ----------------------------------------------------------------------

#[test]
fn prop_sole_tenant_is_bit_identical_to_uncontended_model() {
    let mut rng = Rng::new(0xC0DE02);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        let law = random_law(&mut rng);
        let off = LinkContention::OFF;
        let base = rng.uniform_range(5.0, 200.0);
        for w in [1usize, 2, 5, 8, 9, 16, 33] {
            for nodes in [1usize, 2, 3, 5] {
                let want = m.placed_epoch_secs(base, w, nodes);
                // tenants = 1 under a live law, and any tenancy under a
                // disabled law, must both be the PR-3 float exactly
                let sole = m.contended_epoch_secs(base, w, nodes, law, 1);
                let dark = m.contended_epoch_secs(base, w, nodes, off, 1 + rng.below(6));
                assert_eq!(
                    sole.to_bits(),
                    want.to_bits(),
                    "case {case} w={w} nodes={nodes}: sole tenant drifted"
                );
                assert_eq!(
                    dark.to_bits(),
                    want.to_bits(),
                    "case {case} w={w} nodes={nodes}: disabled law drifted"
                );
            }
        }
    }
}

#[test]
fn prop_sole_tenant_speed_wrapper_matches_plain_and_memo() {
    let mut rng = Rng::new(0xC0DE03);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        let law = random_law(&mut rng);
        let gpn = 2 + rng.below(7);
        let table: Vec<(usize, f64)> =
            (0..5).map(|i| (1usize << i, rng.uniform_range(1e-3, 0.5))).collect();
        let memo = Arc::new(m.contiguous_extra_table(gpn, 33));
        let plain = Speed::placed(Speed::Table(table.clone()), m, gpn);
        let memoed = Speed::placed_memo(Speed::Table(table.clone()), m, gpn, memo.clone());
        let sole = Speed::placed_contended(
            Speed::Table(table.clone()),
            m,
            gpn,
            Some(memo.clone()),
            law,
            1,
        );
        let dark = Speed::placed_contended(
            Speed::Table(table.clone()),
            m,
            gpn,
            Some(memo),
            LinkContention::OFF,
            2 + rng.below(5),
        );
        for w in 0..=33usize {
            let want = plain.epochs_per_sec(w);
            for (name, s) in [("memo", &memoed), ("sole", &sole), ("off-law", &dark)] {
                assert_eq!(
                    s.epochs_per_sec(w).to_bits(),
                    want.to_bits(),
                    "case {case} {name} w={w}: wrapper drifted from plain"
                );
            }
        }
    }
}

// ----------------------------------------------------------------------
// ledger conservation under churn
// ----------------------------------------------------------------------

/// The ledger recomputed from scratch out of the live allocations — the
/// ground truth the incremental bookkeeping must always agree with.
fn recomputed_ledger(c: &ClusterState) -> Vec<usize> {
    let mut exp = vec![0usize; c.spec().nodes];
    for (job, _) in c.placed_jobs() {
        let nodes = c.node_set(job);
        if nodes.len() > 1 {
            for n in nodes {
                exp[n] += 1;
            }
        }
    }
    exp
}

fn assert_ledger_conserved(c: &ClusterState, label: &str) {
    let want = recomputed_ledger(c);
    assert_eq!(c.link_rings(), &want[..], "{label}: ledger != recomputed");
    let crossing_span: usize = c
        .placed_jobs()
        .iter()
        .map(|&(job, _)| c.nodes_spanned(job))
        .filter(|&n| n > 1)
        .sum();
    let total: usize = c.link_rings().iter().sum();
    assert_eq!(total, crossing_span, "{label}: sum(ledger) != summed crossing span");
}

#[test]
fn prop_link_ledger_conserved_under_churn() {
    let mut rng = Rng::new(0xC0DE04);
    for case in 0..CASES {
        for policy in [PlacePolicy::Pack, PlacePolicy::Scatter, PlacePolicy::Spread] {
            let nodes = 2 + rng.below(5);
            let gpn = 2 + rng.below(7);
            let mut c = ClusterState::with_policy(ClusterSpec::new(nodes, gpn), policy);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for step in 0..120 {
                let label = format!("case {case} {policy:?} {nodes}x{gpn} step {step}");
                let roll = rng.uniform();
                if (roll < 0.55 || live.is_empty()) && c.free_gpus() > 0 {
                    let w = 1 + rng.below(c.free_gpus().min(2 * gpn));
                    c.place(next_id, w).unwrap_or_else(|e| panic!("{label}: {e}"));
                    live.push(next_id);
                    next_id += 1;
                } else if roll < 0.8 && !live.is_empty() {
                    let job = live.swap_remove(rng.below(live.len()));
                    c.release(job).unwrap_or_else(|e| panic!("{label}: {e}"));
                } else if !live.is_empty() {
                    let job = live[rng.below(live.len())];
                    let freed = c.free_gpus() + c.span_of(job).gpus;
                    let w = 1 + rng.below(freed.min(2 * gpn));
                    c.rescale(job, w).unwrap_or_else(|e| panic!("{label}: {e}"));
                }
                assert_ledger_conserved(&c, &label);
            }
            // drain: the ledger must return to all-zero, not just balance
            for job in live {
                c.release(job).unwrap();
            }
            assert!(
                c.link_rings().iter().all(|&r| r == 0),
                "case {case} {policy:?}: ledger nonzero after drain: {:?}",
                c.link_rings()
            );
        }
    }
}

// ----------------------------------------------------------------------
// intra-node immunity
// ----------------------------------------------------------------------

#[test]
fn prop_intra_node_gangs_are_immune_to_neighbours() {
    let mut rng = Rng::new(0xC0DE05);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        let law = random_law(&mut rng);
        let base = rng.uniform_range(5.0, 200.0);
        // model level: one node -> base price at any tenant count
        for tenants in 1..=8 {
            for w in [1usize, 2, 4, 7] {
                let got = m.contended_epoch_secs(base, w, 1, law, tenants);
                assert_eq!(
                    got.to_bits(),
                    base.to_bits(),
                    "case {case} w={w} tenants={tenants}: intra-node ring was priced"
                );
            }
        }
        // ledger level: surround a single-node gang with crossing rings;
        // its own tenancy must stay 1 (no uplink in its ring)
        let gpn = 3 + rng.below(5);
        let mut c = ClusterState::with_policy(ClusterSpec::new(4, gpn), PlacePolicy::Pack);
        c.place(0, gpn).unwrap(); // fills node exactly: intra-node
        let mut id = 1u64;
        while c.free_gpus() > gpn {
            // gangs of gpn+1 must cross somewhere
            c.place(id, gpn + 1).unwrap();
            id += 1;
        }
        assert_eq!(c.nodes_spanned(0), 1, "case {case}: victim gang split unexpectedly");
        assert_eq!(c.tenancy_of(0), 1, "case {case}: intra-node gang picked up tenancy");
        // while the crossing neighbours really are contended with each other
        if id > 2 {
            let busiest: usize = c.link_rings().iter().copied().max().unwrap_or(0);
            assert!(busiest >= 1, "case {case}: no ring ever crossed");
        }
    }
}
