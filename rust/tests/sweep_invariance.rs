//! Thread-count invariance of the `sim::sweep` runner (PR 8).
//!
//! The runner's whole contract is that worker count is invisible in the
//! results: cells are pure functions of `(cfg, jobs)`, workers share
//! nothing but immutable inputs (Arc'd traces, speed tables), and
//! results land in submission order. This file pins that contract at
//! the `SimResult` level — every statistic and every per-job completion
//! bit-identical between 1 and 8 workers, and both identical to a plain
//! serial `simulate` call — across flat and 16×8 grids, link contention
//! off and on, and three seeds. The CLI-level half of the claim (stdout
//! bytes of `simulate --all`) lives in `cli_smoke.rs`; the
//! vs-scan-oracle half in `golden_parity.rs`.

use std::sync::Arc;

use ringmaster::cluster::Topology;
use ringmaster::perfmodel::{LinkContention, PlacementModel};
use ringmaster::sim::{
    simulate, sweep, Contention, SimConfig, SimResult, StrategyKind, SweepCell, WorkloadGen,
};

const N_JOBS: usize = 200;
const SEEDS: [u64; 3] = [7, 11, 13];

fn assert_bits(a: &SimResult, b: &SimResult, label: &str) {
    assert_eq!(
        a.avg_completion_hours.to_bits(),
        b.avg_completion_hours.to_bits(),
        "{label}: avg_completion_hours"
    );
    assert_eq!(a.makespan_hours.to_bits(), b.makespan_hours.to_bits(), "{label}: makespan");
    assert_eq!(a.total_rescales, b.total_rescales, "{label}: total_rescales");
    assert_eq!(a.completed, b.completed, "{label}: completed");
    assert_eq!(a.peak_concurrent, b.peak_concurrent, "{label}: peak_concurrent");
    assert_eq!(a.events, b.events, "{label}: events");
    assert_eq!(a.scan_candidates, b.scan_candidates, "{label}: scan_candidates");
    assert_eq!(a.scan_skipped, b.scan_skipped, "{label}: scan_skipped");
    for (i, (x, y)) in a.completion_secs.iter().zip(&b.completion_secs).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: job {i} completion");
    }
}

/// The invariance matrix: {flat(128), 16×8} × {contention off, on} ×
/// three seeds. The contended arms use fixed-6 gangs (forced 6+2 splits
/// on 8-wide nodes) and a comm-bound payload so uplink sharing — the
/// most state-heavy engine path — is genuinely in play; on the flat
/// pool the same law is inert by construction, which is itself part of
/// the claim (enabling it must change nothing without links to share).
fn cells() -> (Vec<SweepCell>, Vec<String>) {
    let mut cells = Vec::new();
    let mut labels = Vec::new();
    for grid in [false, true] {
        for contended in [false, true] {
            for &seed in &SEEDS {
                let strategy =
                    if contended { StrategyKind::Fixed(6) } else { StrategyKind::Precompute };
                let mut cfg = SimConfig::paper(strategy, Contention::Moderate, seed);
                cfg.n_jobs = N_JOBS;
                if grid {
                    cfg = cfg.with_topology(16, 8);
                } else {
                    cfg.capacity = 128;
                    cfg.topology = Topology::flat(128);
                }
                if contended {
                    cfg.placement = PlacementModel::paper().with_model_bytes(1.0e8);
                    cfg.link_contention = LinkContention::fair_share();
                }
                let jobs = Arc::new(WorkloadGen::trace_scale(N_JOBS, 128, seed));
                labels.push(format!(
                    "{} contended={contended} seed={seed}",
                    if grid { "16x8" } else { "flat" }
                ));
                cells.push(SweepCell::new(cfg, jobs));
            }
        }
    }
    (cells, labels)
}

#[test]
fn one_and_eight_workers_produce_identical_simresult_bits() {
    let (cells, labels) = cells();
    // ground truth: each cell run serially, no sweep machinery at all
    let serial: Vec<SimResult> = cells.iter().map(|c| simulate(&c.cfg, &c.jobs)).collect();
    for threads in [1usize, 8] {
        let results = sweep::run_cells(&cells, threads);
        assert_eq!(results.len(), cells.len(), "sweep dropped cells at {threads} workers");
        for (i, (r, s)) in results.iter().zip(&serial).enumerate() {
            assert_bits(r, s, &format!("{} @{threads}t", labels[i]));
        }
    }
}

#[test]
fn every_matrix_cell_completes_its_trace() {
    // guards the matrix itself: an arm that strands jobs would turn the
    // invariance assertions above vacuous for the tail of the trace
    let (cells, labels) = cells();
    let results = sweep::run_cells(&cells, sweep::resolve_threads(None));
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.completed, N_JOBS, "{}: stranded jobs", labels[i]);
    }
}
